//! Ablations of the two training techniques the paper credits for
//! stability (§III-B: "the alternate W and theta training and the softmax
//! temperature were not present [in EdMIPS]. However, we found
//! experimentally that both techniques improve the training stability and
//! final result quality"):
//!
//!   A. temperature annealing ON (tau 5 -> ~0.25) vs OFF (tau = 1 fixed);
//!   B. 20/80 alternated theta/W epochs vs joint updates (theta and W
//!      stepped on every batch — emulated as 50/50 interleave).
//!
//! Run: `cargo run --release --example ablation [-- <bench>]`

use anyhow::Result;
use cwmix::baselines;
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::runtime::Runtime;

fn run_variant(
    rt: &Runtime,
    base: &SearchConfig,
    warm: &cwmix::nas::trainer::StateSnapshot,
    label: &str,
    tau0: f32,
    tau_decay: f32,
) -> Result<()> {
    let mut cfg = base.clone();
    cfg.tau0 = tau0;
    cfg.tau_decay = tau_decay;
    let mut tr = Trainer::new(rt, cfg)?;
    tr.restore(warm);
    let r = tr.run_after_warmup()?;
    // search-phase val-score stability: std-dev across search epochs
    let scores: Vec<f32> = r
        .history
        .iter()
        .filter(|h| h.phase == "search")
        .map(|h| h.val_score)
        .collect();
    let stab = cwmix::util::std_dev(&scores);
    println!(
        "  {label:<34} score {:.4}  size {:.3} Mbit  energy {:.2} uJ  search-std {:.4}",
        r.test_score,
        r.size_mb(),
        r.energy_uj(),
        stab
    );
    Ok(())
}

fn main() -> Result<()> {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "ad".to_string());
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let mut base = SearchConfig::quick(&bench, Mode::ChannelWise, Target::Size, 0.0);
    let tr0 = Trainer::new(&rt, base.clone())?;
    let (reg_s0, _) = tr0.initial_regs()?;
    drop(tr0);
    base.lambda = 0.5 / reg_s0;

    println!("ablation on {bench} (size target, lambda = {:.3e})", base.lambda);
    let warm = baselines::shared_warmup(&rt, &base)?;

    println!("[A] softmax temperature:");
    run_variant(&rt, &base, &warm, "annealed tau 5 -> 0.25 (paper)", 5.0, base.tau_decay)?;
    run_variant(&rt, &base, &warm, "fixed tau = 1 (no annealing)", 1.0, 1.0)?;
    run_variant(&rt, &base, &warm, "fixed tau = 5 (never decisive)", 5.0, 1.0)?;

    println!("[B] theta/W sample split (paper = 20/80 alternated):");
    for (label, frac) in [("20/80 (paper)", 0.2f32), ("50/50", 0.5), ("5/95", 0.05)] {
        let mut cfg = base.clone();
        cfg.theta_frac = frac;
        let mut tr = Trainer::new(&rt, cfg)?;
        tr.restore(&warm);
        let r = tr.run_after_warmup()?;
        println!(
            "  {label:<34} score {:.4}  size {:.3} Mbit  energy {:.2} uJ",
            r.test_score,
            r.size_mb(),
            r.energy_uj()
        );
    }
    Ok(())
}
