//! Fig. 3 panel regeneration: λ sweep of ours vs EdMIPS vs fixed
//! precision on one benchmark/target, with ASCII Pareto plot and the
//! iso-accuracy headline savings.
//!
//! ```bash
//! cargo run --release --example pareto_sweep -- kws size [--full]
//! ```

use anyhow::Result;
use cwmix::coordinator::results;
use cwmix::coordinator::sweep::{run_sweep, DEFAULT_STRENGTHS};
use cwmix::nas::Target;
use cwmix::report;
use cwmix::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bench = args.first().map(|s| s.as_str()).unwrap_or("kws");
    let target = match args.get(1).map(|s| s.as_str()).unwrap_or("size") {
        "energy" => Target::Energy,
        _ => Target::Size,
    };
    let quick = !args.iter().any(|a| a == "--full");

    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let mut log = |s: &str| println!("{s}");
    let sw = run_sweep(&rt, bench, target, &DEFAULT_STRENGTHS, quick, &mut log)?;

    let path = results::save_sweep(
        std::path::Path::new("results"),
        bench,
        target.name(),
        &sw.ours,
        &sw.edmips,
        &sw.fixed,
    )?;
    println!("saved {}", path.display());
    let (b, t, o, e, f) = results::load_sweep(&path)?;
    let target = if t == "energy" { Target::Energy } else { Target::Size };
    println!("{}", report::fig3_panel(&b, target, &o, &e, &f));
    Ok(())
}
