//! End-to-end driver (the repo's E2E validation run, EXPERIMENTS.md §E2E):
//! the full Alg. 1 channel-wise DNAS on the Image Classification
//! benchmark — warmup, 20/80 alternated search with tau annealing,
//! argmax freeze, fine-tune — logging the loss curve at every epoch,
//! then §III-C deployment, HLO-vs-MPIC verification, and the simulated
//! on-target cost.
//!
//! ```bash
//! cargo run --release --example search_ic            # full budget
//! cargo run --release --example search_ic -- --quick # smoke budget
//! ```

use anyhow::Result;
use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{ExecPlan, PackedBackend};
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::report;
use cwmix::runtime::Runtime;

fn main() -> Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());

    let mut cfg = if quick {
        SearchConfig::quick("ic", Mode::ChannelWise, Target::Energy, 0.0)
    } else {
        SearchConfig::new("ic", Mode::ChannelWise, Target::Energy, 0.0)
    };
    // moderate energy pressure: lambda = 0.3 / reg0
    let tr0 = Trainer::new(&rt, cfg.clone())?;
    let (_, reg_e0) = tr0.initial_regs()?;
    drop(tr0);
    cfg.lambda = 0.3 / reg_e0;
    println!(
        "IC ResNet-8 channel-wise search: lambda = {:.3e}, {} train samples",
        cfg.lambda, cfg.train_n
    );

    let mut tr = Trainer::new(&rt, cfg)?;
    let r = tr.run()?;

    println!("\nloss curve:");
    for h in &r.history {
        println!(
            "  [{:8}] epoch {:>2}  train {:.4}  val {:.4}  val_acc {:.3}  tau {:.2}",
            h.phase, h.epoch, h.train_loss, h.val_loss, h.val_score, h.tau
        );
    }
    println!(
        "\nresult: test accuracy {:.3}  size {:.3} Mbit  energy {:.2} uJ (Eq.8)",
        r.test_score,
        r.size_mb(),
        r.energy_uj()
    );
    println!("{}", report::fig4_dump(&r.config_label, &r.assignment));

    // --- deployment: reorder, split, fold, verify, simulate ---------------
    let ds = make_dataset("ic", Split::Test, 64, 0);
    let rep = deploy::verify::verify_against_hlo(&tr, &r.assignment, &ds, 1)?;
    println!(
        "deploy verification: max|d| = {:.2e}, argmax agreement = {:.1}%",
        rep.max_abs_diff,
        rep.argmax_agreement * 100.0
    );

    let deployed = deploy::build(
        &tr.manifest, &tr.params_map(), &tr.bn_map(), &r.assignment)?;
    let plan = ExecPlan::compile(&deployed, &tr.manifest.lut, &PackedBackend)?;
    let feat = tr.manifest.feat_len();
    let (_, cost) = plan.run_batch(&ds.x[0..feat], feat)?;
    println!(
        "MPIC simulation: {:.1} us/inf @250MHz, {:.2} uJ total, {} sub-convs, {} weight bytes",
        cost.latency_us(),
        cost.total_energy_uj(),
        deployed.n_subconvs(),
        deployed.packed_bytes()
    );
    Ok(())
}
