//! §III-C deployment walkthrough on the VWW benchmark: train briefly,
//! pick a mixed assignment, reorder + split + BN-fold, verify against
//! the HLO `infer` graph, and compare the MPIC cost of the mixed model
//! vs the w8x8 and w2x8 fixed baselines.
//!
//! ```bash
//! cargo run --release --example deploy_mpic [-- <bench>]
//! ```

use anyhow::Result;
use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::energy::CostLut;
use cwmix::engine::{ExecPlan, PackedBackend};
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::quant::Assignment;
use cwmix::runtime::Runtime;

fn main() -> Result<()> {
    let bench = std::env::args().nth(1).unwrap_or_else(|| "vww".to_string());
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let mut cfg = SearchConfig::quick(&bench, Mode::ChannelWise, Target::Energy, 0.0);
    let tr0 = Trainer::new(&rt, cfg.clone())?;
    let (_, reg_e0) = tr0.initial_regs()?;
    drop(tr0);
    cfg.lambda = 0.5 / reg_e0;
    let mut tr = Trainer::new(&rt, cfg)?;
    let r = tr.run()?;
    println!("searched mixed assignment: score {:.3}", r.test_score);

    let lut = CostLut::default();
    let ds = make_dataset(&bench, Split::Test, 64, 0);
    let feat = tr.manifest.feat_len();

    // verification of the transform (the §III-C "fully compatible" claim)
    let rep = deploy::verify::verify_against_hlo(&tr, &r.assignment, &ds, 1)?;
    println!(
        "verify: n={} max|d|={:.2e} argmax agreement {:.1}%",
        rep.n_samples,
        rep.max_abs_diff,
        rep.argmax_agreement * 100.0
    );
    assert!(rep.argmax_agreement > 0.95, "deployment diverged from HLO");

    // cost comparison: mixed vs fixed
    let qnames = tr.manifest.qnames();
    let qcouts = tr.manifest.qcouts();
    let candidates = vec![
        ("searched-mixed".to_string(), r.assignment.clone()),
        ("w8x8".to_string(), Assignment::fixed(&qnames, &qcouts, 8, 8)),
        ("w4x4".to_string(), Assignment::fixed(&qnames, &qcouts, 4, 4)),
        ("w2x8".to_string(), Assignment::fixed(&qnames, &qcouts, 2, 8)),
    ];
    println!(
        "\n{:<16} {:>9} {:>10} {:>10} {:>9} {:>9}",
        "assignment", "us/inf", "uJ total", "uJ MAC", "KB flash", "subconvs"
    );
    for (name, a) in candidates {
        let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a)?;
        let plan = ExecPlan::compile(&d, &lut, &PackedBackend)?;
        let (_, cost) = plan.run_batch(&ds.x[0..feat], feat)?;
        println!(
            "{:<16} {:>9.1} {:>10.2} {:>10.2} {:>9.1} {:>9}",
            name,
            cost.latency_us(),
            cost.total_energy_uj(),
            cost.mac_energy_pj() * 1e-6,
            d.packed_bytes() as f64 / 1024.0,
            d.n_subconvs()
        );
    }
    Ok(())
}
