//! Quickstart: load the AOT artifacts, run one fixed-precision QAT
//! baseline on the Keyword Spotting benchmark, and print score + cost.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use cwmix::baselines;
use cwmix::nas::{Mode, SearchConfig, Target};
use cwmix::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());

    // A small QAT run: warmup at 8 bit, then w4x8 fixed-precision.
    let cfg = SearchConfig::quick("kws", Mode::ChannelWise, Target::Size, 0.0);
    println!("warmup ({} epochs, {} samples)...", cfg.warmup_epochs, cfg.train_n);
    let warm = baselines::shared_warmup(&rt, &cfg)?;

    for (wb, xb) in [(8u32, 8u32), (4, 8), (2, 8)] {
        let r = baselines::run_fixed(&rt, &cfg, &warm, wb, xb)?;
        println!(
            "w{wb}x{xb}: accuracy {:.3}  size {:.3} Mbit  energy {:.2} uJ",
            r.test_score,
            r.size_mb(),
            r.energy_uj()
        );
    }
    println!("(mixed-precision search: see examples/search_ic.rs)");
    Ok(())
}
