#!/usr/bin/env bash
# CI profile smoke: run `cwmix profile` against two zoo models and
# assert the per-layer table, the coverage line, and the cost-model-fit
# summary all render.  This drives the same flag surface the
# `profile_cli` integration tests cover in-process, but through the
# release binary CI actually ships — a broken table format or a
# profiler that panics on a real model fails here even if the JSON
# path stays green.
#
# Usage: tools/profile_smoke.sh   (from the repo root, after
#        `cargo build --release`; CWMIX_BIN_DIR overrides target/release)
set -euo pipefail

BIN_DIR=${CWMIX_BIN_DIR:-target/release}
ITERS=${CWMIX_PROFILE_ITERS:-5}

for bench in ad kws; do
    OUT=$("$BIN_DIR/cwmix" profile --bench "$bench" --iters "$ITERS" --batch 4)
    echo "$OUT"
    for want in \
        "== $bench [packed] batch=4 iters=$ITERS ==" \
        "layer" \
        "coverage: nodes" \
        "fit: spearman="; do
        if ! grep -qF -- "$want" <<<"$OUT"; then
            echo "profile output for $bench missing \"$want\"" >&2
            exit 1
        fi
    done
done

# the machine-readable path: --json - must emit pure JSON on stdout
"$BIN_DIR/cwmix" profile --bench ad --iters 2 --batch 2 --json - | python3 -c '
import json, sys
doc = json.load(sys.stdin)
assert doc["version"] == 1.0, doc
layers = doc["benches"][0]["layers"]
assert layers, "no layers profiled"
share = sum(l["share"] for l in layers)
assert abs(share - 1.0) < 1e-6, f"measured shares sum to {share}"
print(f"profile json ok: {len(layers)} layers, shares sum {share:.6f}")
'

echo "profile smoke passed: per-layer tables + fit summary + JSON doc"
