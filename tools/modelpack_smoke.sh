#!/usr/bin/env bash
# CI smoke for the modelpack artifact path (ISSUE 5):
#
#   1. `cwmix compile` every builtin zoo model into <dir>/<bench>.cwm
#      (each artifact is reload-verified bit-identical at emit time)
#   2. `cwmix inspect` every artifact — validates the container end to
#      end and exits non-zero unless the packed size totals match the
#      mpic::cost Eq. (7) packed-byte accounting carried in the pack
#   3. spawn `cwmix serve --modelpack-dir <dir>` on an ephemeral port
#      and run `serve_smoke` with CWMIX_SMOKE_EXPECT_STARTUP=modelpack:
#      every served reply must be bit-identical to an in-process
#      ExecPlan::compile AND /metrics must show every model actually
#      cold-started from its artifact
#   4. assert the server process exits 0 on its own (clean shutdown)
#
# Usage: tools/modelpack_smoke.sh   (from the repo root, after
#        `cargo build --release`; CWMIX_BIN_DIR overrides target/release,
#        CWMIX_PACK_DIR overrides the artifact directory)
set -euo pipefail

BIN_DIR=${CWMIX_BIN_DIR:-target/release}
PACK_DIR=${CWMIX_PACK_DIR:-modelpacks}

echo "--- cwmix compile ---"
"$BIN_DIR/cwmix" compile --out "$PACK_DIR"

echo "--- cwmix inspect ---"
for f in "$PACK_DIR"/*.cwm; do
    "$BIN_DIR/cwmix" inspect --pack "$f"
done

echo "--- cwmix serve --modelpack-dir ---"
LOG=$(mktemp)
"$BIN_DIR/cwmix" serve --addr 127.0.0.1:0 --modelpack-dir "$PACK_DIR" >"$LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT

# the port is OS-assigned: wait for the "listening on" line
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "server died during startup:" >&2
        cat "$LOG" >&2
        exit 1
    fi
    sleep 0.2
done
if [ -z "$ADDR" ]; then
    echo "server never printed its address:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "server at $ADDR"

# every model must have cold-started from its artifact, and replies
# must be bit-identical to an in-process compile
CWMIX_SMOKE_EXPECT_STARTUP=modelpack "$BIN_DIR/serve_smoke" "$ADDR"

# clean shutdown: the serve process must exit 0 by itself, promptly
for _ in $(seq 1 150); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "server still running 30s after shutdown request:" >&2
    cat "$LOG" >&2
    exit 1
fi
trap - EXIT
if ! wait "$SERVER_PID"; then
    echo "server exited non-zero:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "--- server log ---"
cat "$LOG"
if ! grep -q "cold start from" "$LOG"; then
    echo "server log never mentioned a modelpack cold start" >&2
    exit 1
fi
echo "modelpack smoke passed: compile -> inspect -> cold-start serve -> clean shutdown"
