#!/usr/bin/env bash
# CI chaos smoke for supervised serving:
#
#   1. spawn `cwmix serve` on an ephemeral port with a fault plan armed
#      via the env var (CWMIX_FAULTS=engine_panic:ic:once — the server
#      must log the armed plan) and span recording on (CWMIX_TRACE=1)
#   2. run `chaos_smoke`, which drives the acceptance sequence: the
#      injected panic answers an explicit 5xx that still carries its
#      request id, the pre-crash span chain is scrapeable from
#      /v1/trace, the worker respawns, recovery is bit-identical to a
#      locally compiled run_sample, the other models never see an
#      error, and the supervision gauges (worker_panics /
#      worker_respawns / breaker_state) are scrapeable
#   3. assert the panicked request left a structured `request ...`
#      log line (the out-of-process half of the request-id story)
#   4. assert the server process exits 0 on its own (a panicked worker
#      must not poison the shutdown path)
#
# Usage: tools/chaos_smoke.sh   (from the repo root, after
#        `cargo build --release`; CWMIX_BIN_DIR overrides target/release)
set -euo pipefail

BIN_DIR=${CWMIX_BIN_DIR:-target/release}
LOG=$(mktemp)
FAULTS=${CWMIX_CHAOS_FAULTS:-engine_panic:ic:once}
FAULTED=${CWMIX_CHAOS_MODEL:-ic}

CWMIX_FAULTS="$FAULTS" CWMIX_FAULTS_SEED=0 CWMIX_TRACE=1 \
    "$BIN_DIR/cwmix" serve --addr 127.0.0.1:0 >"$LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT

# the port is OS-assigned: wait for the "listening on" line
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "server died during startup:" >&2
        cat "$LOG" >&2
        exit 1
    fi
    sleep 0.2
done
if [ -z "$ADDR" ]; then
    echo "server never printed its address:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "server at $ADDR (faults: $FAULTS)"

# a typo'd chaos run must not silently test nothing: the server logs
# the armed plan at startup
if ! grep -q "fault plan armed" "$LOG"; then
    echo "server never logged the armed fault plan:" >&2
    cat "$LOG" >&2
    exit 1
fi
if ! grep -q "tracing enabled" "$LOG"; then
    echo "server never logged that tracing is enabled (CWMIX_TRACE=1):" >&2
    cat "$LOG" >&2
    exit 1
fi

"$BIN_DIR/chaos_smoke" "$ADDR" "$FAULTED"

# the panicked request must have left a structured request log line —
# 5xx replies are always logged, regardless of CWMIX_LOG_SAMPLE
if ! grep -E "^request model=$FAULTED id=[0-9]+ status=5" "$LOG" >/dev/null; then
    echo "no structured request log line for the panicked request:" >&2
    cat "$LOG" >&2
    exit 1
fi

# clean shutdown: the serve process must exit 0 by itself, promptly —
# an injected panic must not leak into the exit status
for _ in $(seq 1 150); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "server still running 30s after shutdown request:" >&2
    cat "$LOG" >&2
    exit 1
fi
trap - EXIT
if ! wait "$SERVER_PID"; then
    echo "server exited non-zero:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "--- server log ---"
cat "$LOG"
echo "chaos smoke passed: panic -> respawn -> bit-identical recovery -> clean shutdown"
