#!/usr/bin/env bash
# CI smoke for the serving layer (ISSUE 3 satellite):
#
#   1. spawn `cwmix serve` on an ephemeral port (all builtin zoo models)
#   2. run `serve_smoke`, which round-trips one POST /v1/infer/<bench>
#      per model and asserts the reply is bit-identical to a locally
#      compiled ExecPlan::run_sample, then POSTs /admin/shutdown
#   3. assert the server process exits 0 on its own (clean shutdown)
#
# Usage: tools/serve_smoke.sh   (from the repo root, after
#        `cargo build --release`; CWMIX_BIN_DIR overrides target/release)
set -euo pipefail

BIN_DIR=${CWMIX_BIN_DIR:-target/release}
LOG=$(mktemp)

"$BIN_DIR/cwmix" serve --addr 127.0.0.1:0 >"$LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT

# the port is OS-assigned: wait for the "listening on" line
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' "$LOG" | head -n 1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SERVER_PID" 2>/dev/null; then
        echo "server died during startup:" >&2
        cat "$LOG" >&2
        exit 1
    fi
    sleep 0.2
done
if [ -z "$ADDR" ]; then
    echo "server never printed its address:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "server at $ADDR"

"$BIN_DIR/serve_smoke" "$ADDR"

# clean shutdown: the serve process must exit 0 by itself, promptly
for _ in $(seq 1 150); do
    kill -0 "$SERVER_PID" 2>/dev/null || break
    sleep 0.2
done
if kill -0 "$SERVER_PID" 2>/dev/null; then
    echo "server still running 30s after shutdown request:" >&2
    cat "$LOG" >&2
    exit 1
fi
trap - EXIT
if ! wait "$SERVER_PID"; then
    echo "server exited non-zero:" >&2
    cat "$LOG" >&2
    exit 1
fi
echo "--- server log ---"
cat "$LOG"
echo "serve smoke passed: clean shutdown"
