//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The cwmix coordinator's training path links against the PJRT
//! bindings behind the non-default `xla` cargo feature.  Build images
//! without the real bindings still need the *dependency* to resolve, so
//! this crate mirrors exactly the API surface `cwmix` touches:
//!
//! * host-side [`Literal`] construction/decomposition is fully
//!   functional (it is plain host memory — `Tensor::to_literal`
//!   round-trips work under the stub);
//! * anything that would reach a PJRT plugin ([`PjRtClient::cpu`],
//!   compilation, execution) returns [`Error`] explaining that the stub
//!   is in use.
//!
//! To run the real thing, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs checkout — the signatures
//! here were taken from it, so no `cwmix` code changes are needed.

use std::fmt;

/// Stub error: carries a message, formats like the real crate's error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable — cwmix was built against the bundled \
         `xla` stub crate; point the `xla` dependency at the real xla-rs \
         bindings to execute HLO artifacts"
    )))
}

/// Element types of array literals (subset used by cwmix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F32,
    F64,
}

/// Internal literal storage (public only because [`NativeType`]'s
/// methods name it; not part of the real xla-rs surface).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Types a [`Literal`] can be built from / decomposed into.
pub trait NativeType: Sized + Clone {
    fn wrap(v: Vec<Self>) -> Store;
    fn unwrap(s: &Store) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Store {
        Store::F32(v)
    }
    fn unwrap(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            Store::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Store {
        Store::I32(v)
    }
    fn unwrap(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            Store::F32(_) => None,
        }
    }
}

/// Host-side array literal (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    store: Store,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            store: T::wrap(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    fn len(&self) -> i64 {
        match &self.store {
            Store::F32(v) => v.len() as i64,
            Store::I32(v) => v.len() as i64,
        }
    }

    /// Reshape to `dims` (must preserve element count).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.len() {
            return Err(Error(format!(
                "reshape {:?} on literal of {} elements",
                dims,
                self.len()
            )));
        }
        Ok(Literal { store: self.store.clone(), dims: dims.to_vec() })
    }

    /// Host copy-out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.store)
            .ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal (stub literals are never tuples).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty: match &self.store {
                Store::F32(_) => ElementType::F32,
                Store::I32(_) => ElementType::S32,
            },
        })
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// PJRT client handle (errors at construction under the stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn pjrt_surface_errors() {
        assert!(PjRtClient::cpu().is_err());
    }
}
