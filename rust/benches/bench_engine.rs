//! Engine benchmarks: seed scalar path vs the plan/execute engine with
//! the `reference` and `packed` backends, per benchmark model — plus a
//! per-`(p_x, p_w)` sweep of the nine SWAR kernel-table cells, a
//! batch-plane scaling sweep (per-sample time vs batch size B, the
//! weight-stationary amortization the serving batcher exploits) and a
//! cold-start sweep (`ExecPlan::compile` vs `.cwm` modelpack load per
//! model — the registry's two startup paths).
//!
//! Pure Rust — builtin model zoo + synthetic weights, no artifacts and
//! no `xla` feature.  Each model runs a striped mixed-precision
//! assignment (the deployment-relevant case: fragmented sub-conv groups
//! across all three precisions); the combo sweep runs uniform
//! `w{p_w}x{p_x}` assignments so each table cell is isolated.  Emits a
//! machine-readable `BENCH_engine.json` (schema v7: v6 plus per-model
//! `profile/<bench>` cells — profiled-vs-plain `run_batch_planes`
//! overhead ratio and the cost-model Spearman fit from the per-node
//! measurement hooks) at the repo root so future PRs
//! have a perf trajectory
//! (`tools: cargo run --bin bench_compare` diffs two of these and gates
//! CI), and asserts bit-exactness of every path while measuring.
//!
//! ```bash
//! cargo bench --bench bench_engine            # quick (default)
//! CWMIX_BENCH_ENGINE_JSON=out.json cargo bench --bench bench_engine
//! ```

use std::path::Path;

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{
    engine_threads, ExecPlan, PackedBackend, ReferenceBackend, SimdBackend,
};
use cwmix::minijson::Json;
use cwmix::models::zoo::{
    builtin_manifest, stripy_assignment as stripy, synthetic_state, BENCHES,
};
use cwmix::quant::Assignment;
use cwmix::util::timer::measure;

fn out_path() -> String {
    if let Ok(p) = std::env::var("CWMIX_BENCH_ENGINE_JSON") {
        return p;
    }
    // benches run from the package dir (rust/); put the trajectory file
    // at the repo root when recognisable
    if Path::new("../ROADMAP.md").exists() {
        "../BENCH_engine.json".to_string()
    } else {
        "BENCH_engine.json".to_string()
    }
}

/// The conv-heavy model used for the per-combo and batch-plane sweeps.
const COMBO_BENCH: &str = "ic";

/// Batch sizes of the batch-plane scaling cells.
const BATCH_SIZES: [usize; 3] = [1, 4, 8];

/// Batch-plane scaling on the conv-heavy model: packed backend, one
/// engine worker, per-sample wall clock vs batch size — the measured
/// form of the weight-stationary amortization, alongside the MPIC cost
/// model's amortized per-sample prediction.
fn batch_rows() -> anyhow::Result<(Vec<(String, Json)>, bool)> {
    let manifest = builtin_manifest(COMBO_BENCH)?;
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = stripy(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a)?;
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;
    let feat = manifest.feat_len();
    let max_b = *BATCH_SIZES.iter().max().unwrap();
    let ds = make_dataset(COMBO_BENCH, Split::Test, max_b, 4);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();

    // bit-exactness while measuring: every batch size == per-sample
    let mut arena = plan.arena();
    let want: Vec<Vec<f32>> = samples
        .iter()
        .map(|s| plan.run_sample(&mut arena, s))
        .collect::<anyhow::Result<_>>()?;

    println!(
        "\n[{COMBO_BENCH}] batch-plane scaling (packed, single worker, \
         ms/sample):"
    );
    let mut rows = Vec::new();
    let mut prev = f64::INFINITY;
    let mut monotonic = true;
    for bsz in BATCH_SIZES {
        let mut barena = plan.batch_arena(bsz);
        let got = plan.run_batch_planes(&mut barena, &samples[..bsz])?;
        assert_eq!(
            got.as_slice(),
            &want[..bsz],
            "B={bsz} diverged from per-sample run_sample"
        );
        let (ms, _, _) = measure(1, 5, || {
            let _ = plan.run_batch_planes(&mut barena, &samples[..bsz]).unwrap();
        });
        let per_sample = ms / bsz as f64;
        // 5% grace so timer noise cannot flag a flat plateau
        if per_sample > prev * 1.05 {
            monotonic = false;
        }
        prev = prev.min(per_sample);
        let bc = plan.batch_cost(bsz);
        println!(
            "    B={bsz}  {per_sample:>8.3} ms/sample  (model: {:>10.0} \
             cyc/sample, {} weight B amortized)",
            bc.cycles_per_sample, bc.saved_weight_bytes
        );
        rows.push((
            format!("b{bsz}"),
            Json::obj(vec![
                ("batch", Json::num(bsz as f64)),
                ("packed_ms_per_sample", Json::num(per_sample)),
                ("model_cycles_per_sample", Json::num(bc.cycles_per_sample)),
                ("model_energy_pj_per_sample", Json::num(bc.energy_pj_per_sample)),
                ("model_saved_weight_bytes", Json::num(bc.saved_weight_bytes as f64)),
            ]),
        ));
    }
    println!("    per-sample time monotonically non-increasing in B: {monotonic}");
    Ok((rows, monotonic))
}

/// Cold start per model: `ExecPlan::compile` from deployed f32 state
/// vs `ExecPlan::from_modelpack` on the serialized artifact — the
/// registry's two startup paths.  Load skips gather-table construction
/// and weight packing entirely (validate-then-borrow), so it should
/// beat compile on every model; the `cold/<bench>` trajectory cells
/// gate the load/compile ratio.
fn cold_start_rows() -> anyhow::Result<Vec<(String, Json)>> {
    println!("\ncold start per model (packed, stripy): compile vs modelpack load:");
    let mut rows = Vec::new();
    for bench in BENCHES {
        // the registry/`cwmix compile` construction path, so these
        // cells measure exactly what a server cold start amortizes
        let (manifest, model, plan) = cwmix::serve::registry::build_model(
            bench,
            &PackedBackend,
            "stripy",
            0,
            Path::new("artifacts"),
        )?;
        let pack = plan.to_modelpack();

        // bit-exactness of the loaded plan while measuring (the same
        // probe `cwmix compile` gates artifacts with)
        cwmix::serve::registry::verify_pack_roundtrip(&plan, &pack, bench)?;

        let (compile_ms, _, _) = measure(1, 5, || {
            let _ = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
        });
        let (load_ms, _, _) = measure(1, 5, || {
            let _ = ExecPlan::from_modelpack(&pack).unwrap();
        });
        println!(
            "    {bench:<4} compile {compile_ms:>8.3} ms   load {load_ms:>8.3} ms   \
             ({:>5.1}x, pack {} B)",
            compile_ms / load_ms,
            pack.len(),
        );
        rows.push((
            bench.to_string(),
            Json::obj(vec![
                ("compile_ms", Json::num(compile_ms)),
                ("modelpack_load_ms", Json::num(load_ms)),
                ("pack_bytes", Json::num(pack.len() as f64)),
                ("speedup_load_vs_compile", Json::num(compile_ms / load_ms)),
            ]),
        ));
    }
    Ok(rows)
}

/// Fused requantize per model: `ExecPlan::compile` (fusion on) vs
/// `compile_with(.., false)` (the two-pass oracle) on the packed
/// backend and the striped assignment — asserting bit-exactness while
/// measuring, and reporting the per-sample activation bytes the fusion
/// pass removed from the quantized producer→consumer edges (the
/// Eq. (7) activation-traffic share).
fn fused_rows() -> anyhow::Result<Vec<(String, Json)>> {
    const B: usize = 8;
    println!("\nfused requantize per model (packed, stripy, B={B}, ms/sample):");
    let mut rows = Vec::new();
    for bench in BENCHES {
        let manifest = builtin_manifest(bench)?;
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a)?;
        let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;
        let unfused =
            ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false)?;
        let stats = fused.fusion();
        assert!(stats.fused_edges > 0, "{bench}: no fusion coverage");
        assert!(
            stats.act_bytes_fused < stats.act_bytes_unfused,
            "{bench}: fusion coverage > 0 must reduce activation bytes moved"
        );

        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, B, 6);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let mut fa = fused.batch_arena(B);
        let mut ua = unfused.batch_arena(B);

        // bit-exactness while measuring: fused == two-pass, whole batch
        let got = fused.run_batch_planes(&mut fa, &samples)?;
        let want = unfused.run_batch_planes(&mut ua, &samples)?;
        assert_eq!(got, want, "{bench}: fused diverged from the two-pass path");

        let (fused_ms, _, _) = measure(1, 5, || {
            let _ = fused.run_batch_planes(&mut fa, &samples).unwrap();
        });
        let (unfused_ms, _, _) = measure(1, 5, || {
            let _ = unfused.run_batch_planes(&mut ua, &samples).unwrap();
        });
        let (fused_per, unfused_per) = (fused_ms / B as f64, unfused_ms / B as f64);
        println!(
            "    {bench:<4} fused {fused_per:>8.3}  two-pass {unfused_per:>8.3}  \
             ({:>5.2}x, {}/{} edges, {} act B/sample saved)",
            unfused_per / fused_per,
            stats.fused_edges,
            stats.total_edges,
            stats.act_bytes_saved(),
        );
        rows.push((
            bench.to_string(),
            Json::obj(vec![
                ("fused_ms_per_sample", Json::num(fused_per)),
                ("unfused_ms_per_sample", Json::num(unfused_per)),
                ("speedup_fused_vs_unfused", Json::num(unfused_per / fused_per)),
                ("total_edges", Json::num(stats.total_edges as f64)),
                ("fused_edges", Json::num(stats.fused_edges as f64)),
                ("requant_fused_ratio", Json::num(stats.fused_ratio())),
                ("elided_f32_slots", Json::num(stats.elided_f32 as f64)),
                ("residual_plane_reuse_hits", Json::num(stats.reuse_hits as f64)),
                (
                    "act_bytes_unfused_per_sample",
                    Json::num(stats.act_bytes_unfused as f64),
                ),
                (
                    "act_bytes_fused_per_sample",
                    Json::num(stats.act_bytes_fused as f64),
                ),
                (
                    "act_bytes_saved_per_sample",
                    Json::num(stats.act_bytes_saved() as f64),
                ),
            ]),
        ));
    }
    Ok(rows)
}

/// SIMD backend per model: batched (B=8) weight-stationary execution,
/// simd vs packed on the striped assignment.  The batch axis is where
/// the vector tiers live — `run_sample` (B=1) delegates to the SWAR
/// cells by construction — so these cells measure `run_batch_planes`
/// per sample.  Bit-exactness is asserted while measuring; on a host
/// without AVX2 the dispatched tier is `swar` and the ratio hovers
/// around 1.0 (`bench_compare` skips its speedup gate there).
fn simd_rows() -> anyhow::Result<Vec<(String, Json)>> {
    const B: usize = 8;
    let tier = cwmix::engine::simd::active_tier_name();
    println!("\nsimd backend per model (tier {tier}, stripy, B={B}, ms/sample):");
    let mut rows = Vec::new();
    for bench in BENCHES {
        let manifest = builtin_manifest(bench)?;
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a)?;
        let packed = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;
        let simd = ExecPlan::compile(&model, &manifest.lut, &SimdBackend)?;
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, B, 9);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let mut pa = packed.batch_arena(B);
        let mut sa = simd.batch_arena(B);

        // bit-exactness while measuring, whole batch
        let want = packed.run_batch_planes(&mut pa, &samples)?;
        let got = simd.run_batch_planes(&mut sa, &samples)?;
        assert_eq!(got, want, "{bench}: simd diverged from packed");

        let (packed_ms, _, _) = measure(1, 5, || {
            let _ = packed.run_batch_planes(&mut pa, &samples).unwrap();
        });
        let (simd_ms, _, _) = measure(1, 5, || {
            let _ = simd.run_batch_planes(&mut sa, &samples).unwrap();
        });
        let (simd_per, packed_per) = (simd_ms / B as f64, packed_ms / B as f64);
        println!(
            "    {bench:<4} simd {simd_per:>8.3}  packed {packed_per:>8.3}  \
             ({:>5.2}x)",
            packed_per / simd_per
        );
        rows.push((
            bench.to_string(),
            Json::obj(vec![
                ("simd_ms_per_sample", Json::num(simd_per)),
                ("packed_ms_per_sample", Json::num(packed_per)),
                ("speedup_simd_vs_packed", Json::num(packed_per / simd_per)),
            ]),
        ));
    }
    Ok(rows)
}

/// Profiling-hook overhead per model: `run_batch_planes` plain vs
/// under a live `PlanProfile` (B=8, packed, stripy).  The hooks read
/// two clocks per node, so the ratio should hover near 1.0; the cell
/// also records the Spearman fit between measured node wall time and
/// the cost model's predicted cycles — the `cwmix profile` headline
/// number, kept on the perf trajectory.
fn profile_rows() -> anyhow::Result<Vec<(String, Json)>> {
    const B: usize = 8;
    println!("\nprofiling hooks per model (packed, stripy, B={B}, ms/sample):");
    let mut rows = Vec::new();
    for bench in BENCHES {
        let manifest = builtin_manifest(bench)?;
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a)?;
        let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, B, 11);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let mut arena = plan.batch_arena(B);
        let mut prof = plan.profile();

        // bit-exactness while measuring: the hooks only read clocks
        let want = plan.run_batch_planes(&mut arena, &samples)?;
        let got = plan.run_batch_planes_profiled(&mut arena, &samples, &mut prof)?;
        assert_eq!(got, want, "{bench}: profiled pass diverged from plain");

        let (plain_ms, _, _) = measure(1, 5, || {
            let _ = plan.run_batch_planes(&mut arena, &samples).unwrap();
        });
        let (prof_ms, _, _) = measure(1, 5, || {
            let _ = plan
                .run_batch_planes_profiled(&mut arena, &samples, &mut prof)
                .unwrap();
        });
        let (plain_per, prof_per) = (plain_ms / B as f64, prof_ms / B as f64);

        let cost = plan.cost();
        let (mut measured, mut predicted) = (Vec::new(), Vec::new());
        for node in &prof.nodes {
            if let Some(ix) = node.cost_ix {
                measured.push(node.wall_ns() as f64);
                predicted.push(cost.layers[ix].total_cycles());
            }
        }
        let fit = cwmix::util::stats::spearman(&measured, &predicted);
        println!(
            "    {bench:<4} plain {plain_per:>8.3}  profiled {prof_per:>8.3}  \
             ({:>5.2}x overhead, spearman {fit:.3})",
            prof_per / plain_per
        );
        rows.push((
            bench.to_string(),
            Json::obj(vec![
                ("plain_ms_per_sample", Json::num(plain_per)),
                ("profiled_ms_per_sample", Json::num(prof_per)),
                ("overhead_profiled_vs_plain", Json::num(prof_per / plain_per)),
                ("spearman_measured_vs_model", Json::num(fit)),
                ("profiled_nodes", Json::num(measured.len() as f64)),
            ]),
        ));
    }
    Ok(rows)
}

fn combo_rows() -> anyhow::Result<Vec<(String, Json)>> {
    let manifest = builtin_manifest(COMBO_BENCH)?;
    let (params, bn) = synthetic_state(&manifest, 0);
    let feat = manifest.feat_len();
    let ds = make_dataset(COMBO_BENCH, Split::Test, 1, 2);
    let mut rows = Vec::new();
    println!(
        "\n[{COMBO_BENCH}] per-(p_x, p_w) kernel cells (uniform assignments, \
         ms/inf single-thread):"
    );
    println!(
        "    {:<6} {:>12} {:>12} {:>8}",
        "combo", "reference", "packed", "speedup"
    );
    for px in [2u32, 4, 8] {
        for pw in [2u32, 4, 8] {
            let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), pw, px);
            let model = deploy::build(&manifest, &params, &bn, &a)?;
            let ref_plan = ExecPlan::compile(&model, &manifest.lut, &ReferenceBackend)?;
            let packed_plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;

            // correctness while measuring: both backends == oracle
            let (want, _) = cwmix::mpic::run_sample(&model, &ds.x[0..feat], &manifest.lut)?;
            let mut arena = ref_plan.arena();
            let ref_out = ref_plan.run_sample(&mut arena, &ds.x[0..feat])?;
            let mut arena = packed_plan.arena();
            let packed_out = packed_plan.run_sample(&mut arena, &ds.x[0..feat])?;
            assert_eq!(ref_out, want, "x{px}w{pw}: reference diverged");
            assert_eq!(packed_out, want, "x{px}w{pw}: packed diverged");

            let mut arena = ref_plan.arena();
            let (ref_ms, _, _) = measure(1, 5, || {
                let _ = ref_plan.run_sample(&mut arena, &ds.x[0..feat]).unwrap();
            });
            let mut arena = packed_plan.arena();
            let (packed_ms, _, _) = measure(1, 5, || {
                let _ = packed_plan.run_sample(&mut arena, &ds.x[0..feat]).unwrap();
            });
            println!(
                "    x{px}w{pw}  {ref_ms:>12.3} {packed_ms:>12.3} {:>7.2}x",
                ref_ms / packed_ms
            );
            rows.push((
                format!("x{px}w{pw}"),
                Json::obj(vec![
                    ("act_bits", Json::num(px as f64)),
                    ("weight_bits", Json::num(pw as f64)),
                    ("reference_ms_per_inf", Json::num(ref_ms)),
                    ("packed_ms_per_inf", Json::num(packed_ms)),
                    ("speedup_packed_vs_reference", Json::num(ref_ms / packed_ms)),
                ]),
            ));
        }
    }
    Ok(rows)
}

fn main() -> anyhow::Result<()> {
    println!("=== engine benchmarks (builtin zoo, striped mixed assignment) ===");
    let batch = 32usize;
    let threads = engine_threads(batch);
    let mut bench_objs: Vec<(&str, Json)> = Vec::new();

    for bench in BENCHES {
        let manifest = builtin_manifest(bench)?;
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a)?;
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, batch, 0);

        let ref_plan = ExecPlan::compile(&model, &manifest.lut, &ReferenceBackend)?;
        let packed_plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend)?;

        // correctness first: all three paths bit-identical on a sample
        let (seed_out, cost) = cwmix::mpic::run_sample(&model, &ds.x[0..feat], &manifest.lut)?;
        let mut arena = ref_plan.arena();
        let ref_out = ref_plan.run_sample(&mut arena, &ds.x[0..feat])?;
        let mut arena = packed_plan.arena();
        let packed_out = packed_plan.run_sample(&mut arena, &ds.x[0..feat])?;
        let bit_exact = seed_out == ref_out && seed_out == packed_out;
        assert!(bit_exact, "{bench}: engine output diverged from the oracle");

        // 1. seed scalar path: per-sample interpreter, re-derived
        //    geometry, per-sample cost accounting + allocations
        let (seed_ms, _, _) = measure(1, 5, || {
            let _ =
                cwmix::mpic::run_sample(&model, &ds.x[0..feat], &manifest.lut)
                    .unwrap();
        });

        // 2/3. engine single-thread, reference vs packed
        let mut arena = ref_plan.arena();
        let (ref_ms, _, _) = measure(1, 5, || {
            let _ = ref_plan.run_sample(&mut arena, &ds.x[0..feat]).unwrap();
        });
        let mut arena = packed_plan.arena();
        let (packed_ms, _, _) = measure(1, 5, || {
            let _ = packed_plan.run_sample(&mut arena, &ds.x[0..feat]).unwrap();
        });

        // 4. engine packed, threaded batch (per-inference wall clock)
        let (batch_ms, _, _) = measure(1, 3, || {
            let _ = packed_plan
                .run_batch_threads(&ds.x, feat, threads)
                .unwrap();
        });
        let packed_mt_ms = batch_ms / batch as f64;

        let macs = cost.total_macs();
        println!(
            "\n[{bench}] {:.2} MMAC, {} sub-convs, packed weights {} B \
             (reference {} B)",
            macs as f64 / 1e6,
            model.n_subconvs(),
            packed_plan.weight_bytes(),
            ref_plan.weight_bytes(),
        );
        println!(
            "    seed scalar      {seed_ms:>8.3} ms/inf \
             ({:>6.1} MMAC/s)",
            macs as f64 / seed_ms / 1e3
        );
        println!(
            "    engine/reference {ref_ms:>8.3} ms/inf  ({:.2}x vs seed)",
            seed_ms / ref_ms
        );
        println!(
            "    engine/packed    {packed_ms:>8.3} ms/inf  ({:.2}x vs seed)",
            seed_ms / packed_ms
        );
        println!(
            "    packed x{threads} threads {packed_mt_ms:>6.3} ms/inf  \
             ({:.2}x vs seed)",
            seed_ms / packed_mt_ms
        );

        bench_objs.push((
            bench,
            Json::obj(vec![
                ("macs", Json::num(macs as f64)),
                ("n_subconvs", Json::num(model.n_subconvs() as f64)),
                ("weight_bytes_packed", Json::num(packed_plan.weight_bytes() as f64)),
                ("weight_bytes_reference", Json::num(ref_plan.weight_bytes() as f64)),
                ("seed_scalar_ms_per_inf", Json::num(seed_ms)),
                ("engine_reference_ms_per_inf", Json::num(ref_ms)),
                ("engine_packed_ms_per_inf", Json::num(packed_ms)),
                ("engine_packed_mt_ms_per_inf", Json::num(packed_mt_ms)),
                ("speedup_packed_vs_seed", Json::num(seed_ms / packed_ms)),
                ("speedup_packed_mt_vs_seed", Json::num(seed_ms / packed_mt_ms)),
                ("bit_exact_vs_oracle", Json::Bool(bit_exact)),
            ]),
        ));
    }

    let combos = combo_rows()?;
    let combo_obj = Json::Obj(combos.into_iter().collect());
    let (batch_cells, batch_monotonic) = batch_rows()?;
    let batch_obj = Json::Obj(batch_cells.into_iter().collect());
    let cold_cells = cold_start_rows()?;
    let cold_obj = Json::Obj(cold_cells.into_iter().collect());
    let fused_cells = fused_rows()?;
    let fused_obj = Json::Obj(fused_cells.into_iter().collect());
    let simd_cells = simd_rows()?;
    let simd_obj = Json::Obj(simd_cells.into_iter().collect());
    let profile_cells = profile_rows()?;
    let profile_obj = Json::Obj(profile_cells.into_iter().collect());

    let report = Json::obj(vec![
        ("version", Json::num(7.0)),
        ("threads", Json::num(threads as f64)),
        ("batch", Json::num(batch as f64)),
        ("assignment", Json::str("stripy-2/4/8")),
        ("benches", Json::obj(bench_objs)),
        ("combo_bench", Json::str(COMBO_BENCH)),
        ("combos", combo_obj),
        ("batch_bench", Json::str(COMBO_BENCH)),
        ("batch_cells", batch_obj),
        ("batch_monotonic_non_increasing", Json::Bool(batch_monotonic)),
        ("cold_start", cold_obj),
        ("fused", fused_obj),
        ("simd_tier", Json::str(cwmix::engine::simd::active_tier_name())),
        ("simd", simd_obj),
        ("profile", profile_obj),
    ]);
    let path = out_path();
    std::fs::write(&path, report.pretty())?;
    println!("\nwrote {path}");
    Ok(())
}
