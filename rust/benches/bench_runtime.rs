//! L3 runtime benchmarks (the §Perf step-latency numbers): per-graph
//! compile time and per-step execute latency for every benchmark, plus
//! the literal-conversion overhead share (host tensor -> xla literal ->
//! device and back).

#[path = "common/mod.rs"]
mod common;

use cwmix::data::{make_dataset, BatchIter, Split};
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::quant::Assignment;
use cwmix::runtime::Runtime;
use cwmix::tensor::Tensor;
use cwmix::util::timer::measure;
use cwmix::util::{Pcg32, Stopwatch};

fn main() -> anyhow::Result<()> {
    println!("=== runtime benchmarks (PJRT CPU) ===");
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    println!("platform: {}", rt.platform());

    // literal conversion overhead
    let t = Tensor::new(vec![32, 32, 32, 3], vec![0.5; 32 * 32 * 32 * 3]);
    let (ms, _, _) = measure(3, 50, || {
        let _ = t.to_literal().unwrap();
    });
    println!(
        "literal conversion: {:.3} ms for a 393 KB batch tensor ({:.1} GB/s)",
        ms,
        t.len() as f64 * 4.0 / ms / 1e6
    );

    for bench in ["ad", "kws", "ic", "vww"] {
        println!("\n[{bench}]");
        // compile times
        for g in ["train_w_hard", "search_theta_cw", "search_w_cw", "eval"] {
            let sw = Stopwatch::start();
            let _ = rt.graph(bench, g)?;
            println!("  compile {g:<16} {:>7.2} s", sw.elapsed_s());
        }
        // step latency through the Trainer path (includes literal I/O)
        let mut cfg = SearchConfig::quick(bench, Mode::ChannelWise, Target::Size, 0.0);
        cfg.warmup_epochs = 1;
        cfg.train_n = 64;
        let mut tr = Trainer::new(&rt, cfg)?;
        let sw = Stopwatch::start();
        tr.warmup()?; // 2 batches + eval
        let warm_s = sw.elapsed_s();
        let a8 = Assignment::fixed(&tr.manifest.qnames(), &tr.manifest.qcouts(), 8, 8);
        let ds = make_dataset(bench, Split::Val, 64, 0);
        let mut rng = Pcg32::seeded(0);
        let _b = BatchIter::new(&ds, 32, &mut rng).next().unwrap();
        let sw = Stopwatch::start();
        let mut evals = 0;
        while sw.elapsed_s() < 2.0 {
            let _ = tr.evaluate(Split::Val, &a8)?;
            evals += 1;
        }
        println!(
            "  warmup epoch (2 steps + eval): {:.2} s; eval epoch: {:.3} s",
            warm_s,
            sw.elapsed_s() / evals as f64
        );
    }
    Ok(())
}
