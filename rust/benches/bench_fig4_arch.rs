//! Fig. 4 regeneration: the example precision assignments on IC with the
//! energy regularizer — ours (channel-wise) vs EdMIPS (layer-wise) at
//! matched λ, printed as the per-layer table the paper draws (activation
//! bits + fraction of weight channels per precision), plus the energy
//! delta between the two (the circled Pareto points' 26.4% claim).

#[path = "common/mod.rs"]
mod common;

use cwmix::baselines;
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::report;
use cwmix::runtime::Runtime;
use cwmix::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 4 / IC energy-regularized assignments ===");
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let mk = |mode| {
        if common::full() {
            SearchConfig::new("ic", mode, Target::Energy, 0.0)
        } else {
            SearchConfig::quick("ic", mode, Target::Energy, 0.0)
        }
    };
    let sw = Stopwatch::start();
    let base = mk(Mode::ChannelWise);
    let warm = baselines::shared_warmup(&rt, &base)?;
    let (_, reg_e0) = Trainer::new(&rt, base.clone())?.initial_regs()?;
    let lambda = 0.3 / reg_e0;

    let mut cfg_cw = mk(Mode::ChannelWise);
    cfg_cw.lambda = lambda;
    let ours = baselines::run_ours(&rt, &cfg_cw, &warm)?;

    let mut cfg_lw = mk(Mode::LayerWise);
    cfg_lw.lambda = lambda;
    let edmips = baselines::run_edmips(&rt, &cfg_lw, &warm)?;

    println!("{}", report::fig4_dump("ours (channel-wise)", &ours.assignment));
    println!("{}", report::fig4_dump("EdMIPS (layer-wise)", &edmips.assignment));
    println!(
        "ours:   acc {:.3}  energy {:.2} uJ   | EdMIPS: acc {:.3}  energy {:.2} uJ",
        ours.test_score,
        ours.energy_uj(),
        edmips.test_score,
        edmips.energy_uj()
    );
    if ours.test_score >= edmips.test_score - 0.002 {
        println!(
            "energy saving at >= EdMIPS accuracy: {:.1}%  (paper circled points: 26.4%)",
            (1.0 - ours.energy_pj / edmips.energy_pj) * 100.0
        );
    }
    // the paper's qualitative observation: first/last activations stay 8-bit
    let first = &ours.assignment.layers[0];
    let last = ours.assignment.layers.last().unwrap();
    println!(
        "first/last layer activations: x{} / x{} (paper: both remain 8-bit)",
        first.act_bits, last.act_bits
    );
    println!("bench_fig4_arch: {:.1}s wall", sw.elapsed_s());
    Ok(())
}
