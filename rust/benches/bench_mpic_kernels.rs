//! MPIC simulator micro-benchmarks:
//!
//! 1. simulated MAC throughput by (p_x, p_w) — must follow the LUT's
//!    lane structure (the MPIC SIMD claim);
//! 2. §III-C sub-convolution scheduling overhead as group count grows —
//!    the paper's "negligible compared to the benefits" claim, quantified;
//! 3. host-side simulator throughput (engineering number for §Perf);
//! 4. pack/unpack bandwidth for the sub-byte flash layout.

#[path = "common/mod.rs"]
mod common;

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::energy::CostLut;
use cwmix::engine::{ExecPlan, PackedBackend};
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::quant::{pack_subbyte, unpack_subbyte, Assignment, LayerAssignment};
use cwmix::runtime::Runtime;
use cwmix::util::timer::measure;
use cwmix::util::Pcg32;

fn main() -> anyhow::Result<()> {
    println!("=== MPIC simulator micro-benchmarks ===");
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let cfg = SearchConfig::quick("kws", Mode::ChannelWise, Target::Size, 0.0);
    let tr = Trainer::new(&rt, cfg)?;
    let lut = CostLut::default();
    let ds = make_dataset("kws", Split::Test, 4, 0);
    let feat = tr.manifest.feat_len();
    let names = tr.manifest.qnames();
    let couts = tr.manifest.qcouts();

    // 1. modelled cycles by precision combo (uniform nets)
    println!("\n[1] simulated inference cost by (p_x, p_w):");
    println!("    {:<8} {:>12} {:>10} {:>9}", "combo", "cycles", "us@250MHz", "uJ");
    for &(px, pw) in &[(8u32, 8u32), (8, 4), (8, 2), (4, 4), (4, 2), (2, 2)] {
        let a = Assignment::fixed(&names, &couts, pw, px);
        let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a)?;
        let cost = ExecPlan::compile(&d, &lut, &PackedBackend)?.cost().clone();
        println!(
            "    w{pw}x{px}    {:>12.0} {:>10.1} {:>9.3}",
            cost.total_cycles(),
            cost.latency_us(),
            cost.total_energy_uj()
        );
    }

    // 2. sub-conv scheduling overhead vs fragmentation
    println!("\n[2] sub-conv scheduling overhead (vs 1-group baseline):");
    let mut rng = Pcg32::seeded(7);
    let base_a = Assignment::fixed(&names, &couts, 8, 8);
    let d0 = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &base_a)?;
    let c0 = ExecPlan::compile(&d0, &lut, &PackedBackend)?.cost().clone();
    for frag in [2usize, 3, 8, 16] {
        // random interleaving with `frag` alternations per layer
        let a = Assignment {
            layers: names
                .iter()
                .zip(&couts)
                .map(|(n, &c)| LayerAssignment {
                    name: n.clone(),
                    act_bits: 8,
                    weight_bits: (0..c)
                        .map(|i| {
                            let band = i * frag / c.max(1);
                            if band % 2 == 0 { 8 } else { [2u32, 4][rng.below(2) as usize] }
                        })
                        .collect(),
                })
                .collect(),
        };
        let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a)?;
        let c = ExecPlan::compile(&d, &lut, &PackedBackend)?.cost().clone();
        let overhead: f64 = c.layers.iter().map(|l| l.overhead_cycles).sum();
        println!(
            "    {:>3} groups total: overhead {:>7.0} cyc = {:.2}% of inference ({:.0} cyc)",
            d.n_subconvs(),
            overhead,
            overhead / c0.total_cycles() * 100.0,
            c.total_cycles(),
        );
    }

    // 3. host-side simulator throughput
    println!("\n[3] host simulator throughput:");
    let a = Assignment::fixed(&names, &couts, 8, 8);
    let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a)?;
    let plan = ExecPlan::compile(&d, &lut, &PackedBackend)?;
    let (mean_ms, min_ms, max_ms) = measure(2, 10, || {
        let _ = plan.run_batch(&ds.x[0..feat], feat).unwrap();
    });
    let macs = 2.6e6; // DS-CNN ~2.6 MMAC
    println!(
        "    kws inference: mean {mean_ms:.2} ms (min {min_ms:.2}, max {max_ms:.2}) = {:.0} MMAC/s",
        macs / mean_ms / 1e3
    );

    // 4. pack/unpack bandwidth
    println!("\n[4] sub-byte pack/unpack:");
    let vals: Vec<i32> = (0..1_000_000).map(|i| (i % 3) as i32 - 1).collect();
    for bits in [2u32, 4, 8] {
        let (pack_ms, _, _) = measure(1, 5, || {
            let _ = pack_subbyte(&vals, bits);
        });
        let packed = pack_subbyte(&vals, bits);
        let (unpack_ms, _, _) = measure(1, 5, || {
            let _ = unpack_subbyte(&packed, bits, vals.len());
        });
        println!(
            "    {bits}-bit: pack {:.0} MB/s, unpack {:.0} MB/s",
            vals.len() as f64 / pack_ms / 1e3,
            vals.len() as f64 / unpack_ms / 1e3
        );
    }
    Ok(())
}
