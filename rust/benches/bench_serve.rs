//! Serving-layer load generator: closed-loop clients against an
//! in-process `cwmix serve` instance, micro-batching ON vs OFF.
//!
//! Starts the server twice on an ephemeral port with the same model and
//! drives it with N concurrent keep-alive HTTP clients, each sending
//! its next request as soon as the previous reply lands (closed loop):
//!
//! * **batch1** — `max_batch = 1`: every request is its own engine
//!   call through the single batcher worker (the no-coalescing
//!   baseline);
//! * **micro_batch** — `max_batch = 16, max_wait_us = 1000`: pending
//!   requests from unrelated clients coalesce into one batch-plane
//!   engine call (weight-stationary amortization across riders).
//!
//! Per config it reports client-observed throughput, p50/p99 latency,
//! the mean executed batch size (from the per-reply `batch` field) and
//! the keep-alive connection-reuse count (connections opened vs
//! requests sent — every client rides one connection unless the server
//! drops it, and reconnects are counted so the gauge stays honest),
//! and writes a machine-readable `BENCH_serve.json` next to
//! `BENCH_engine.json` so the serving trajectory is versioned alongside
//! the engine's.  Under a concurrency of 16 the micro-batch config
//! should sustain batches ≥ 4 and beat batch1 throughput on any
//! multi-core machine.
//!
//! ```bash
//! cargo bench --bench bench_serve
//! CWMIX_BENCH_SERVE_CONC=32 CWMIX_BENCH_SERVE_REQS=200 \
//!     cargo bench --bench bench_serve
//! ```

use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use cwmix::data::{make_dataset, Split};
use cwmix::minijson::Json;
use cwmix::serve::client::{infer_body, output_of, Conn};
use cwmix::serve::{
    serve, BatchPolicy, ModelRegistry, RegistryConfig, ServeConfig,
};

/// The model under load (conv-heavy enough for batching to matter,
/// light enough for CI).
const BENCH: &str = "kws";

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct LoadStats {
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    mean_batch: f64,
    max_batch_seen: usize,
    /// TCP connections opened across all clients (keep-alive reuse:
    /// the floor is one per client; every extra one is a reconnect)
    connections_opened: usize,
    requests_per_connection: f64,
}

/// Supervision gauges scraped from `/metrics` at the end of a config's
/// load run (server still up).  The bench runs with faults disarmed, so
/// every counter must be zero and the breaker closed — recording them
/// in the trajectory makes a supervision regression (a spurious panic
/// or deadline expiry under plain load) visible in the artifact diff.
struct SupervisionGauges {
    worker_panics: f64,
    worker_respawns: f64,
    deadline_expired: f64,
    breaker_state: f64,
    breaker_opens: f64,
    slow_client_closes: f64,
}

/// Scrape + sanity-check the supervision surface for `BENCH`.
fn scrape_supervision(addr: SocketAddr) -> anyhow::Result<SupervisionGauges> {
    let mut conn = Conn::connect(addr)?;
    let m = conn.get("/metrics")?;
    anyhow::ensure!(m.status == 200, "GET /metrics -> {}", m.status);
    let model = m.body.get("models")?.get(BENCH)?;
    let g = SupervisionGauges {
        worker_panics: model.get("worker_panics")?.as_f64()?,
        worker_respawns: model.get("worker_respawns")?.as_f64()?,
        deadline_expired: model.get("deadline_expired_total")?.as_f64()?,
        breaker_state: model.get("breaker_state")?.as_f64()?,
        breaker_opens: model.get("breaker_opens")?.as_f64()?,
        slow_client_closes: m.body.get("slow_client_closes")?.as_f64()?,
    };
    // disarmed faults must be perfect no-ops under load
    anyhow::ensure!(
        g.worker_panics == 0.0 && g.worker_respawns == 0.0,
        "worker panicked during a disarmed bench run \
         (panics {}, respawns {})",
        g.worker_panics,
        g.worker_respawns
    );
    anyhow::ensure!(
        g.breaker_state == 0.0 && g.breaker_opens == 0.0,
        "breaker not closed after a disarmed bench run"
    );
    anyhow::ensure!(
        g.deadline_expired == 0.0,
        "{} requests expired their deadline under plain load",
        g.deadline_expired
    );
    Ok(g)
}

/// Drive `clients` closed-loop clients x `reqs` requests each, every
/// client pipelining all its requests down one keep-alive connection
/// (reconnecting — and counting it — only if the server drops the
/// socket, e.g. the idle reaper).
fn run_load(
    addr: SocketAddr,
    body: Arc<String>,
    want: Arc<Vec<f32>>,
    clients: usize,
    reqs: usize,
) -> anyhow::Result<LoadStats> {
    type ClientOut = (Vec<(f64, usize)>, usize);
    let t0 = Instant::now();
    let mut all: Vec<(f64, usize)> = Vec::with_capacity(clients * reqs);
    let mut connections_opened = 0usize;
    let results: Vec<anyhow::Result<ClientOut>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let body = Arc::clone(&body);
                let want = Arc::clone(&want);
                scope.spawn(move || -> anyhow::Result<ClientOut> {
                    let path = format!("/v1/infer/{BENCH}");
                    let mut conn = Conn::connect(addr)?;
                    let mut conns = 1usize;
                    let mut lats = Vec::with_capacity(reqs);
                    for _ in 0..reqs {
                        let t = Instant::now();
                        let resp = match conn.post(&path, &body) {
                            Ok(r) => r,
                            Err(_) => {
                                // server closed the keep-alive socket:
                                // reconnect once, counted so the reuse
                                // gauge stays honest
                                conn = Conn::connect(addr)?;
                                conns += 1;
                                conn.post(&path, &body)?
                            }
                        };
                        let ms = t.elapsed().as_secs_f64() * 1e3;
                        anyhow::ensure!(
                            resp.status == 200,
                            "infer -> {}: {}",
                            resp.status,
                            resp.body.dumps()
                        );
                        // correctness under load: bit-identical
                        anyhow::ensure!(
                            output_of(&resp.body)? == *want,
                            "served output diverged under load"
                        );
                        let batch = resp.body.get("batch")?.as_f64()? as usize;
                        lats.push((ms, batch));
                    }
                    Ok((lats, conns))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .collect()
    });
    for r in results {
        let (lats, conns) = r?;
        all.extend(lats);
        connections_opened += conns;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let n = all.len();
    anyhow::ensure!(n > 0, "no requests completed");
    let mut lat: Vec<f64> = all.iter().map(|&(ms, _)| ms).collect();
    lat.sort_unstable_by(f64::total_cmp);
    let at = |p: f64| lat[((n - 1) as f64 * p).round() as usize];
    let mean_batch =
        all.iter().map(|&(_, b)| b as f64).sum::<f64>() / n as f64;
    let max_batch_seen = all.iter().map(|&(_, b)| b).max().unwrap_or(0);
    Ok(LoadStats {
        throughput_rps: n as f64 / wall_s,
        p50_ms: at(0.50),
        p99_ms: at(0.99),
        mean_batch,
        max_batch_seen,
        connections_opened,
        requests_per_connection: n as f64 / connections_opened.max(1) as f64,
    })
}

/// One server lifecycle under `policy`, loaded, then shut down cleanly.
fn run_config(
    policy: BatchPolicy,
    body: &Arc<String>,
    want: &Arc<Vec<f32>>,
    clients: usize,
    reqs: usize,
) -> anyhow::Result<(LoadStats, SupervisionGauges)> {
    let reg_cfg = RegistryConfig {
        benches: vec![BENCH.to_string()],
        policy,
        ..RegistryConfig::default()
    };
    let registry = Arc::new(ModelRegistry::build(&reg_cfg)?);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_conns: clients + 8,
        ..ServeConfig::default()
    };
    let server = serve(registry, cfg)?;
    let stats = run_load(server.addr(), Arc::clone(body), Arc::clone(want), clients, reqs);
    // scrape the supervision surface before the server goes away
    let gauges = match &stats {
        Ok(_) => Some(scrape_supervision(server.addr())?),
        Err(_) => None,
    };
    server.stop()?;
    Ok((stats?, gauges.expect("gauges scraped on success")))
}

fn stats_json(s: &LoadStats, g: &SupervisionGauges, policy: &BatchPolicy) -> Json {
    Json::obj(vec![
        ("max_batch", Json::num(policy.max_batch as f64)),
        ("max_wait_us", Json::num(policy.max_wait_us as f64)),
        ("throughput_rps", Json::num(s.throughput_rps)),
        ("p50_ms", Json::num(s.p50_ms)),
        ("p99_ms", Json::num(s.p99_ms)),
        ("mean_batch", Json::num(s.mean_batch)),
        ("max_batch_seen", Json::num(s.max_batch_seen as f64)),
        ("connections_opened", Json::num(s.connections_opened as f64)),
        ("requests_per_connection", Json::num(s.requests_per_connection)),
        // supervision gauges (all zero on a healthy disarmed run —
        // scrape_supervision hard-fails otherwise; recorded so the
        // trajectory artifact documents that invariant)
        ("worker_panics", Json::num(g.worker_panics)),
        ("worker_respawns", Json::num(g.worker_respawns)),
        ("deadline_expired_total", Json::num(g.deadline_expired)),
        ("breaker_state", Json::num(g.breaker_state)),
        ("breaker_opens", Json::num(g.breaker_opens)),
        ("slow_client_closes", Json::num(g.slow_client_closes)),
    ])
}

fn out_path() -> String {
    if let Ok(p) = std::env::var("CWMIX_BENCH_SERVE_JSON") {
        return p;
    }
    if Path::new("../ROADMAP.md").exists() {
        "../BENCH_serve.json".to_string()
    } else {
        "BENCH_serve.json".to_string()
    }
}

fn main() -> anyhow::Result<()> {
    let clients = env_usize("CWMIX_BENCH_SERVE_CONC", 16);
    let reqs = env_usize("CWMIX_BENCH_SERVE_REQS", 100);
    println!(
        "=== serve load generator: {BENCH}, {clients} closed-loop clients x \
         {reqs} reqs ==="
    );

    // one deterministic sample + its expected output, shared by every
    // client (the server compiles the identical default registry)
    let probe_cfg = RegistryConfig {
        benches: vec![BENCH.to_string()],
        ..RegistryConfig::default()
    };
    let probe = ModelRegistry::build(&probe_cfg)?;
    let plan = probe.entries().next().unwrap().plan();
    let feat = plan.feat();
    let ds = make_dataset(BENCH, Split::Test, 1, 0);
    let input = &ds.x[..feat];
    let mut arena = plan.arena();
    let want = Arc::new(plan.run_sample(&mut arena, input)?);
    let body = Arc::new(infer_body(input));
    drop(probe);

    let batch1_policy = BatchPolicy { max_batch: 1, ..BatchPolicy::default() };
    let micro_policy = BatchPolicy {
        max_batch: 16,
        max_wait_us: 1_000,
        ..BatchPolicy::default()
    };

    let (batch1, batch1_sup) =
        run_config(batch1_policy.clone(), &body, &want, clients, reqs)?;
    let (micro, micro_sup) =
        run_config(micro_policy.clone(), &body, &want, clients, reqs)?;

    let speedup = micro.throughput_rps / batch1.throughput_rps;
    println!(
        "    batch1      {:>8.1} req/s  p50 {:>6.2} ms  p99 {:>6.2} ms  \
         mean batch {:>5.2}",
        batch1.throughput_rps, batch1.p50_ms, batch1.p99_ms, batch1.mean_batch
    );
    println!(
        "    micro-batch {:>8.1} req/s  p50 {:>6.2} ms  p99 {:>6.2} ms  \
         mean batch {:>5.2} (max {})",
        micro.throughput_rps,
        micro.p50_ms,
        micro.p99_ms,
        micro.mean_batch,
        micro.max_batch_seen
    );
    println!("    micro-batching throughput x{speedup:.2} vs batch1");
    println!(
        "    keep-alive reuse: {} + {} connections for {} requests \
         ({:.1} / {:.1} reqs per connection)",
        batch1.connections_opened,
        micro.connections_opened,
        2 * clients * reqs,
        batch1.requests_per_connection,
        micro.requests_per_connection,
    );
    if micro.mean_batch < 4.0 {
        println!(
            "    note: mean batch {:.2} < 4 — machine too fast or too few \
             clients for sustained coalescing",
            micro.mean_batch
        );
    }
    println!(
        "    supervision (disarmed run): 0 panics, 0 respawns, breaker \
         closed, 0 deadline expiries — gauges recorded in the trajectory"
    );

    let report = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("bench", Json::str(BENCH)),
        ("concurrency", Json::num(clients as f64)),
        ("reqs_per_client", Json::num(reqs as f64)),
        ("batch1", stats_json(&batch1, &batch1_sup, &batch1_policy)),
        ("micro_batch", stats_json(&micro, &micro_sup, &micro_policy)),
        ("speedup_microbatch_vs_batch1", Json::num(speedup)),
    ]);
    let path = out_path();
    std::fs::write(&path, report.pretty())?;
    println!("wrote {path}");
    Ok(())
}
