//! Regenerates both Fig. 3 panels (score vs energy, score vs size) for
//! the VWW benchmark: our channel-wise DNAS vs EdMIPS vs fixed wNxM.
//! See common/mod.rs for budget env vars.

#[path = "common/mod.rs"]
mod common;

use cwmix::nas::Target;

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 3 / vww ===");
    common::fig3_bench("vww", Target::Energy)?;
    common::fig3_bench("vww", Target::Size)?;
    Ok(())
}
