//! Shared harness for the criterion-less bench binaries (`harness = false`;
//! criterion is not in the offline crate set).  Each bench prints the
//! paper-figure series it regenerates plus wall-clock timings, and honours:
//!
//! * `CWMIX_BENCH_FULL=1` — full search budgets (paper-scale runs; the
//!   default is the quick budget so `cargo bench` completes in minutes);
//! * `CWMIX_BENCH_OUT=dir` — where to store the sweep JSONs (default
//!   `results/bench`).

// Shared across bench binaries; not every binary uses every helper.
#![allow(dead_code)]

use std::path::PathBuf;

use cwmix::coordinator::results;
use cwmix::coordinator::sweep::run_sweep;
use cwmix::nas::Target;
use cwmix::report;
use cwmix::runtime::Runtime;
use cwmix::util::Stopwatch;

pub fn full() -> bool {
    std::env::var("CWMIX_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

pub fn out_dir() -> PathBuf {
    PathBuf::from(std::env::var("CWMIX_BENCH_OUT").unwrap_or_else(|_| "results/bench".into()))
}

/// Bench-budget λ strengths.  The default single-λ point keeps a full
/// `cargo bench` run tractable on one core (a representative
/// ours-vs-EdMIPS-vs-fixed panel); `CWMIX_BENCH_FULL=1` uses the paper
/// grid, and the recorded multi-λ sweeps live in `results/` via
/// `cwmix sweep` (EXPERIMENTS.md).
pub fn strengths() -> Vec<f32> {
    if full() {
        cwmix::coordinator::sweep::DEFAULT_STRENGTHS.to_vec()
    } else {
        vec![0.5]
    }
}

/// Regenerate one Fig. 3 panel and print it.
pub fn fig3_bench(bench: &str, target: Target) -> anyhow::Result<()> {
    let rt = Runtime::cpu(std::path::Path::new("artifacts"))?;
    let sw = Stopwatch::start();
    let mut log = |s: &str| eprintln!("  {s}");
    let out = run_sweep(&rt, bench, target, &strengths(), !full(), &mut log)?;
    // (bench-mode budgets are the `quick` SearchConfig; the recorded
    // multi-lambda paper-scale sweeps live in results/ — EXPERIMENTS.md)
    let secs = sw.elapsed_s();
    let path = results::save_sweep(
        &out_dir(),
        bench,
        target.name(),
        &out.ours,
        &out.edmips,
        &out.fixed,
    )?;
    let (b, _, o, e, f) = results::load_sweep(&path)?;
    println!("{}", report::fig3_panel(&b, target, &o, &e, &f));
    println!(
        "bench_fig3_{bench}/{}: {:.1}s wall ({} searches + {} baselines), saved {}",
        target.name(),
        secs,
        out.ours.len() + out.edmips.len(),
        out.fixed.len(),
        path.display()
    );
    Ok(())
}
