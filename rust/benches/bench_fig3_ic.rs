//! Regenerates both Fig. 3 panels (score vs energy, score vs size) for
//! the IC benchmark: our channel-wise DNAS vs EdMIPS vs fixed wNxM.
//! See common/mod.rs for budget env vars.

#[path = "common/mod.rs"]
mod common;

use cwmix::nas::Target;

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 3 / ic ===");
    common::fig3_bench("ic", Target::Energy)?;
    common::fig3_bench("ic", Target::Size)?;
    Ok(())
}
