//! Fused-requantize contract (ISSUE 6): a plan whose producers code
//! their consumers' packed planes at the epilogue exit is
//! **bit-identical** to the two-pass plan that materializes every f32
//! slot and re-quantizes on the consumer side — on all four zoo
//! geometries × all nine `(p_x, p_w)` combos × every batch size, with
//! the `reference` backend (which never fuses) and the engine's own
//! unfused compile (`ExecPlan::compile_with(.., false)`) as oracles.
//!
//! Also pinned here: the compile-time [`FusionStats`] the pass reports
//! (uniform assignments fuse every quantized edge; striped assignments
//! fall back wherever residual branches disagree on `p_x`), the
//! residual-plane *reuse* vs *fallback* split on the ic residual
//! topology, and PACT clip-boundary inputs (exact clip, overshoot,
//! negatives, half-step ties) through the fused exit.

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{ExecPlan, FusionStats, PackedBackend, ReferenceBackend, SimdBackend};
use cwmix::models::zoo::{builtin_manifest, stripy_assignment, synthetic_state};
use cwmix::quant::Assignment;

/// The serve-layer default `BatchPolicy::max_batch`.
const MAX_BATCH: usize = 8;

/// Degenerate, ragged and full batches.
const BATCH_SIZES: [usize; 3] = [1, 7, MAX_BATCH];

/// Run `samples` through `plan` per batch size, reusing one arena so a
/// fused plan's extra plane slots are also exercised for cross-batch
/// staleness.
fn batch_outputs(plan: &ExecPlan, samples: &[&[f32]]) -> Vec<Vec<Vec<f32>>> {
    let mut arena = plan.batch_arena(MAX_BATCH);
    BATCH_SIZES
        .iter()
        .map(|&b| plan.run_batch_planes(&mut arena, &samples[..b]).unwrap())
        .collect()
}

/// Fused vs both oracles on `bench`, all nine fixed `(p_x, p_w)`
/// combos, every batch size.
fn check_all_nine_combos_fused(bench: &str) {
    let manifest = builtin_manifest(bench).unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let feat = manifest.feat_len();
    let ds = make_dataset(bench, Split::Test, MAX_BATCH, 13);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
    for xb in [2u32, 4, 8] {
        for wb in [2u32, 4, 8] {
            let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), wb, xb);
            let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
            let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
            let unfused =
                ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false)
                    .unwrap();
            let reference =
                ExecPlan::compile(&model, &manifest.lut, &ReferenceBackend).unwrap();

            // the oracles really are unfused; the fused plan really is
            // fused — uniform assignments make every signature agree,
            // so coverage must be total
            assert_eq!(unfused.fusion(), &FusionStats::default());
            assert_eq!(reference.fusion(), &FusionStats::default());
            let stats = fused.fusion();
            assert!(stats.total_edges > 0, "{bench}: no quantized edges");
            assert_eq!(
                stats.fused_edges, stats.total_edges,
                "{bench} w{wb}x{xb}: uniform assignment must fuse every edge"
            );
            assert!(
                stats.act_bytes_fused < stats.act_bytes_unfused,
                "{bench} w{wb}x{xb}: fusion moved no fewer activation bytes"
            );
            assert!(stats.act_bytes_saved() > 0);

            let want = batch_outputs(&unfused, &samples);
            let got = batch_outputs(&fused, &samples);
            assert_eq!(
                got, want,
                "{bench} w{wb}x{xb}: fused diverged from unfused PackedBackend"
            );
            let oracle = batch_outputs(&reference, &samples);
            assert_eq!(
                got, oracle,
                "{bench} w{wb}x{xb}: fused diverged from the reference backend"
            );

            // the simd backend fuses for free (the fusion seam sits
            // above the kernel boundary) and must stay bit-identical
            // through the fused exit on every tier
            let simd = ExecPlan::compile(&model, &manifest.lut, &SimdBackend).unwrap();
            assert_eq!(simd.fusion().fused_edges, stats.fused_edges);
            assert_eq!(
                batch_outputs(&simd, &samples),
                oracle,
                "{bench} w{wb}x{xb}: fused simd diverged from the reference backend"
            );
        }
    }
}

#[test]
fn fused_bit_exact_all_combos_ic() {
    check_all_nine_combos_fused("ic");
}

#[test]
fn fused_bit_exact_all_combos_kws() {
    check_all_nine_combos_fused("kws");
}

#[test]
fn fused_bit_exact_all_combos_vww() {
    check_all_nine_combos_fused("vww");
}

#[test]
fn fused_bit_exact_all_combos_ad() {
    check_all_nine_combos_fused("ad");
}

/// Striped per-channel assignments (activation bits cycling 2/4/8 down
/// the layers): the fusion pass must fall back wherever consumers of a
/// residual tap disagree on `p_x`, and the result must still be
/// bit-exact — anchored to the out-of-engine oracle
/// `mpic::exec::run_sample` on the first two samples.
#[test]
fn striped_assignments_fused_match_oracle() {
    for bench in ["ic", "kws", "vww", "ad"] {
        let manifest = builtin_manifest(bench).unwrap();
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy_assignment(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, MAX_BATCH, 11);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let oracle: Vec<Vec<f32>> = samples[..2]
            .iter()
            .map(|s| cwmix::mpic::run_sample(&model, s, &manifest.lut).unwrap().0)
            .collect();
        let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
        let unfused =
            ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false).unwrap();
        let want = batch_outputs(&unfused, &samples);
        let got = batch_outputs(&fused, &samples);
        assert_eq!(got, want, "{bench}: fused striped diverged from unfused");
        let simd = ExecPlan::compile(&model, &manifest.lut, &SimdBackend).unwrap();
        assert_eq!(
            batch_outputs(&simd, &samples),
            want,
            "{bench}: fused striped simd diverged from unfused packed"
        );
        // the full-batch row ties the first two outputs to the oracle
        assert_eq!(
            &got[BATCH_SIZES.len() - 1][..2],
            oracle.as_slice(),
            "{bench}: fused striped diverged from mpic::exec::run_sample"
        );
    }
}

/// The ic residual topology, both fusion regimes:
///
/// * uniform `w8x8` — every consumer of a block-output tap agrees on
///   `p_x`, so the two conv-shortcut blocks each share one saved packed
///   plane (2 reuse hits), all 8 quantized edges fuse, and the three
///   inner `c1` layers (whose values have no f32 reader) skip their f32
///   slot writes entirely;
/// * striped — the tap consumers land on different `p_x`, so both
///   2-consumer groups fall back to the f32 path (4 of 8 edges fuse, no
///   reuse) and execution stays bit-exact.
#[test]
fn residual_plane_reuse_and_fallback() {
    let manifest = builtin_manifest("ic").unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let feat = manifest.feat_len();
    let ds = make_dataset("ic", Split::Test, MAX_BATCH, 29);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();

    let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), 8, 8);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let stats = fused.fusion();
    assert_eq!(stats.total_edges, 8);
    assert_eq!(stats.fused_edges, 8);
    assert_eq!(stats.reuse_hits, 2, "one shared plane per conv-shortcut block");
    assert_eq!(stats.elided_f32, 3, "the three c1 values have no f32 reader");
    let unfused =
        ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false).unwrap();
    assert_eq!(
        batch_outputs(&fused, &samples),
        batch_outputs(&unfused, &samples),
        "ic w8x8: plane reuse diverged from the two-pass path"
    );

    let a = stripy_assignment(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let stats = fused.fusion();
    assert_eq!(stats.total_edges, 8);
    assert_eq!(
        stats.fused_edges, 4,
        "striped tap consumers disagree on p_x: both groups must fall back"
    );
    assert_eq!(stats.reuse_hits, 0);
    let unfused =
        ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false).unwrap();
    assert_eq!(
        batch_outputs(&fused, &samples),
        batch_outputs(&unfused, &samples),
        "ic striped: residual fallback diverged from the two-pass path"
    );
}

/// Inputs crafted at the PACT quantizer's decision boundaries — exact
/// clip `alpha`, overshoot, negatives, signed zero and `k + 0.5`
/// half-step ties for every `p_x` step size at the zoo clip
/// `alpha = 6.0` — where one misplaced rounding or clamp in the fused
/// exit would flip a code.
fn boundary_inputs(feat: usize, n: usize) -> Vec<Vec<f32>> {
    let alpha = 6.0f32;
    let mut vals = vec![-2.5f32, -0.0, 0.0, alpha, alpha + 3.25, 7.5];
    for bits in [2u32, 4, 8] {
        let eps = alpha / ((1u32 << bits) - 1) as f32;
        for k in [0.5f32, 1.5, 2.5] {
            vals.push(eps * k);
        }
    }
    (0..n)
        .map(|i| (0..feat).map(|j| vals[(i + j) % vals.len()]).collect())
        .collect()
}

#[test]
fn clip_boundary_inputs_bit_exact() {
    for bench in ["ic", "ad"] {
        let manifest = builtin_manifest(bench).unwrap();
        let (params, bn) = synthetic_state(&manifest, 0);
        let feat = manifest.feat_len();
        let inputs = boundary_inputs(feat, MAX_BATCH);
        let samples: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        for xb in [2u32, 4, 8] {
            for wb in [2u32, 4, 8] {
                let a =
                    Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), wb, xb);
                let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
                let fused =
                    ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
                let unfused =
                    ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false)
                        .unwrap();
                let got = batch_outputs(&fused, &samples);
                assert_eq!(
                    got,
                    batch_outputs(&unfused, &samples),
                    "{bench} w{wb}x{xb}: boundary inputs diverged fused vs unfused"
                );
                let oracle = cwmix::mpic::run_sample(&model, samples[0], &manifest.lut)
                    .unwrap()
                    .0;
                assert_eq!(
                    got[0][0], oracle,
                    "{bench} w{wb}x{xb}: boundary input diverged from the oracle"
                );
            }
        }
    }
}
