//! Shared helpers for the artifact-dependent integration tests.

use std::path::Path;

/// True when `make artifacts` has run; tests skip themselves otherwise.
pub fn has_artifacts() -> bool {
    let ok = Path::new("artifacts/ad/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
    }
    ok
}
