//! End-to-end serving test: a real `TcpListener` server, real HTTP
//! clients, concurrent `/v1/infer` on two zoo models with outputs
//! bit-identical to `ExecPlan::run_sample`, the documented error
//! paths, metrics accounting, and a clean shutdown.
//!
//! Pure Rust, ephemeral ports, no artifacts — this is the acceptance
//! criterion of ISSUE 3 run as a tier-1 test.

use std::sync::Arc;

use cwmix::data::{make_dataset, Split};
use cwmix::minijson::Json;
use cwmix::serve::client::{infer_body, output_of, Conn};
use cwmix::serve::{
    serve, BatchPolicy, ModelRegistry, RegistryConfig, ServeConfig, Server,
};

/// Registry over `benches` + a server on an ephemeral port.
fn start(benches: &[&str], policy: BatchPolicy) -> (Arc<ModelRegistry>, Server) {
    let reg_cfg = RegistryConfig {
        benches: benches.iter().map(|b| b.to_string()).collect(),
        policy,
        ..RegistryConfig::default()
    };
    let registry = Arc::new(ModelRegistry::build(&reg_cfg).unwrap());
    let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() };
    let server = serve(Arc::clone(&registry), cfg).unwrap();
    (registry, server)
}

/// Expected output for sample `i` of a bench, straight from the plan.
fn expected(registry: &ModelRegistry, bench: &str, i: usize) -> (Vec<f32>, Vec<f32>) {
    let plan = registry.get(bench).unwrap().plan();
    let feat = plan.feat();
    let ds = make_dataset(bench, Split::Test, i + 1, 0);
    let input = ds.x[i * feat..(i + 1) * feat].to_vec();
    let mut arena = plan.arena();
    let want = plan.run_sample(&mut arena, &input).unwrap();
    (input, want)
}

#[test]
fn concurrent_infer_two_models_bit_identical() {
    let (registry, server) = start(&["ic", "kws"], BatchPolicy::default());
    let addr = server.addr();

    // /v1/models lists both models with their geometry
    let mut probe = Conn::connect(addr).unwrap();
    let models = probe.get("/v1/models").unwrap();
    assert_eq!(models.status, 200);
    let listed = models.body.get("models").unwrap().as_arr().unwrap();
    assert_eq!(listed.len(), 2);

    // 16 concurrent clients across both models, distinct samples —
    // every reply must be bit-identical to the plan
    let mut cases: Vec<(String, String, Vec<f32>)> = Vec::new();
    for bench in ["ic", "kws"] {
        for i in 0..4 {
            let (input, want) = expected(&registry, bench, i);
            cases.push((bench.to_string(), infer_body(&input), want));
        }
    }
    std::thread::scope(|scope| {
        for _ in 0..2 {
            for (bench, body, want) in &cases {
                scope.spawn(move || {
                    let mut conn = Conn::connect(addr).unwrap();
                    let resp =
                        conn.post(&format!("/v1/infer/{bench}"), body).unwrap();
                    assert_eq!(resp.status, 200, "{}", resp.body.dumps());
                    assert_eq!(
                        &output_of(&resp.body).unwrap(),
                        want,
                        "{bench}: served output diverged"
                    );
                    let batch =
                        resp.body.get("batch").unwrap().as_f64().unwrap();
                    assert!(batch >= 1.0);
                });
            }
        }
    });

    // metrics saw all 16 infer requests across the two models (fresh
    // connection: the probe may have idled past the server's timeout
    // during a slow debug-build run)
    drop(probe);
    let mut probe = Conn::connect(addr).unwrap();
    let metrics = probe.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let total = metrics.body.get("requests").unwrap().as_f64().unwrap();
    assert_eq!(total, 16.0);
    drop(probe);
    server.stop().unwrap();
    registry.shutdown();
}

#[test]
fn error_paths_answer_correctly() {
    let (registry, server) = start(&["ad"], BatchPolicy::default());
    let mut conn = Conn::connect(server.addr()).unwrap();

    // unknown model
    let r = conn.post("/v1/infer/nonesuch", &infer_body(&[1.0])).unwrap();
    assert_eq!(r.status, 404);
    // wrong method on infer
    let r = conn.get("/v1/infer/ad").unwrap();
    assert_eq!(r.status, 405);
    // unknown route
    let r = conn.get("/v2/oops").unwrap();
    assert_eq!(r.status, 404);
    // malformed JSON body
    let r = conn.post("/v1/infer/ad", "{\"input\": [1, 2,").unwrap();
    assert_eq!(r.status, 400);
    // non-UTF-8-safe but valid JSON missing the input field
    let r = conn.post("/v1/infer/ad", "{\"x\": 1}").unwrap();
    assert_eq!(r.status, 400);
    // wrong input length
    let r = conn.post("/v1/infer/ad", &infer_body(&[1.0, 2.0])).unwrap();
    assert_eq!(r.status, 400);
    // deep-nesting bomb: hardened minijson answers 400, no stack blowup
    let bomb = format!("{{\"input\": {}1{}}}", "[".repeat(4096), "]".repeat(4096));
    let r = conn.post("/v1/infer/ad", &bomb).unwrap();
    assert_eq!(r.status, 400);
    // the connection survives every 4xx (framing stays intact)
    let models = conn.get("/v1/models").unwrap();
    assert_eq!(models.status, 200);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

#[test]
fn oversized_body_is_rejected() {
    let reg_cfg = RegistryConfig {
        benches: vec!["ad".to_string()],
        ..RegistryConfig::default()
    };
    let registry = Arc::new(ModelRegistry::build(&reg_cfg).unwrap());
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_body_bytes: 1024,
        ..ServeConfig::default()
    };
    let server = serve(Arc::clone(&registry), cfg).unwrap();
    let mut conn = Conn::connect(server.addr()).unwrap();
    let big = infer_body(&vec![0.25f32; 4096]); // way past 1 KiB
    let r = conn.post("/v1/infer/ad", &big).unwrap();
    assert_eq!(r.status, 413);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// Liveness + readiness probes: healthy server answers both; every
/// model reports a closed breaker; readiness flips to 503 once
/// shutdown begins (drain-then-close for load balancers).
#[test]
fn healthz_and_readyz_report_breaker_state() {
    let (registry, server) = start(&["ic", "kws"], BatchPolicy::default());
    let mut conn = Conn::connect(server.addr()).unwrap();

    let h = conn.get("/healthz").unwrap();
    assert_eq!(h.status, 200);
    assert_eq!(h.body.get("ok").unwrap(), &Json::Bool(true));

    let r = conn.get("/readyz").unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(r.body.get("ready").unwrap(), &Json::Bool(true));
    for bench in ["ic", "kws"] {
        let m = r.body.get("models").unwrap().get(bench).unwrap();
        assert_eq!(m.get("ready").unwrap(), &Json::Bool(true));
        assert_eq!(m.get("breaker").unwrap().as_str().unwrap(), "closed");
    }

    // supervision gauges ride /metrics from the start (all zero here)
    let m = conn.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    let ic = m.body.get("models").unwrap().get("ic").unwrap();
    assert_eq!(ic.get("worker_respawns").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(ic.get("breaker_state").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(ic.get("deadline_expired_total").unwrap().as_f64().unwrap(), 0.0);
    assert!(m.body.get("slow_client_closes").is_ok());
    assert!(m.body.get("idle_reaped").is_ok());

    // once shutdown lands, readiness reports not-ready
    let bye = conn.post("/admin/shutdown", "").unwrap();
    assert_eq!(bye.status, 200);
    let mut late = Conn::connect(server.addr());
    if let Ok(conn2) = late.as_mut() {
        // the acceptor may or may not still pick us up mid-shutdown;
        // if it does, readyz must say not-ready
        if let Ok(r) = conn2.get("/readyz") {
            assert_eq!(r.status, 503);
            assert_eq!(r.body.get("ready").unwrap(), &Json::Bool(false));
        }
    }
    drop(conn);
    drop(late);
    server.join().unwrap();
    registry.shutdown();
}

/// Shutdown-race regression (supervised-serving satellite): a request
/// in flight when `POST /admin/shutdown` lands must still get its
/// bit-identical reply — drain-then-close, never a dropped-sender
/// error.
#[test]
fn inflight_request_survives_admin_shutdown() {
    // a long coalescing window keeps the infer in flight while the
    // shutdown lands; the drain must execute it (and the shutdown
    // notify must flush it promptly, not after the full window)
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait_us: 3_000_000,
        ..BatchPolicy::default()
    };
    let (registry, server) = start(&["ad"], policy);
    let addr = server.addr();
    let (input, want) = expected(&registry, "ad", 0);

    let inflight = std::thread::spawn(move || {
        let mut conn = Conn::connect(addr).unwrap();
        conn.post("/v1/infer/ad", &infer_body(&input)).unwrap()
    });
    // let the request reach the batcher queue before shutting down
    std::thread::sleep(std::time::Duration::from_millis(150));
    let mut admin = Conn::connect(addr).unwrap();
    let bye = admin.post("/admin/shutdown", "").unwrap();
    assert_eq!(bye.status, 200);
    drop(admin);

    let resp = inflight.join().expect("in-flight client panicked");
    assert_eq!(resp.status, 200, "in-flight request dropped: {}", resp.body.dumps());
    assert_eq!(
        output_of(&resp.body).unwrap(),
        want,
        "drained reply diverged from run_sample"
    );
    server.join().unwrap();
    registry.shutdown();
}

#[test]
fn shutdown_endpoint_is_clean() {
    let (registry, server) = start(&["ad"], BatchPolicy::default());
    let addr = server.addr();
    let mut conn = Conn::connect(addr).unwrap();

    // answer one real request first
    let (input, want) = expected(&registry, "ad", 0);
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(output_of(&r.body).unwrap(), want);

    let bye = conn.post("/admin/shutdown", "").unwrap();
    assert_eq!(bye.status, 200);
    assert_eq!(bye.body.get("ok").unwrap(), &Json::Bool(true));
    drop(conn);

    // join() must return: acceptor unblocked, handlers drained,
    // batcher workers joined
    server.join().unwrap();
    // post-shutdown, the batcher refuses instead of hanging
    let entry = registry.get("ad").unwrap();
    assert!(entry.batcher().submit(input, 1).is_err());
}

/// Observability round trip (DESIGN.md §9): with tracing on, one
/// served inference stamps a request id into the reply body and
/// leaves its span chain — request, admission, queue_wait, batch_ride
/// — scrapeable from `GET /v1/trace` as chrome://tracing events, with
/// every child span contained by the request envelope.
#[test]
fn trace_spans_and_request_id_round_trip() {
    cwmix::trace::set_enabled(true);
    let (registry, server) = start(&["ad"], BatchPolicy::default());
    let mut conn = Conn::connect(server.addr()).unwrap();

    let (input, want) = expected(&registry, "ad", 0);
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), want);
    let id = r.body.get("request_id").unwrap().as_f64().unwrap();
    assert!(id >= 1.0, "request id must start at 1 (got {id})");

    let t = conn.get("/v1/trace?last=4096").unwrap();
    assert_eq!(t.status, 200);
    assert_eq!(t.body.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let events = t.body.get("traceEvents").unwrap().as_arr().unwrap();
    let mine: Vec<&Json> = events
        .iter()
        .filter(|e| {
            e.get("args").unwrap().get("req").unwrap().as_f64().unwrap() == id
        })
        .collect();
    let name_of = |e: &Json| e.get("name").unwrap().as_str().unwrap().to_string();
    let names: Vec<String> = mine.iter().map(|&e| name_of(e)).collect();
    for need in ["request", "admission", "queue_wait", "batch_ride"] {
        assert!(
            names.iter().any(|n| n == need),
            "span {need} missing for request {id}: {names:?}"
        );
    }
    // children are contained by the request envelope (same µs clock)
    let window = |e: &Json| {
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        (ts, ts + e.get("dur").unwrap().as_f64().unwrap())
    };
    let req_ev = *mine.iter().find(|&&e| name_of(e) == "request").unwrap();
    let (r0, r1) = window(req_ev);
    for &e in &mine {
        if name_of(e) == "request" {
            continue;
        }
        let (c0, c1) = window(e);
        // 1 ms slack: start/end are captured on different threads
        assert!(
            c0 >= r0 - 1_000.0 && c1 <= r1 + 1_000.0,
            "span {} [{c0}, {c1}] escapes request [{r0}, {r1}]",
            name_of(e)
        );
    }
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// `GET /metrics?format=prometheus` renders the text exposition: one
/// `# TYPE` header per family, per-model labels, and the latency
/// summary quantiles — while the default JSON route stays unchanged.
#[test]
fn prometheus_exposition_over_http() {
    let (registry, server) = start(&["ad"], BatchPolicy::default());
    let mut conn = Conn::connect(server.addr()).unwrap();

    let (input, _) = expected(&registry, "ad", 0);
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200);

    let (status, text) = conn.get_text("/metrics?format=prometheus").unwrap();
    assert_eq!(status, 200);
    assert!(
        text.contains("# TYPE cwmix_requests_total counter"),
        "missing requests family:\n{text}"
    );
    assert!(text.contains("cwmix_requests_total{model=\"ad\"} 1"));
    assert!(text.contains("cwmix_latency_us{model=\"ad\",quantile=\"0.99\"}"));
    assert!(text.contains("cwmix_batch_size_bucket{model=\"ad\",le=\"+Inf\"}"));
    assert!(text.contains("cwmix_uptime_seconds"));
    assert!(text.contains("cwmix_model_bytes{model=\"ad\"}"));
    // the JSON default is untouched
    let m = conn.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    assert!(m.body.get("models").is_ok());
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}
