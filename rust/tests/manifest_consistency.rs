//! Manifests (emitted by aot.py) must agree with the Rust-side models:
//! geometry invariants, LUT equality, slot shapes.  Skips cleanly
//! unless `make artifacts` has run.

use std::path::Path;

use cwmix::energy::{CostLut, CYCLES_PER_MAC, ENERGY_PJ_PER_MAC};
use cwmix::models::Manifest;

const BENCHES: [&str; 4] = ["ic", "kws", "vww", "ad"];

fn artifacts() -> &'static Path {
    Path::new("artifacts")
}

mod common;
use common::has_artifacts;

#[test]
fn all_manifests_load_and_validate() {
    if !has_artifacts() {
        return;
    }
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        m.validate().unwrap_or_else(|e| panic!("{b}: {e}"));
        assert_eq!(m.benchmark, b);
        assert_eq!(m.precisions, vec![2, 4, 8]);
    }
}

#[test]
fn lut_matches_rust_constants() {
    if !has_artifacts() {
        return;
    }
    // single-source-of-truth check: python energy_lut == rust lut.rs
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        let r = CostLut::default();
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (m.lut.energy_pj[i][j] - ENERGY_PJ_PER_MAC[i][j]).abs() < 1e-5,
                    "{b} energy LUT drift at {i},{j}"
                );
                assert!(
                    (m.lut.cycles[i][j] - CYCLES_PER_MAC[i][j]).abs() < 1e-7,
                    "{b} cycle LUT drift at {i},{j}"
                );
                // python computes in f64 then casts; allow 1 ULP
                assert!((m.lut.energy_pj[i][j] - r.energy_pj[i][j]).abs() < 1e-6);
            }
        }
    }
}

#[test]
fn geometry_ops_formula_holds() {
    if !has_artifacts() {
        return;
    }
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        for l in m.qlayers() {
            let cin_g = if l.kind == "dwconv" { 1 } else { l.cin };
            if l.kind == "fc" {
                assert_eq!(l.ops, l.cout * l.cin, "{b}/{}", l.name);
                assert_eq!(l.weights_per_channel, l.cin);
            } else {
                assert_eq!(
                    l.ops,
                    l.out_h * l.out_w * l.cout * cin_g * l.kx * l.ky,
                    "{b}/{}",
                    l.name
                );
                assert_eq!(l.weights_per_channel, cin_g * l.kx * l.ky);
            }
        }
    }
}

#[test]
fn dataset_geometry_matches_manifest() {
    if !has_artifacts() {
        return;
    }
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        let ds = cwmix::data::make_dataset(b, cwmix::data::Split::Train, 8, 0);
        assert_eq!(ds.feat, m.input_shape, "{b}");
        if m.loss == "ce" {
            assert_eq!(ds.n_classes, m.n_classes, "{b}");
        }
    }
}

#[test]
fn param_slots_cover_all_quant_layers() {
    if !has_artifacts() {
        return;
    }
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        let names: Vec<&str> = m.params.iter().map(|s| s.name.as_str()).collect();
        for l in m.qlayers() {
            assert!(names.contains(&format!("{}.w", l.name).as_str()), "{b}/{}", l.name);
            assert!(names.contains(&format!("{}.alpha", l.name).as_str()));
            // weight slot shape product = cout * weights_per_channel
            let slot = m
                .params
                .iter()
                .find(|s| s.name == format!("{}.w", l.name))
                .unwrap();
            assert_eq!(slot.len(), l.cout * l.weights_per_channel, "{b}/{}", l.name);
        }
    }
}

#[test]
fn graph_files_exist() {
    if !has_artifacts() {
        return;
    }
    for b in BENCHES {
        let m = Manifest::load(artifacts(), b).unwrap();
        for g in [
            "train_w_hard",
            "search_theta_cw",
            "search_theta_lw",
            "search_w_cw",
            "search_w_lw",
            "eval",
            "infer",
        ] {
            assert!(m.graph_path(g).exists(), "{b}/{g} missing");
        }
    }
}
