//! Batch-plane equivalence contract (ISSUE 4): batched execution is
//! **bit-identical** to per-sample execution for every batch size, on
//! all four zoo geometries × both backends × all nine `(p_x, p_w)`
//! combos — the refactor changes *when* work happens (planes quantized
//! once per batch, weight words decoded once and ridden across all
//! columns), never *what* is computed.
//!
//! Pure Rust: builtin zoo + deterministic synthetic state, no
//! artifacts.  Batch sizes cover the serve default `max_batch` (8), a
//! ragged non-divisor (7), the smallest coalesced batch (2) and the
//! degenerate batch of one.  The striped-assignment spot check also
//! anchors each geometry against the out-of-engine oracle
//! `mpic::exec::run_sample`, and the sharded entry points
//! (`run_samples` / `run_batch_threads`) are asserted invariant under
//! batch-chunk fan-out.

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{ExecPlan, KernelBackend, PackedBackend, ReferenceBackend};
use cwmix::models::zoo::{builtin_manifest, stripy_assignment, synthetic_state};
use cwmix::quant::Assignment;

/// The serve-layer default `BatchPolicy::max_batch`.
const MAX_BATCH: usize = 8;

const BATCH_SIZES: [usize; 4] = [1, 2, 7, MAX_BATCH];

/// All nine `(p_x, p_w)` fixed combos on `bench`, both backends, every
/// batch size bit-exact vs per-sample `run_sample`.
fn check_all_nine_combos_batched(bench: &str) {
    let manifest = builtin_manifest(bench).unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let feat = manifest.feat_len();
    let ds = make_dataset(bench, Split::Test, MAX_BATCH, 7);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
    for xb in [2u32, 4, 8] {
        for wb in [2u32, 4, 8] {
            let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), wb, xb);
            let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
            for backend in [&ReferenceBackend as &dyn KernelBackend, &PackedBackend] {
                let plan = ExecPlan::compile(&model, &manifest.lut, backend).unwrap();
                let mut arena = plan.arena();
                let want: Vec<Vec<f32>> = samples
                    .iter()
                    .map(|s| plan.run_sample(&mut arena, s).unwrap())
                    .collect();
                let mut batch_arena = plan.batch_arena(MAX_BATCH);
                for bsz in BATCH_SIZES {
                    let got = plan
                        .run_batch_planes(&mut batch_arena, &samples[..bsz])
                        .unwrap();
                    assert_eq!(
                        got.as_slice(),
                        &want[..bsz],
                        "{bench} w{wb}x{xb} {}: batch of {bsz} diverged from \
                         per-sample run_sample",
                        backend.name()
                    );
                }
            }
        }
    }
}

#[test]
fn batch_sizes_bit_exact_all_combos_ic() {
    check_all_nine_combos_batched("ic");
}

#[test]
fn batch_sizes_bit_exact_all_combos_kws() {
    check_all_nine_combos_batched("kws");
}

#[test]
fn batch_sizes_bit_exact_all_combos_vww() {
    check_all_nine_combos_batched("vww");
}

#[test]
fn batch_sizes_bit_exact_all_combos_ad() {
    check_all_nine_combos_batched("ad");
}

/// Striped per-channel assignments (fragmented sub-conv groups across
/// all three precisions, residual joins, depthwise chains) under every
/// batch size — anchored against the scalar oracle
/// `mpic::exec::run_sample` (the oracle interprets slowly, so it
/// anchors the first two samples; the rest compare against the
/// engine's per-sample path, which those two tie to the oracle).
#[test]
fn striped_assignments_batched_match_oracle() {
    for bench in ["ic", "kws", "vww", "ad"] {
        let manifest = builtin_manifest(bench).unwrap();
        let (params, bn) = synthetic_state(&manifest, 0);
        let a = stripy_assignment(&manifest);
        let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, MAX_BATCH, 11);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let oracle: Vec<Vec<f32>> = samples[..2]
            .iter()
            .map(|s| cwmix::mpic::run_sample(&model, s, &manifest.lut).unwrap().0)
            .collect();
        for backend in [&ReferenceBackend as &dyn KernelBackend, &PackedBackend] {
            let plan = ExecPlan::compile(&model, &manifest.lut, backend).unwrap();
            let mut arena = plan.arena();
            let want: Vec<Vec<f32>> = samples
                .iter()
                .map(|s| plan.run_sample(&mut arena, s).unwrap())
                .collect();
            assert_eq!(
                &want[..2],
                oracle.as_slice(),
                "{bench} {}: per-sample path diverged from the oracle",
                backend.name()
            );
            let mut batch_arena = plan.batch_arena(MAX_BATCH);
            for bsz in BATCH_SIZES {
                let got = plan
                    .run_batch_planes(&mut batch_arena, &samples[..bsz])
                    .unwrap();
                assert_eq!(
                    got.as_slice(),
                    &want[..bsz],
                    "{bench} {}: batch of {bsz} diverged per-sample",
                    backend.name()
                );
            }
        }
    }
}

/// The sharded entry points produce identical outputs whatever the
/// worker count — sharding is by batch-chunk now, and chunk boundaries
/// must be invisible.
#[test]
fn batch_chunk_sharding_invariant() {
    let manifest = builtin_manifest("kws").unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = stripy_assignment(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let feat = manifest.feat_len();
    let n = 13; // ragged against every chunking
    let ds = make_dataset("kws", Split::Test, n, 5);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
    let seq = plan.run_samples(&samples, 1).unwrap();
    for threads in [2usize, 3, 8] {
        let par = plan.run_samples(&samples, threads).unwrap();
        assert_eq!(seq, par, "threads={threads}");
    }
    let mut arena = plan.arena();
    for (s, o) in samples.iter().zip(&seq) {
        assert_eq!(&plan.run_sample(&mut arena, s).unwrap(), o);
    }
}

/// Batch-plane validation: oversized batches and wrong-length samples
/// are errors, not panics or corruption.
#[test]
fn batch_plane_rejects_bad_batches() {
    let manifest = builtin_manifest("ad").unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), 8, 8);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let feat = manifest.feat_len();
    let xv = vec![0.0f32; feat];
    let shortv = vec![0.0f32; feat - 1];
    let (x, short): (&[f32], &[f32]) = (&xv, &shortv);
    let mut arena = plan.batch_arena(2);
    assert_eq!(arena.capacity(), 2);
    // over capacity
    let err = plan.run_batch_planes(&mut arena, &[x, x, x]).unwrap_err();
    assert!(err.to_string().contains("capacity"), "{err}");
    // wrong feature length anywhere in the batch
    assert!(plan.run_batch_planes(&mut arena, &[x, short]).is_err());
    // empty batch is a no-op
    assert!(plan.run_batch_planes(&mut arena, &[]).unwrap().is_empty());
    // the arena stays usable after rejections
    assert_eq!(plan.run_batch_planes(&mut arena, &[x, x]).unwrap().len(), 2);
}
