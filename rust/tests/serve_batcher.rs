//! Batcher coverage: coalescing respects `max_batch`, a lone request
//! flushes at `max_wait_us`, the shed path replies under a full queue,
//! batched results are bit-identical to per-sample `ExecPlan::run_sample`
//! calls — the engine-equivalence contract extended through the serve
//! path — and the supervised lifecycle holds: drain semantics at
//! shutdown (every admitted sender gets a reply or an explicit error,
//! never a hang) and panic → respawn → bit-identical recovery.
//!
//! Pure Rust: builtin zoo + synthetic state, no artifacts, no sockets
//! (the HTTP layer has its own end-to-end test; the socket-level chaos
//! scenarios live in `serve_chaos.rs`).

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{ExecPlan, PackedBackend};
use cwmix::models::zoo::{builtin_manifest, stripy_assignment, synthetic_state};
use cwmix::serve::batcher::{ReplyError, ReplyResult};
use cwmix::serve::{BatchPolicy, Batcher, Faults, Metrics, SubmitError, WorkerOpts};

/// Compile the stripy-packed plan for one bench (the server default).
fn plan_for(bench: &str) -> Arc<ExecPlan> {
    let manifest = builtin_manifest(bench).unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = stripy_assignment(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    Arc::new(ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap())
}

/// Distinct samples from the bench's synthetic test split.
fn samples(bench: &str, n: usize, feat: usize) -> Vec<Vec<f32>> {
    let ds = make_dataset(bench, Split::Test, n, 3);
    (0..n).map(|i| ds.x[i * feat..(i + 1) * feat].to_vec()).collect()
}

fn recv_ok(rx: &Receiver<ReplyResult>) -> (Vec<f32>, usize) {
    let reply = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("batcher dropped a request")
        .expect("engine error");
    (reply.output, reply.batch)
}

/// Coalescing respects `max_batch`, and batched outputs are
/// bit-identical to per-sample `run_sample` calls.
#[test]
fn coalesces_up_to_max_batch_bit_identically() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let metrics = Arc::new(Metrics::default());
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait_us: 200_000, // long window: all submits land inside it
        queue_cap: 64,
        threads: 2,
        ..BatchPolicy::default()
    };
    let batcher = Batcher::start(
        Arc::clone(&plan),
        Arc::clone(&metrics),
        policy,
        WorkerOpts::default(),
    );

    let inputs = samples("ad", 10, feat);
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| batcher.submit(x.clone(), i as u64 + 1).expect("admitted"))
        .collect();

    let mut arena = plan.arena();
    let mut max_seen = 0;
    for (x, rx) in inputs.iter().zip(&rxs) {
        let (out, batch) = recv_ok(rx);
        assert!(batch <= 4, "batch {batch} exceeds max_batch");
        max_seen = max_seen.max(batch);
        let want = plan.run_sample(&mut arena, x).unwrap();
        assert_eq!(out, want, "batched output != run_sample");
    }
    // 10 requests admitted inside a 200 ms window against max_batch=4
    // must have coalesced at least once
    assert!(max_seen >= 2, "no coalescing observed (max batch {max_seen})");
    assert_eq!(metrics.requests(), 10);
    assert_eq!(metrics.shed(), 0);
    batcher.shutdown();
}

/// A lone request flushes after ~max_wait_us even though the batch
/// never fills.
#[test]
fn lone_request_flushes_at_max_wait() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let metrics = Arc::new(Metrics::default());
    let policy = BatchPolicy {
        max_batch: 64,
        max_wait_us: 20_000, // 20 ms
        queue_cap: 8,
        threads: 1,
        ..BatchPolicy::default()
    };
    let batcher = Batcher::start(Arc::clone(&plan), metrics, policy, WorkerOpts::default());

    let x = samples("ad", 1, feat).remove(0);
    let t0 = Instant::now();
    let rx = batcher.submit(x.clone(), 1).unwrap();
    let (out, batch) = recv_ok(&rx);
    let waited = t0.elapsed();
    assert_eq!(batch, 1);
    assert!(
        waited < Duration::from_secs(10),
        "lone request stalled {waited:?} (max_wait flush broken)"
    );
    let mut arena = plan.arena();
    assert_eq!(out, plan.run_sample(&mut arena, &x).unwrap());
    batcher.shutdown();
}

/// Submits against a full queue shed immediately with `Overloaded`
/// (and are counted), instead of growing the queue without bound.
#[test]
fn full_queue_sheds_with_explicit_reply() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let metrics = Arc::new(Metrics::default());
    let policy = BatchPolicy {
        max_batch: 8,
        // the worker holds the first request for the whole window, so
        // the queue stays populated while we overfill it
        max_wait_us: 2_000_000,
        queue_cap: 2,
        threads: 1,
        ..BatchPolicy::default()
    };
    let batcher = Batcher::start(
        Arc::clone(&plan),
        Arc::clone(&metrics),
        policy,
        WorkerOpts::default(),
    );

    let inputs = samples("ad", 3, feat);
    let rx1 = batcher.submit(inputs[0].clone(), 1).unwrap();
    let rx2 = batcher.submit(inputs[1].clone(), 2).unwrap();
    // queue now holds 2 = queue_cap pending requests (the worker is
    // inside its coalescing window, nothing drained yet)
    let shed = batcher.submit(inputs[2].clone(), 3);
    assert!(
        matches!(shed, Err(SubmitError::Overloaded)),
        "expected Overloaded, got {shed:?}"
    );
    assert_eq!(metrics.shed(), 1);

    // shutdown drains: the two admitted requests still get answers
    batcher.shutdown();
    let (out1, _) = recv_ok(&rx1);
    let (out2, _) = recv_ok(&rx2);
    let mut arena = plan.arena();
    assert_eq!(out1, plan.run_sample(&mut arena, &inputs[0]).unwrap());
    assert_eq!(out2, plan.run_sample(&mut arena, &inputs[1]).unwrap());
}

/// Wrong-length inputs are refused at the door (they never poison a
/// coalesced batch) and shutdown refuses new work.
#[test]
fn bad_input_and_shutdown_refusals() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let batcher = Batcher::start(
        Arc::clone(&plan),
        Arc::new(Metrics::default()),
        BatchPolicy::default(),
        WorkerOpts::default(),
    );
    match batcher.submit(vec![0.0; feat + 1], 1) {
        Err(SubmitError::BadInput(_)) => {}
        other => panic!("expected BadInput, got {other:?}"),
    }
    batcher.shutdown();
    match batcher.submit(vec![0.0; feat], 2) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
}

/// The coalesced path equals N independent single-sample requests: the
/// same inputs through a `max_batch = 1` batcher (every request is its
/// own one-sample engine call) and through a coalescing batcher
/// produce identical outputs — the serving-level statement of the
/// batch-plane bit-exactness contract.
#[test]
fn coalesced_equals_independent_single_requests() {
    let plan = plan_for("kws");
    let feat = plan.feat();
    let n = 10;
    let inputs = samples("kws", n, feat);

    // independent: no coalescing possible, every reply rode batch 1
    let solo_policy = BatchPolicy {
        max_batch: 1,
        max_wait_us: 1_000,
        queue_cap: 64,
        threads: 1,
        ..BatchPolicy::default()
    };
    let solo = Batcher::start(
        Arc::clone(&plan),
        Arc::new(Metrics::default()),
        solo_policy,
        WorkerOpts::default(),
    );
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| solo.submit(x.clone(), i as u64 + 1).expect("admitted"))
        .collect();
    let independent: Vec<Vec<f32>> = rxs
        .iter()
        .map(|rx| {
            let (out, batch) = recv_ok(rx);
            assert_eq!(batch, 1, "max_batch=1 must never coalesce");
            out
        })
        .collect();
    solo.shutdown();

    // coalescing: a long window so the batch actually fills
    let coal_policy = BatchPolicy {
        max_batch: n,
        max_wait_us: 200_000,
        queue_cap: 64,
        threads: 1,
        ..BatchPolicy::default()
    };
    let metrics = Arc::new(Metrics::default());
    let coal = Batcher::start(
        Arc::clone(&plan),
        Arc::clone(&metrics),
        coal_policy,
        WorkerOpts::default(),
    );
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| coal.submit(x.clone(), i as u64 + 1).expect("admitted"))
        .collect();
    let mut max_seen = 0;
    for (rx, want) in rxs.iter().zip(&independent) {
        let (out, batch) = recv_ok(rx);
        max_seen = max_seen.max(batch);
        assert_eq!(&out, want, "coalesced output != independent request");
    }
    assert!(max_seen >= 2, "no coalescing observed (max batch {max_seen})");
    // the batch-efficiency gauges saw the coalesced traffic
    assert!(metrics.mean_ridden_batch() >= 2.0);
    assert!(metrics.batch_plane_hit_ratio() > 0.0);
    coal.shutdown();
}

/// The serve path is bit-identical on a conv model too (ad above is
/// FC-only): kws exercises conv + depthwise + the packed gather path
/// under threaded batch execution.
#[test]
fn conv_model_bit_identical_through_batcher() {
    let plan = plan_for("kws");
    let feat = plan.feat();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait_us: 100_000,
        queue_cap: 64,
        threads: 4,
        ..BatchPolicy::default()
    };
    let batcher = Batcher::start(
        Arc::clone(&plan),
        Arc::new(Metrics::default()),
        policy,
        WorkerOpts::default(),
    );
    let inputs = samples("kws", 8, feat);
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| batcher.submit(x.clone(), i as u64 + 1).expect("admitted"))
        .collect();
    let mut arena = plan.arena();
    for (x, rx) in inputs.iter().zip(&rxs) {
        let (out, _) = recv_ok(rx);
        assert_eq!(out, plan.run_sample(&mut arena, x).unwrap());
    }
    batcher.shutdown();
}

/// Drain semantics (supervised-serving satellite): enqueue N requests
/// into a long coalescing window, trigger shutdown mid-batch, and
/// assert **every** sender receives either a result or an explicit
/// shutting-down error — never a hang, never a silently dropped
/// sender.
#[test]
fn shutdown_mid_batch_answers_every_sender() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let policy = BatchPolicy {
        max_batch: 3, // several drain iterations for 8 requests
        // a window long enough that shutdown lands mid-coalescing
        max_wait_us: 5_000_000,
        queue_cap: 64,
        threads: 1,
        ..BatchPolicy::default()
    };
    let batcher = Batcher::start(
        Arc::clone(&plan),
        Arc::new(Metrics::default()),
        policy,
        WorkerOpts::default(),
    );

    let n = 8;
    let inputs = samples("ad", n, feat);
    let rxs: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, x)| batcher.submit(x.clone(), i as u64 + 1).expect("admitted"))
        .collect();
    batcher.shutdown();

    let mut arena = plan.arena();
    for (i, (x, rx)) in inputs.iter().zip(&rxs).enumerate() {
        // the bounded recv is the no-hang assertion
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(reply)) => {
                let want = plan.run_sample(&mut arena, x).unwrap();
                assert_eq!(reply.output, want, "request {i}: drained reply diverged");
            }
            Ok(Err(ReplyError::ShuttingDown)) => {}
            Ok(Err(e)) => panic!("request {i}: unexpected error {e}"),
            Err(e) => panic!("request {i}: sender dropped without a reply ({e})"),
        }
    }
}

/// Supervision at the batcher level: an injected engine panic fails
/// only the in-flight batch (those riders see an explicit failure, not
/// a hang), the worker respawns, and subsequent replies are
/// bit-identical to `run_sample` — the recovery contract
/// `serve_chaos.rs` re-proves over sockets.
#[test]
fn worker_panic_respawns_and_recovers_bit_identically() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let metrics = Arc::new(Metrics::default());
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait_us: 1_000,
        queue_cap: 64,
        threads: 1,
        ..BatchPolicy::default()
    };
    let opts = WorkerOpts {
        model: "ad".to_string(),
        faults: Arc::new(Faults::parse("engine_panic:ad:once", 0).unwrap()),
        ..WorkerOpts::default()
    };
    let batcher = Batcher::start(Arc::clone(&plan), Arc::clone(&metrics), policy, opts);

    let inputs = samples("ad", 2, feat);
    // first request rides the panicking batch: its reply sender dies
    // with the worker stack — an explicit disconnect, not a hang
    let rx = batcher.submit(inputs[0].clone(), 1).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)) {
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {}
        other => panic!("expected a dropped sender from the panicked batch, got {other:?}"),
    }

    // the supervisor respawns the worker; the next request must
    // succeed bit-identically (fresh arena, same plan)
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        assert!(Instant::now() < deadline, "worker never respawned");
        if metrics.worker_respawns() >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let rx = batcher.submit(inputs[1].clone(), 2).unwrap();
    let (out, _) = recv_ok(&rx);
    let mut arena = plan.arena();
    assert_eq!(out, plan.run_sample(&mut arena, &inputs[1]).unwrap());
    assert_eq!(metrics.worker_panics(), 1);
    assert_eq!(batcher.supervision().panics(), 1);
    batcher.shutdown();
}

/// Deadline enforcement at dequeue: a stalled worker ages the queue
/// past `max_wait + infer_budget`, and the aged requests answer
/// `Expired` (the HTTP 504 path) without riding a batch.
#[test]
fn stalled_worker_expires_queued_requests() {
    let plan = plan_for("ad");
    let feat = plan.feat();
    let metrics = Arc::new(Metrics::default());
    let policy = BatchPolicy {
        max_batch: 1, // the stall victim rides alone; the rest queue up
        max_wait_us: 1_000,
        queue_cap: 64,
        threads: 1,
        infer_budget_us: 20_000, // 21 ms deadline window
    };
    let opts = WorkerOpts {
        model: "ad".to_string(),
        // the first batch stalls 300 ms — far past every queued
        // request's deadline
        faults: Arc::new(Faults::parse("engine_stall:ad:once:300", 0).unwrap()),
        ..WorkerOpts::default()
    };
    let batcher = Batcher::start(Arc::clone(&plan), Arc::clone(&metrics), policy, opts);

    let inputs = samples("ad", 3, feat);
    let rx_stalled = batcher.submit(inputs[0].clone(), 1).unwrap();
    let rx_a = batcher.submit(inputs[1].clone(), 2).unwrap();
    let rx_b = batcher.submit(inputs[2].clone(), 3).unwrap();

    // the stalled batch itself still completes (slow, not dead)
    let (out, _) = recv_ok(&rx_stalled);
    let mut arena = plan.arena();
    assert_eq!(out, plan.run_sample(&mut arena, &inputs[0]).unwrap());

    // the queued requests aged past their deadline during the stall
    for (i, rx) in [rx_a, rx_b].iter().enumerate() {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Err(ReplyError::Expired)) => {}
            other => panic!("queued request {i}: expected Expired, got {other:?}"),
        }
    }
    assert_eq!(metrics.deadline_expired(), 2);
    batcher.shutdown();
}
