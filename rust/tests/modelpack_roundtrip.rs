//! Modelpack contract (ISSUE 5): a `.cwm` artifact round-trips the
//! *entire* compile output — `from_modelpack` executions are
//! **bit-identical** to the fresh `ExecPlan::compile` they came from,
//! across all four zoo models × all three backends × striped
//! assignments (the `simd` backend shares the packed flash image and
//! re-resolves its dispatch tier on the loading host) —
//! and hostile bytes (truncations at every boundary, corrupted
//! checksums, version skew, offsets past EOF, semantic corruption)
//! always yield typed [`PackError`]s, never panics.
//!
//! Pure Rust: builtin zoo + deterministic synthetic state.

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{
    inspect, read_provenance, ExecPlan, FusionStats, KernelBackend, PackedBackend,
    Provenance, ReferenceBackend, SimdBackend,
};
use cwmix::modelpack::{self, PackError};
use cwmix::models::zoo::{
    builtin_manifest, stripy_assignment, synthetic_state, BENCHES,
};
use cwmix::quant::Assignment;

fn backends() -> [&'static dyn KernelBackend; 3] {
    [&ReferenceBackend, &PackedBackend, &SimdBackend]
}

/// Compile `bench` with the striped assignment (the adversarial case:
/// fragmented sub-conv groups across all three precisions).
fn compiled(bench: &str, backend: &dyn KernelBackend) -> (deploy::DeployedModel, ExecPlan) {
    let manifest = builtin_manifest(bench).unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = stripy_assignment(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let plan = ExecPlan::compile(&model, &manifest.lut, backend).unwrap();
    (model, plan)
}

#[test]
fn roundtrip_bit_identical_all_models_all_backends() {
    for bench in BENCHES {
        let manifest = builtin_manifest(bench).unwrap();
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, 4, 3);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        for backend in backends() {
            let (_, plan) = compiled(bench, backend);
            let pack = plan.to_modelpack();
            let loaded = ExecPlan::from_modelpack(&pack)
                .unwrap_or_else(|e| panic!("{bench}/{}: {e}", backend.name()));

            // metadata round-trips (the tier is re-resolved on load,
            // which on one host yields the same answer)
            assert_eq!(loaded.bench(), plan.bench());
            assert_eq!(loaded.backend_name(), plan.backend_name());
            assert_eq!(loaded.kernel_tier(), plan.kernel_tier());
            assert_eq!(loaded.feat(), plan.feat());
            assert_eq!(loaded.out_len(), plan.out_len());
            assert_eq!(loaded.weight_bytes(), plan.weight_bytes());

            // the input-independent cost round-trips exactly
            assert_eq!(loaded.cost().total_cycles(), plan.cost().total_cycles());
            assert_eq!(
                loaded.cost().total_energy_pj(),
                plan.cost().total_energy_pj()
            );
            assert_eq!(loaded.cost().total_macs(), plan.cost().total_macs());
            assert_eq!(loaded.cost().total_mem_bytes(), plan.cost().total_mem_bytes());
            assert_eq!(
                loaded.batch_cost(8).saved_weight_bytes,
                plan.batch_cost(8).saved_weight_bytes
            );

            // execution is bit-identical, per sample and batched
            let want = plan.run_samples(&samples, 1).unwrap();
            let got = loaded.run_samples(&samples, 1).unwrap();
            assert_eq!(got, want, "{bench}/{}: batched outputs diverged", backend.name());
            let mut arena = loaded.batch_arena(samples.len());
            let planes = loaded.run_batch_planes(&mut arena, &samples).unwrap();
            assert_eq!(planes, want, "{bench}/{}: batch planes diverged", backend.name());
        }
    }
}

#[test]
fn inspect_totals_match_cost_model_and_deployment() {
    for bench in BENCHES {
        for backend in backends() {
            let (model, plan) = compiled(bench, backend);
            let rep = inspect(&plan.to_modelpack()).unwrap();
            // the per-channel accounting reconstructed from the stored
            // groups equals the §III-C transform's Eq. (7) bytes AND the
            // cost model's packed-weight traffic charge
            assert_eq!(rep.packed_total(), model.packed_bytes(), "{bench}");
            assert!(rep.matches_cost_model(), "{bench}/{}", backend.name());
            let f32_total: usize =
                model.qlayers().map(|l| l.qweights.len() * 4).sum();
            assert_eq!(rep.f32_total(), f32_total);
            assert_eq!(rep.int8_total() * 4, f32_total);
            // histogram covers every channel of every layer
            for (il, dl) in rep.layers.iter().zip(model.qlayers()) {
                assert_eq!(il.channels_at.iter().sum::<usize>(), dl.spec.cout);
                assert_eq!(il.name, dl.spec.name);
            }
            assert_eq!(rep.bench, bench);
            assert_eq!(rep.backend, backend.name());
            // packed weights are genuinely sub-byte: the headline claim
            assert!(rep.packed_total() < rep.int8_total(), "{bench}");
        }
    }
}

#[test]
fn truncation_at_every_boundary_is_typed_error() {
    let (_, plan) = compiled("kws", &PackedBackend);
    let pack = plan.to_modelpack();
    // every section boundary, the header/table edges, and a stride of
    // interior cuts (a full per-byte sweep is O(n²) in checksum work)
    let mut cuts: Vec<usize> = vec![0, 1, 7, 8, 24, 31, 32, 39, 40, pack.len() - 1];
    let container = modelpack::Container::parse(&pack).unwrap();
    for s in &container.sections {
        cuts.extend([s.off, s.off + 1, s.off + s.len]);
    }
    cuts.extend((0..pack.len()).step_by(997));
    for cut in cuts {
        let cut = cut.min(pack.len() - 1);
        let err = ExecPlan::from_modelpack(&pack[..cut])
            .err()
            .unwrap_or_else(|| panic!("cut {cut} loaded"));
        assert!(
            matches!(
                err,
                PackError::Truncated { .. }
                    | PackError::BadMagic
                    | PackError::LengthMismatch { .. }
            ),
            "cut {cut}: unexpected {err}"
        );
    }
}

#[test]
fn corrupted_bytes_and_bad_headers_are_typed_errors() {
    let (_, plan) = compiled("ad", &PackedBackend);
    let pack = plan.to_modelpack();

    // flipped payload byte without resealing → checksum mismatch
    let mut bad = pack.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x55;
    assert!(matches!(
        ExecPlan::from_modelpack(&bad).unwrap_err(),
        PackError::ChecksumMismatch { .. }
    ));

    // bad magic
    let mut bad = pack.clone();
    bad[0] = b'!';
    modelpack::reseal(&mut bad);
    assert_eq!(ExecPlan::from_modelpack(&bad).unwrap_err(), PackError::BadMagic);

    // major version skew (resealed, so only the version differs)
    let mut bad = pack.clone();
    bad[8] = 7;
    modelpack::reseal(&mut bad);
    assert!(matches!(
        ExecPlan::from_modelpack(&bad).unwrap_err(),
        PackError::VersionSkew { major: 7, .. }
    ));

    // minor version skew is forward-compatible
    let mut ok = pack.clone();
    ok[10] = 42;
    modelpack::reseal(&mut ok);
    assert!(ExecPlan::from_modelpack(&ok).is_ok());

    // unknown flag bits are an error (they mark unskippable changes)
    let mut bad = pack.clone();
    bad[12] = 0x80;
    modelpack::reseal(&mut bad);
    assert!(matches!(
        ExecPlan::from_modelpack(&bad).unwrap_err(),
        PackError::UnsupportedFlags(_)
    ));

    // a section offset pushed past EOF
    let mut bad = pack.clone();
    let entry_off = modelpack::HEADER_LEN + 8;
    bad[entry_off..entry_off + 8].copy_from_slice(&(1u64 << 42).to_le_bytes());
    modelpack::reseal(&mut bad);
    assert!(matches!(
        ExecPlan::from_modelpack(&bad).unwrap_err(),
        PackError::OffsetOutOfRange { .. }
    ));
}

#[test]
fn semantic_corruption_never_panics() {
    // flip each byte of the PLAN and META sections in turn (resealing
    // the checksum so the corruption reaches the semantic validators):
    // the loader must return SOME error or a plan whose execution was
    // proven safe by validation — it must never panic.  Exhaustive over
    // the small ad model's sections.
    let (_, plan) = compiled("ad", &ReferenceBackend);
    let pack = plan.to_modelpack();
    let container = modelpack::Container::parse(&pack).unwrap();
    let mut targets = Vec::new();
    for kind in [modelpack::SECTION_META, modelpack::SECTION_PLAN] {
        let s = container.find(kind).unwrap();
        targets.extend(s.off..s.off + s.len);
    }
    for pos in targets {
        let mut bad = pack.clone();
        bad[pos] ^= 0x01;
        modelpack::reseal(&mut bad);
        // Ok or Err both fine; what is being asserted is "no panic"
        // (and, when it loads, that running it stays safe)
        if let Ok(p) = ExecPlan::from_modelpack(&bad) {
            let feat = p.feat();
            if feat == plan.feat() {
                let ds = make_dataset("ad", Split::Test, 1, 0);
                let mut arena = p.arena();
                let _ = p.run_sample(&mut arena, &ds.x[..feat]);
            }
        }
    }
}

#[test]
fn unknown_sections_are_skipped_on_load() {
    let (_, plan) = compiled("ic", &PackedBackend);
    let pack = plan.to_modelpack();
    let container = modelpack::Container::parse(&pack).unwrap();
    // re-assemble with an extra future-kind section appended
    let mut sections: Vec<(u32, Vec<u8>)> = container
        .sections
        .iter()
        .map(|s| (s.kind, container.section(s.kind).unwrap().to_vec()))
        .collect();
    sections.push((777, b"a section from a future writer".to_vec()));
    let future = modelpack::assemble(&sections);
    let loaded = ExecPlan::from_modelpack(&future).unwrap();

    let manifest = builtin_manifest("ic").unwrap();
    let feat = manifest.feat_len();
    let ds = make_dataset("ic", Split::Test, 1, 0);
    let mut arena = plan.arena();
    let want = plan.run_sample(&mut arena, &ds.x[..feat]).unwrap();
    let mut arena = loaded.arena();
    let got = loaded.run_sample(&mut arena, &ds.x[..feat]).unwrap();
    assert_eq!(got, want, "future-section pack diverged");
}

#[test]
fn provenance_roundtrips_and_guards_the_registry_cold_start() {
    use cwmix::serve::{ModelRegistry, RegistryConfig};

    let (_, plan) = compiled("ad", &PackedBackend);
    // plain packs carry no provenance; provenance'd packs round-trip it
    // and still load + execute
    assert_eq!(read_provenance(&plan.to_modelpack()).unwrap(), None);
    let prov = Provenance { assignment: "stripy".to_string(), seed: 0 };
    let pack = plan.to_modelpack_with(Some(&prov));
    assert_eq!(read_provenance(&pack).unwrap(), Some(prov.clone()));
    assert_eq!(inspect(&pack).unwrap().provenance, Some(prov.clone()));
    let loaded = ExecPlan::from_modelpack(&pack).unwrap();
    let ds = make_dataset("ad", Split::Test, 1, 0);
    let feat = plan.feat();
    let mut arena = plan.arena();
    let want = plan.run_sample(&mut arena, &ds.x[..feat]).unwrap();
    let mut arena = loaded.arena();
    assert_eq!(loaded.run_sample(&mut arena, &ds.x[..feat]).unwrap(), want);

    // registry: a matching pack cold-starts; a provenance mismatch is
    // refused and falls back to compilation (the numerics guard)
    let dir = std::env::temp_dir().join(format!("cwm_prov_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = RegistryConfig {
        benches: vec!["ad".to_string()],
        modelpack_dir: Some(dir.clone()),
        ..RegistryConfig::default()
    };
    std::fs::write(dir.join("ad.cwm"), &pack).unwrap();
    let reg = ModelRegistry::build(&cfg).unwrap();
    assert_eq!(reg.get("ad").unwrap().startup().source, "modelpack");
    reg.shutdown();

    let skewed = Provenance { assignment: "w8x8".to_string(), seed: 9 };
    std::fs::write(dir.join("ad.cwm"), plan.to_modelpack_with(Some(&skewed))).unwrap();
    let reg = ModelRegistry::build(&cfg).unwrap();
    assert_eq!(reg.get("ad").unwrap().startup().source, "compile");
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Walk the PLAN stream of `pack` to the first quant record and return
/// the absolute offset of its group-count field (the layout is pinned
/// by `engine::pack`'s encoder, which this test intentionally mirrors).
fn first_group_count_offset(pack: &[u8]) -> usize {
    let c = modelpack::Container::parse(pack).unwrap();
    let s = c.find(modelpack::SECTION_PLAN).unwrap();
    let b = &pack[s.off..s.off + s.len];
    let rd_u32 = |p: usize| u32::from_le_bytes(b[p..p + 4].try_into().unwrap());
    let mut p = 4; // n_nodes
    loop {
        p += 4 + 4 + 1 + 4 + 8; // src, dst, save flag, save slot, out_len
        let tag = b[p];
        p += 1;
        match tag {
            0 => {}       // NoOp
            1 => p += 12, // AvgPool
            2 => p += 13, // Add
            3 => {
                p += 4 + rd_u32(p) as usize; // name
                p += 1 + 1; // fc, depthwise
                p += 8 + 4 + 8 + 4 + 4 + 4; // k, kk, in_len, out_h, out_w, cout
                p += 4 + 4 + 4; // act_alpha, act_eps, act_bits
                p += 8 * 5; // cin, pixel_bytes, plane_bytes, seg_bits, col_bytes
                p += 1; // relu_inline
                let has_post = b[p];
                p += 1;
                if has_post == 1 {
                    p += 4 + 8 + 1; // other, len, relu
                }
                return s.off + p;
            }
            other => panic!("unknown node tag {other}"),
        }
    }
}

#[test]
fn uncovered_channel_groups_are_rejected() {
    // a pack whose sub-conv groups do not tile [0, cout) must be
    // refused: the executor writes outputs only per group, so an
    // uncovered channel would surface stale arena data from a previous
    // batch (a cross-request leak under the serving batcher's resident
    // arena).
    let (_, plan) = compiled("ad", &ReferenceBackend);
    let pack = plan.to_modelpack();
    assert!(ExecPlan::from_modelpack(&pack).is_ok(), "baseline pack must load");

    let ngroups_off = first_group_count_offset(&pack);
    // group 0's len field: after the count u32 and the group's bits
    // u32 + start u64
    let len_off = ngroups_off + 4 + 4 + 8;
    let len0 = u64::from_le_bytes(pack[len_off..len_off + 8].try_into().unwrap());
    assert!(len0 >= 1);

    // shrink group 0 by one channel: a gap opens in the tiling
    let mut bad = pack.clone();
    bad[len_off..len_off + 8].copy_from_slice(&(len0 - 1).to_le_bytes());
    modelpack::reseal(&mut bad);
    assert!(matches!(
        ExecPlan::from_modelpack(&bad).unwrap_err(),
        PackError::Malformed(_)
    ));

    // drop the trailing groups entirely: the tail channels go uncovered
    let n_groups = u32::from_le_bytes(pack[ngroups_off..ngroups_off + 4].try_into().unwrap());
    assert!(n_groups >= 2, "stripy assignment fragments into several groups");
    let mut bad = pack.clone();
    bad[ngroups_off..ngroups_off + 4].copy_from_slice(&1u32.to_le_bytes());
    modelpack::reseal(&mut bad);
    assert!(ExecPlan::from_modelpack(&bad).is_err());
}

/// Fused plans (format minor 1: `KIND_QUANT_FUSED` records + the META
/// fusion extension) round-trip the *entire* fusion state — plane-slot
/// layout, per-layer fuse/reuse/elision flags, coverage stats — and the
/// loaded plan executes bit-identically, batched and per sample.
#[test]
fn fused_plan_roundtrip_preserves_fusion_state() {
    for bench in BENCHES {
        let manifest = builtin_manifest(bench).unwrap();
        let (params, bn) = synthetic_state(&manifest, 0);
        // uniform assignment: every quantized edge fuses (and ic's
        // residual taps share planes), the richest fusion state
        let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), 8, 8);
        let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
        let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
        assert!(plan.fusion().fused_edges > 0, "{bench}: nothing fused");

        let pack = plan.to_modelpack();
        let loaded = ExecPlan::from_modelpack(&pack)
            .unwrap_or_else(|e| panic!("{bench}: {e}"));
        assert_eq!(loaded.fusion(), plan.fusion(), "{bench}: stats diverged");
        let rep = inspect(&pack).unwrap();
        assert_eq!(&rep.fusion, plan.fusion());
        assert!(rep.plane_slots > 1, "{bench}: fused plan needs extra planes");
        assert!(rep.layers.iter().any(|l| l.fused_out));

        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, 4, 3);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let want = plan.run_samples(&samples, 1).unwrap();
        assert_eq!(loaded.run_samples(&samples, 1).unwrap(), want, "{bench}");
        let mut arena = loaded.batch_arena(samples.len());
        let got = loaded.run_batch_planes(&mut arena, &samples).unwrap();
        assert_eq!(got, want, "{bench}: loaded fused batch planes diverged");
    }
}

/// Byte-flip sweep over a *fused* pack's PLAN and META sections (the
/// new record kind and the fusion extension): the loader must return a
/// typed error or a plan whose execution validation proved safe —
/// never panic.
#[test]
fn fused_pack_semantic_corruption_never_panics() {
    let (_, plan) = compiled("ad", &PackedBackend);
    assert!(plan.fusion().fused_edges > 0, "ad/packed must fuse");
    let pack = plan.to_modelpack();
    let container = modelpack::Container::parse(&pack).unwrap();
    let mut targets = Vec::new();
    for kind in [modelpack::SECTION_META, modelpack::SECTION_PLAN] {
        let s = container.find(kind).unwrap();
        targets.extend(s.off..s.off + s.len);
    }
    for pos in targets {
        let mut bad = pack.clone();
        bad[pos] ^= 0x01;
        modelpack::reseal(&mut bad);
        if let Ok(p) = ExecPlan::from_modelpack(&bad) {
            let feat = p.feat();
            if feat == plan.feat() {
                let ds = make_dataset("ad", Split::Test, 1, 0);
                let mut arena = p.arena();
                let _ = p.run_sample(&mut arena, &ds.x[..feat]);
            }
        }
    }
}

/// A minor-0 pack (written before fused requantize existed) must still
/// load and execute.  An unfused plan's body encodes byte-identically
/// to the minor-0 format, so stamping the old version onto one
/// reproduces a genuine old artifact.
#[test]
fn minor_zero_unfused_packs_load_and_execute() {
    let manifest = builtin_manifest("kws").unwrap();
    let (params, bn) = synthetic_state(&manifest, 0);
    let a = stripy_assignment(&manifest);
    let model = deploy::build(&manifest, &params, &bn, &a).unwrap();
    let plan =
        ExecPlan::compile_with(&model, &manifest.lut, &PackedBackend, false).unwrap();
    assert_eq!(plan.fusion(), &FusionStats::default());

    let mut pack = plan.to_modelpack();
    pack[10] = 0; // version_minor lives at header bytes 10..12
    pack[11] = 0;
    modelpack::reseal(&mut pack);
    let loaded = ExecPlan::from_modelpack(&pack).unwrap();
    assert_eq!(loaded.fusion(), &FusionStats::default());
    let rep = inspect(&pack).unwrap();
    assert_eq!(rep.version, (1, 0));
    assert_eq!(rep.plane_slots, 1);
    assert!(rep.layers.iter().all(|l| !l.fused_out && !l.plane_reused));

    // and it computes exactly what today's fused compile computes
    let fused = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let feat = manifest.feat_len();
    let ds = make_dataset("kws", Split::Test, 4, 3);
    let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
    assert_eq!(
        loaded.run_samples(&samples, 1).unwrap(),
        fused.run_samples(&samples, 1).unwrap(),
        "minor-0 pack diverged from the fused engine"
    );
}

#[test]
fn missing_required_section_is_typed_error() {
    let (_, plan) = compiled("ad", &PackedBackend);
    let pack = plan.to_modelpack();
    let container = modelpack::Container::parse(&pack).unwrap();
    for dropped in [
        modelpack::SECTION_META,
        modelpack::SECTION_PLAN,
        modelpack::SECTION_COST,
        modelpack::SECTION_DATA,
    ] {
        let sections: Vec<(u32, Vec<u8>)> = container
            .sections
            .iter()
            .filter(|s| s.kind != dropped)
            .map(|s| (s.kind, container.section(s.kind).unwrap().to_vec()))
            .collect();
        let partial = modelpack::assemble(&sections);
        assert_eq!(
            ExecPlan::from_modelpack(&partial).unwrap_err(),
            PackError::MissingSection(dropped)
        );
    }
}
