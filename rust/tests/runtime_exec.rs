//! Runtime integration: compile + execute real artifacts, check training
//! semantics end to end (loss decreases, eval consistent, state threads).
//!
//! Needs `--features xla` (real bindings) and `make artifacts`; skips
//! cleanly when the artifacts are absent.

#![cfg(feature = "xla")]

use std::path::Path;

use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::quant::Assignment;
use cwmix::runtime::Runtime;

fn rt() -> Runtime {
    Runtime::cpu(Path::new("artifacts")).unwrap()
}

mod common;
use common::has_artifacts;

#[test]
fn warmup_reduces_loss_ad() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let mut cfg = SearchConfig::quick("ad", Mode::ChannelWise, Target::Size, 0.0);
    cfg.warmup_epochs = 3;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    tr.warmup().unwrap();
    let h = &tr.history;
    assert!(h.len() >= 3);
    assert!(
        h.last().unwrap().train_loss < h[0].train_loss * 0.8,
        "warmup did not learn: {} -> {}",
        h[0].train_loss,
        h.last().unwrap().train_loss
    );
}

#[test]
fn eval_scores_improve_over_random_kws() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let mut cfg = SearchConfig::quick("kws", Mode::ChannelWise, Target::Size, 0.0);
    cfg.warmup_epochs = 6;
    cfg.train_n = 512;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let a8 = Assignment::fixed(&tr.manifest.qnames(), &tr.manifest.qcouts(), 8, 8);
    let (_, acc_before) = tr.evaluate(cwmix::data::Split::Test, &a8).unwrap();
    tr.warmup().unwrap();
    let (_, acc_after) = tr.evaluate(cwmix::data::Split::Test, &a8).unwrap();
    // 12-way classification: random ~= 0.083
    assert!(acc_before < 0.35, "untrained acc suspicious: {acc_before}");
    assert!(acc_after > acc_before + 0.15, "{acc_before} -> {acc_after}");
}

#[test]
fn quantization_hurts_at_2bit_weights() {
    if !has_artifacts() {
        return;
    }
    // after a short warmup, w2 must lose accuracy vs w8 (the premise of
    // the whole trade-off space)
    let rt = rt();
    let mut cfg = SearchConfig::quick("kws", Mode::ChannelWise, Target::Size, 0.0);
    cfg.warmup_epochs = 6;
    cfg.train_n = 512;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    tr.warmup().unwrap();
    let names = tr.manifest.qnames();
    let couts = tr.manifest.qcouts();
    let (l8, _) = tr
        .evaluate(cwmix::data::Split::Test, &Assignment::fixed(&names, &couts, 8, 8))
        .unwrap();
    let (l2, _) = tr
        .evaluate(cwmix::data::Split::Test, &Assignment::fixed(&names, &couts, 2, 8))
        .unwrap();
    assert!(l2 > l8, "2-bit weights should hurt: loss {l2} vs {l8}");
}

#[test]
fn snapshot_restore_roundtrip() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let mut cfg = SearchConfig::quick("ad", Mode::ChannelWise, Target::Size, 0.0);
    cfg.warmup_epochs = 1;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    tr.warmup().unwrap();
    let snap = tr.snapshot();
    let a8 = Assignment::fixed(&tr.manifest.qnames(), &tr.manifest.qcouts(), 8, 8);
    let (l1, _) = tr.evaluate(cwmix::data::Split::Val, &a8).unwrap();
    // more training changes the params...
    tr.train_hard_phase("extra", 1, &a8, false).unwrap();
    // ...restore brings the old loss back exactly
    tr.restore(&snap);
    let (l2, _) = tr.evaluate(cwmix::data::Split::Val, &a8).unwrap();
    assert!((l1 - l2).abs() < 1e-6, "{l1} vs {l2}");
}

#[test]
fn graph_cache_reuses_compilations() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let g1 = rt.graph("ad", "eval").unwrap();
    let g2 = rt.graph("ad", "eval").unwrap();
    assert!(std::sync::Arc::ptr_eq(&g1, &g2));
    assert_eq!(rt.compiled_count(), 1);
}
