//! Backend-equivalence contract of the inference engine.
//!
//! Pure Rust — runs on the default feature set with no artifacts: model
//! geometry comes from the builtin zoo, weights from the deterministic
//! synthetic initialiser.  Asserts:
//!
//! * `packed` and `simd` are **bit-identical** to `reference` (and all
//!   to the scalar oracle `mpic::exec::run_sample`) across all nine
//!   `(p_x, p_w) ∈ {2,4,8}²` fixed combos — on the FC-only topology
//!   *and* on a conv/depthwise topology, so every cell of the SWAR
//!   kernel table runs against ragged K values (conv K = 27/9/...);
//!   the `simd` assertions honor `CWMIX_SIMD`, and CI runs this suite
//!   under both `auto` and `off` so the vector tiers *and* the scalar
//!   fallback stay proven on the same runner;
//! * the same bit-exactness on all four benchmark topologies under an
//!   adversarially striped per-channel assignment (residual joins,
//!   depthwise chains, FC-only);
//! * inputs saturating the PACT clip (all codes at the `2^p_x - 1`
//!   boundary) stay bit-exact through the packed plane;
//! * the plan's compile-time cost equals the oracle's per-sample
//!   accounting and the Eq. (8) energy model;
//! * `run_batch` reports malformed batches as errors (no panic) and is
//!   thread-count invariant;
//! * `pack_subbyte`/`unpack_subbyte` round-trip the full signed range.

use cwmix::data::{make_dataset, Split};
use cwmix::deploy::{self, DeployedModel};
use cwmix::engine::{ExecPlan, KernelBackend, PackedBackend, ReferenceBackend, SimdBackend};
use cwmix::models::zoo::{builtin_manifest, stripy_assignment as stripy, synthetic_state};
use cwmix::models::Manifest;
use cwmix::quant::{pack_subbyte, unpack_subbyte, Assignment};
use cwmix::util::Pcg32;

fn build(manifest: &Manifest, a: &Assignment) -> DeployedModel {
    let (params, bn) = synthetic_state(manifest, 0);
    deploy::build(manifest, &params, &bn, a).unwrap()
}

/// Oracle outputs + cost for `n` samples.
fn oracle_run(
    model: &DeployedModel,
    manifest: &Manifest,
    xs: &[f32],
    n: usize,
) -> (Vec<Vec<f32>>, cwmix::mpic::InferenceCost) {
    let feat = manifest.feat_len();
    let mut outs = Vec::new();
    let mut cost = None;
    for i in 0..n {
        let (o, c) = cwmix::mpic::run_sample(
            model,
            &xs[i * feat..(i + 1) * feat],
            &manifest.lut,
        )
        .unwrap();
        outs.push(o);
        cost.get_or_insert(c);
    }
    (outs, cost.unwrap())
}

fn engine_run(
    model: &DeployedModel,
    manifest: &Manifest,
    backend: &dyn KernelBackend,
    xs: &[f32],
    n: usize,
) -> (Vec<Vec<f32>>, cwmix::mpic::InferenceCost) {
    let feat = manifest.feat_len();
    let plan = ExecPlan::compile(model, &manifest.lut, backend).unwrap();
    plan.run_batch_threads(&xs[..n * feat], feat, 1).unwrap()
}

fn assert_costs_equal(
    bench: &str,
    got: &cwmix::mpic::InferenceCost,
    want: &cwmix::mpic::InferenceCost,
) {
    assert_eq!(got.layers.len(), want.layers.len(), "{bench}: layer count");
    for (g, w) in got.layers.iter().zip(&want.layers) {
        assert_eq!(g.name, w.name, "{bench}");
        assert_eq!(g.mac_cycles, w.mac_cycles, "{bench}/{}", g.name);
        assert_eq!(g.overhead_cycles, w.overhead_cycles, "{bench}/{}", g.name);
        assert_eq!(g.mem_bytes, w.mem_bytes, "{bench}/{}", g.name);
        assert_eq!(g.mac_energy_pj, w.mac_energy_pj, "{bench}/{}", g.name);
        assert_eq!(g.macs_by_group, w.macs_by_group, "{bench}/{}", g.name);
    }
}

/// All nine `(p_x, p_w)` combos on `bench`, `n` samples per combo.
fn check_all_nine_combos(bench: &str, n: usize) {
    let manifest = builtin_manifest(bench).unwrap();
    let ds = make_dataset(bench, Split::Test, n.max(2), 1);
    for xb in [2u32, 4, 8] {
        for wb in [2u32, 4, 8] {
            let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), wb, xb);
            let model = build(&manifest, &a);
            let (want, oc) = oracle_run(&model, &manifest, &ds.x, n);
            let (ref_out, rc) = engine_run(&model, &manifest, &ReferenceBackend, &ds.x, n);
            let (packed_out, pc) = engine_run(&model, &manifest, &PackedBackend, &ds.x, n);
            let (simd_out, sc) = engine_run(&model, &manifest, &SimdBackend, &ds.x, n);
            assert_eq!(ref_out, want, "{bench}: reference vs oracle w{wb}x{xb}");
            assert_eq!(packed_out, want, "{bench}: packed vs oracle w{wb}x{xb}");
            assert_eq!(simd_out, want, "{bench}: simd vs oracle w{wb}x{xb}");
            assert_costs_equal(bench, &rc, &oc);
            assert_costs_equal(bench, &pc, &oc);
            assert_costs_equal(bench, &sc, &oc);
        }
    }
}

#[test]
fn all_nine_precision_combos_bit_exact_ad() {
    // FC-only topology: the dot_wide kernel row of the table
    check_all_nine_combos("ad", 2);
}

#[test]
fn all_nine_precision_combos_bit_exact_kws() {
    // conv + depthwise chains: every SWAR cell sees ragged conv K
    // values (tail lanes of the packed registers) and the gather paths
    check_all_nine_combos("kws", 1);
}

#[test]
fn pact_clip_boundary_bit_exact() {
    // inputs far above alpha drive every activation code to the clip
    // boundary 2^p_x - 1 — the extreme-code path through the packed
    // plane must match the oracle bit for bit
    let manifest = builtin_manifest("ic").unwrap();
    let a = stripy(&manifest);
    let model = build(&manifest, &a);
    let feat = manifest.feat_len();
    let hot = vec![1.0e6f32; feat];
    let (want, _) = cwmix::mpic::run_sample(&model, &hot, &manifest.lut).unwrap();
    for backend in [&ReferenceBackend as &dyn KernelBackend, &PackedBackend, &SimdBackend] {
        let plan = ExecPlan::compile(&model, &manifest.lut, backend).unwrap();
        let mut arena = plan.arena();
        let got = plan.run_sample(&mut arena, &hot).unwrap();
        assert_eq!(got, want, "{} at clip boundary", backend.name());
    }
}

#[test]
fn all_four_geometries_bit_exact_striped() {
    for bench in ["ic", "kws", "vww", "ad"] {
        let manifest = builtin_manifest(bench).unwrap();
        let a = stripy(&manifest);
        let model = build(&manifest, &a);
        let ds = make_dataset(bench, Split::Test, 2, 3);
        let n = 1;
        let (want, oc) = oracle_run(&model, &manifest, &ds.x, n);
        let (ref_out, rc) = engine_run(&model, &manifest, &ReferenceBackend, &ds.x, n);
        let (packed_out, pc) = engine_run(&model, &manifest, &PackedBackend, &ds.x, n);
        let (simd_out, sc) = engine_run(&model, &manifest, &SimdBackend, &ds.x, n);
        assert_eq!(ref_out, want, "{bench}: reference vs oracle");
        assert_eq!(packed_out, want, "{bench}: packed vs oracle");
        assert_eq!(simd_out, want, "{bench}: simd vs oracle");
        assert_costs_equal(bench, &rc, &oc);
        assert_costs_equal(bench, &pc, &oc);
        assert_costs_equal(bench, &sc, &oc);
    }
}

/// The simd backend across batch sizes {1, 7, 8} on all four zoo
/// geometries under striped assignments: the vector kernels see full
/// vector blocks (B=8), pure remainders (B=7, all-SWAR cascade on the
/// i32 path) and the no-batch-axis case (B=1), and every output is
/// bit-identical to the packed backend and the out-of-engine oracle.
/// Honors `CWMIX_SIMD`, so the CI `off` run exercises the scalar
/// fallback through the same assertions.
#[test]
fn simd_backend_batch_sizes_bit_exact_striped() {
    for bench in ["ic", "kws", "vww", "ad"] {
        let manifest = builtin_manifest(bench).unwrap();
        let a = stripy(&manifest);
        let model = build(&manifest, &a);
        let feat = manifest.feat_len();
        let ds = make_dataset(bench, Split::Test, 8, 7);
        let samples: Vec<&[f32]> = ds.x.chunks_exact(feat).collect();
        let simd = ExecPlan::compile(&model, &manifest.lut, &SimdBackend).unwrap();
        let packed = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
        assert_eq!(simd.backend_name(), "simd");
        assert_eq!(simd.kernel_tier(), SimdBackend.tier());
        let mut sa = simd.batch_arena(8);
        let mut pa = packed.batch_arena(8);
        for b in [1usize, 7, 8] {
            let got = simd.run_batch_planes(&mut sa, &samples[..b]).unwrap();
            let want = packed.run_batch_planes(&mut pa, &samples[..b]).unwrap();
            assert_eq!(got, want, "{bench} b={b}: simd vs packed");
        }
        let oracle = cwmix::mpic::run_sample(&model, samples[0], &manifest.lut)
            .unwrap()
            .0;
        let got = simd.run_batch_planes(&mut sa, &samples[..1]).unwrap();
        assert_eq!(got[0], oracle, "{bench}: simd vs mpic::exec oracle");
    }
}

#[test]
fn plan_cost_matches_energy_model() {
    // MAC-only energy of the plan == Eq. (8) with one-hot NAS params,
    // and total MACs == sum of layer ops — same contract the xla-gated
    // integration test asserts against trained artifacts.
    let manifest = builtin_manifest("kws").unwrap();
    let a = stripy(&manifest);
    let model = build(&manifest, &a);
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let cost = plan.cost();
    let want = cwmix::energy::model_energy_pj(&manifest.geom(), &a, &manifest.lut);
    let got = cost.mac_energy_pj();
    assert!((got - want).abs() / want < 1e-6, "sim {got} vs Eq.8 {want}");
    let ops: u64 = manifest.geom().qlayers.iter().map(|l| l.ops as u64).sum();
    assert_eq!(cost.total_macs(), ops);
}

#[test]
fn run_batch_rejects_ragged_input() {
    let manifest = builtin_manifest("ad").unwrap();
    let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), 8, 8);
    let model = build(&manifest, &a);
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let feat = manifest.feat_len();
    // not a whole number of samples: error, not panic
    let err = plan.run_batch(&vec![0.0; feat + 1], feat).unwrap_err();
    assert!(err.to_string().contains("whole number"), "{err}");
    // wrong feature length
    assert!(plan.run_batch(&vec![0.0; feat], feat - 1).is_err());
}

#[test]
fn run_batch_thread_count_invariant() {
    let manifest = builtin_manifest("ad").unwrap();
    let a = stripy(&manifest);
    let model = build(&manifest, &a);
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    let feat = manifest.feat_len();
    let ds = make_dataset("ad", Split::Test, 16, 5);
    let (seq, c1) = plan.run_batch_threads(&ds.x, feat, 1).unwrap();
    let (par, c4) = plan.run_batch_threads(&ds.x, feat, 4).unwrap();
    assert_eq!(seq, par);
    assert_eq!(c1.total_cycles(), c4.total_cycles());
    assert_eq!(seq.len(), 16);
}

#[test]
fn pack_roundtrip_full_signed_range() {
    // property-style: every representable value round-trips, including
    // the most negative code (-2^(b-1), producible by packing even if
    // the quantizer never emits it)
    let mut rng = Pcg32::seeded(42);
    for bits in [2u32, 4, 8] {
        let lo = -(1i32 << (bits - 1));
        let hi = (1i32 << (bits - 1)) - 1;
        let mut vals: Vec<i32> = (lo..=hi).collect();
        for _ in 0..500 {
            vals.push(lo + rng.below((hi - lo + 1) as u32) as i32);
        }
        let packed = pack_subbyte(&vals, bits);
        let back = unpack_subbyte(&packed, bits, vals.len());
        assert_eq!(back, vals, "bits={bits}");
    }
}

#[test]
fn packed_weights_match_flash_footprint() {
    // the packed backend's storage is exactly the Eq. (7) byte count
    // the Fig. 3 memory axis reports
    let manifest = builtin_manifest("ic").unwrap();
    let a = stripy(&manifest);
    let model = build(&manifest, &a);
    let plan = ExecPlan::compile(&model, &manifest.lut, &PackedBackend).unwrap();
    assert_eq!(plan.weight_bytes(), model.packed_bytes());
}
