//! The §III-C contract: deployed integer execution == HLO `infer`, for
//! every benchmark topology (residual joins, depthwise chains, FC-only)
//! and for adversarially mixed per-channel assignments.
//!
//! Needs `--features xla` and `make artifacts`; skips cleanly otherwise.

#![cfg(feature = "xla")]

use std::path::Path;

use cwmix::data::{make_dataset, Split};
use cwmix::deploy;
use cwmix::engine::{ExecPlan, PackedBackend};
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::quant::Assignment;
use cwmix::runtime::Runtime;

fn rt() -> Runtime {
    Runtime::cpu(Path::new("artifacts")).unwrap()
}

mod common;
use common::has_artifacts;

/// Deterministic "stripy" mixed assignment (see
/// `models::zoo::stripy_assignment`): exercises reordering, residual
/// space joins and fragmented groups.
fn stripy(tr: &Trainer) -> Assignment {
    cwmix::models::zoo::stripy_assignment(&tr.manifest)
}

fn check_bench(bench: &str, warmup_epochs: usize, min_agree: f32) {
    let rt = rt();
    let mut cfg = SearchConfig::quick(bench, Mode::ChannelWise, Target::Size, 0.0);
    cfg.warmup_epochs = warmup_epochs;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    tr.warmup().unwrap(); // realistic weights + BN stats
    let a = stripy(&tr);
    let ds = make_dataset(bench, Split::Test, 32, 0);
    let rep = deploy::verify::verify_against_hlo(&tr, &a, &ds, 1).unwrap();
    assert!(
        rep.argmax_agreement >= min_agree,
        "{bench}: agreement {} < {min_agree}",
        rep.argmax_agreement
    );
    assert!(
        rep.max_abs_diff < 1e-2,
        "{bench}: max diff {}",
        rep.max_abs_diff
    );
}

#[test]
fn ad_fc_only_matches() {
    if !has_artifacts() {
        return;
    }
    check_bench("ad", 1, 1.0);
}

#[test]
fn kws_depthwise_matches() {
    if !has_artifacts() {
        return;
    }
    check_bench("kws", 1, 0.99);
}

#[test]
fn ic_residual_matches() {
    if !has_artifacts() {
        return;
    }
    check_bench("ic", 1, 0.99);
}

#[test]
fn deployed_costs_match_energy_model() {
    if !has_artifacts() {
        return;
    }
    // MAC-only energy of the simulator == Eq. (8) with one-hot NAS params
    let rt = rt();
    let cfg = SearchConfig::quick("kws", Mode::ChannelWise, Target::Size, 0.0);
    let tr = Trainer::new(&rt, cfg).unwrap();
    let a = stripy(&tr);
    let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a).unwrap();
    let plan = ExecPlan::compile(&d, &tr.manifest.lut, &PackedBackend).unwrap();
    let cost = plan.cost();
    let want = cwmix::energy::model_energy_pj(&tr.manifest.geom(), &a, &tr.manifest.lut);
    let got = cost.mac_energy_pj();
    assert!(
        (got - want).abs() / want < 1e-6,
        "sim {got} vs Eq.8 {want}"
    );
    // total MACs must equal sum of ops
    let ops: u64 = tr.manifest.geom().qlayers.iter().map(|l| l.ops as u64).sum();
    assert_eq!(cost.total_macs(), ops);
}

#[test]
fn groups_partition_channels() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let cfg = SearchConfig::quick("ic", Mode::ChannelWise, Target::Size, 0.0);
    let tr = Trainer::new(&rt, cfg).unwrap();
    let a = stripy(&tr);
    let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a).unwrap();
    for l in d.qlayers() {
        let covered: usize = l.groups.iter().map(|g| g.len).sum();
        assert_eq!(covered, l.spec.cout, "{}", l.spec.name);
        // runs are contiguous and ordered
        let mut pos = 0;
        for g in &l.groups {
            assert_eq!(g.start, pos, "{}", l.spec.name);
            pos += g.len;
            // every channel in the run has the run's bits
            for c in g.start..g.start + g.len {
                assert_eq!(l.weight_bits[c], g.bits);
            }
        }
    }
}

#[test]
fn packed_bytes_match_quant_module() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let cfg = SearchConfig::quick("ad", Mode::ChannelWise, Target::Size, 0.0);
    let tr = Trainer::new(&rt, cfg).unwrap();
    let a = stripy(&tr);
    let d = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &a).unwrap();
    for (l, la) in d.qlayers().zip(&a.layers) {
        // per-layer packed bytes must not depend on channel *order*
        let direct = cwmix::quant::packed_weight_bytes(
            l.spec.cout,
            l.spec.weights_per_channel,
            &la.weight_bits,
        );
        assert_eq!(l.packed_bytes(), direct, "{}", l.spec.name);
    }
}
