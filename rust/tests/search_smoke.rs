//! End-to-end Alg. 1 smoke: a tiny channel-wise search must produce a
//! valid, *mixed* assignment whose regularizer pressure shows up in the
//! extracted bits; results must round-trip the store.
//!
//! Needs `--features xla` and `make artifacts`; skips cleanly otherwise.

#![cfg(feature = "xla")]

use std::path::Path;

use cwmix::coordinator::results;
use cwmix::nas::{Mode, SearchConfig, Target, Trainer};
use cwmix::runtime::Runtime;

fn rt() -> Runtime {
    Runtime::cpu(Path::new("artifacts")).unwrap()
}

mod common;
use common::has_artifacts;

fn tiny(bench: &str, target: Target, lambda_rel: f32) -> SearchConfig {
    let mut cfg = SearchConfig::quick(bench, Mode::ChannelWise, target, 0.0);
    cfg.warmup_epochs = 1;
    cfg.search_epochs = 3;
    cfg.finetune_epochs = 1;
    cfg.lambda = lambda_rel;
    cfg
}

#[test]
fn size_pressure_reduces_bits_ad() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let mut cfg = tiny("ad", Target::Size, 0.0);
    let tr0 = Trainer::new(&rt, cfg.clone()).unwrap();
    let (reg_s0, _) = tr0.initial_regs().unwrap();
    drop(tr0);
    cfg.lambda = 3.0 / reg_s0; // strong size pressure
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let r = tr.run().unwrap();
    // mean weight bits must drop clearly below 8
    let mut total = 0usize;
    let mut bits_sum = 0u64;
    for l in &r.assignment.layers {
        total += l.weight_bits.len();
        bits_sum += l.weight_bits.iter().map(|&b| b as u64).sum::<u64>();
        // size target: activations pinned at 8
        assert_eq!(l.act_bits, 8, "{}", l.name);
    }
    let mean_bits = bits_sum as f64 / total as f64;
    assert!(mean_bits < 6.0, "no size pressure visible: mean {mean_bits}");
    assert!(r.size_bits < 0.75 * 8.0 * reg_s0 as f64 / 8.0);
}

#[test]
fn zero_lambda_keeps_high_bits_ad() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let cfg = tiny("ad", Target::Size, 0.0); // lambda = 0: only accuracy
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let r = tr.run().unwrap();
    // without pressure, search has no reason to go all-2-bit
    let mut n2 = 0usize;
    let mut total = 0usize;
    for l in &r.assignment.layers {
        n2 += l.weight_bits.iter().filter(|&&b| b == 2).count();
        total += l.weight_bits.len();
    }
    assert!(
        (n2 as f64) < 0.8 * total as f64,
        "lambda=0 collapsed to 2-bit ({n2}/{total})"
    );
}

#[test]
fn layerwise_mode_gives_uniform_layers() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let mut cfg = tiny("ad", Target::Size, 0.0);
    cfg.mode = Mode::LayerWise;
    cfg.lambda = 1e-6;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let r = tr.run().unwrap();
    for l in &r.assignment.layers {
        let first = l.weight_bits[0];
        assert!(
            l.weight_bits.iter().all(|&b| b == first),
            "layer-wise search produced per-channel bits in {}",
            l.name
        );
    }
}

#[test]
fn results_store_roundtrip_with_real_result() {
    if !has_artifacts() {
        return;
    }
    let rt = rt();
    let cfg = tiny("ad", Target::Size, 1e-6);
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let r = tr.run().unwrap();
    let dir = std::env::temp_dir().join("cwmix_search_smoke");
    let _ = std::fs::remove_dir_all(&dir);
    let path = results::save_sweep(
        &dir, "ad", "size", std::slice::from_ref(&r), &[], &[]).unwrap();
    let (b, t, o, e, f) = results::load_sweep(&path).unwrap();
    assert_eq!((b.as_str(), t.as_str()), ("ad", "size"));
    assert_eq!(o.len(), 1);
    assert!(e.is_empty() && f.is_empty());
    assert_eq!(o[0].assignment, r.assignment);
    assert!((o[0].test_score - r.test_score).abs() < 1e-6);
    let _ = std::fs::remove_dir_all(&dir);
}
