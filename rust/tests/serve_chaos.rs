//! Chaos suite: every armed failpoint driven end-to-end over real
//! sockets (the supervised-serving acceptance criterion).
//!
//! The scenarios prove the supervision story the serve module
//! advertises:
//!
//! * an injected engine panic never kills the server — the worker
//!   respawns and subsequent replies are **bit-identical** to
//!   `ExecPlan::run_sample`, while the other model's requests never see
//!   an error;
//! * an engine stall ages the queue past the request deadline and the
//!   backlog sheds as explicit 504s, while the other model stays live;
//! * K consecutive panics open the per-model circuit breaker (503 +
//!   `Retry-After`), which half-opens after its cooldown and closes on
//!   the first success;
//! * the queue-full failpoint exercises the 503 shed path without real
//!   overload;
//! * slow clients and idle keep-alive connections are reaped and
//!   counted;
//! * a mid-reply write stall delivers the delayed reply intact, closes
//!   the connection, and is gauged as `write_stalls`;
//! * injected registry load errors / artifact corruption make the cold
//!   start fall back to compilation instead of taking the server down.
//!
//! Faults are armed through the library config (`Arc<Faults>`), not the
//! env var, so scenarios cannot leak into each other or into the rest
//! of the test binary; the `CWMIX_FAULTS` env path is exercised by
//! `tools/chaos_smoke.sh` in CI.

use std::io::{Read, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cwmix::data::{make_dataset, Split};
use cwmix::minijson::Json;
use cwmix::serve::client::{infer_body, output_of, Conn};
use cwmix::serve::{
    serve, BatchPolicy, Faults, ModelRegistry, RegistryConfig, ServeConfig, Server,
    SupervisorCfg,
};

/// Fast supervision knobs so breaker/backoff scenarios run in
/// milliseconds, not the production-scale defaults.
fn fast_supervisor() -> SupervisorCfg {
    SupervisorCfg {
        breaker_k: 3,
        cooldown_ms: 300,
        cooldown_cap_ms: 3_000,
        backoff_base_ms: 5,
        backoff_cap_ms: 50,
    }
}

/// Registry + server on an ephemeral port with `spec` armed.
fn start_faulted(
    benches: &[&str],
    policy: BatchPolicy,
    spec: &str,
) -> (Arc<ModelRegistry>, Server) {
    let faults = Arc::new(Faults::parse(spec, 0).unwrap());
    let reg_cfg = RegistryConfig {
        benches: benches.iter().map(|b| b.to_string()).collect(),
        policy,
        faults: Arc::clone(&faults),
        supervisor: fast_supervisor(),
        ..RegistryConfig::default()
    };
    let registry = Arc::new(ModelRegistry::build(&reg_cfg).unwrap());
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        faults,
        ..ServeConfig::default()
    };
    let server = serve(Arc::clone(&registry), cfg).unwrap();
    (registry, server)
}

/// Input + oracle output for sample `i` of a bench, straight from the
/// served plan (batching and respawns must stay bit-identical to this).
fn expected(registry: &ModelRegistry, bench: &str, i: usize) -> (Vec<f32>, Vec<f32>) {
    let plan = registry.get(bench).unwrap().plan();
    let feat = plan.feat();
    let ds = make_dataset(bench, Split::Test, i + 1, 0);
    let input = ds.x[i * feat..(i + 1) * feat].to_vec();
    let mut arena = plan.arena();
    let want = plan.run_sample(&mut arena, &input).unwrap();
    (input, want)
}

/// Poll one model's `/metrics` gauge until `pred` holds (30 s cap).
fn poll_gauge(
    addr: std::net::SocketAddr,
    bench: &str,
    key: &str,
    pred: impl Fn(f64) -> bool,
) -> f64 {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut conn = Conn::connect(addr).unwrap();
        let m = conn.get("/metrics").unwrap();
        assert_eq!(m.status, 200);
        let v = m
            .body
            .get("models")
            .unwrap()
            .get(bench)
            .unwrap()
            .get(key)
            .unwrap()
            .as_f64()
            .unwrap();
        if pred(v) {
            return v;
        }
        assert!(
            Instant::now() < deadline,
            "gauge {bench}.{key} never satisfied predicate (last {v})"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The acceptance scenario: an injected engine panic fails exactly the
/// in-flight ic request, the worker respawns, ic replies come back
/// bit-identical to `run_sample`, and kws never sees an error.
#[test]
fn engine_panic_respawns_and_recovery_is_bit_identical() {
    let (registry, server) = start_faulted(
        &["ic", "kws"],
        BatchPolicy { max_wait_us: 1_000, ..BatchPolicy::default() },
        "engine_panic:ic:once",
    );
    let addr = server.addr();
    let (ic_in, ic_want) = expected(&registry, "ic", 0);
    let (kws_in, kws_want) = expected(&registry, "kws", 0);

    // the faulted model's first request rides the panicking batch:
    // an explicit 500, never a hang, never a dead server
    let mut conn = Conn::connect(addr).unwrap();
    let r = conn.post("/v1/infer/ic", &infer_body(&ic_in)).unwrap();
    assert_eq!(r.status, 500, "panicked batch must answer 500: {}", r.body.dumps());

    // the other model is untouched, before the respawn even lands
    let r = conn.post("/v1/infer/kws", &infer_body(&kws_in)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), kws_want);

    // supervision: the panic was counted and the worker respawned
    poll_gauge(addr, "ic", "worker_respawns", |v| v >= 1.0);
    let panics = poll_gauge(addr, "ic", "worker_panics", |v| v >= 1.0);
    assert_eq!(panics, 1.0);

    // recovered replies are bit-identical to the plan oracle
    for i in 0..3 {
        let (input, want) = expected(&registry, "ic", i);
        let r = conn.post("/v1/infer/ic", &infer_body(&input)).unwrap();
        assert_eq!(r.status, 200, "post-respawn infer failed: {}", r.body.dumps());
        assert_eq!(
            output_of(&r.body).unwrap(),
            want,
            "ic sample {i}: post-respawn reply diverged from run_sample"
        );
    }

    // only the faulted model saw failures
    let kws_panics = poll_gauge(addr, "kws", "worker_panics", |v| v == 0.0);
    assert_eq!(kws_panics, 0.0);
    let m = conn.get("/metrics").unwrap();
    let kws = m.body.get("models").unwrap().get("kws").unwrap();
    assert_eq!(kws.get("errors").unwrap().as_f64().unwrap(), 0.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// A stalled ic worker ages its queue past `max_wait + infer_budget`:
/// the backlog sheds as 504s at dequeue, the stalled batch itself still
/// completes, and kws stays live throughout.
#[test]
fn engine_stall_expires_backlog_while_other_model_stays_live() {
    let policy = BatchPolicy {
        max_batch: 1, // the stall victim rides alone; the rest queue up
        max_wait_us: 1_000,
        infer_budget_us: 50_000, // 51 ms deadline window
        ..BatchPolicy::default()
    };
    let (registry, server) =
        start_faulted(&["ic", "kws"], policy, "engine_stall:ic:always:400");
    let addr = server.addr();
    let (ic_in, ic_want) = expected(&registry, "ic", 0);
    let (kws_in, kws_want) = expected(&registry, "kws", 0);

    // slow victim: dequeued fresh (inside its deadline), then stalled
    // 400 ms mid-execution — late but correct
    let ic_in_slow = ic_in.clone();
    let slow = std::thread::spawn(move || {
        let mut conn = Conn::connect(addr).unwrap();
        conn.post("/v1/infer/ic", &infer_body(&ic_in_slow)).unwrap()
    });
    // while the worker stalls, these age past their 51 ms deadline
    std::thread::sleep(Duration::from_millis(100));
    let backlog: Vec<_> = (0..2)
        .map(|_| {
            let input = ic_in.clone();
            std::thread::spawn(move || {
                let mut conn = Conn::connect(addr).unwrap();
                conn.post("/v1/infer/ic", &infer_body(&input)).unwrap()
            })
        })
        .collect();
    // kws lives through the whole ic stall
    let mut conn = Conn::connect(addr).unwrap();
    let r = conn.post("/v1/infer/kws", &infer_body(&kws_in)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), kws_want);

    let r = slow.join().unwrap();
    assert_eq!(r.status, 200, "stalled-but-live batch must complete: {}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), ic_want);
    for (i, h) in backlog.into_iter().enumerate() {
        let r = h.join().unwrap();
        assert_eq!(
            r.status, 504,
            "backlog request {i}: expected a deadline 504, got {} {}",
            r.status,
            r.body.dumps()
        );
    }
    poll_gauge(addr, "ic", "deadline_expired_total", |v| v >= 2.0);
    poll_gauge(addr, "kws", "deadline_expired_total", |v| v == 0.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// K consecutive panics open the breaker: refusals answer 503 with a
/// retry hint, `/readyz` reports the model (and here the whole node)
/// not ready, the breaker half-opens after its cooldown, and the first
/// success closes it again — with bit-identical numerics.
#[test]
fn breaker_opens_after_k_panics_then_half_closes() {
    let (registry, server) = start_faulted(
        &["ic"],
        BatchPolicy { max_wait_us: 1_000, ..BatchPolicy::default() },
        "engine_panic:ic:times=3",
    );
    let addr = server.addr();
    let (input, want) = expected(&registry, "ic", 0);
    let mut conn = Conn::connect(addr).unwrap();

    // three sequential requests = three one-request batches = three
    // consecutive panics (replies arrive at panic time, so waiting for
    // each 500 keeps the batches separate)
    for i in 0..3 {
        let r = conn.post("/v1/infer/ic", &infer_body(&input)).unwrap();
        assert_eq!(r.status, 500, "panic {i}: {}", r.body.dumps());
    }
    // the 500 reply races the supervisor's on_panic by a hair (the
    // sender drops during unwinding); wait for the breaker gauge
    // before testing admission
    poll_gauge(addr, "ic", "breaker_state", |v| v == 2.0);

    // breaker open: refused at the door with a retry hint
    let r = conn.post("/v1/infer/ic", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 503, "open breaker must refuse: {}", r.body.dumps());
    let retry = r.body.get("retry_after_s").unwrap().as_f64().unwrap();
    assert!(retry >= 1.0, "refusal must carry a retry hint, got {retry}");
    let rz = conn.get("/readyz").unwrap();
    assert_eq!(rz.status, 503, "only model open => node not ready");
    let ic = rz.body.get("models").unwrap().get("ic").unwrap();
    assert_eq!(ic.get("breaker").unwrap().as_str().unwrap(), "open");

    // cooldown elapses -> half-open admits a probe; the fault budget
    // (times=3) is exhausted, so the probe succeeds and closes the
    // breaker
    std::thread::sleep(Duration::from_millis(400));
    let r = conn.post("/v1/infer/ic", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200, "half-open probe must pass: {}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), want, "post-breaker reply diverged");
    let rz = conn.get("/readyz").unwrap();
    assert_eq!(rz.status, 200, "closed breaker => ready: {}", rz.body.dumps());

    let m = conn.get("/metrics").unwrap();
    let ic = m.body.get("models").unwrap().get("ic").unwrap();
    assert_eq!(ic.get("breaker_opens").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(ic.get("breaker_state").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(ic.get("breaker_state_name").unwrap().as_str().unwrap(), "closed");
    assert_eq!(ic.get("worker_panics").unwrap().as_f64().unwrap(), 3.0);
    assert!(ic.get("breaker_rejects").unwrap().as_f64().unwrap() >= 1.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// The queue-full failpoint exercises the shed path without real
/// overload: one 503, then normal service.
#[test]
fn queue_full_fault_sheds_once_then_recovers() {
    let (registry, server) =
        start_faulted(&["ad"], BatchPolicy::default(), "queue_full:ad:once");
    let addr = server.addr();
    let (input, want) = expected(&registry, "ad", 0);
    let mut conn = Conn::connect(addr).unwrap();

    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 503, "queue_full fault must shed: {}", r.body.dumps());
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), want);

    let m = conn.get("/metrics").unwrap();
    let ad = m.body.get("models").unwrap().get("ad").unwrap();
    assert_eq!(ad.get("shed").unwrap().as_f64().unwrap(), 1.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// The write half of the socket: a `write_stall` failpoint flushes a
/// partial reply, sleeps, then finishes.  The delayed reply must still
/// frame one intact response with bit-correct output, the server must
/// close the connection (no stalled keep-alive slot), and the stall
/// must be visible in the top-level `write_stalls` gauge.
#[test]
fn write_stall_delivers_intact_reply_then_closes() {
    let (registry, server) =
        start_faulted(&["ad"], BatchPolicy::default(), "write_stall:*:once:150");
    let addr = server.addr();
    let (input, want) = expected(&registry, "ad", 0);

    // raw socket: read_to_string only returns at EOF, so a completed
    // read proves the forced `Connection: close` actually closed us
    let payload = infer_body(&input);
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let t0 = Instant::now();
    write!(
        s,
        "POST /v1/infer/ad HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        payload.len(),
        payload
    )
    .unwrap();
    s.flush().unwrap();
    let mut reply = String::new();
    s.read_to_string(&mut reply).unwrap();
    assert!(
        t0.elapsed() >= Duration::from_millis(150),
        "reply arrived before the injected stall elapsed"
    );
    assert!(reply.starts_with("HTTP/1.1 200 "), "stalled reply got: {reply:?}");
    assert!(reply.contains("Connection: close\r\n"), "{reply:?}");
    let body_at = reply.find("\r\n\r\n").unwrap() + 4;
    let body = cwmix::minijson::parse_bytes(reply[body_at..].as_bytes()).unwrap();
    assert_eq!(
        output_of(&body).unwrap(),
        want,
        "mid-write stall corrupted the reply"
    );

    // once: the next request is unstalled and keeps its connection
    let mut conn = Conn::connect(addr).unwrap();
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), want);
    let m = conn.get("/metrics").unwrap();
    assert!(m.body.get("write_stalls").unwrap().as_f64().unwrap() >= 1.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// The reaper: a client that goes quiet mid-request is answered 408
/// and counted as a slow-client close; an idle keep-alive connection is
/// reaped silently — both visible in `/metrics`.
#[test]
fn slow_and_idle_clients_are_reaped_and_counted() {
    let reg_cfg = RegistryConfig {
        benches: vec!["ad".to_string()],
        ..RegistryConfig::default()
    };
    let registry = Arc::new(ModelRegistry::build(&reg_cfg).unwrap());
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        read_timeout: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let server = serve(Arc::clone(&registry), cfg).unwrap();
    let addr = server.addr();

    // slow client: half a request, then silence — the reaper must
    // answer 408 and close, freeing the handler thread
    let mut slow = std::net::TcpStream::connect(addr).unwrap();
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    slow.write_all(b"POST /v1/infer/ad HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"inp")
        .unwrap();
    slow.flush().unwrap();
    let mut reply = String::new();
    slow.read_to_string(&mut reply).unwrap(); // server closes after the 408
    assert!(reply.starts_with("HTTP/1.1 408 "), "slow client got: {reply:?}");

    // idle client: connects, says nothing, gets reaped without a reply
    let mut idle = std::net::TcpStream::connect(addr).unwrap();
    idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut end = Vec::new();
    idle.read_to_end(&mut end).unwrap();
    assert!(end.is_empty(), "idle reap must be silent, got {end:?}");

    let mut conn = Conn::connect(addr).unwrap();
    let m = conn.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    assert!(m.body.get("slow_client_closes").unwrap().as_f64().unwrap() >= 1.0);
    assert!(m.body.get("idle_reaped").unwrap().as_f64().unwrap() >= 1.0);
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// Registry-side failpoints: an injected load error or a flipped byte
/// in the `.cwm` must fall back to compilation — never a dead server,
/// never silently different numerics.
#[test]
fn registry_load_faults_fall_back_to_compile() {
    use cwmix::engine::{PackedBackend, Provenance};
    use cwmix::serve::registry::build_model;

    let dir = std::env::temp_dir().join(format!("cwm_chaos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let prov = Provenance { assignment: "stripy".to_string(), seed: 0 };
    for bench in ["ic", "ad"] {
        let (_, _, plan) =
            build_model(bench, &PackedBackend, "stripy", 0, &dir.join("no-artifacts"))
                .unwrap();
        std::fs::write(
            dir.join(format!("{bench}.cwm")),
            plan.to_modelpack_with(Some(&prov)),
        )
        .unwrap();
    }

    // control: disarmed faults cold-start both models from their packs
    let cfg = RegistryConfig {
        benches: vec!["ic".to_string(), "ad".to_string()],
        modelpack_dir: Some(dir.clone()),
        ..RegistryConfig::default()
    };
    let reg = ModelRegistry::build(&cfg).unwrap();
    assert_eq!(reg.get("ic").unwrap().startup().source, "modelpack");
    assert_eq!(reg.get("ad").unwrap().startup().source, "modelpack");
    reg.shutdown();

    // armed: ic's pack read "fails", ad's pack is corrupted in memory —
    // both models must come up anyway, via the compile path
    let cfg = RegistryConfig {
        faults: Arc::new(
            Faults::parse("registry_load_error:ic:once,artifact_corrupt:ad:once", 0)
                .unwrap(),
        ),
        ..cfg
    };
    let reg = ModelRegistry::build(&cfg).unwrap();
    assert_eq!(reg.get("ic").unwrap().startup().source, "compile");
    assert_eq!(reg.get("ad").unwrap().startup().source, "compile");
    // and the fallback serves the same numerics the pack would have
    for bench in ["ic", "ad"] {
        let plan = reg.get(bench).unwrap().plan();
        let feat = plan.feat();
        let ds = make_dataset(bench, Split::Test, 1, 0);
        let mut arena = plan.arena();
        let got = plan.run_sample(&mut arena, &ds.x[..feat]).unwrap();
        let loaded = cwmix::engine::ExecPlan::from_modelpack(
            &std::fs::read(dir.join(format!("{bench}.cwm"))).unwrap(),
        )
        .unwrap();
        let mut arena = loaded.arena();
        assert_eq!(
            got,
            loaded.run_sample(&mut arena, &ds.x[..feat]).unwrap(),
            "{bench}: fallback compile diverged from the pack"
        );
    }
    reg.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Request-id chaos (DESIGN.md §9): a request whose worker panics
/// still carries its admission-stamped id into the explicit 500 reply,
/// and the spans recorded before the worker died — request, admission,
/// queue_wait — survive in `/v1/trace`.  The shell harness
/// (`tools/chaos_smoke.sh`) additionally greps the same id out of the
/// server's structured log line; here we prove the in-process half.
#[test]
fn request_id_survives_worker_panic() {
    cwmix::trace::set_enabled(true);
    let (registry, server) = start_faulted(
        &["ad"],
        BatchPolicy { max_wait_us: 1_000, ..BatchPolicy::default() },
        "engine_panic:ad:once",
    );
    let addr = server.addr();
    let (input, want) = expected(&registry, "ad", 0);
    let mut conn = Conn::connect(addr).unwrap();

    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 500, "panicked batch must answer 500: {}", r.body.dumps());
    let id = r.body.get("request_id").unwrap().as_f64().unwrap();
    assert!(id >= 1.0, "500 reply lost its request id: {}", r.body.dumps());

    // spans recorded at admission/dequeue time outlive the worker
    let t = conn.get("/v1/trace?last=4096").unwrap();
    assert_eq!(t.status, 200);
    let events = t.body.get("traceEvents").unwrap().as_arr().unwrap();
    let names: Vec<String> = events
        .iter()
        .filter(|e| {
            e.get("args").unwrap().get("req").unwrap().as_f64().unwrap() == id
        })
        .map(|e| e.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    for need in ["request", "admission", "queue_wait"] {
        assert!(
            names.iter().any(|n| n == need),
            "span {need} missing after panic: {names:?}"
        );
    }

    // recovery answers bit-identically with a fresh, later id
    poll_gauge(addr, "ad", "worker_respawns", |v| v >= 1.0);
    let r = conn.post("/v1/infer/ad", &infer_body(&input)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body.dumps());
    assert_eq!(output_of(&r.body).unwrap(), want);
    let id2 = r.body.get("request_id").unwrap().as_f64().unwrap();
    assert!(id2 > id, "request ids must be monotone ({id2} after {id})");
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}

/// Json sanity for the supervision surface: `/metrics` stays parseable
/// with gauges injected (guards the bench_serve scrape).
#[test]
fn metrics_supervision_gauges_have_stable_names() {
    let (registry, server) = start_faulted(&["ad"], BatchPolicy::default(), "");
    let mut conn = Conn::connect(server.addr()).unwrap();
    let m = conn.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    let ad = m.body.get("models").unwrap().get("ad").unwrap();
    for key in [
        "worker_panics",
        "worker_respawns",
        "deadline_expired_total",
        "breaker_rejects",
        "breaker_state",
        "breaker_opens",
    ] {
        assert!(
            matches!(ad.get(key), Ok(Json::Num(_))),
            "missing or wrong-typed gauge {key}"
        );
    }
    assert_eq!(ad.get("breaker_state_name").unwrap().as_str().unwrap(), "closed");
    drop(conn);
    server.stop().unwrap();
    registry.shutdown();
}
