//! `cwmix profile` acceptance: the per-layer profiler is deterministic
//! across runs on the same seed (same layer sequence, same predicted
//! shares — the measured times may wobble, the *structure* may not),
//! its JSON doc is well-formed, and the human table carries the
//! per-layer rows plus the model-fit summary.
//!
//! Spawns the real binary (`CARGO_BIN_EXE_cwmix`), so this also guards
//! the flag surface the `profile-smoke` CI job drives.

use std::process::Command;

use cwmix::minijson::{parse, Json};

fn run_profile(args: &[&str]) -> (String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_cwmix"))
        .arg("profile")
        .args(args)
        .output()
        .expect("spawning cwmix profile");
    assert!(
        out.status.success(),
        "cwmix profile {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    (
        String::from_utf8(out.stdout).expect("non-UTF-8 stdout"),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn bench_doc(doc: &Json) -> &Json {
    &doc.get("benches").unwrap().as_arr().unwrap()[0]
}

/// (name, predicted_share) sequence — the deterministic skeleton.
fn skeleton(doc: &Json) -> Vec<(String, f64)> {
    bench_doc(doc)
        .get("layers")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| {
            (
                l.get("name").unwrap().as_str().unwrap().to_string(),
                l.get("predicted_share").unwrap().as_f64().unwrap(),
            )
        })
        .collect()
}

#[test]
fn two_runs_same_seed_agree_on_structure() {
    let args = ["--bench", "ad", "--iters", "5", "--batch", "4", "--json", "-"];
    let (out1, _) = run_profile(&args);
    let (out2, _) = run_profile(&args);
    let d1 = parse(&out1).expect("run 1 stdout is not JSON");
    let d2 = parse(&out2).expect("run 2 stdout is not JSON");

    let s1 = skeleton(&d1);
    let s2 = skeleton(&d2);
    assert!(!s1.is_empty(), "no layers profiled");
    assert_eq!(s1, s2, "layer sequence / predicted shares diverged across runs");

    for d in [&d1, &d2] {
        let b = bench_doc(d);
        assert_eq!(b.get("bench").unwrap().as_str().unwrap(), "ad");
        let fit = b.get("spearman").unwrap().as_f64().unwrap();
        assert!((-1.0..=1.0).contains(&fit), "spearman {fit} out of range");
        // shares are normalized over the accounted nodes
        let sum: f64 = b
            .get("layers")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|l| l.get("share").unwrap().as_f64().unwrap())
            .sum();
        assert!((sum - 1.0).abs() < 1e-6, "measured shares sum to {sum}");
        // every profiled layer executed every pass
        let iters = b.get("iters").unwrap().as_f64().unwrap();
        for l in b.get("layers").unwrap().as_arr().unwrap() {
            assert_eq!(l.get("calls").unwrap().as_f64().unwrap(), iters);
            assert!(l.get("bytes_moved").unwrap().as_f64().unwrap() > 0.0);
        }
    }
}

#[test]
fn table_mode_prints_rows_and_fit_summary() {
    let (out, _) = run_profile(&["--bench", "ad", "--iters", "3", "--batch", "2"]);
    assert!(out.contains("== ad [packed] batch=2 iters=3 =="), "{out}");
    assert!(out.contains("layer"), "missing table header:\n{out}");
    assert!(out.contains("fit: spearman="), "missing fit summary:\n{out}");
    assert!(out.contains("coverage: nodes"), "missing coverage line:\n{out}");
}

#[test]
fn json_file_output_lands_on_disk() {
    let path = std::env::temp_dir().join(format!("cwmix_prof_{}.json", std::process::id()));
    let path_s = path.to_str().unwrap();
    let _ = run_profile(&["--bench", "ad", "--iters", "2", "--json", path_s]);
    let text = std::fs::read_to_string(&path).expect("profile JSON not written");
    let doc = parse(&text).expect("file output is not JSON");
    assert_eq!(doc.get("version").unwrap().as_f64().unwrap(), 1.0);
    std::fs::remove_file(&path).ok();
}
