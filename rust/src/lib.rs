//! # cwmix — Channel-wise Mixed-precision Assignment for edge DNN inference
//!
//! Rust + JAX + Pallas reproduction of Risso et al., *"Channel-wise
//! Mixed-precision Assignment for DNN Inference on Constrained Edge
//! Nodes"*, IGSC 2022.
//!
//! This crate is the **Layer-3 coordinator** of the three-layer stack
//! (see `DESIGN.md`): it owns the NAS training loop (Alg. 1), the λ-sweep
//! Pareto exploration (Fig. 3), the §III-C deployment transform, the MPIC
//! RISC-V simulator substrate, and the PJRT runtime that executes the
//! AOT-lowered JAX/Pallas graphs from `artifacts/`.  Python never runs on
//! any path in this crate.
//!
//! Module map:
//! * [`util`] — RNG, statistics (incl. AUC), timers, ASCII plots.
//! * [`minijson`] — dependency-free JSON (manifests, configs, results).
//! * [`tensor`] — small host tensors + `xla::Literal` conversion.
//! * [`data`] — the four synthetic MLPerf-Tiny-shaped dataset generators.
//! * [`models`] — benchmark model geometry parsed from the manifest.
//! * [`quant`] — affine/PACT quantization, sub-byte packing, assignments.
//! * [`energy`] — the MPIC `C(p_x, p_w)` LUT and Eq. (7)/(8) evaluation.
//! * [`mpic`] — the MPIC mixed-precision RISC-V simulator substrate.
//! * [`deploy`] — filter reordering / sub-convolution splitting (§III-C).
//! * [`runtime`] — PJRT client wrapper executing `artifacts/*.hlo.txt`.
//! * [`nas`] — the Alg. 1 three-phase DNAS driver.
//! * [`baselines`] — EdMIPS (layer-wise) and fixed-precision baselines.
//! * [`coordinator`] — λ sweeps, Pareto fronts, experiment registry.
//! * [`report`] — Fig. 3 / Fig. 4 style reporting.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod energy;
pub mod minijson;
pub mod models;
pub mod mpic;
pub mod nas;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

/// The searched bit-width set `P_W = P_X = {2, 4, 8}` (paper §III).
pub const PRECISIONS: [u32; 3] = [2, 4, 8];

/// Index of a precision inside [`PRECISIONS`].
pub fn precision_index(bits: u32) -> usize {
    match bits {
        2 => 0,
        4 => 1,
        8 => 2,
        _ => panic!("unsupported precision {bits}"),
    }
}
