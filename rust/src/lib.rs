//! # cwmix — Channel-wise Mixed-precision Assignment for edge DNN inference
//!
//! Rust + JAX + Pallas reproduction of Risso et al., *"Channel-wise
//! Mixed-precision Assignment for DNN Inference on Constrained Edge
//! Nodes"*, IGSC 2022.
//!
//! This crate is the **Layer-3 coordinator** of the three-layer stack
//! (see `DESIGN.md`): it owns the NAS training loop (Alg. 1), the λ-sweep
//! Pareto exploration (Fig. 3), the §III-C deployment transform, the
//! plan/execute integer inference engine, the MPIC RISC-V simulator
//! substrate, and the PJRT runtime that executes the AOT-lowered
//! JAX/Pallas graphs from `artifacts/`.  Python never runs on any path
//! in this crate.
//!
//! ## Feature flags
//!
//! * **default** — pure Rust: the deployment transform, the inference
//!   engine, the MPIC cost model, the builtin model zoo, reporting.
//!   Builds and tests green with no artifacts and no PJRT plugin.
//! * **`xla`** — enables [`runtime`] (PJRT), [`nas::trainer`],
//!   the search [`baselines`], [`deploy::verify`] and the λ-sweep
//!   driver.  Needs the real xla-rs bindings (see `rust/xla-stub`) and
//!   `make artifacts`.
//!
//! Module map:
//! * [`util`] — RNG, statistics (incl. AUC), timers, ASCII plots.
//! * [`minijson`] — dependency-free JSON (manifests, configs, results).
//! * [`tensor`] — small host tensors (+ `xla::Literal` conversion, xla).
//! * [`data`] — the four synthetic MLPerf-Tiny-shaped dataset generators.
//! * [`models`] — benchmark model geometry: manifest parsing + the
//!   builtin Rust [`models::zoo`] mirror of the four topologies.
//! * [`quant`] — affine/PACT quantization, sub-byte packing, assignments.
//! * [`energy`] — the MPIC `C(p_x, p_w)` LUT and Eq. (7)/(8) evaluation.
//! * [`mpic`] — the MPIC mixed-precision RISC-V simulator substrate
//!   (scalar oracle executor + cost accounting).
//! * [`deploy`] — filter reordering / sub-convolution splitting (§III-C).
//! * [`engine`] — compile-once/run-many inference engine: `ExecPlan`
//!   plan/execute split, pluggable [`engine::KernelBackend`]s
//!   (`reference` scalar oracle, `packed` sub-byte kernels), threaded
//!   batch execution, `.cwm` modelpack serialization
//!   ([`engine::pack`]).
//! * [`modelpack`] — the `.cwm` compiled-model artifact container:
//!   versioned/checksummed sections, hostile-input-hardened readers,
//!   zero-copy views into one owned aligned buffer.
//! * [`serve`] — resident multi-model inference server: `ModelRegistry`
//!   of precompiled `ExecPlan`s, dynamic micro-batching with bounded
//!   admission, pure-`std` HTTP/1.1 front end, serving metrics.
//! * [`trace`] — end-to-end request tracing: lock-free per-thread span
//!   rings (single-branch disabled path), request-id allocation,
//!   chrome://tracing export (`GET /v1/trace`, `--trace-out`).
//! * [`runtime`] — PJRT client wrapper executing `artifacts/*.hlo.txt`
//!   (`xla` feature).
//! * [`nas`] — the Alg. 1 three-phase DNAS driver (trainer: `xla`).
//! * [`baselines`] — EdMIPS (layer-wise) and fixed-precision baselines.
//! * [`coordinator`] — λ sweeps, Pareto fronts, experiment registry.
//! * [`report`] — Fig. 3 / Fig. 4 style reporting.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod energy;
pub mod engine;
pub mod minijson;
pub mod modelpack;
pub mod models;
pub mod mpic;
pub mod nas;
pub mod quant;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod util;

/// The searched bit-width set `P_W = P_X = {2, 4, 8}` (paper §III).
pub const PRECISIONS: [u32; 3] = [2, 4, 8];

/// Index of a precision inside [`PRECISIONS`].
pub fn precision_index(bits: u32) -> usize {
    match bits {
        2 => 0,
        4 => 1,
        8 => 2,
        _ => panic!("unsupported precision {bits}"),
    }
}
