//! Comparison baselines of Fig. 3.
//!
//! * **EdMIPS** (Cai et al., CVPR 2020) — layer-wise DNAS.  Per the
//!   paper's fair-comparison protocol it shares *everything* with our
//!   method (PACT quantizer, 20/80 alternation, tau annealing, LUT
//!   regularizer) except the gamma granularity — so it is simply the
//!   [`Mode::LayerWise`] search space driven by the same
//!   [`crate::nas::Trainer`] (the `search_*_lw` graphs).
//!
//! * **Fixed precision** `wNxM` — uniform N-bit weights / M-bit
//!   activations QAT, N, M in {2, 4, 8}.  Runs as a warmup-restore +
//!   hard-assignment QAT phase (the same `train_w_hard` graph that
//!   serves warmup and fine-tuning).

#[cfg(feature = "xla")]
use anyhow::Result;

#[cfg(feature = "xla")]
use crate::nas::trainer::{StateSnapshot, Trainer};
#[cfg(feature = "xla")]
use crate::nas::{Mode, SearchConfig, SearchResult};
use crate::nas::Target;
#[cfg(feature = "xla")]
use crate::quant::Assignment;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;

/// The `wNxM` grid of Fig. 3.  For the size plots the paper only shows
/// `wNx8` (activation bits don't change model size); for energy it shows
/// all combos except the non-convergent `w?x2` on VWW — the caller
/// filters, we just train.
pub fn fixed_grid(weights: &[u32], acts: &[u32]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    for &w in weights {
        for &x in acts {
            out.push((w, x));
        }
    }
    out
}

/// Train one fixed-precision baseline from a shared warmup snapshot.
#[cfg(feature = "xla")]
pub fn run_fixed(
    rt: &Runtime,
    cfg: &SearchConfig,
    warm: &StateSnapshot,
    wbits: u32,
    xbits: u32,
) -> Result<SearchResult> {
    let mut cfg = cfg.clone();
    cfg.mode = Mode::ChannelWise; // irrelevant for hard assignments
    let mut tr = Trainer::new(rt, cfg)?;
    tr.restore(warm);
    let a = Assignment::fixed(
        &tr.manifest.qnames(), &tr.manifest.qcouts(), wbits, xbits);
    let epochs = tr.cfg.finetune_epochs + tr.cfg.search_epochs / 2;
    tr.train_hard_phase("baseline", epochs, &a, true)?;
    let mut r = tr.result_for(&a)?;
    r.config_label = format!("{}-w{wbits}x{xbits}", tr.cfg.bench);
    Ok(r)
}

/// Run the EdMIPS comparison search (layer-wise mode) for one lambda.
#[cfg(feature = "xla")]
pub fn run_edmips(
    rt: &Runtime,
    cfg: &SearchConfig,
    warm: &StateSnapshot,
) -> Result<SearchResult> {
    let mut cfg = cfg.clone();
    cfg.mode = Mode::LayerWise;
    let mut tr = Trainer::new(rt, cfg)?;
    tr.restore(warm);
    tr.run_after_warmup()
}

/// Run our channel-wise search for one lambda.
#[cfg(feature = "xla")]
pub fn run_ours(
    rt: &Runtime,
    cfg: &SearchConfig,
    warm: &StateSnapshot,
) -> Result<SearchResult> {
    let mut cfg = cfg.clone();
    cfg.mode = Mode::ChannelWise;
    let mut tr = Trainer::new(rt, cfg)?;
    tr.restore(warm);
    tr.run_after_warmup()
}

/// Shared warmup for a whole sweep (Alg. 1: "Warmup needs to be performed
/// only once, reusing the result for multiple searches").
#[cfg(feature = "xla")]
pub fn shared_warmup(rt: &Runtime, cfg: &SearchConfig) -> Result<StateSnapshot> {
    let mut tr = Trainer::new(rt, cfg.clone())?;
    tr.warmup()?;
    Ok(tr.snapshot())
}

/// Which fixed baselines Fig. 3 shows for a (bench, target) pair.
/// `quick` keeps the representative diagonal only (one-core budgets).
pub fn fig3_fixed_combos(bench: &str, target: Target, quick: bool) -> Vec<(u32, u32)> {
    match target {
        // memory plots: only wNx8 (activation bits don't affect size)
        Target::Size => fixed_grid(&[2, 4, 8], &[8]),
        Target::Energy if quick => vec![(8, 8), (4, 4), (2, 2)],
        Target::Energy => {
            let acts: &[u32] = if bench == "vww" {
                &[4, 8] // w?x2 does not converge on VWW (paper §IV-B)
            } else {
                &[2, 4, 8]
            };
            fixed_grid(&[2, 4, 8], acts)
        }
    }
}
