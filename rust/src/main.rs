//! `cwmix` CLI — launcher for searches, sweeps, evaluation, deployment
//! and reporting.  See `cwmix help` or README.md §Quickstart.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cwmix::coordinator::cli::dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
