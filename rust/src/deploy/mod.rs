//! §III-C deployment transform: filter reordering + sub-convolution split.
//!
//! The DNAS emits arbitrary per-channel bit-widths (Fig. 2 top-left).  To
//! run on single-precision mixed kernels (MPIC / CMix-NN style), each
//! layer's filters are **reordered** so equal-precision filters are
//! contiguous, the layer is **split** into ≤ |P_W| single-precision
//! sub-convolutions, and every *consumer* of the layer's output gets its
//! weights **permuted along C_in** so each weight still multiplies the
//! right activation (Fig. 2 bottom).  All offline, zero runtime cost
//! beyond scheduling the sub-layers.
//!
//! Two constraints the paper leaves implicit, handled here explicitly:
//!
//! * **Residual adds** tie channel identities of several producers
//!   together — all tensors joined by elementwise adds form one *channel
//!   space* (union-find below) and must share a single permutation.  The
//!   permutation sorts channels by the tuple of the space's producers'
//!   bit-widths, so *every* producer still sees its own channels grouped
//!   into contiguous runs (at most |P_W|^k runs for k producers — 9 for a
//!   2-producer residual join, each still a valid single-precision
//!   sub-convolution).
//! * **Depthwise convolutions** preserve channel identity, so a dwconv's
//!   output lives in the *same* space as its input and its own per-channel
//!   bits simply join that space's sort key.
//!
//! The network *output* space is reordered like every other space (not
//! doing so fragments the last layer into up to C_out sub-convolutions);
//! the resulting output permutation is recorded in
//! [`DeployedModel::output_perm`] and undone when results are read — a
//! free relabeling of logits / reconstruction indices on device.
//!
//! BN folding: `y = (acc * s_w[c] * eps_x - mean) * g / sqrt(var+eps) + b`
//! collapses into `y = acc * A[c] + B[c]`, precomputed here so the MPIC
//! simulator's per-channel epilogue is two flops.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::models::{LayerSpec, Manifest};
use crate::quant::{quantize_weights_perchannel, Assignment};
use crate::tensor::Tensor;

#[cfg(feature = "xla")]
pub mod verify;

const BN_EPS: f32 = 1e-3;

/// A contiguous single-precision run of output channels (one sub-conv).
#[derive(Clone, Debug, PartialEq)]
pub struct SubConv {
    pub bits: u32,
    pub start: usize,
    pub len: usize,
}

/// A deployable quantized layer, fully folded and permuted.
#[derive(Clone, Debug)]
pub struct DeployedLayer {
    pub spec: LayerSpec,
    /// input activation quantization (this layer's PACT)
    pub act_bits: u32,
    pub alpha: f32,
    /// integer weights, (cout x K) row-major, permuted rows *and* columns
    pub qweights: Vec<i32>,
    /// per permuted output channel
    pub w_scale: Vec<f32>,
    pub weight_bits: Vec<u32>,
    /// folded epilogue: y[c] = acc[c] * a_fold[c] + b_fold[c]
    pub a_fold: Vec<f32>,
    pub b_fold: Vec<f32>,
    /// contiguous single-precision runs covering all channels
    pub groups: Vec<SubConv>,
}

impl DeployedLayer {
    /// K = weights per output channel.
    pub fn k(&self) -> usize {
        self.spec.weights_per_channel
    }

    /// Packed flash footprint of this layer's weights, in bytes.
    pub fn packed_bytes(&self) -> usize {
        crate::quant::packed_weight_bytes(
            self.spec.cout, self.k(), &self.weight_bits)
    }
}

/// A node of the deployed graph (quantized layer or structural op).
#[derive(Clone, Debug)]
pub struct DeployedNode {
    pub spec: LayerSpec,
    pub layer: Option<DeployedLayer>,
}

/// The §III-C output: a reordered, split, BN-folded network.
#[derive(Clone, Debug)]
pub struct DeployedModel {
    pub bench: String,
    pub loss: String,
    pub n_classes: usize,
    pub input_shape: Vec<usize>,
    pub nodes: Vec<DeployedNode>,
    /// permutation applied to each named space (diagnostics/tests)
    pub space_perms: HashMap<String, Vec<usize>>,
    /// output-channel permutation: executed output index `i` holds the
    /// natural channel `output_perm[i]` (the executor un-permutes final
    /// results; on-device this is a free label remap of the logits)
    pub output_perm: Vec<usize>,
}

impl DeployedModel {
    pub fn qlayers(&self) -> impl Iterator<Item = &DeployedLayer> {
        self.nodes.iter().filter_map(|n| n.layer.as_ref())
    }

    /// Total packed weight bytes (the Fig. 3 memory axis).
    pub fn packed_bytes(&self) -> usize {
        self.qlayers().map(|l| l.packed_bytes()).sum()
    }

    /// Total sub-convolution count (scheduling overhead indicator).
    pub fn n_subconvs(&self) -> usize {
        self.qlayers().map(|l| l.groups.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Union-find over channel spaces.
// ---------------------------------------------------------------------------

struct UnionFind {
    parent: Vec<usize>,
}

impl UnionFind {
    fn new(n: usize) -> Self {
        UnionFind { parent: (0..n).collect() }
    }

    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let r = self.find(self.parent[x]);
            self.parent[x] = r;
            r
        } else {
            x
        }
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[rb] = ra;
        }
    }
}

/// Channel-space analysis result.
struct Spaces {
    /// space root id for each layer index's *output* tensor
    out_space: Vec<usize>,
    /// space root id for each layer index's *input* tensor
    in_space: Vec<usize>,
    /// channels per space root
    width: HashMap<usize, usize>,
}

/// Walk the graph, assigning tensor spaces and uniting over adds/dwconvs.
fn analyze_spaces(layers: &[LayerSpec]) -> Result<Spaces> {
    let n = layers.len();
    let mut uf = UnionFind::new(n + 1); // node i's output = space i; n = input image
    let input_space = n;
    let mut cur = input_space;
    let mut cur_width = 0usize; // input channels resolved per layer below
    let mut tags: HashMap<String, (usize, usize)> = HashMap::new();
    let mut out_space = vec![0usize; n];
    let mut in_space = vec![0usize; n];
    let width: HashMap<usize, usize> = HashMap::new();

    for (i, l) in layers.iter().enumerate() {
        if let Some(tag) = &l.input_from {
            let &(s, w) = tags
                .get(tag)
                .ok_or_else(|| anyhow!("unknown tag {tag}"))?;
            cur = s;
            cur_width = w;
        }
        if i == 0 || (cur == input_space && cur_width == 0) {
            cur_width = l.cin.max(cur_width);
        }
        in_space[i] = cur;
        match l.kind.as_str() {
            "conv" | "fc" => {
                cur = i;
                cur_width = l.cout;
            }
            "dwconv" => {
                // channel identity preserved: output shares the input space
                uf.union(cur, i);
                cur = i;
                cur_width = l.cout;
            }
            "avgpool" | "flatten" | "tap" => {
                // channel space passes through (flatten keeps C innermost)
            }
            "add" => {
                let tag = l.add_from.as_ref().ok_or_else(|| anyhow!("add without tag"))?;
                let &(s, w) = tags.get(tag).ok_or_else(|| anyhow!("unknown tag {tag}"))?;
                if w != cur_width {
                    bail!("add width mismatch {w} vs {cur_width}");
                }
                uf.union(cur, s);
            }
            other => bail!("unknown kind {other}"),
        }
        // residual epilogue carried *on* a quant layer (conv+add fusion):
        // its output joins the saved tensor's channel space.
        if l.is_quant() {
            if let Some(tag) = &l.add_from {
                let &(s, w) = tags.get(tag).ok_or_else(|| anyhow!("unknown tag {tag}"))?;
                if w != cur_width {
                    bail!("residual width mismatch {w} vs {cur_width} at {}", l.name);
                }
                uf.union(cur, s);
            }
        }
        out_space[i] = cur;
        if let Some(tag) = &l.save_as {
            tags.insert(tag.clone(), (cur, cur_width));
        }
    }

    // resolve roots
    let mut spaces = Spaces {
        out_space: vec![0; n],
        in_space: vec![0; n],
        width,
    };
    for i in 0..n {
        spaces.out_space[i] = uf.find(out_space[i]);
        spaces.in_space[i] = uf.find(in_space[i]);
    }
    // widths: quant layer outputs define their space width
    for (i, l) in layers.iter().enumerate() {
        if l.is_quant() {
            spaces.width.insert(spaces.out_space[i], l.cout);
        }
    }
    let input_root = uf.find(input_space);
    spaces.width.entry(input_root).or_insert_with(|| {
        layers
            .iter()
            .find(|l| l.is_quant())
            .map(|l| l.cin)
            .unwrap_or(0)
    });
    Ok(spaces)
}

// ---------------------------------------------------------------------------
// Build.
// ---------------------------------------------------------------------------

/// Build the deployed model from trained parameters and an assignment.
///
/// `params` / `bn_state` map manifest tensor names (`<layer>.w`, ...) to
/// trained values; `assign.layers` follows qidx order.
pub fn build(
    manifest: &Manifest,
    params: &HashMap<String, Tensor>,
    bn_state: &HashMap<String, Tensor>,
    assign: &Assignment,
) -> Result<DeployedModel> {
    let layers = &manifest.layers;
    let spaces = analyze_spaces(layers)?;
    let qlayers = manifest.qlayers();
    if qlayers.len() != assign.layers.len() {
        bail!(
            "assignment has {} layers, model has {}",
            assign.layers.len(),
            qlayers.len()
        );
    }
    let by_name: HashMap<&str, usize> = qlayers
        .iter()
        .enumerate()
        .map(|(qi, l)| (l.name.as_str(), qi))
        .collect();

    // ---- 1. permutation per space -----------------------------------------
    // producers of a space = quant layers whose output lands in it
    let mut producers: HashMap<usize, Vec<usize>> = HashMap::new(); // space -> layer idx
    for (i, l) in layers.iter().enumerate() {
        if l.is_quant() {
            producers.entry(spaces.out_space[i]).or_default().push(i);
        }
    }
    // The output space IS reordered too (§Perf: pinning it to identity
    // fragments the final layer into up to C_out sub-convs); the executor
    // un-permutes the final buffer, which on-device is a free relabeling.
    let last_q = layers
        .iter()
        .enumerate()
        .rev()
        .find(|(_, l)| l.is_quant())
        .map(|(i, _)| spaces.out_space[i])
        .ok_or_else(|| anyhow!("no quant layers"))?;

    let mut space_perm: HashMap<usize, Vec<usize>> = HashMap::new();
    for (&space, prods) in &producers {
        let width = *spaces
            .width
            .get(&space)
            .ok_or_else(|| anyhow!("unknown space width"))?;
        // sort key: bits per producer (name-sorted for determinism), then idx
        let mut prods_sorted = prods.clone();
        prods_sorted.sort_by_key(|&i| layers[i].name.clone());
        let mut perm: Vec<usize> = (0..width).collect();
        perm.sort_by_key(|&c| {
            let mut key: Vec<u32> = Vec::with_capacity(prods_sorted.len());
            for &li in &prods_sorted {
                let qi = by_name[layers[li].name.as_str()];
                key.push(assign.layers[qi].weight_bits[c]);
            }
            (key, c)
        });
        space_perm.insert(space, perm);
    }
    // spaces without producers (input image) are identity
    let identity_for = |space: usize, width: usize| -> Vec<usize> {
        let _ = space;
        (0..width).collect()
    };

    // ---- 2. per-layer fold + permute ---------------------------------------
    let mut nodes = Vec::with_capacity(layers.len());
    for (i, l) in layers.iter().enumerate() {
        if !l.is_quant() {
            nodes.push(DeployedNode { spec: l.clone(), layer: None });
            continue;
        }
        let qi = by_name[l.name.as_str()];
        let la = &assign.layers[qi];
        let w = params
            .get(&format!("{}.w", l.name))
            .ok_or_else(|| anyhow!("missing weights for {}", l.name))?;
        let cout = l.cout;
        let k = l.weights_per_channel;
        if w.len() != cout * k {
            bail!("weight size mismatch for {}", l.name);
        }

        let out_perm = space_perm
            .get(&spaces.out_space[i])
            .cloned()
            .unwrap_or_else(|| identity_for(spaces.out_space[i], cout));
        let in_width = if l.kind == "fc" { l.cin } else { l.cin };
        let in_perm = space_perm
            .get(&spaces.in_space[i])
            .cloned()
            .unwrap_or_else(|| identity_for(spaces.in_space[i], in_width));

        // --- permute weights: rows by out_perm, input-channel cols by in_perm
        // conv layout (cout, kx, ky, cin_g); fc layout (cout, cin)
        let cin_g = if l.kind == "dwconv" { 1 } else { l.cin };
        let spatial = l.kx * l.ky;
        let mut wperm = vec![0.0f32; cout * k];
        for (new_c, &old_c) in out_perm.iter().enumerate() {
            for s in 0..spatial {
                for ci in 0..cin_g {
                    let src_ci = if l.kind == "conv" && in_perm.len() == cin_g {
                        in_perm[ci]
                    } else if l.kind == "fc" && in_perm.len() == cin_g {
                        in_perm[ci]
                    } else {
                        ci
                    };
                    let src = old_c * k + s * cin_g + src_ci;
                    let dst = new_c * k + s * cin_g + ci;
                    wperm[dst] = w.data()[src];
                }
            }
        }
        // dwconv: the single input channel of filter c IS channel c — row
        // permutation already aligns it with the (shared) space perm.

        // --- per-channel bits in permuted order + integer quantization
        let bits_perm: Vec<u32> = out_perm.iter().map(|&c| la.weight_bits[c]).collect();
        let (qw, w_scale) = quantize_weights_perchannel(&wperm, cout, &bits_perm);

        // --- epilogue fold (BN with running stats, optional bias)
        let mut a_fold = vec![0.0f32; cout];
        let mut b_fold = vec![0.0f32; cout];
        let bias = params.get(&format!("{}.b", l.name));
        let (bn_s, bn_b, bn_m, bn_v) = if l.bn {
            (
                params.get(&format!("{}.bn_scale", l.name)),
                params.get(&format!("{}.bn_bias", l.name)),
                bn_state.get(&format!("{}.bn_mean", l.name)),
                bn_state.get(&format!("{}.bn_var", l.name)),
            )
        } else {
            (None, None, None, None)
        };
        for (new_c, &old_c) in out_perm.iter().enumerate() {
            let m = w_scale[new_c]; // acc -> weight-scaled float (x step applied in exec)
            let (mut a, mut b) = (m, 0.0f32);
            if l.bn {
                let g = bn_s.unwrap().data()[old_c];
                let be = bn_b.unwrap().data()[old_c];
                let mu = bn_m.unwrap().data()[old_c];
                let va = bn_v.unwrap().data()[old_c];
                let inv = g / (va + BN_EPS).sqrt();
                a = m * inv;
                b = be - mu * inv;
            } else if let Some(bias) = bias {
                b = bias.data()[old_c];
            }
            a_fold[new_c] = a;
            b_fold[new_c] = b;
        }

        // --- contiguous single-precision runs
        let mut groups: Vec<SubConv> = Vec::new();
        for (c, &b) in bits_perm.iter().enumerate() {
            match groups.last_mut() {
                Some(g) if g.bits == b && g.start + g.len == c => g.len += 1,
                _ => groups.push(SubConv { bits: b, start: c, len: 1 }),
            }
        }

        let alpha = params
            .get(&format!("{}.alpha", l.name))
            .map(|t| t.item())
            .ok_or_else(|| anyhow!("missing alpha for {}", l.name))?;

        nodes.push(DeployedNode {
            spec: l.clone(),
            layer: Some(DeployedLayer {
                spec: l.clone(),
                act_bits: la.act_bits,
                alpha,
                qweights: qw,
                w_scale,
                weight_bits: bits_perm,
                a_fold,
                b_fold,
                groups,
            }),
        });
    }

    let mut space_perms = HashMap::new();
    for (space, perm) in &space_perm {
        space_perms.insert(format!("space{space}"), perm.clone());
    }
    let out_width = *spaces.width.get(&last_q).unwrap_or(&0);
    let output_perm = space_perm
        .get(&last_q)
        .cloned()
        .unwrap_or_else(|| (0..out_width).collect());
    Ok(DeployedModel {
        bench: manifest.benchmark.clone(),
        loss: manifest.loss.clone(),
        n_classes: manifest.n_classes,
        input_shape: manifest.input_shape.clone(),
        nodes,
        space_perms,
        output_perm,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mklayer(name: &str, kind: &str, cin: usize, cout: usize) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            kind: kind.into(),
            cin,
            cout,
            kx: 1,
            ky: 1,
            stride: 1,
            relu: true,
            bn: false,
            bias: false,
            in_h: 4,
            in_w: 4,
            out_h: 4,
            out_w: 4,
            qidx: -1,
            ops: cin * cout * 16,
            weights_per_channel: cin,
            save_as: None,
            add_from: None,
            input_from: None,
        }
    }

    #[test]
    fn residual_unions_spaces() {
        // c1 -> tap(save t) -> c2 -> add(t)  : c1 and c2 outputs same space
        let mut l0 = mklayer("c1", "conv", 3, 8);
        let mut tap = mklayer("t", "tap", 8, 8);
        tap.save_as = Some("t0".into());
        let l2 = mklayer("c2", "conv", 8, 8);
        let mut add = mklayer("a", "add", 8, 8);
        add.add_from = Some("t0".into());
        l0.qidx = 0;
        let mut l2 = l2;
        l2.qidx = 1;
        let layers = vec![l0, tap, l2, add];
        let s = analyze_spaces(&layers).unwrap();
        assert_eq!(s.out_space[0], s.out_space[2]);
    }

    #[test]
    fn dwconv_shares_input_space() {
        let mut c = mklayer("c1", "conv", 3, 8);
        c.qidx = 0;
        let mut dw = mklayer("dw", "dwconv", 8, 8);
        dw.qidx = 1;
        dw.weights_per_channel = 9;
        let layers = vec![c, dw];
        let s = analyze_spaces(&layers).unwrap();
        assert_eq!(s.out_space[0], s.out_space[1]);
    }

    #[test]
    fn groups_cover_all_channels_contiguously() {
        let bits = [8u32, 2, 2, 4, 4, 4, 8, 8];
        // emulate run construction
        let mut groups: Vec<SubConv> = Vec::new();
        for (c, &b) in bits.iter().enumerate() {
            match groups.last_mut() {
                Some(g) if g.bits == b && g.start + g.len == c => g.len += 1,
                _ => groups.push(SubConv { bits: b, start: c, len: 1 }),
            }
        }
        let total: usize = groups.iter().map(|g| g.len).sum();
        assert_eq!(total, bits.len());
        assert_eq!(groups.len(), 4);
    }
}
