//! Numerical verification of the §III-C transform: the deployed integer
//! pipeline must compute the same function as the `infer` HLO graph.
//!
//! Checked end-to-end on trained weights by `examples/deploy_mpic.rs`
//! and `tests/deploy_matches_hlo.rs`: reorder + split + BN-fold + integer
//! conv == float fake-quantized conv, up to f32 rounding in the epilogue.

use anyhow::Result;

use crate::data::{BatchIter, Dataset};
use crate::engine::{ExecPlan, PackedBackend};
use crate::nas::Trainer;
use crate::quant::Assignment;

/// Agreement metrics between deployed execution and the HLO `infer` graph.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub n_samples: usize,
    pub max_abs_diff: f32,
    pub mean_abs_diff: f32,
    /// fraction of samples whose argmax matches (classification) or 1.0
    /// for reconstruction models
    pub argmax_agreement: f32,
}

/// Compare the deployed model against the `infer` graph on `n_batches`
/// of a dataset.
pub fn verify_against_hlo(
    tr: &Trainer,
    a: &Assignment,
    ds: &Dataset,
    n_batches: usize,
) -> Result<VerifyReport> {
    let deployed = super::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), a)?;
    // compile once, run every batch through the same plan
    let plan = ExecPlan::compile(&deployed, &tr.manifest.lut, &PackedBackend)?;
    let feat = tr.manifest.feat_len();
    let batch = tr.manifest.batch;
    let mut max_d = 0.0f32;
    let mut sum_d = 0.0f64;
    let mut n_el = 0usize;
    let mut agree = 0usize;
    let mut n = 0usize;
    for b in BatchIter::sequential(ds, batch).take(n_batches) {
        let hlo = tr.infer(a, &b.x, batch)?;
        let (sim, _cost) = plan.run_batch(&b.x, feat)?;
        for i in 0..batch {
            assert_eq!(hlo[i].len(), sim[i].len(), "output width mismatch");
            for (h, s) in hlo[i].iter().zip(&sim[i]) {
                let d = (h - s).abs();
                max_d = max_d.max(d);
                sum_d += d as f64;
                n_el += 1;
            }
            let am_h = crate::util::stats::argmax(&hlo[i]);
            let am_s = crate::util::stats::argmax(&sim[i]);
            if am_h == am_s || tr.manifest.loss != "ce" {
                agree += 1;
            }
            n += 1;
        }
    }
    Ok(VerifyReport {
        n_samples: n,
        max_abs_diff: max_d,
        mean_abs_diff: (sum_d / n_el.max(1) as f64) as f32,
        argmax_agreement: agree as f32 / n.max(1) as f32,
    })
}
