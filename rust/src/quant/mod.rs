//! Quantization utilities on the Rust side.
//!
//! The *training-time* fake quantization lives in the JAX/Pallas graphs;
//! this module implements the *deployment-time* integer pipeline:
//!
//! * [`quantize_weights_perchannel`] — real integer weights + per-channel
//!   scales (the symmetric scheme the HLO graphs fake-quantize with);
//! * [`quantize_acts_pact`] — unsigned activation quantization against a
//!   learned PACT `alpha`;
//! * [`pack_subbyte`] / [`unpack_subbyte`] — 2/4-bit weight packing into
//!   bytes, i.e. the non-volatile-memory layout whose footprint Eq. (7)
//!   optimizes (and the MPIC simulator's load granularity);
//! * [`pack_acts_subbyte`] / [`unpack_acts_subbyte`] /
//!   [`quantize_acts_pact_packed`] — the unsigned activation mirror of
//!   the weight packing, defining the engine's packed activation plane
//!   (the in-RAM layout MPIC's `sdotp` activation registers load from);
//! * [`Assignment`] — a concrete per-channel bit-width assignment
//!   extracted from NAS parameters by row-wise argmax, plus the one-hot
//!   encoding fed back into the hard-assignment HLO graphs.

pub mod affine;

pub use affine::AffineQuant;

use crate::{precision_index, PRECISIONS};

/// Per-layer precision decision: activation bits + per-channel weight bits.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerAssignment {
    pub name: String,
    pub act_bits: u32,
    /// one entry per output channel
    pub weight_bits: Vec<u32>,
}

impl LayerAssignment {
    /// Uniform (fixed-precision) assignment for a layer.
    pub fn fixed(name: &str, act_bits: u32, weight_bits: u32, cout: usize) -> Self {
        LayerAssignment {
            name: name.to_string(),
            act_bits,
            weight_bits: vec![weight_bits; cout],
        }
    }

    /// Fraction of channels at each precision (the Fig. 4 bars).
    pub fn fractions(&self) -> [f32; 3] {
        let mut counts = [0usize; 3];
        for &b in &self.weight_bits {
            counts[precision_index(b)] += 1;
        }
        let n = self.weight_bits.len().max(1) as f32;
        [counts[0] as f32 / n, counts[1] as f32 / n, counts[2] as f32 / n]
    }
}

/// A whole-network assignment (one entry per quantized layer, in order).
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub layers: Vec<LayerAssignment>,
}

impl Assignment {
    /// Row-wise argmax extraction from raw NAS parameters.
    ///
    /// `delta`: `|P_X|` logits; `gamma`: `rows * |P_W|` logits row-major
    /// (rows = 1 for layer-wise searches gets broadcast to `cout`).
    pub fn from_nas_params(
        names: &[String],
        deltas: &[Vec<f32>],
        gammas: &[(usize, Vec<f32>)], // (rows, row-major logits)
        couts: &[usize],
    ) -> Assignment {
        assert_eq!(names.len(), deltas.len());
        assert_eq!(names.len(), gammas.len());
        let mut layers = Vec::with_capacity(names.len());
        for i in 0..names.len() {
            let act_bits = PRECISIONS[crate::util::stats::argmax(&deltas[i])];
            let (rows, g) = &gammas[i];
            let np = PRECISIONS.len();
            let mut weight_bits = Vec::with_capacity(couts[i]);
            if *rows == 1 {
                let b = PRECISIONS[crate::util::stats::argmax(&g[0..np])];
                weight_bits = vec![b; couts[i]];
            } else {
                assert_eq!(*rows, couts[i]);
                for r in 0..*rows {
                    let row = &g[r * np..(r + 1) * np];
                    weight_bits.push(PRECISIONS[crate::util::stats::argmax(row)]);
                }
            }
            layers.push(LayerAssignment {
                name: names[i].clone(),
                act_bits,
                weight_bits,
            });
        }
        Assignment { layers }
    }

    /// One-hot encoding for the hard-assignment HLO graphs:
    /// per layer, (`delta_oh` len 3, `gamma_oh` cout x 3 row-major).
    pub fn to_one_hot(&self) -> Vec<(Vec<f32>, Vec<f32>)> {
        self.layers
            .iter()
            .map(|l| {
                let mut d = vec![0.0f32; 3];
                d[precision_index(l.act_bits)] = 1.0;
                let mut g = vec![0.0f32; l.weight_bits.len() * 3];
                for (c, &b) in l.weight_bits.iter().enumerate() {
                    g[c * 3 + precision_index(b)] = 1.0;
                }
                (d, g)
            })
            .collect()
    }

    /// Uniform fixed-precision assignment over a model's quantized layers.
    pub fn fixed(names: &[String], couts: &[usize], wbits: u32, xbits: u32) -> Self {
        Assignment {
            layers: names
                .iter()
                .zip(couts)
                .map(|(n, &c)| LayerAssignment::fixed(n, xbits, wbits, c))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Integer quantization (deployment).
// ---------------------------------------------------------------------------

/// Symmetric per-channel weight quantization.
///
/// Returns `(q, scales)` with `q[i] in [-(2^(b-1)-1), 2^(b-1)-1]` and
/// `w ~= q * scale[channel]` — exactly the grid the Pallas fake-quant
/// kernel trains against, so deployment is lossless w.r.t. training.
pub fn quantize_weights_perchannel(
    w: &[f32],
    cout: usize,
    bits_per_channel: &[u32],
) -> (Vec<i32>, Vec<f32>) {
    assert_eq!(bits_per_channel.len(), cout);
    assert_eq!(w.len() % cout, 0);
    let k = w.len() / cout;
    let mut q = vec![0i32; w.len()];
    let mut scales = vec![0.0f32; cout];
    for c in 0..cout {
        let row = &w[c * k..(c + 1) * k];
        let levels = ((1i32 << (bits_per_channel[c] - 1)) - 1) as f32;
        let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-8);
        let s = amax / levels;
        scales[c] = s;
        for (j, &v) in row.iter().enumerate() {
            // round-half-to-even matches XLA's jnp.round exactly
            q[c * k + j] = (v / s).round_ties_even().clamp(-levels, levels) as i32;
        }
    }
    (q, scales)
}

/// PACT unsigned activation quantization: returns `(q, step)` with
/// `q in [0, 2^bits - 1]`, `x ~= q * step`.
pub fn quantize_acts_pact(x: &[f32], alpha: f32, bits: u32) -> (Vec<u32>, f32) {
    let levels = ((1u32 << bits) - 1) as f32;
    let a = alpha.max(1e-6);
    let eps = a / levels;
    let q = x
        .iter()
        .map(|&v| ((v.clamp(0.0, a)) / eps).round_ties_even() as u32)
        .collect();
    (q, eps)
}

// ---------------------------------------------------------------------------
// Sub-byte packing (the model-size layout of Eq. (7)).
// ---------------------------------------------------------------------------

/// Pack signed integers of width `bits` (2/4/8) into bytes, little-endian
/// within a byte.  Values must fit the signed range of `bits`.
pub fn pack_subbyte(values: &[i32], bits: u32) -> Vec<u8> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per_byte = (8 / bits) as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    let mut out = vec![0u8; values.len().div_ceil(per_byte)];
    for (i, &v) in values.iter().enumerate() {
        let enc = (v as i8 as u8) & mask; // two's complement truncation
        out[i / per_byte] |= enc << ((i % per_byte) as u32 * bits);
    }
    out
}

/// Pack **unsigned** activation codes of width `bits` (2/4/8) into
/// bytes, little-endian within a byte — the activation mirror of
/// [`pack_subbyte`].  The engine's packed activation plane uses this
/// layout per pixel (its in-arena quantizer writes it directly without
/// the `Vec` detour; the bit-exactness contract against
/// `mpic::exec::run_sample` in `tests/engine_equivalence.rs` is what
/// keeps the two in lockstep).  Codes must fit `bits` (`< 2^bits`).
pub fn pack_acts_subbyte(codes: &[u32], bits: u32) -> Vec<u8> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per_byte = (8 / bits) as usize;
    let mask = (1u32 << bits) - 1;
    let mut out = vec![0u8; codes.len().div_ceil(per_byte)];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c <= mask, "code {c} exceeds {bits}-bit range");
        out[i / per_byte] |= ((c & mask) as u8) << ((i % per_byte) as u32 * bits);
    }
    out
}

/// Inverse of [`pack_acts_subbyte`], producing `n` unsigned codes.
pub fn unpack_acts_subbyte(bytes: &[u8], bits: u32, n: usize) -> Vec<u32> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per_byte = (8 / bits) as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    (0..n)
        .map(|i| {
            let b = bytes[i / per_byte];
            ((b >> ((i % per_byte) as u32 * bits)) & mask) as u32
        })
        .collect()
}

/// [`quantize_acts_pact`] fused with [`pack_acts_subbyte`]: quantize a
/// buffer and emit the packed sub-byte codes directly.  This is the
/// standalone reference of what the engine's per-layer in-arena plane
/// quantizer computes for one byte-aligned run (a pixel, or a whole FC
/// input); callers outside the engine use it to produce plane-layout
/// codes without an `ExecPlan`.
pub fn quantize_acts_pact_packed(x: &[f32], alpha: f32, bits: u32) -> (Vec<u8>, f32) {
    assert!(matches!(bits, 2 | 4 | 8));
    let levels = ((1u32 << bits) - 1) as f32;
    let a = alpha.max(1e-6);
    let eps = a / levels;
    let per_byte = (8 / bits) as usize;
    let mut out = vec![0u8; x.len().div_ceil(per_byte)];
    for (i, &v) in x.iter().enumerate() {
        let code = ((v.clamp(0.0, a)) / eps).round_ties_even() as u32;
        out[i / per_byte] |= (code as u8) << ((i % per_byte) as u32 * bits);
    }
    (out, eps)
}

/// Inverse of [`pack_subbyte`] (sign-extending), producing `n` values.
pub fn unpack_subbyte(bytes: &[u8], bits: u32, n: usize) -> Vec<i32> {
    assert!(matches!(bits, 2 | 4 | 8));
    let per_byte = (8 / bits) as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    let sign_bit = 1u8 << (bits - 1);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let b = bytes[i / per_byte];
        let raw = (b >> ((i % per_byte) as u32 * bits)) & mask;
        let v = if raw & sign_bit != 0 {
            (raw as i32) - (1i32 << bits)
        } else {
            raw as i32
        };
        out.push(v);
    }
    out
}

/// Packed byte size of a per-channel-quantized weight tensor — the model
/// size the Fig. 3 memory axis reports (each channel row padded to a byte
/// boundary, which is how CMix-NN-style layouts store reordered groups).
pub fn packed_weight_bytes(cout: usize, k: usize, bits_per_channel: &[u32]) -> usize {
    assert_eq!(bits_per_channel.len(), cout);
    bits_per_channel
        .iter()
        .map(|&b| (k * b as usize).div_ceil(8))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn weight_quant_roundtrip_error_bounded() {
        let mut rng = Pcg32::seeded(1);
        let cout = 4;
        let k = 32;
        let w: Vec<f32> = (0..cout * k).map(|_| rng.normal_ms(0.0, 0.3)).collect();
        for bits in [2u32, 4, 8] {
            let (q, s) = quantize_weights_perchannel(&w, cout, &vec![bits; cout]);
            let max_err = w
                .iter()
                .enumerate()
                .map(|(i, &v)| (v - q[i] as f32 * s[i / k]).abs())
                .fold(0.0f32, f32::max);
            let worst_step = s.iter().cloned().fold(0.0f32, f32::max);
            assert!(max_err <= worst_step * 0.5 + 1e-6,
                    "bits={bits} err {max_err} step {worst_step}");
        }
    }

    #[test]
    fn act_quant_range() {
        let x = [-1.0f32, 0.0, 0.5, 3.0, 10.0];
        let (q, eps) = quantize_acts_pact(&x, 4.0, 4);
        assert_eq!(q[0], 0);
        assert_eq!(q[4], 15); // clamped to alpha
        assert!((eps - 4.0 / 15.0).abs() < 1e-6);
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        let mut rng = Pcg32::seeded(3);
        for bits in [2u32, 4, 8] {
            let lo = -(1i32 << (bits - 1)) + 1;
            let hi = (1i32 << (bits - 1)) - 1;
            let vals: Vec<i32> = (0..97)
                .map(|_| lo + rng.below((hi - lo + 1) as u32) as i32)
                .collect();
            let packed = pack_subbyte(&vals, bits);
            assert_eq!(packed.len(), (97 * bits as usize).div_ceil(8));
            let back = unpack_subbyte(&packed, bits, vals.len());
            assert_eq!(back, vals, "bits={bits}");
        }
    }

    #[test]
    fn act_pack_unpack_roundtrip_all_widths() {
        let mut rng = Pcg32::seeded(7);
        for bits in [2u32, 4, 8] {
            let hi = (1u32 << bits) - 1;
            // include both extremes: zero and the PACT clip boundary
            let mut codes: Vec<u32> = (0..101).map(|_| rng.below(hi + 1)).collect();
            codes[0] = hi;
            codes[100] = 0;
            let packed = pack_acts_subbyte(&codes, bits);
            assert_eq!(packed.len(), (101 * bits as usize).div_ceil(8));
            let back = unpack_acts_subbyte(&packed, bits, codes.len());
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn packed_act_quant_matches_unpacked() {
        // the fused quantize+pack path is the same function as
        // quantize_acts_pact followed by pack_acts_subbyte
        let mut rng = Pcg32::seeded(9);
        for bits in [2u32, 4, 8] {
            let x: Vec<f32> = (0..57).map(|_| rng.normal_ms(0.5, 1.0)).collect();
            let (q, eps) = quantize_acts_pact(&x, 1.5, bits);
            let (packed, eps2) = quantize_acts_pact_packed(&x, 1.5, bits);
            assert_eq!(eps, eps2);
            assert_eq!(packed, pack_acts_subbyte(&q, bits), "bits={bits}");
        }
    }

    #[test]
    fn packed_bytes_mixed() {
        // 3 channels of k=10 weights at 2/4/8 bits:
        // ceil(20/8)+ceil(40/8)+ceil(80/8) = 3+5+10
        assert_eq!(packed_weight_bytes(3, 10, &[2, 4, 8]), 18);
    }

    #[test]
    fn assignment_argmax_extraction() {
        let names = vec!["a".to_string(), "b".to_string()];
        let deltas = vec![vec![0.1, 0.9, 0.2], vec![0.0, 0.0, 1.0]];
        // layer a: per-channel (2 rows), layer b: layer-wise (1 row)
        let gammas = vec![
            (2usize, vec![1.0, 0.0, 0.0, 0.0, 0.0, 2.0]),
            (1usize, vec![0.0, 5.0, 1.0]),
        ];
        let a = Assignment::from_nas_params(&names, &deltas, &gammas, &[2, 3]);
        assert_eq!(a.layers[0].act_bits, 4);
        assert_eq!(a.layers[0].weight_bits, vec![2, 8]);
        assert_eq!(a.layers[1].act_bits, 8);
        assert_eq!(a.layers[1].weight_bits, vec![4, 4, 4]);
    }

    #[test]
    fn one_hot_encodes_assignment() {
        let a = Assignment::fixed(
            &["l".to_string()], &[2], 4, 8);
        let oh = a.to_one_hot();
        assert_eq!(oh[0].0, vec![0.0, 0.0, 1.0]); // act 8-bit
        assert_eq!(oh[0].1, vec![0.0, 1.0, 0.0, 0.0, 1.0, 0.0]); // w 4-bit x2
    }

    #[test]
    fn fractions_sum_to_one() {
        let l = LayerAssignment {
            name: "x".into(),
            act_bits: 8,
            weight_bits: vec![2, 2, 4, 8],
        };
        let f = l.fractions();
        assert!((f[0] - 0.5).abs() < 1e-6);
        assert!((f.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }
}
