//! General affine quantization (the paper's Eq. (1)) and calibration.
//!
//! The deployed pipeline uses the two *specialisations* that the MPIC
//! kernels and the training graphs share (PACT for unsigned activations,
//! symmetric per-channel for weights — `super`), but the paper's Eq. (1)
//! is the general asymmetric map
//!
//! ```text
//! t_n = clamp_{0..2^n-1}( round( (t - alpha_t) / eps_t ) ),
//! eps_t = (beta_t - alpha_t) / (2^n - 1)
//! ```
//!
//! which this module implements for completeness plus min/max and
//! percentile calibration of `[alpha_t, beta_t]` — used by the data
//! pipeline tests and available to downstream users quantizing tensors
//! the NAS does not touch (e.g. network inputs from uint8 sensors).

/// An affine quantizer: `q = clamp(round((x - alpha) / eps))`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AffineQuant {
    pub alpha: f32,
    pub eps: f32,
    pub bits: u32,
}

impl AffineQuant {
    /// From an explicit `[alpha, beta]` range (Eq. (1)).
    pub fn from_range(alpha: f32, beta: f32, bits: u32) -> AffineQuant {
        let levels = ((1u64 << bits) - 1) as f32;
        let eps = ((beta - alpha) / levels).max(1e-12);
        AffineQuant { alpha, eps, bits }
    }

    /// Min/max calibration over a tensor.
    pub fn calibrate_minmax(xs: &[f32], bits: u32) -> AffineQuant {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in xs {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() || !hi.is_finite() || lo == hi {
            return AffineQuant::from_range(0.0, 1.0, bits);
        }
        AffineQuant::from_range(lo, hi, bits)
    }

    /// Percentile calibration (clips outliers; `p` in (0, 0.5], e.g. 0.01
    /// keeps the [1%, 99%] range) — the standard PTQ trick.
    pub fn calibrate_percentile(xs: &[f32], bits: u32, p: f32) -> AffineQuant {
        if xs.is_empty() {
            return AffineQuant::from_range(0.0, 1.0, bits);
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let lo_i = ((n as f32 * p) as usize).min(n - 1);
        let hi_i = ((n as f32 * (1.0 - p)) as usize).min(n - 1);
        AffineQuant::from_range(sorted[lo_i], sorted[hi_i.max(lo_i)], bits)
    }

    /// Quantize one value to its integer code.
    pub fn quantize(&self, x: f32) -> u32 {
        let levels = ((1u64 << self.bits) - 1) as f32;
        (((x - self.alpha) / self.eps).round_ties_even()).clamp(0.0, levels) as u32
    }

    /// Dequantize a code back to float.
    pub fn dequantize(&self, q: u32) -> f32 {
        self.alpha + q as f32 * self.eps
    }

    /// Fake-quantize (quantize then dequantize).
    pub fn fake(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn codes_cover_full_range() {
        let q = AffineQuant::from_range(-1.0, 1.0, 4);
        assert_eq!(q.quantize(-1.0), 0);
        assert_eq!(q.quantize(1.0), 15);
        assert_eq!(q.quantize(-5.0), 0); // clamped
        assert_eq!(q.quantize(5.0), 15);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg32::seeded(5);
        for bits in [2u32, 4, 8] {
            let xs: Vec<f32> = (0..500).map(|_| rng.normal_ms(0.3, 1.0)).collect();
            let q = AffineQuant::calibrate_minmax(&xs, bits);
            for &x in &xs {
                let err = (x - q.fake(x)).abs();
                assert!(err <= q.eps * 0.5 + 1e-6,
                        "bits={bits} x={x} err={err} eps={}", q.eps);
            }
        }
    }

    #[test]
    fn asymmetric_handles_shifted_ranges() {
        // all-positive data must not waste codes on negatives
        let xs: Vec<f32> = (0..100).map(|i| 10.0 + i as f32 * 0.01).collect();
        let q = AffineQuant::calibrate_minmax(&xs, 8);
        assert!(q.alpha >= 10.0 - 1e-6);
        assert_eq!(q.quantize(10.0), 0);
    }

    #[test]
    fn percentile_clips_outliers() {
        let mut xs = vec![0.5f32; 1000];
        xs[0] = -100.0;
        xs[999] = 100.0;
        let mm = AffineQuant::calibrate_minmax(&xs, 8);
        let pc = AffineQuant::calibrate_percentile(&xs, 8, 0.01);
        assert!(pc.eps < mm.eps / 10.0);
    }

    #[test]
    fn degenerate_input_safe() {
        let q = AffineQuant::calibrate_minmax(&[3.0, 3.0, 3.0], 4);
        let _ = q.quantize(3.0);
        let q2 = AffineQuant::calibrate_minmax(&[], 4);
        let mid = q2.quantize(0.5);
        assert!((7..=8).contains(&mid)); // mid-range of default [0,1] (ties-even)
    }

    #[test]
    fn symmetric_is_special_case() {
        // Eq. (1) with alpha = -beta reproduces the symmetric weight grid
        // (up to the even-levels offset)
        let xs: Vec<f32> = vec![-0.9, -0.3, 0.0, 0.4, 0.9];
        let q = AffineQuant::from_range(-0.9, 0.9, 8);
        for &x in &xs {
            assert!((q.fake(x) - x).abs() <= q.eps * 0.5 + 1e-7);
        }
    }
}
