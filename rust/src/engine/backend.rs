//! Pluggable integer dot-product kernels.
//!
//! A [`KernelBackend`] turns a deployed layer's quantized weights into a
//! [`LayerKernel`] — the object the executor calls once per (output
//! pixel, output channel) with the gathered activation column.  Two
//! implementations ship:
//!
//! * [`ReferenceBackend`] — the seed scalar loops over `i32` weight rows,
//!   kept bit-for-bit identical to `mpic::exec::run_sample` and used as
//!   the exactness oracle for every other backend;
//! * [`PackedBackend`] — weights stored in the sub-byte flash layout of
//!   Eq. (7) (`quant::pack_subbyte`, one byte-aligned row per output
//!   channel) and multiplied by unrolled decode kernels selected per
//!   `(p_x, p_w)` — the software model of MPIC's per-precision SIMD
//!   modes.  Integer decode is exact, so results are bit-identical to
//!   the reference backend while touching `8/p_w` times less weight
//!   memory.
//!
//! Accumulation contract: [`LayerKernel::dot`] accumulates in `i32`
//! (convolutions: `K * 255 * 127` fits comfortably), while
//! [`LayerKernel::dot_wide`] accumulates in `i64` for FC layers whose
//! `K` is unbounded.  Both match the scalar oracle exactly because
//! integer addition is associative.

use crate::deploy::DeployedLayer;
use crate::precision_index;
use crate::quant::pack_subbyte;

/// A backend prepares per-layer weight storage + dot kernels.
pub trait KernelBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Build the execution kernel for one deployed layer.
    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel>;
}

/// Per-layer kernel: weight rows dotted against gathered activations.
pub trait LayerKernel: Send + Sync {
    /// `i32` dot of output channel `c`'s weight row against `col`
    /// (`col.len()` == K of the layer; conv/dwconv path).
    fn dot(&self, c: usize, col: &[i32]) -> i32;

    /// `i64`-accumulating dot (FC path, unbounded K).
    fn dot_wide(&self, c: usize, col: &[i32]) -> i64;

    /// Bytes of weight storage held by this kernel (diagnostics).
    fn weight_bytes(&self) -> usize;
}

// ---------------------------------------------------------------------------
// Reference backend: the seed scalar loops.
// ---------------------------------------------------------------------------

/// Scalar `i32` weight rows — the bit-exactness oracle.
pub struct ReferenceBackend;

struct ReferenceKernel {
    k: usize,
    qw: Vec<i32>,
}

impl KernelBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel> {
        Box::new(ReferenceKernel { k: dl.k(), qw: dl.qweights.clone() })
    }
}

impl LayerKernel for ReferenceKernel {
    #[inline]
    fn dot(&self, c: usize, col: &[i32]) -> i32 {
        let row = &self.qw[c * self.k..(c + 1) * self.k];
        let mut acc = 0i32;
        for (x, w) in col.iter().zip(row) {
            acc += x * w;
        }
        acc
    }

    #[inline]
    fn dot_wide(&self, c: usize, col: &[i32]) -> i64 {
        let row = &self.qw[c * self.k..(c + 1) * self.k];
        let mut acc = 0i64;
        for (x, w) in col.iter().zip(row) {
            acc += *x as i64 * *w as i64;
        }
        acc
    }

    fn weight_bytes(&self) -> usize {
        self.qw.len() * std::mem::size_of::<i32>()
    }
}

// ---------------------------------------------------------------------------
// Packed backend: sub-byte rows + unrolled decode kernels.
// ---------------------------------------------------------------------------

/// Sub-byte bit-packed weight rows (the Eq. (7) flash layout).
pub struct PackedBackend;

type RowDot = fn(&[u8], &[i32]) -> i32;
type RowDotWide = fn(&[u8], &[i32]) -> i64;

/// Kernel table indexed `[precision_index(p_x)][precision_index(p_w)]`,
/// mirroring MPIC's per-(p_x, p_w) SIMD mode CSR.  Activation codes
/// reach the kernels as pre-gathered `i32` lanes, so today the three
/// activation rows share the weight-decode bodies; the table is the seam
/// where activation-packed SWAR kernels plug in (ROADMAP "Open items").
const DOT_KERNELS: [[RowDot; 3]; 3] = [
    [dot_w2, dot_w4, dot_w8],
    [dot_w2, dot_w4, dot_w8],
    [dot_w2, dot_w4, dot_w8],
];

const DOT_KERNELS_WIDE: [[RowDotWide; 3]; 3] = [
    [dot_w2_wide, dot_w4_wide, dot_w8_wide],
    [dot_w2_wide, dot_w4_wide, dot_w8_wide],
    [dot_w2_wide, dot_w4_wide, dot_w8_wide],
];

#[inline(always)]
fn sext(v: i32, bits: u32) -> i32 {
    // two's-complement sign extension of a `bits`-wide field in v's LSBs
    if v & (1 << (bits - 1)) != 0 {
        v - (1 << bits)
    } else {
        v
    }
}

/// 2-bit rows: 4 MACs per weight byte, unrolled.
fn dot_w2(row: &[u8], col: &[i32]) -> i32 {
    let mut acc = 0i32;
    let mut chunks = col.chunks_exact(4);
    for (chunk, &b) in (&mut chunks).zip(row) {
        let b = b as i32;
        acc += chunk[0] * sext(b & 3, 2);
        acc += chunk[1] * sext((b >> 2) & 3, 2);
        acc += chunk[2] * sext((b >> 4) & 3, 2);
        acc += chunk[3] * sext((b >> 6) & 3, 2);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let b = row[col.len() / 4] as i32;
        for (j, x) in rem.iter().enumerate() {
            acc += x * sext((b >> (2 * j)) & 3, 2);
        }
    }
    acc
}

/// 4-bit rows: 2 MACs per weight byte, unrolled.
fn dot_w4(row: &[u8], col: &[i32]) -> i32 {
    let mut acc = 0i32;
    let mut chunks = col.chunks_exact(2);
    for (chunk, &b) in (&mut chunks).zip(row) {
        let b = b as i32;
        acc += chunk[0] * sext(b & 0xf, 4);
        acc += chunk[1] * sext((b >> 4) & 0xf, 4);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let b = row[col.len() / 2] as i32;
        acc += rem[0] * sext(b & 0xf, 4);
    }
    acc
}

/// 8-bit rows: one byte per weight.
fn dot_w8(row: &[u8], col: &[i32]) -> i32 {
    let mut acc = 0i32;
    for (x, &b) in col.iter().zip(row) {
        acc += x * (b as i8 as i32);
    }
    acc
}

fn dot_w2_wide(row: &[u8], col: &[i32]) -> i64 {
    let mut acc = 0i64;
    let mut chunks = col.chunks_exact(4);
    for (chunk, &b) in (&mut chunks).zip(row) {
        let b = b as i32;
        acc += chunk[0] as i64 * sext(b & 3, 2) as i64;
        acc += chunk[1] as i64 * sext((b >> 2) & 3, 2) as i64;
        acc += chunk[2] as i64 * sext((b >> 4) & 3, 2) as i64;
        acc += chunk[3] as i64 * sext((b >> 6) & 3, 2) as i64;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let b = row[col.len() / 4] as i32;
        for (j, &x) in rem.iter().enumerate() {
            acc += x as i64 * sext((b >> (2 * j)) & 3, 2) as i64;
        }
    }
    acc
}

fn dot_w4_wide(row: &[u8], col: &[i32]) -> i64 {
    let mut acc = 0i64;
    let mut chunks = col.chunks_exact(2);
    for (chunk, &b) in (&mut chunks).zip(row) {
        let b = b as i32;
        acc += chunk[0] as i64 * sext(b & 0xf, 4) as i64;
        acc += chunk[1] as i64 * sext((b >> 4) & 0xf, 4) as i64;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let b = row[col.len() / 2] as i32;
        acc += rem[0] as i64 * sext(b & 0xf, 4) as i64;
    }
    acc
}

fn dot_w8_wide(row: &[u8], col: &[i32]) -> i64 {
    let mut acc = 0i64;
    for (x, &b) in col.iter().zip(row) {
        acc += *x as i64 * (b as i8 as i64);
    }
    acc
}

struct PackedRow {
    /// byte offset into `bytes`
    offset: u32,
    /// row length in bytes
    len: u32,
    /// `precision_index(weight_bits)`
    widx: u8,
}

struct PackedKernel {
    /// all channel rows, each padded to a byte boundary (the CMix-NN
    /// reordered-group layout `quant::packed_weight_bytes` sizes)
    bytes: Vec<u8>,
    rows: Vec<PackedRow>,
    /// `precision_index(act_bits)` — selects the kernel-table row
    aidx: usize,
}

impl KernelBackend for PackedBackend {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel> {
        let k = dl.k();
        let cout = dl.spec.cout;
        let mut bytes = Vec::with_capacity(dl.packed_bytes());
        let mut rows = Vec::with_capacity(cout);
        for c in 0..cout {
            let bits = dl.weight_bits[c];
            let packed = pack_subbyte(&dl.qweights[c * k..(c + 1) * k], bits);
            rows.push(PackedRow {
                offset: bytes.len() as u32,
                len: packed.len() as u32,
                widx: precision_index(bits) as u8,
            });
            bytes.extend_from_slice(&packed);
        }
        Box::new(PackedKernel {
            bytes,
            rows,
            aidx: precision_index(dl.act_bits),
        })
    }
}

impl PackedKernel {
    #[inline(always)]
    fn row(&self, c: usize) -> (&[u8], usize) {
        let r = &self.rows[c];
        (
            &self.bytes[r.offset as usize..(r.offset + r.len) as usize],
            r.widx as usize,
        )
    }
}

impl LayerKernel for PackedKernel {
    #[inline]
    fn dot(&self, c: usize, col: &[i32]) -> i32 {
        let (row, widx) = self.row(c);
        DOT_KERNELS[self.aidx][widx](row, col)
    }

    #[inline]
    fn dot_wide(&self, c: usize, col: &[i32]) -> i64 {
        let (row, widx) = self.row(c);
        DOT_KERNELS_WIDE[self.aidx][widx](row, col)
    }

    fn weight_bytes(&self) -> usize {
        self.bytes.len()
    }
}

/// Resolve a backend by CLI/bench name.
pub fn backend_by_name(name: &str) -> anyhow::Result<&'static dyn KernelBackend> {
    match name {
        "reference" | "ref" => Ok(&ReferenceBackend),
        "packed" => Ok(&PackedBackend),
        other => anyhow::bail!("unknown backend {other:?} (reference|packed)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_row(rng: &mut Pcg32, k: usize, bits: u32) -> Vec<i32> {
        let hi = (1i32 << (bits - 1)) - 1;
        (0..k).map(|_| rng.below((2 * hi + 1) as u32) as i32 - hi).collect()
    }

    #[test]
    fn packed_dot_matches_scalar_all_widths() {
        let mut rng = Pcg32::seeded(11);
        for bits in [2u32, 4, 8] {
            // ragged K values exercise the tail paths
            for k in [1usize, 3, 4, 5, 7, 8, 64, 65, 127] {
                let w = random_row(&mut rng, k, bits);
                let col: Vec<i32> =
                    (0..k).map(|_| rng.below(256) as i32).collect();
                let packed = pack_subbyte(&w, bits);
                let want: i32 =
                    col.iter().zip(&w).map(|(x, v)| x * v).sum();
                let got = match bits {
                    2 => dot_w2(&packed, &col),
                    4 => dot_w4(&packed, &col),
                    _ => dot_w8(&packed, &col),
                };
                assert_eq!(got, want, "bits={bits} k={k}");
                let got_wide = match bits {
                    2 => dot_w2_wide(&packed, &col),
                    4 => dot_w4_wide(&packed, &col),
                    _ => dot_w8_wide(&packed, &col),
                };
                assert_eq!(got_wide, want as i64, "wide bits={bits} k={k}");
            }
        }
    }

    #[test]
    fn sext_covers_full_range() {
        assert_eq!(sext(0, 2), 0);
        assert_eq!(sext(1, 2), 1);
        assert_eq!(sext(2, 2), -2);
        assert_eq!(sext(3, 2), -1);
        assert_eq!(sext(0x7, 4), 7);
        assert_eq!(sext(0x8, 4), -8);
        assert_eq!(sext(0xf, 4), -1);
    }

    #[test]
    fn backend_names_resolve() {
        assert_eq!(backend_by_name("packed").unwrap().name(), "packed");
        assert_eq!(backend_by_name("ref").unwrap().name(), "reference");
        assert!(backend_by_name("simd").is_err());
    }
}
