//! Pluggable integer dot-product kernels over packed sub-byte operands.
//!
//! A [`KernelBackend`] turns a deployed layer's quantized weights into a
//! [`LayerKernel`] — the object the executor calls once per (output
//! pixel, output channel) with the **packed** activation column: `K`
//! unsigned codes of the layer's `p_x` width, packed densely LSB-first
//! into bytes by the executor's quantize/gather stage (see
//! `engine::plan`).  Three implementations ship:
//!
//! * [`ReferenceBackend`] — scalar `i32` weight rows dotted against
//!   codes decoded one at a time, kept bit-for-bit identical to
//!   `mpic::exec::run_sample` and used as the in-engine exactness oracle
//!   for every other backend;
//! * [`PackedBackend`] — weights stored in the sub-byte flash layout of
//!   Eq. (7) (`quant::pack_subbyte`, one byte-aligned row per output
//!   channel) and multiplied by **nine distinct SWAR kernels**, one per
//!   `(p_x, p_w)` combination.  Each kernel iteration fetches one 32-bit
//!   word of the *wider* operand and the matching 8/16 bits of the
//!   narrower one, then decodes `32 / max(p_x, p_w)` lane pairs from the
//!   fetched words — the software model of MPIC's mixed-precision
//!   `sdotp` modes (`mpic::regfile` is the per-lane reference).  Integer
//!   decode is exact, so results are bit-identical to the reference
//!   backend while touching `8/p_w` times less weight memory *and*
//!   `8/p_x` times less activation memory per dot;
//! * [`SimdBackend`] — the same Eq. (7) weight layout executed through
//!   explicit x86 vector kernels (`engine::simd`): the **batch axis is
//!   the vector axis** (each sample owns one vector lane), the dispatch
//!   tier (AVX-512 → AVX2 → SWAR) is picked **once per process** via
//!   `is_x86_feature_detected!` (overridable with
//!   `CWMIX_SIMD=off|avx2|avx512|auto`), and per sample the
//!   accumulation order is unchanged — the tier is a throughput knob,
//!   never a numerics knob.  On non-x86 hosts, or with `CWMIX_SIMD=off`,
//!   the backend *is* the SWAR fallback.
//!
//! Accumulation contract: [`LayerKernel::dot`] accumulates in `i32`
//! (convolutions: `K * 255 * 127` fits comfortably), while
//! [`LayerKernel::dot_wide`] accumulates in `i64` for FC layers whose
//! `K` is unbounded.  Both match the scalar oracle exactly because
//! integer addition is associative.
//!
//! **Batched entry points.** [`LayerKernel::dot_batch`] /
//! [`LayerKernel::dot_wide_batch`] take `B` packed columns side by side
//! (`stride` bytes apart) and fill one accumulator per column.  The
//! packed backend's batch kernels are **weight-stationary**: each
//! 32-bit weight word is fetched and sign-decoded **once**, then ridden
//! across all `B` activation columns before the next word is touched —
//! the batch-level analogue of MPIC amortizing its sub-byte weight
//! unpack across a full `sdotp` register.  Per column the accumulation
//! order is identical to the single-column kernel, so batched results
//! are bit-identical by construction (asserted below for every cell,
//! ragged K and extreme codes).
//!
//! **Fused requantize lives above this seam.** Kernels stay
//! plane-agnostic: they read packed columns and return integer dots,
//! and the executor's *epilogue* decides whether the f32 result lands
//! in an arena slot, in the consumer layer's packed plane, or both
//! (`engine::plan::fuse_requant`).  That keeps all nine `(p_x, p_w)`
//! SWAR cells — and every `engine::simd` vector tier — oblivious to
//! fusion: a backend is correct for the fused path iff it is correct
//! for the two-pass path, which is exactly what the oracle contract
//! asserts.

use super::simd;
use crate::deploy::DeployedLayer;
use crate::modelpack::{ByteArr, I32Arr};
use crate::precision_index;
use crate::quant::pack_subbyte;

/// A backend prepares per-layer weight storage + dot kernels.
pub trait KernelBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// The dispatch tier actually executing this backend's kernels —
    /// `name()` for single-tier backends; the `simd` backend reports
    /// the CPU tier (`avx512`/`avx2`/`swar`) selected at load.  Bench
    /// JSON and `/metrics` record this so every number names the code
    /// path that produced it.
    fn tier(&self) -> &'static str {
        self.name()
    }

    /// Build the execution kernel for one deployed layer.
    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel>;
}

/// A kernel's weight state, borrowed for modelpack serialization — the
/// seam `engine::pack` uses to round-trip a plan without re-packing
/// weights or materializing f32s.  Each variant is exactly what the
/// matching backend needs to rebuild its [`LayerKernel`].
pub enum KernelState<'a> {
    /// [`ReferenceBackend`]: scalar `i32` rows.
    Reference { k: usize, act_bits: u32, qw: &'a [i32] },
    /// [`PackedBackend`]: the Eq. (7) sub-byte flash image plus per-row
    /// `(byte offset, precision index)` descriptors.
    Packed { k: usize, act_index: usize, rows: Vec<(u32, u8)>, bytes: &'a [u8] },
}

/// Per-layer kernel: weight rows dotted against packed activation
/// columns.
///
/// `xcol` holds the layer's `K` activation codes (`p_x`-bit unsigned,
/// packed densely LSB-first; slack bits zero).  The slice may be longer
/// than `ceil(K * p_x / 8)` bytes — kernels only read the packed codes.
///
/// The batched entry points take `B = out.len()` columns side by side:
/// sample `j`'s column starts at `cols[j * stride]`, each in the same
/// packed layout `xcol` uses.  `out[j]` must be **bit-identical** to
/// the per-column dot of column `j` — batching changes *when* weight
/// words are fetched, never what is accumulated.  The defaults fall
/// back to per-column dots; backends override them to amortize weight
/// fetch + decode across the batch (weight-stationary execution).
pub trait LayerKernel: Send + Sync {
    /// `i32` dot of output channel `c`'s weight row against `xcol`
    /// (conv/dwconv path).
    fn dot(&self, c: usize, xcol: &[u8]) -> i32;

    /// `i64`-accumulating dot (FC path, unbounded K).
    fn dot_wide(&self, c: usize, xcol: &[u8]) -> i64;

    /// Batched [`Self::dot`] over `out.len()` columns at `stride`.
    fn dot_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i32]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.dot(c, &cols[j * stride..]);
        }
    }

    /// Batched [`Self::dot_wide`] over `out.len()` columns at `stride`.
    fn dot_wide_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i64]) {
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.dot_wide(c, &cols[j * stride..]);
        }
    }

    /// Bytes of weight storage held by this kernel (diagnostics).
    fn weight_bytes(&self) -> usize;

    /// Borrow this kernel's weight state for modelpack serialization.
    fn state(&self) -> KernelState<'_>;
}

// ---------------------------------------------------------------------------
// Shared sub-byte decode helpers.
// ---------------------------------------------------------------------------

#[inline(always)]
pub(super) fn sext(v: i32, bits: u32) -> i32 {
    // two's-complement sign extension of a `bits`-wide field in v's LSBs
    if v & (1 << (bits - 1)) != 0 {
        v - (1 << bits)
    } else {
        v
    }
}

/// Little-endian load of `nbytes` (1/2/4) bytes into a `u32`.  With a
/// constant `nbytes` this compiles to a single unaligned load.
#[inline(always)]
pub(super) fn load_le(buf: &[u8], off: usize, nbytes: usize) -> u32 {
    let mut w = 0u32;
    for (i, &b) in buf[off..off + nbytes].iter().enumerate() {
        w |= (b as u32) << (8 * i);
    }
    w
}

/// Decode unsigned code `idx` from a dense `bits`-wide packed buffer.
/// `bits` divides 8, so a code never straddles a byte boundary.
#[inline(always)]
pub(super) fn extract_code(buf: &[u8], idx: usize, bits: u32) -> u32 {
    let per = (8 / bits) as usize;
    let b = buf[idx / per] as u32;
    (b >> ((idx % per) as u32 * bits)) & ((1u32 << bits) - 1)
}

/// Decode signed weight code `idx` (sign-extending) from a packed row.
#[inline(always)]
pub(super) fn extract_weight(buf: &[u8], idx: usize, bits: u32) -> i32 {
    sext(extract_code(buf, idx, bits) as i32, bits)
}

// ---------------------------------------------------------------------------
// Reference backend: scalar i32 weight rows, per-code activation decode.
// ---------------------------------------------------------------------------

/// Scalar `i32` weight rows — the in-engine bit-exactness oracle.
pub struct ReferenceBackend;

struct ReferenceKernel {
    k: usize,
    /// `p_x` of the layer input — how `xcol` codes are decoded
    act_bits: u32,
    /// owned on compile, zero-copy artifact view on modelpack load
    qw: I32Arr,
}

impl KernelBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel> {
        Box::new(ReferenceKernel {
            k: dl.k(),
            act_bits: dl.act_bits,
            qw: dl.qweights.clone().into(),
        })
    }
}

/// Rebuild a reference kernel from modelpack state (`engine::pack` has
/// already validated `qw.len()`, `k` and `act_bits`).
pub(super) fn reference_kernel_from_parts(
    k: usize,
    act_bits: u32,
    qw: I32Arr,
) -> Box<dyn LayerKernel> {
    Box::new(ReferenceKernel { k, act_bits, qw })
}

impl LayerKernel for ReferenceKernel {
    #[inline]
    fn dot(&self, c: usize, xcol: &[u8]) -> i32 {
        let row = &self.qw[c * self.k..(c + 1) * self.k];
        let mut acc = 0i32;
        for (j, &w) in row.iter().enumerate() {
            acc += extract_code(xcol, j, self.act_bits) as i32 * w;
        }
        acc
    }

    #[inline]
    fn dot_wide(&self, c: usize, xcol: &[u8]) -> i64 {
        let row = &self.qw[c * self.k..(c + 1) * self.k];
        let mut acc = 0i64;
        for (j, &w) in row.iter().enumerate() {
            acc += extract_code(xcol, j, self.act_bits) as i64 * w as i64;
        }
        acc
    }

    fn weight_bytes(&self) -> usize {
        self.qw.len() * std::mem::size_of::<i32>()
    }

    fn state(&self) -> KernelState<'_> {
        KernelState::Reference { k: self.k, act_bits: self.act_bits, qw: &self.qw }
    }
}

// ---------------------------------------------------------------------------
// Packed backend: sub-byte rows x packed columns, nine SWAR kernels.
// ---------------------------------------------------------------------------

/// Sub-byte bit-packed weight rows (the Eq. (7) flash layout) multiplied
/// by per-`(p_x, p_w)` SWAR kernels against packed activation columns.
pub struct PackedBackend;

pub(super) type RowDot = fn(&[u8], &[u8], usize) -> i32;
pub(super) type RowDotWide = fn(&[u8], &[u8], usize) -> i64;
pub(super) type RowDotBatch = fn(&[u8], usize, &[u8], usize, &mut [i32]);
pub(super) type RowDotWideBatch = fn(&[u8], usize, &[u8], usize, &mut [i64]);

/// Generates one `(p_x, p_w)` SWAR kernel family: single-column `i32` +
/// `i64` dots and their **weight-stationary batched** variants.  Per
/// iteration the *wider* operand fills one 32-bit register
/// (`LANES = 32 / max(p_x, p_w)` lane pairs, exactly one MPIC `sdotp`);
/// the narrower operand contributes `LANES * min(p_x, p_w)` bits of the
/// same fetch.  Tail codes past the last full register are decoded one
/// at a time.
///
/// The batched variants ride each fetched-and-decoded weight register
/// across all `B = out.len()` activation columns before fetching the
/// next one, so weight decode cost amortizes with the batch size
/// exactly as on MPIC, where the sub-byte weight unpack dominates the
/// `sdotp` issue rate.  Per sample the accumulation order (register
/// ascending, lane ascending, then the scalar tail) is identical to the
/// single-column kernel, so results are bit-identical by construction.
macro_rules! swar_kernel {
    ($dot:ident, $dot_wide:ident, $dot_batch:ident, $dot_wide_batch:ident,
     $px:literal, $pw:literal) => {
        fn $dot(xcol: &[u8], wrow: &[u8], k: usize) -> i32 {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let full = k / LANES;
            let mut acc = 0i32;
            for i in 0..full {
                let xw = load_le(xcol, i * XSTEP, XSTEP);
                let ww = load_le(wrow, i * WSTEP, WSTEP);
                for lane in 0..LANES as u32 {
                    let x = ((xw >> (lane * PX)) & XMASK) as i32;
                    let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW);
                    acc += x * w;
                }
            }
            for j in full * LANES..k {
                acc += extract_code(xcol, j, PX) as i32 * extract_weight(wrow, j, PW);
            }
            acc
        }

        fn $dot_wide(xcol: &[u8], wrow: &[u8], k: usize) -> i64 {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let full = k / LANES;
            let mut acc = 0i64;
            for i in 0..full {
                let xw = load_le(xcol, i * XSTEP, XSTEP);
                let ww = load_le(wrow, i * WSTEP, WSTEP);
                for lane in 0..LANES as u32 {
                    let x = ((xw >> (lane * PX)) & XMASK) as i64;
                    let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW) as i64;
                    acc += x * w;
                }
            }
            for j in full * LANES..k {
                acc += extract_code(xcol, j, PX) as i64
                    * extract_weight(wrow, j, PW) as i64;
            }
            acc
        }

        fn $dot_batch(cols: &[u8], stride: usize, wrow: &[u8], k: usize, out: &mut [i32]) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let full = k / LANES;
            out.fill(0);
            let mut ws = [0i32; LANES];
            for i in 0..full {
                // fetch + decode one weight register, ride every column
                let ww = load_le(wrow, i * WSTEP, WSTEP);
                for (lane, w) in ws.iter_mut().enumerate() {
                    *w = sext(((ww >> (lane as u32 * PW)) & WMASK) as i32, PW);
                }
                let xoff = i * XSTEP;
                for (j, acc) in out.iter_mut().enumerate() {
                    let xw = load_le(cols, j * stride + xoff, XSTEP);
                    for (lane, &w) in ws.iter().enumerate() {
                        let x = ((xw >> (lane as u32 * PX)) & XMASK) as i32;
                        *acc += x * w;
                    }
                }
            }
            for j in full * LANES..k {
                let w = extract_weight(wrow, j, PW);
                for (s, acc) in out.iter_mut().enumerate() {
                    *acc += extract_code(&cols[s * stride..], j, PX) as i32 * w;
                }
            }
        }

        fn $dot_wide_batch(cols: &[u8], stride: usize, wrow: &[u8], k: usize, out: &mut [i64]) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let full = k / LANES;
            out.fill(0);
            let mut ws = [0i64; LANES];
            for i in 0..full {
                let ww = load_le(wrow, i * WSTEP, WSTEP);
                for (lane, w) in ws.iter_mut().enumerate() {
                    *w = sext(((ww >> (lane as u32 * PW)) & WMASK) as i32, PW) as i64;
                }
                let xoff = i * XSTEP;
                for (j, acc) in out.iter_mut().enumerate() {
                    let xw = load_le(cols, j * stride + xoff, XSTEP);
                    for (lane, &w) in ws.iter().enumerate() {
                        let x = ((xw >> (lane as u32 * PX)) & XMASK) as i64;
                        *acc += x * w;
                    }
                }
            }
            for j in full * LANES..k {
                let w = extract_weight(wrow, j, PW) as i64;
                for (s, acc) in out.iter_mut().enumerate() {
                    *acc += extract_code(&cols[s * stride..], j, PX) as i64 * w;
                }
            }
        }
    };
}

swar_kernel!(dot_x2_w2, dot_x2_w2_wide, dot_x2_w2_b, dot_x2_w2_wb, 2, 2); // 16 lanes
swar_kernel!(dot_x2_w4, dot_x2_w4_wide, dot_x2_w4_b, dot_x2_w4_wb, 2, 4); //  8 lanes
swar_kernel!(dot_x2_w8, dot_x2_w8_wide, dot_x2_w8_b, dot_x2_w8_wb, 2, 8); //  4 lanes
swar_kernel!(dot_x4_w2, dot_x4_w2_wide, dot_x4_w2_b, dot_x4_w2_wb, 4, 2); //  8 lanes
swar_kernel!(dot_x4_w4, dot_x4_w4_wide, dot_x4_w4_b, dot_x4_w4_wb, 4, 4); //  8 lanes
swar_kernel!(dot_x4_w8, dot_x4_w8_wide, dot_x4_w8_b, dot_x4_w8_wb, 4, 8); //  4 lanes
swar_kernel!(dot_x8_w2, dot_x8_w2_wide, dot_x8_w2_b, dot_x8_w2_wb, 8, 2); //  4 lanes
swar_kernel!(dot_x8_w4, dot_x8_w4_wide, dot_x8_w4_b, dot_x8_w4_wb, 8, 4); //  4 lanes
swar_kernel!(dot_x8_w8, dot_x8_w8_wide, dot_x8_w8_b, dot_x8_w8_wb, 8, 8); //  4 lanes

/// Kernel table indexed `[precision_index(p_x)][precision_index(p_w)]`,
/// mirroring MPIC's per-(p_x, p_w) SIMD mode CSR.  Both operands arrive
/// packed, so every cell is a genuinely distinct SWAR body: the lane
/// grid, fetch widths and decode masks all depend on the combination.
pub(super) const DOT_KERNELS: [[RowDot; 3]; 3] = [
    [dot_x2_w2, dot_x2_w4, dot_x2_w8],
    [dot_x4_w2, dot_x4_w4, dot_x4_w8],
    [dot_x8_w2, dot_x8_w4, dot_x8_w8],
];

pub(super) const DOT_KERNELS_WIDE: [[RowDotWide; 3]; 3] = [
    [dot_x2_w2_wide, dot_x2_w4_wide, dot_x2_w8_wide],
    [dot_x4_w2_wide, dot_x4_w4_wide, dot_x4_w8_wide],
    [dot_x8_w2_wide, dot_x8_w4_wide, dot_x8_w8_wide],
];

/// Weight-stationary batched mirror of [`DOT_KERNELS`]: one weight
/// register fetch + decode ridden across all `B` packed columns.
pub(super) const DOT_KERNELS_BATCH: [[RowDotBatch; 3]; 3] = [
    [dot_x2_w2_b, dot_x2_w4_b, dot_x2_w8_b],
    [dot_x4_w2_b, dot_x4_w4_b, dot_x4_w8_b],
    [dot_x8_w2_b, dot_x8_w4_b, dot_x8_w8_b],
];

pub(super) const DOT_KERNELS_WIDE_BATCH: [[RowDotWideBatch; 3]; 3] = [
    [dot_x2_w2_wb, dot_x2_w4_wb, dot_x2_w8_wb],
    [dot_x4_w2_wb, dot_x4_w4_wb, dot_x4_w8_wb],
    [dot_x8_w2_wb, dot_x8_w4_wb, dot_x8_w8_wb],
];

struct PackedRow {
    /// byte offset into `bytes`
    offset: u32,
    /// `precision_index(weight_bits)`
    widx: u8,
}

struct PackedKernel {
    /// K = codes per row (same for every channel of the layer)
    k: usize,
    /// all channel rows, each padded to a byte boundary (the CMix-NN
    /// reordered-group layout `quant::packed_weight_bytes` sizes) —
    /// owned on compile, zero-copy artifact view on modelpack load
    bytes: ByteArr,
    rows: Vec<PackedRow>,
    /// `precision_index(act_bits)` — selects the kernel-table row
    aidx: usize,
}

/// Pack one deployed layer into the Eq. (7) flash image: one byte-
/// aligned sub-byte row per output channel.  Shared by the packed and
/// simd backends — both execute the identical weight layout, so a
/// `.cwm` serialized by one loads into the other bit-for-bit.
fn pack_layer(dl: &DeployedLayer) -> (usize, ByteArr, Vec<PackedRow>, usize) {
    let k = dl.k();
    let cout = dl.spec.cout;
    let mut bytes = Vec::with_capacity(dl.packed_bytes());
    let mut rows = Vec::with_capacity(cout);
    for c in 0..cout {
        let bits = dl.weight_bits[c];
        let packed = pack_subbyte(&dl.qweights[c * k..(c + 1) * k], bits);
        rows.push(PackedRow {
            offset: bytes.len() as u32,
            widx: precision_index(bits) as u8,
        });
        bytes.extend_from_slice(&packed);
    }
    (k, bytes.into(), rows, precision_index(dl.act_bits))
}

impl KernelBackend for PackedBackend {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel> {
        let (k, bytes, rows, aidx) = pack_layer(dl);
        Box::new(PackedKernel { k, bytes, rows, aidx })
    }
}

/// Rebuild a packed kernel from modelpack state (`engine::pack` has
/// already validated every row's `(offset, widx)` against `bytes` and
/// `act_index` against the kernel table bounds) — the zero-copy load
/// path: `bytes` stays the borrowed flash image, nothing is re-packed.
pub(super) fn packed_kernel_from_parts(
    k: usize,
    act_index: usize,
    rows: Vec<(u32, u8)>,
    bytes: ByteArr,
) -> Box<dyn LayerKernel> {
    Box::new(PackedKernel {
        k,
        bytes,
        rows: rows
            .into_iter()
            .map(|(offset, widx)| PackedRow { offset, widx })
            .collect(),
        aidx: act_index,
    })
}

impl PackedKernel {
    #[inline(always)]
    fn row(&self, c: usize) -> (&[u8], usize) {
        let r = &self.rows[c];
        (&self.bytes[r.offset as usize..], r.widx as usize)
    }
}

impl LayerKernel for PackedKernel {
    #[inline]
    fn dot(&self, c: usize, xcol: &[u8]) -> i32 {
        let (row, widx) = self.row(c);
        DOT_KERNELS[self.aidx][widx](xcol, row, self.k)
    }

    #[inline]
    fn dot_wide(&self, c: usize, xcol: &[u8]) -> i64 {
        let (row, widx) = self.row(c);
        DOT_KERNELS_WIDE[self.aidx][widx](xcol, row, self.k)
    }

    #[inline]
    fn dot_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i32]) {
        let (row, widx) = self.row(c);
        DOT_KERNELS_BATCH[self.aidx][widx](cols, stride, row, self.k, out);
    }

    #[inline]
    fn dot_wide_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i64]) {
        let (row, widx) = self.row(c);
        DOT_KERNELS_WIDE_BATCH[self.aidx][widx](cols, stride, row, self.k, out);
    }

    fn weight_bytes(&self) -> usize {
        self.bytes.len()
    }

    fn state(&self) -> KernelState<'_> {
        KernelState::Packed {
            k: self.k,
            act_index: self.aidx,
            rows: self.rows.iter().map(|r| (r.offset, r.widx)).collect(),
            bytes: &self.bytes,
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD backend: packed layout, runtime-dispatched x86 vector kernels.
// ---------------------------------------------------------------------------

/// The [`PackedBackend`] weight layout executed through the
/// `engine::simd` vector kernels.  The batched weight-stationary entry
/// points are the hot seam: each 32-bit weight word is decoded once and
/// ridden across all `B` columns with the **batch axis as the vector
/// axis**, so per sample nothing about the accumulation changes and the
/// results stay bit-identical to [`ReferenceBackend`] on every tier.
///
/// The tier (AVX-512 → AVX2 → SWAR) is resolved once per process at
/// first model load — `simd::active` — and reported via
/// [`KernelBackend::tier`].  Single-column dots delegate to the SWAR
/// cells directly: `B = 1` has no batch axis to vectorize.
pub struct SimdBackend;

struct SimdKernel {
    k: usize,
    /// same flash image [`PackedKernel`] holds — serialized identically
    bytes: ByteArr,
    rows: Vec<PackedRow>,
    aidx: usize,
    /// tier tables resolved at load (process-wide, never changes after)
    tables: &'static simd::Tables,
}

impl KernelBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn tier(&self) -> &'static str {
        simd::active_tier_name()
    }

    fn prepare(&self, dl: &DeployedLayer) -> Box<dyn LayerKernel> {
        let (k, bytes, rows, aidx) = pack_layer(dl);
        Box::new(SimdKernel { k, bytes, rows, aidx, tables: simd::active() })
    }
}

/// Rebuild a simd kernel from modelpack state — the weight image is the
/// [`KernelState::Packed`] layout verbatim (`engine::pack` validation
/// already ran); only the dispatch tables differ from the packed
/// backend, and those are re-resolved on the *loading* host, so a
/// `.cwm` compiled on an AVX-512 box runs correctly on a SWAR-only one.
pub(super) fn simd_kernel_from_parts(
    k: usize,
    act_index: usize,
    rows: Vec<(u32, u8)>,
    bytes: ByteArr,
) -> Box<dyn LayerKernel> {
    Box::new(SimdKernel {
        k,
        bytes,
        rows: rows
            .into_iter()
            .map(|(offset, widx)| PackedRow { offset, widx })
            .collect(),
        aidx: act_index,
        tables: simd::active(),
    })
}

impl SimdKernel {
    #[inline(always)]
    fn row(&self, c: usize) -> (&[u8], usize) {
        let r = &self.rows[c];
        (&self.bytes[r.offset as usize..], r.widx as usize)
    }
}

impl LayerKernel for SimdKernel {
    #[inline]
    fn dot(&self, c: usize, xcol: &[u8]) -> i32 {
        let (row, widx) = self.row(c);
        DOT_KERNELS[self.aidx][widx](xcol, row, self.k)
    }

    #[inline]
    fn dot_wide(&self, c: usize, xcol: &[u8]) -> i64 {
        let (row, widx) = self.row(c);
        DOT_KERNELS_WIDE[self.aidx][widx](xcol, row, self.k)
    }

    #[inline]
    fn dot_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i32]) {
        let (row, widx) = self.row(c);
        self.tables.batch[self.aidx][widx](cols, stride, row, self.k, out);
    }

    #[inline]
    fn dot_wide_batch(&self, c: usize, cols: &[u8], stride: usize, out: &mut [i64]) {
        let (row, widx) = self.row(c);
        self.tables.wide_batch[self.aidx][widx](cols, stride, row, self.k, out);
    }

    fn weight_bytes(&self) -> usize {
        self.bytes.len()
    }

    fn state(&self) -> KernelState<'_> {
        // identical layout to PackedKernel — the artifact records the
        // backend *name*, not the tier, so packs stay host-portable
        KernelState::Packed {
            k: self.k,
            act_index: self.aidx,
            rows: self.rows.iter().map(|r| (r.offset, r.widx)).collect(),
            bytes: &self.bytes,
        }
    }
}

/// Resolve a backend by CLI/bench name.
pub fn backend_by_name(name: &str) -> anyhow::Result<&'static dyn KernelBackend> {
    match name {
        "reference" | "ref" => Ok(&ReferenceBackend),
        "packed" => Ok(&PackedBackend),
        "simd" => Ok(&SimdBackend),
        other => anyhow::bail!(
            "unknown backend {other:?} (valid: reference|packed|simd; \
             simd would dispatch to the {:?} tier on this host)",
            simd::active_tier_name()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack_acts_subbyte;
    use crate::util::Pcg32;
    use crate::PRECISIONS;

    /// Random signed row over the FULL `bits` range, including the most
    /// negative code `-(2^(bits-1))` (producible by packing even though
    /// the symmetric quantizer never emits it).
    fn random_row(rng: &mut Pcg32, k: usize, bits: u32) -> Vec<i32> {
        let lo = -(1i32 << (bits - 1));
        let hi = (1i32 << (bits - 1)) - 1;
        (0..k).map(|_| lo + rng.below((hi - lo + 1) as u32) as i32).collect()
    }

    /// Ragged K values: tail lanes of every register width (16/8/4
    /// lanes), single-code columns, and byte-straddling lengths.
    const RAGGED_K: [usize; 14] = [1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 33, 64, 127];

    #[test]
    fn all_nine_combos_match_scalar_ragged_and_extreme() {
        let mut rng = Pcg32::seeded(11);
        for (ai, &px) in PRECISIONS.iter().enumerate() {
            for (wi, &pw) in PRECISIONS.iter().enumerate() {
                for k in RAGGED_K {
                    let mut w = random_row(&mut rng, k, pw);
                    let mut x: Vec<u32> = (0..k).map(|_| rng.below(1 << px)).collect();
                    // extreme codes at both ends: the PACT clip boundary
                    // and the most negative weight code
                    x[0] = (1 << px) - 1;
                    w[0] = -(1i32 << (pw - 1));
                    if k > 1 {
                        x[k - 1] = (1 << px) - 1;
                        w[k - 1] = -(1i32 << (pw - 1));
                    }
                    let xcol = pack_acts_subbyte(&x, px);
                    let wrow = pack_subbyte(&w, pw);
                    let want: i64 = x.iter().zip(&w).map(|(&a, &b)| a as i64 * b as i64).sum();
                    let got = DOT_KERNELS[ai][wi](&xcol, &wrow, k);
                    assert_eq!(got as i64, want, "px={px} pw={pw} k={k}");
                    let got_wide = DOT_KERNELS_WIDE[ai][wi](&xcol, &wrow, k);
                    assert_eq!(got_wide, want, "wide px={px} pw={pw} k={k}");
                }
            }
        }
    }

    /// Weight-stationary batch kernels are bit-identical to running the
    /// single-column kernel per column — every table cell, ragged K
    /// values, extreme codes, batch sizes including 1, and a stride
    /// wider than the column (batch-plane slack bytes between columns).
    #[test]
    fn batch_kernels_match_per_column_all_cells() {
        let mut rng = Pcg32::seeded(23);
        for (ai, &px) in PRECISIONS.iter().enumerate() {
            for (wi, &pw) in PRECISIONS.iter().enumerate() {
                for k in [1usize, 5, 16, 17, 33, 127] {
                    for b in [1usize, 2, 3, 8] {
                        let mut w = random_row(&mut rng, k, pw);
                        w[0] = -(1i32 << (pw - 1));
                        let wrow = pack_subbyte(&w, pw);
                        let col_bytes = (k * px as usize).div_ceil(8);
                        let stride = col_bytes + 3; // slack between columns
                        let mut cols = vec![0u8; b * stride];
                        let mut singles32 = vec![0i32; b];
                        let mut singles64 = vec![0i64; b];
                        for j in 0..b {
                            let mut x: Vec<u32> =
                                (0..k).map(|_| rng.below(1 << px)).collect();
                            x[0] = (1 << px) - 1;
                            let packed = pack_acts_subbyte(&x, px);
                            cols[j * stride..j * stride + col_bytes]
                                .copy_from_slice(&packed);
                            singles32[j] =
                                DOT_KERNELS[ai][wi](&packed, &wrow, k);
                            singles64[j] =
                                DOT_KERNELS_WIDE[ai][wi](&packed, &wrow, k);
                        }
                        let mut out32 = vec![0i32; b];
                        DOT_KERNELS_BATCH[ai][wi](&cols, stride, &wrow, k, &mut out32);
                        assert_eq!(out32, singles32, "px={px} pw={pw} k={k} b={b}");
                        let mut out64 = vec![0i64; b];
                        DOT_KERNELS_WIDE_BATCH[ai][wi](&cols, stride, &wrow, k, &mut out64);
                        assert_eq!(out64, singles64, "wide px={px} pw={pw} k={k} b={b}");
                    }
                }
            }
        }
    }

    /// The default (fallback) batched entry points on a backend that
    /// does not override them agree with its per-column dots.
    #[test]
    fn default_batch_entry_points_match_per_column() {
        let mut rng = Pcg32::seeded(29);
        let (k, px, b) = (29usize, 4u32, 3usize);
        let w = random_row(&mut rng, k, 8);
        let kern = ReferenceKernel { k, act_bits: px, qw: w.into() };
        let col_bytes = (k * px as usize).div_ceil(8);
        let stride = col_bytes + 1;
        let mut cols = vec![0u8; b * stride];
        let mut want32 = vec![0i32; b];
        let mut want64 = vec![0i64; b];
        for j in 0..b {
            let x: Vec<u32> = (0..k).map(|_| rng.below(1 << px)).collect();
            let packed = pack_acts_subbyte(&x, px);
            cols[j * stride..j * stride + col_bytes].copy_from_slice(&packed);
            want32[j] = kern.dot(0, &packed);
            want64[j] = kern.dot_wide(0, &packed);
        }
        let mut out32 = vec![0i32; b];
        kern.dot_batch(0, &cols, stride, &mut out32);
        assert_eq!(out32, want32);
        let mut out64 = vec![0i64; b];
        kern.dot_wide_batch(0, &cols, stride, &mut out64);
        assert_eq!(out64, want64);
    }

    #[test]
    fn reference_kernel_decodes_packed_columns() {
        // the reference backend reads the same packed columns; its
        // scalar decode must agree with the SWAR kernels
        let mut rng = Pcg32::seeded(17);
        for &px in &PRECISIONS {
            let k = 29;
            let x: Vec<u32> = (0..k).map(|_| rng.below(1 << px)).collect();
            let w = random_row(&mut rng, k, 8);
            let xcol = pack_acts_subbyte(&x, px);
            let want: i64 = x.iter().zip(&w).map(|(&a, &b)| a as i64 * b as i64).sum();
            let kern = ReferenceKernel { k, act_bits: px, qw: w.into() };
            assert_eq!(kern.dot(0, &xcol) as i64, want, "px={px}");
            assert_eq!(kern.dot_wide(0, &xcol), want, "wide px={px}");
        }
    }

    #[test]
    fn sext_covers_full_range() {
        assert_eq!(sext(0, 2), 0);
        assert_eq!(sext(1, 2), 1);
        assert_eq!(sext(2, 2), -2);
        assert_eq!(sext(3, 2), -1);
        assert_eq!(sext(0x7, 4), 7);
        assert_eq!(sext(0x8, 4), -8);
        assert_eq!(sext(0xf, 4), -1);
        assert_eq!(sext(0x80, 8), -128);
        assert_eq!(sext(0xff, 8), -1);
    }

    #[test]
    fn load_le_matches_from_le_bytes() {
        let buf = [0x12u8, 0x34, 0x56, 0x78, 0x9a];
        assert_eq!(load_le(&buf, 0, 4), u32::from_le_bytes([0x12, 0x34, 0x56, 0x78]));
        assert_eq!(load_le(&buf, 1, 2), 0x5634);
        assert_eq!(load_le(&buf, 4, 1), 0x9a);
    }

    #[test]
    fn backend_names_resolve() {
        assert_eq!(backend_by_name("packed").unwrap().name(), "packed");
        assert_eq!(backend_by_name("ref").unwrap().name(), "reference");
        assert_eq!(backend_by_name("simd").unwrap().name(), "simd");
    }

    #[test]
    fn unknown_backend_error_lists_names_and_tier() {
        let err = backend_by_name("vliw").unwrap_err().to_string();
        for needle in ["reference", "packed", "simd", SimdBackend.tier()] {
            assert!(err.contains(needle), "{err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn simd_backend_reports_a_known_tier() {
        let tier = SimdBackend.tier();
        assert!(
            ["swar", "avx2", "avx512"].contains(&tier),
            "unexpected tier {tier:?}"
        );
        // single-tier backends report their own name
        assert_eq!(PackedBackend.tier(), "packed");
        assert_eq!(ReferenceBackend.tier(), "reference");
    }

    /// Every vector tier available on this host is bit-identical to the
    /// single-column SWAR kernels — all nine cells, ragged K, extreme
    /// codes, batch sizes straddling both vector widths (8-wide i32 /
    /// 4-wide i64 on AVX2, 16/8 on AVX-512) plus their remainders, and
    /// a stride wider than the column.
    #[test]
    fn simd_tier_batch_kernels_match_swar_all_cells() {
        let mut rng = Pcg32::seeded(37);
        for tables in simd::available_tables() {
            for (ai, &px) in PRECISIONS.iter().enumerate() {
                for (wi, &pw) in PRECISIONS.iter().enumerate() {
                    for k in [1usize, 5, 17, 33, 127] {
                        for b in [1usize, 3, 7, 8, 9, 15, 16, 17, 33] {
                            let mut w = random_row(&mut rng, k, pw);
                            w[0] = -(1i32 << (pw - 1));
                            let wrow = pack_subbyte(&w, pw);
                            let col_bytes = (k * px as usize).div_ceil(8);
                            // no slack: the *last* column must end flush
                            // at the buffer end, like the zero-copy FC
                            // planes — catches any vector over-read
                            let stride = col_bytes;
                            let mut cols = vec![0u8; b * stride];
                            let mut singles32 = vec![0i32; b];
                            let mut singles64 = vec![0i64; b];
                            for j in 0..b {
                                let mut x: Vec<u32> =
                                    (0..k).map(|_| rng.below(1 << px)).collect();
                                x[0] = (1 << px) - 1;
                                let packed = pack_acts_subbyte(&x, px);
                                cols[j * stride..j * stride + col_bytes]
                                    .copy_from_slice(&packed);
                                singles32[j] = DOT_KERNELS[ai][wi](&packed, &wrow, k);
                                singles64[j] =
                                    DOT_KERNELS_WIDE[ai][wi](&packed, &wrow, k);
                            }
                            let tier = tables.tier.name();
                            let mut out32 = vec![0i32; b];
                            tables.batch[ai][wi](&cols, stride, &wrow, k, &mut out32);
                            assert_eq!(
                                out32, singles32,
                                "{tier} px={px} pw={pw} k={k} b={b}"
                            );
                            let mut out64 = vec![0i64; b];
                            tables.wide_batch[ai][wi](
                                &cols, stride, &wrow, k, &mut out64,
                            );
                            assert_eq!(
                                out64, singles64,
                                "{tier} wide px={px} pw={pw} k={k} b={b}"
                            );
                        }
                    }
                }
            }
        }
    }
}
