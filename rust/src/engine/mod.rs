//! Compile-once / run-many integer inference engine.
//!
//! The seed deployed-inference path (`mpic::exec`) interprets a
//! [`DeployedModel`](crate::deploy::DeployedModel) sample by sample,
//! re-deriving padding/im2col geometry, re-allocating every activation
//! buffer and re-cloning the saved-tensor map on each call.  This module
//! is the plan/execute split that replaces it on the hot path:
//!
//! * [`ExecPlan::compile`] lowers a deployed model **once** into a
//!   self-contained plan: arena slot assignments, precomputed SAME
//!   padding/im2col gather tables (byte offsets into the packed
//!   activation plane), folded per-channel epilogues, the per-layer
//!   [`InferenceCost`](crate::mpic::cost::InferenceCost)
//!   (input-independent, accounted at compile time), and per-layer
//!   kernels prepared by a [`KernelBackend`];
//! * [`ExecPlan::run_sample`] / [`ExecPlan::run_batch`] execute it with
//!   zero per-sample allocation besides the returned outputs: each
//!   quantized layer's input is PACT-quantized **once into a packed
//!   sub-byte plane** (`p_x`-bit codes, one byte-aligned run per pixel)
//!   and the dot kernels consume densely packed columns gathered from
//!   it.  Batches fan out across `std::thread::scope` workers with
//!   per-thread [`Arena`]s;
//! * [`KernelBackend`] is the pluggable seam for the integer dot
//!   kernels: [`ReferenceBackend`] (scalar `i32` weight rows, the
//!   in-engine bit-exactness oracle) and [`PackedBackend`] (sub-byte
//!   bit-packed weight rows × packed activation columns through nine
//!   distinct per-`(p_x, p_w)` SWAR kernels, mirroring MPIC's
//!   mixed-precision `sdotp` modes).  All backends are bit-identical by
//!   contract — `tests/engine_equivalence.rs` enforces it against
//!   `mpic::exec::run_sample` across all nine `(p_x, p_w) ∈ {2,4,8}²`
//!   combos and the four benchmark topologies.
//!
//! There is deliberately **no** per-call convenience wrapper that
//! compiles and runs in one shot: every caller holds an [`ExecPlan`]
//! (that is the point of the plan/execute split).

pub mod arena;
pub mod backend;
pub mod plan;

pub use arena::Arena;
pub use backend::{
    backend_by_name, KernelBackend, LayerKernel, PackedBackend,
    ReferenceBackend,
};
pub use plan::{engine_threads, ExecPlan};
