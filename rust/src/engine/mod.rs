//! Compile-once / run-many integer inference engine.
//!
//! The seed deployed-inference path (`mpic::exec`) interprets a
//! [`DeployedModel`](crate::deploy::DeployedModel) sample by sample,
//! re-deriving padding/im2col geometry, re-allocating every activation
//! buffer and re-cloning the saved-tensor map on each call.  This module
//! is the plan/execute split that replaces it on the hot path:
//!
//! * [`ExecPlan::compile`] lowers a deployed model **once** into a
//!   self-contained plan: arena slot assignments, precomputed SAME
//!   padding/im2col gather tables, folded per-channel epilogues, the
//!   per-layer [`InferenceCost`](crate::mpic::cost::InferenceCost)
//!   (input-independent, accounted at compile time), and per-layer
//!   kernels prepared by a [`KernelBackend`];
//! * [`ExecPlan::run_sample`] / [`ExecPlan::run_batch`] execute it with
//!   zero per-sample allocation besides the returned outputs, fanning
//!   batches across `std::thread::scope` workers with per-thread
//!   [`Arena`]s;
//! * [`KernelBackend`] is the pluggable seam for the integer dot
//!   kernels: [`ReferenceBackend`] (the seed scalar loops, the
//!   bit-exactness oracle) and [`PackedBackend`] (sub-byte bit-packed
//!   weight rows with unrolled decode kernels per `(p_x, p_w)`,
//!   mirroring MPIC's mixed-precision SIMD modes).  All backends are
//!   bit-identical by contract — `tests/engine_equivalence.rs` enforces
//!   it across all nine `(p_x, p_w) ∈ {2,4,8}²` combos and the four
//!   benchmark topologies.

pub mod arena;
pub mod backend;
pub mod plan;

pub use arena::Arena;
pub use backend::{
    backend_by_name, KernelBackend, LayerKernel, PackedBackend,
    ReferenceBackend,
};
pub use plan::{engine_threads, ExecPlan};

use anyhow::Result;

use crate::deploy::DeployedModel;
use crate::energy::CostLut;
use crate::mpic::cost::InferenceCost;

/// One-shot convenience: compile a plan against `backend` and run the
/// whole batch.  Callers executing more than one batch should keep the
/// [`ExecPlan`] (that is the point of the plan/execute split).
pub fn run_batch(
    model: &DeployedModel,
    xs: &[f32],
    feat: usize,
    lut: &CostLut,
    backend: &dyn KernelBackend,
) -> Result<(Vec<Vec<f32>>, InferenceCost)> {
    let plan = ExecPlan::compile(model, lut, backend)?;
    plan.run_batch(xs, feat)
}
