//! Compile-once / run-many integer inference engine, batch-major.
//!
//! The seed deployed-inference path (`mpic::exec`) interprets a
//! [`DeployedModel`](crate::deploy::DeployedModel) sample by sample,
//! re-deriving padding/im2col geometry, re-allocating every activation
//! buffer and re-cloning the saved-tensor map on each call.  This module
//! is the plan/execute split that replaces it on the hot path:
//!
//! * [`ExecPlan::compile`] lowers a deployed model **once** into a
//!   self-contained plan: arena slot assignments, precomputed SAME
//!   padding/im2col gather tables (byte offsets into the packed
//!   activation plane), folded per-channel epilogues, the per-layer
//!   [`InferenceCost`](crate::mpic::cost::InferenceCost)
//!   (input-independent, accounted at compile time), and per-layer
//!   kernels prepared by a [`KernelBackend`];
//! * [`ExecPlan::run_batch_planes`] executes a whole batch
//!   **batch-major** with zero per-sample allocation besides the
//!   returned outputs: per quantized layer, every sample's input is
//!   PACT-quantized into a packed sub-byte plane (`p_x`-bit codes, one
//!   byte-aligned run per pixel, one stride-addressed plane per sample
//!   in the batch [`Arena`]) in a single pass, and the dot kernels'
//!   batched entry points ride each fetched weight word across all `B`
//!   packed columns (weight-stationary SWAR).  A compile-time fusion
//!   pass additionally folds the PACT quantize+pack of fusible
//!   layer-to-layer edges into the producer's epilogue exit (**fused
//!   requantize**): the producer codes the consumer's packed plane
//!   directly, eliding the f32 round-trip, and residual taps whose
//!   branches agree on `p_x` reuse one saved packed plane — coverage is
//!   reported per plan by [`FusionStats`].  [`ExecPlan::run_sample`]
//!   is the one-sample batch; [`ExecPlan::run_samples`] /
//!   [`ExecPlan::run_batch`] shard across `std::thread::scope` workers
//!   **by batch-chunk** (≤ [`MAX_BATCH_CHUNK`] samples per pass), one
//!   batch [`Arena`] per worker;
//! * [`KernelBackend`] is the pluggable seam for the integer dot
//!   kernels: [`ReferenceBackend`] (scalar `i32` weight rows, the
//!   in-engine bit-exactness oracle), [`PackedBackend`] (sub-byte
//!   bit-packed weight rows × packed activation columns through nine
//!   distinct per-`(p_x, p_w)` SWAR kernels — each with a
//!   weight-stationary batched variant — mirroring MPIC's
//!   mixed-precision `sdotp` modes), and [`SimdBackend`] (the same
//!   packed layout driven through explicit x86 vector kernels
//!   ([`simd`]), the batch axis as the vector axis, with the
//!   AVX-512 → AVX2 → SWAR dispatch tier resolved once per process by
//!   `is_x86_feature_detected!` / `CWMIX_SIMD`).  All backends are
//!   bit-identical by contract — `tests/engine_equivalence.rs`
//!   enforces it against `mpic::exec::run_sample` across all nine
//!   `(p_x, p_w) ∈ {2,4,8}²` combos and the four benchmark topologies,
//!   and `tests/engine_batch_plane.rs` re-enforces it per batch size.
//!
//! There is deliberately **no** per-call convenience wrapper that
//! compiles and runs in one shot: every caller holds an [`ExecPlan`]
//! (that is the point of the plan/execute split).
//!
//! For observability, [`ExecPlan::run_batch_planes_profiled`]
//! accumulates per-node wall time (quantize vs. kernel+epilogue split),
//! modeled bytes moved and executed-batch histograms into a
//! [`PlanProfile`] — the measurement side of the `cwmix profile`
//! cost-model-fit report (DESIGN.md §9) — and every pass emits
//! `engine_pass`/`node` spans through [`crate::trace`] when tracing is
//! enabled (a single predicted branch per site when it is not).
//!
//! Compiled plans are durable: [`ExecPlan::to_modelpack`] /
//! [`ExecPlan::from_modelpack`] ([`pack`]) round-trip the *entire*
//! compile output through the versioned `.cwm` artifact container
//! ([`crate::modelpack`]) with bit-identical execution — the registry
//! cold-start path and `cwmix compile`/`inspect` build on it.

pub mod arena;
pub mod backend;
pub mod pack;
pub mod plan;
pub mod simd;

pub use arena::Arena;
pub use backend::{
    backend_by_name, KernelBackend, KernelState, LayerKernel, PackedBackend,
    ReferenceBackend, SimdBackend,
};
pub use pack::{inspect, read_provenance, InspectLayer, InspectReport, Provenance};
pub use plan::{
    engine_threads, ExecPlan, FusionStats, NodeProfile, PlanProfile, MAX_BATCH_CHUNK,
};
