//! Per-worker scratch memory for plan execution.
//!
//! An [`Arena`] owns every buffer one worker thread needs to run any
//! number of samples through an `ExecPlan`: the activation slots (two
//! ping-pong scratch slots + one exactly-sized slot per saved residual
//! tag) and the packed quantization/gather scratch.  Nothing is
//! allocated per sample or per layer — the seed executor's per-layer
//! `Vec` allocations and `HashMap<String, Act>` clones are what this
//! replaces.
//!
//! The quantization scratch is **sub-byte packed** (`u8`, not `u32`):
//! `xplane` holds the executing layer's activation codes at its `p_x`
//! width (one byte-aligned run per input pixel) and `col` holds the
//! densely packed im2col column the dot kernels consume — `8 / p_x`
//! times smaller than the unpacked lanes they replaced.

/// Scratch buffers for one execution worker.
pub struct Arena {
    /// activation slots, indexed by the plan's slot ids
    pub(super) slots: Vec<Vec<f32>>,
    /// packed PACT activation plane of the layer currently executing
    /// (`p_x`-bit codes, one byte-aligned run per pixel)
    pub(super) xplane: Vec<u8>,
    /// densely packed im2col column / FC input codes (`p_x`-bit), with
    /// slack bytes for the unaligned-assembly spill
    pub(super) col: Vec<u8>,
}

impl Arena {
    pub(super) fn new(slot_len: &[usize], plane_len: usize, col_len: usize) -> Arena {
        Arena {
            slots: slot_len.iter().map(|&l| vec![0.0; l]).collect(),
            xplane: vec![0; plane_len],
            col: vec![0; col_len],
        }
    }

    /// Total bytes held (diagnostics).
    pub fn bytes(&self) -> usize {
        let f: usize = self.slots.iter().map(|s| s.len() * 4).sum();
        f + self.xplane.len() + self.col.len()
    }
}
