//! Per-worker scratch memory for plan execution — batch-plane layout.
//!
//! An [`Arena`] owns every buffer one worker thread needs to run
//! batches of up to `cap` samples through an `ExecPlan`, batch-major:
//! the activation slots (two ping-pong scratch slots + one
//! exactly-sized slot per saved residual tag), the packed
//! quantization/gather scratch and the batched accumulator rows.
//! Nothing is allocated per sample or per layer — the seed executor's
//! per-layer `Vec` allocations and `HashMap<String, Act>` clones are
//! what this replaced, and the batch-plane layout additionally removes
//! the per-sample re-quantization the per-sample executor paid.
//!
//! **Stride addressing.** Every buffer holds `cap` per-sample regions
//! at a fixed stride (the plan's per-sample sizes): sample `j`'s slice
//! of slot `i` starts at `j * slot_len[i]`, its packed activation
//! planes at `j * plane_len` (within each plane slot) and its im2col
//! column at `j * col_len`.  The plan owns the strides; the arena only
//! owns the storage.
//!
//! The quantization scratch is **sub-byte packed** (`u8`, not `u32`):
//! `planes` holds packed `p_x`-bit activation codes (one byte-aligned
//! run per pixel, one plane per sample) and `col` holds the densely
//! packed im2col columns the batched dot kernels consume — `8 / p_x`
//! times smaller than the unpacked lanes they replaced, `cap` columns
//! side by side so one weight fetch can ride every sample's column
//! (weight-stationary execution).
//!
//! **Plane slots.** An unfused plan uses a single plane buffer (the
//! executing layer's input, dead once the layer finishes).  A plan with
//! fused requantize keeps more than one plane live at a time — a fused
//! producer codes the *consumer's* plane while reading its own, and a
//! residual tap's shared plane survives across intervening layers — so
//! `planes` holds `plane_slots` equally-sized buffers indexed by the
//! plan's plane-slot ids (0/1 flip between adjacent fused pairs, ids
//! ≥ 2 are dedicated reuse planes).
//!
//! Fully-fused chains also shrink the f32 side: a producer whose value
//! has no f32 reader skips its slot write entirely, and the fusion pass
//! drops the dead tag-slot saves, so those bytes are never touched.

/// Scratch buffers for one execution worker, sized for `cap` samples.
pub struct Arena {
    /// batch capacity: samples per batch-plane pass
    pub(super) cap: usize,
    /// activation slots, indexed by the plan's slot ids; each holds
    /// `cap` per-sample regions at the slot's stride
    pub(super) slots: Vec<Vec<f32>>,
    /// packed PACT activation planes (`p_x`-bit codes, one byte-aligned
    /// run per pixel, one plane per sample at the plan's plane stride),
    /// indexed by the plan's plane-slot ids
    pub(super) planes: Vec<Vec<u8>>,
    /// densely packed im2col columns / FC input codes (`p_x`-bit), one
    /// column per sample at the plan's column stride, each with slack
    /// bytes for the unaligned-assembly spill
    pub(super) col: Vec<u8>,
    /// batched `i32` dot accumulators (conv/dwconv), one per sample
    pub(super) acc: Vec<i32>,
    /// batched `i64` dot accumulators (FC), one per sample
    pub(super) acc_wide: Vec<i64>,
}

impl Arena {
    pub(super) fn new(
        slot_len: &[usize],
        plane_len: usize,
        plane_slots: usize,
        col_len: usize,
        cap: usize,
    ) -> Arena {
        Arena {
            cap,
            slots: slot_len.iter().map(|&l| vec![0.0; cap * l]).collect(),
            planes: (0..plane_slots.max(1))
                .map(|_| vec![0; cap * plane_len])
                .collect(),
            col: vec![0; cap * col_len],
            acc: vec![0; cap],
            acc_wide: vec![0; cap],
        }
    }

    /// Samples one batch-plane pass through this arena can hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total bytes held (diagnostics).
    pub fn bytes(&self) -> usize {
        let f: usize = self.slots.iter().map(|s| s.len() * 4).sum();
        let p: usize = self.planes.iter().map(|p| p.len()).sum();
        f + p + self.col.len() + self.acc.len() * 4 + self.acc_wide.len() * 8
    }
}
