//! Per-worker scratch memory for plan execution.
//!
//! An [`Arena`] owns every buffer one worker thread needs to run any
//! number of samples through an `ExecPlan`: the activation slots (two
//! ping-pong scratch slots + one exactly-sized slot per saved residual
//! tag) and the quantization/gather scratch.  Nothing is allocated per
//! sample or per layer — the seed executor's per-layer `Vec` allocations
//! and `HashMap<String, Act>` clones are what this replaces.

/// Scratch buffers for one execution worker.
pub struct Arena {
    /// activation slots, indexed by the plan's slot ids
    pub(super) slots: Vec<Vec<f32>>,
    /// PACT activation codes of the layer currently executing
    pub(super) q: Vec<u32>,
    /// gathered im2col column / FC input codes as `i32`
    pub(super) col: Vec<i32>,
}

impl Arena {
    pub(super) fn new(slot_len: &[usize], q_len: usize, col_len: usize) -> Arena {
        Arena {
            slots: slot_len.iter().map(|&l| vec![0.0; l]).collect(),
            q: vec![0; q_len],
            col: vec![0; col_len],
        }
    }

    /// Total bytes held (diagnostics).
    pub fn bytes(&self) -> usize {
        let f: usize = self.slots.iter().map(|s| s.len() * 4).sum();
        f + self.q.len() * 4 + self.col.len() * 4
    }
}
