//! `ExecPlan` ⇄ `.cwm` modelpack serialization.
//!
//! [`ExecPlan::to_modelpack`] serializes **everything**
//! `ExecPlan::compile` derives — arena slot layout, node list, packed
//! sub-byte weight rows, channel-wise sub-convolution groups, folded
//! epilogues, im2col gather tables and the input-independent
//! [`InferenceCost`] — into the sectioned container defined by
//! [`crate::modelpack`].  [`ExecPlan::from_modelpack`] is the
//! **validate-then-borrow** inverse: after the container and every
//! record is checked, the large arrays (weight rows, `i32` gather
//! tables, `f32` epilogues) become zero-copy views into the one owned
//! aligned buffer — no re-packing, no f32 weight materialization, and
//! the loaded plan executes **bit-identically** to a fresh compile
//! (`tests/modelpack_roundtrip.rs` asserts it across the zoo × both
//! backends).
//!
//! Hostile-input contract: a crafted or corrupted pack yields a typed
//! [`PackError`]; it can never panic the loader *or* a later
//! execution.  Decode therefore re-derives every geometry invariant
//! the executor's unchecked indexing relies on (slot ids in range,
//! buffer lengths consistent with `(cin, p_x, K)`, every gather entry
//! inside the packed plane, every weight-row descriptor inside the
//! flash image, kernel-table indices in bounds) and rejects packs that
//! violate any of them.
//!
//! [`inspect`] parses a pack into an [`InspectReport`] — the artifact
//! form of the paper's memory comparison: per-layer channel bit-width
//! histograms and the packed-vs-int8-vs-f32 size table, cross-checked
//! against the `mpic::cost` Eq. (7) packed-byte accounting carried in
//! the pack.

use crate::modelpack::{
    assemble, malformed, AlignedBuf, Bytes, ByteArr, Container, DataWriter, F32Arr,
    I32Arr, PackError, PackReader, PackWriter, SECTION_COST, SECTION_DATA,
    SECTION_META, SECTION_PLAN, SECTION_PROV,
};
use crate::mpic::cost::{InferenceCost, LayerCost};
use crate::precision_index;
use crate::PRECISIONS;
use std::sync::Arc;

use super::backend::{
    backend_by_name, packed_kernel_from_parts, reference_kernel_from_parts,
    simd_kernel_from_parts, KernelState,
};
use super::plan::{
    ExecPlan, FusionStats, NodeKind, OutFuse, PlanNode, PostAdd, QuantOp, COL_SLACK,
};

// Caps on hostile counts/sizes: far above any real model, low enough
// that a lying pack cannot drive pathological allocations.
const MAX_NODES: usize = 1 << 16;
const MAX_SLOTS: usize = 1 << 16;
/// f32 elements per arena slot (256 MiB).
const MAX_SLOT_ELEMS: usize = 1 << 26;
/// f32 elements across ALL slots: every arena buffer is allocated at
/// `cap (≤ 32) ×` these sizes, so per-slot caps alone would still let
/// a ~0.5 MB crafted pack drive a multi-TiB allocation (and abort the
/// process) at `plan.arena()` time.  64 MiB of f32 per sample bounds
/// the worst hostile arena at ~2 GiB — far above any zoo model, far
/// below an allocation-failure DoS.
const MAX_TOTAL_SLOT_ELEMS: u64 = 1 << 24;
/// bytes per packed plane / column buffer (also ×32 in a batch arena).
const MAX_BUF_BYTES: usize = 1 << 26;
const MAX_CHANNELS: usize = 1 << 24;
const MAX_K: usize = 1 << 24;
const MAX_COST_LAYERS: usize = 1 << 16;
/// packed-plane arena slots a fused plan may declare (real plans use a
/// handful: two flip slots + one per residual-reuse group)
const MAX_PLANE_SLOTS: usize = 1 << 12;

// Node kind tags.
const KIND_NOOP: u8 = 0;
const KIND_AVGPOOL: u8 = 1;
const KIND_ADD: u8 = 2;
const KIND_QUANT: u8 = 3;
/// a quantized layer carrying fused-requantize state (format minor ≥ 1):
/// the full [`KIND_QUANT`] record followed by the fusion extension —
/// layers without fusion state keep tag 3, so unfused plans stay
/// byte-identical to minor-0 packs
const KIND_QUANT_FUSED: u8 = 4;

// Kernel backend tags.
const KERNEL_REFERENCE: u8 = 0;
const KERNEL_PACKED: u8 = 1;

// ---------------------------------------------------------------------------
// Encode.
// ---------------------------------------------------------------------------

/// Provenance of a pack's model state: the construction parameters the
/// weights were synthesized under.  Not needed to *execute* a plan —
/// it exists so a loader that was asked for specific parameters can
/// refuse a pack built under different ones instead of silently
/// serving its numerics (`ModelRegistry` cross-checks it on cold
/// start; `cwmix compile` always writes it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Provenance {
    /// assignment spec (`stripy` | `w<N>x<M>`)
    pub assignment: String,
    /// synthetic-state seed
    pub seed: u64,
}

impl ExecPlan {
    /// Serialize this plan into a sealed `.cwm` byte image.
    pub fn to_modelpack(&self) -> Vec<u8> {
        self.to_modelpack_with(None)
    }

    /// [`Self::to_modelpack`] with an optional provenance section.
    pub fn to_modelpack_with(&self, provenance: Option<&Provenance>) -> Vec<u8> {
        let mut data = DataWriter::default();

        // PLAN stream (fills DATA with the big arrays as it goes)
        let mut p = PackWriter::default();
        p.u32(self.nodes.len() as u32);
        for node in &self.nodes {
            p.u32(node.src as u32);
            p.u32(node.dst as u32);
            p.bool(node.save.is_some());
            p.u32(node.save.unwrap_or(0) as u32);
            p.u64(node.out_len as u64);
            match &node.kind {
                NodeKind::NoOp => p.u8(KIND_NOOP),
                NodeKind::AvgPool { in_h, in_w, c } => {
                    p.u8(KIND_AVGPOOL);
                    p.u32(*in_h as u32);
                    p.u32(*in_w as u32);
                    p.u32(*c as u32);
                }
                NodeKind::Add { other, len, relu } => {
                    p.u8(KIND_ADD);
                    p.u32(*other as u32);
                    p.u64(*len as u64);
                    p.bool(*relu);
                }
                NodeKind::Quant(op) => {
                    let fused = op.in_plane_slot != 0
                        || op.in_plane_ready
                        || op.out_fuse.is_some();
                    p.u8(if fused { KIND_QUANT_FUSED } else { KIND_QUANT });
                    encode_quant(&mut p, &mut data, op);
                    if fused {
                        encode_fusion(&mut p, op);
                    }
                }
            }
        }

        // META
        let mut m = PackWriter::default();
        m.str(&self.bench);
        m.str(self.backend_name);
        m.u64(self.feat as u64);
        m.u64(self.out_len as u64);
        m.u32(self.out_slot as u32);
        m.bool(self.permute);
        m.u32(self.slot_len.len() as u32);
        for &l in &self.slot_len {
            m.u64(l as u64);
        }
        m.u64(self.plane_len as u64);
        m.u64(self.col_len as u64);
        m.u64(self.weight_bytes as u64);
        m.u64(self.weight_traffic_bytes);
        m.u32(self.output_perm.len() as u32);
        for &c in &self.output_perm {
            m.u32(c as u32);
        }
        // fused-requantize extension (format minor ≥ 1), written only
        // when there is fusion state to carry: unfused plans stay
        // byte-identical to minor-0 packs
        if self.plane_slots > 1 || self.fusion != FusionStats::default() {
            m.u32(self.plane_slots as u32);
            m.u32(self.fusion.total_edges as u32);
            m.u32(self.fusion.fused_edges as u32);
            m.u32(self.fusion.elided_f32 as u32);
            m.u32(self.fusion.reuse_hits as u32);
            m.u64(self.fusion.act_bytes_unfused);
            m.u64(self.fusion.act_bytes_fused);
        }

        // COST
        let mut c = PackWriter::default();
        c.u32(self.cost.layers.len() as u32);
        for lc in &self.cost.layers {
            c.str(&lc.name);
            c.u32(lc.macs_by_group.len() as u32);
            for &(bits, macs) in &lc.macs_by_group {
                c.u32(bits);
                c.u64(macs);
            }
            c.f64(lc.mac_cycles);
            c.f64(lc.overhead_cycles);
            c.u64(lc.mem_bytes);
            c.f64(lc.mac_energy_pj);
            c.f64(lc.mem_energy_pj);
            c.f64(lc.ctrl_energy_pj);
        }

        let mut sections = vec![
            (SECTION_META, m.into_bytes()),
            (SECTION_PLAN, p.into_bytes()),
            (SECTION_COST, c.into_bytes()),
            (SECTION_DATA, data.into_bytes()),
        ];
        if let Some(prov) = provenance {
            let mut pr = PackWriter::default();
            pr.str(&prov.assignment);
            pr.u64(prov.seed);
            sections.push((SECTION_PROV, pr.into_bytes()));
        }
        assemble(&sections)
    }

    /// Deserialize a plan from `.cwm` bytes; the large arrays borrow
    /// zero-copy from one owned aligned copy of the file.
    pub fn from_modelpack(bytes: &[u8]) -> Result<ExecPlan, PackError> {
        decode_plan(&Container::parse(bytes)?)
    }

    /// [`Self::from_modelpack`] plus the pack's recorded [`Provenance`]
    /// from the same single container parse — the registry's cold-start
    /// entry point (parsing twice would double the aligned copy and
    /// checksum work the load path exists to keep small).
    pub fn from_modelpack_with_provenance(
        bytes: &[u8],
    ) -> Result<(ExecPlan, Option<Provenance>), PackError> {
        let container = Container::parse(bytes)?;
        let provenance = provenance_of(&container)?;
        Ok((decode_plan(&container)?, provenance))
    }
}

/// Read the optional provenance section of a pack (the container —
/// header, checksum, section table — is fully validated on the way).
pub fn read_provenance(bytes: &[u8]) -> Result<Option<Provenance>, PackError> {
    provenance_of(&Container::parse(bytes)?)
}

fn provenance_of(container: &Container) -> Result<Option<Provenance>, PackError> {
    let Some(s) = container.find(SECTION_PROV) else {
        return Ok(None);
    };
    let mut r = PackReader::new(&container.buf.as_bytes()[s.off..s.off + s.len]);
    let assignment = r.str()?;
    let seed = r.u64()?;
    r.finish()?;
    Ok(Some(Provenance { assignment, seed }))
}

fn encode_quant(p: &mut PackWriter, data: &mut DataWriter, op: &QuantOp) {
    p.str(&op.name);
    p.bool(op.fc);
    p.bool(op.depthwise);
    p.u64(op.k as u64);
    p.u32(op.kk as u32);
    p.u64(op.in_len as u64);
    p.u32(op.out_h as u32);
    p.u32(op.out_w as u32);
    p.u32(op.cout as u32);
    p.f32(op.act_alpha);
    p.f32(op.act_eps);
    p.u32(op.act_bits);
    p.u64(op.cin as u64);
    p.u64(op.pixel_bytes as u64);
    p.u64(op.plane_bytes as u64);
    p.u64(op.seg_bits as u64);
    p.u64(op.col_bytes as u64);
    p.bool(op.relu_inline);
    p.bool(op.post_add.is_some());
    if let Some(pa) = &op.post_add {
        p.u32(pa.other as u32);
        p.u64(pa.len as u64);
        p.bool(pa.relu);
    }
    p.u32(op.groups.len() as u32);
    for g in &op.groups {
        p.u32(g.bits);
        p.u64(g.start as u64);
        p.u64(g.len as u64);
    }
    let (off, len) = data.f32s(&op.a_eps);
    p.u64(off);
    p.u64(len);
    let (off, len) = data.f32s(&op.b_fold);
    p.u64(off);
    p.u64(len);
    let (off, len) = data.i32s(&op.gather);
    p.u64(off);
    p.u64(len);
    match op.kernel.state() {
        KernelState::Reference { k, act_bits, qw } => {
            p.u8(KERNEL_REFERENCE);
            p.u64(k as u64);
            p.u32(act_bits);
            let (off, len) = data.i32s(qw);
            p.u64(off);
            p.u64(len);
        }
        KernelState::Packed { k, act_index, rows, bytes } => {
            p.u8(KERNEL_PACKED);
            p.u64(k as u64);
            p.u8(act_index as u8);
            p.u32(rows.len() as u32);
            for (offset, widx) in rows {
                p.u32(offset);
                p.u8(widx);
            }
            let (off, len) = data.bytes(bytes);
            p.u64(off);
            p.u64(len);
        }
    }
}

/// The [`KIND_QUANT_FUSED`] extension, appended after the base quant
/// record (kernel included).
fn encode_fusion(p: &mut PackWriter, op: &QuantOp) {
    p.u32(op.in_plane_slot as u32);
    p.bool(op.in_plane_ready);
    p.bool(op.out_fuse.is_some());
    if let Some(of) = &op.out_fuse {
        p.u32(of.plane_slot as u32);
        p.u32(of.bits);
        p.f32(of.alpha);
        p.f32(of.eps);
        p.u64(of.cin as u64);
        p.u64(of.pixel_bytes as u64);
        p.u64(of.plane_bytes as u64);
        p.bool(of.keep_f32);
    }
}

// ---------------------------------------------------------------------------
// Decode.
// ---------------------------------------------------------------------------

/// The DATA section as an absolute window into the container buffer;
/// relative `(offset, len)` references resolve to bounds-checked
/// [`Bytes`] views.
struct DataView<'c> {
    buf: &'c Arc<AlignedBuf>,
    off: usize,
    len: usize,
}

impl DataView<'_> {
    fn slice(&self, r: &mut PackReader<'_>) -> Result<Bytes, PackError> {
        let rel = r.len64()?;
        let len = r.len64()?;
        let end = rel.checked_add(len).ok_or(PackError::OffsetOutOfRange {
            offset: rel as u64,
            len: len as u64,
            limit: self.len as u64,
        })?;
        if end > self.len {
            return Err(PackError::OffsetOutOfRange {
                offset: rel as u64,
                len: len as u64,
                limit: self.len as u64,
            });
        }
        Bytes::new(self.buf, self.off + rel, len)
    }
}

struct Meta {
    bench: String,
    backend_name: &'static str,
    /// dispatch tier on the *loading* host (re-resolved, not stored in
    /// the artifact — a `.cwm` stays portable across CPU generations)
    kernel_tier: &'static str,
    feat: usize,
    out_len: usize,
    out_slot: usize,
    permute: bool,
    slot_len: Vec<usize>,
    plane_len: usize,
    plane_slots: usize,
    col_len: usize,
    weight_bytes: usize,
    weight_traffic_bytes: u64,
    output_perm: Vec<usize>,
    fusion: FusionStats,
}

fn decode_meta(bytes: &[u8]) -> Result<Meta, PackError> {
    let mut r = PackReader::new(bytes);
    let bench = r.str()?;
    let backend = r.str()?;
    // map to the registered backend's static name (also proves the
    // pack's backend exists in this build); the dispatch tier is
    // re-resolved on this host, never trusted from the file
    let resolved = backend_by_name(&backend)
        .map_err(|_| malformed(format!("unknown backend {backend:?}")))?;
    let backend_name = resolved.name();
    let kernel_tier = resolved.tier();
    let feat = r.len64()?;
    let out_len = r.len64()?;
    let out_slot = r.u32()? as usize;
    let permute = r.bool()?;
    let n_slots = r.count(8, MAX_SLOTS)?;
    let mut slot_len = Vec::with_capacity(n_slots);
    let mut total_elems = 0u64;
    for _ in 0..n_slots {
        let l = r.len64()?;
        if l > MAX_SLOT_ELEMS {
            return Err(malformed(format!("slot of {l} elements")));
        }
        total_elems += l as u64;
        slot_len.push(l);
    }
    if total_elems > MAX_TOTAL_SLOT_ELEMS {
        return Err(malformed(format!("{total_elems} slot elements in total")));
    }
    let plane_len = r.len64()?;
    let col_len = r.len64()?;
    if plane_len > MAX_BUF_BYTES || col_len > MAX_BUF_BYTES {
        return Err(malformed("plane/column buffer size over cap"));
    }
    let weight_bytes = r.len64()?;
    let weight_traffic_bytes = r.u64()?;
    let n_perm = r.count(4, MAX_SLOT_ELEMS)?;
    let mut output_perm = Vec::with_capacity(n_perm);
    for _ in 0..n_perm {
        output_perm.push(r.u32()? as usize);
    }
    // fused-requantize extension (format minor ≥ 1): present only when
    // the writer had fusion state — minor-0 packs and unfused plans end
    // here and decode with the single-plane defaults
    let (plane_slots, fusion) = if r.remaining() > 0 {
        let ps = r.u32()? as usize;
        if ps == 0 || ps > MAX_PLANE_SLOTS {
            return Err(malformed(format!("{ps} plane slots")));
        }
        if ps.saturating_mul(plane_len) > MAX_BUF_BYTES {
            return Err(malformed("plane buffers exceed the size cap"));
        }
        let fusion = FusionStats {
            total_edges: r.u32()? as usize,
            fused_edges: r.u32()? as usize,
            elided_f32: r.u32()? as usize,
            reuse_hits: r.u32()? as usize,
            act_bytes_unfused: r.u64()?,
            act_bytes_fused: r.u64()?,
        };
        (ps, fusion)
    } else {
        (1, FusionStats::default())
    };
    r.finish()?;

    if slot_len.len() < 2 {
        return Err(malformed("fewer than two scratch slots"));
    }
    if out_slot >= slot_len.len() {
        return Err(malformed(format!("out_slot {out_slot} out of range")));
    }
    if feat > slot_len[0] || out_len > slot_len[out_slot] {
        return Err(malformed("feat/out_len exceed their slots"));
    }
    if permute {
        if output_perm.len() != out_len {
            return Err(malformed("output permutation length mismatch"));
        }
        if output_perm.iter().any(|&c| c >= out_len) {
            return Err(malformed("output permutation entry out of range"));
        }
    }
    Ok(Meta {
        bench,
        backend_name,
        kernel_tier,
        feat,
        out_len,
        out_slot,
        permute,
        slot_len,
        plane_len,
        plane_slots,
        col_len,
        weight_bytes,
        weight_traffic_bytes,
        output_perm,
        fusion,
    })
}

fn decode_cost(bytes: &[u8]) -> Result<InferenceCost, PackError> {
    let mut r = PackReader::new(bytes);
    let n = r.count(4, MAX_COST_LAYERS)?;
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str()?;
        let ng = r.count(12, MAX_CHANNELS)?;
        let mut macs_by_group = Vec::with_capacity(ng);
        for _ in 0..ng {
            let bits = r.u32()?;
            let macs = r.u64()?;
            macs_by_group.push((bits, macs));
        }
        layers.push(LayerCost {
            name,
            macs_by_group,
            mac_cycles: r.f64()?,
            overhead_cycles: r.f64()?,
            mem_bytes: r.u64()?,
            mac_energy_pj: r.f64()?,
            mem_energy_pj: r.f64()?,
            ctrl_energy_pj: r.f64()?,
        });
    }
    r.finish()?;
    Ok(InferenceCost { layers })
}

fn decode_plan(container: &Container) -> Result<ExecPlan, PackError> {
    let meta = decode_meta(container.section(SECTION_META)?)?;
    let cost = decode_cost(container.section(SECTION_COST)?)?;
    let (doff, dlen) = container.section_range(SECTION_DATA)?;
    let data = DataView { buf: &container.buf, off: doff, len: dlen };

    let plan_bytes = container.section(SECTION_PLAN)?;
    let mut r = PackReader::new(plan_bytes);
    let n_nodes = r.count(14, MAX_NODES)?;
    let n_slots = meta.slot_len.len();
    let mut nodes = Vec::with_capacity(n_nodes);
    // Write-coverage analysis: arenas are reused across batches, so any
    // slot bytes a node reads (or the output/save copies emit) that were
    // not written *this pass* would surface another request's data.
    // Track the written prefix of every slot (elements) and reject a
    // plan whose reads or copies reach beyond it.  The input copy
    // defines `feat` elements of slot 0 before the first node runs.
    let mut defined = vec![0usize; n_slots];
    defined[0] = meta.feat;
    // Plane-coverage analysis, the packed-plane analogue of `defined`:
    // plane buffers persist across batches too, so a consumer marked
    // `in_plane_ready` must read a plane some earlier node of this pass
    // coded **with the consumer's own signature** (p_x, PACT clip/step
    // bit patterns, plane geometry) — anything else would surface stale
    // codes from another request, or reinterpret a differently-shaped
    // plane.
    let mut plane_sig: Vec<Option<(u32, u32, u32, usize, usize, usize)>> =
        vec![None; meta.plane_slots];
    for _ in 0..n_nodes {
        let src = r.u32()? as usize;
        let dst = r.u32()? as usize;
        let has_save = r.bool()?;
        let save_raw = r.u32()? as usize;
        let save = has_save.then_some(save_raw);
        let out_len = r.len64()?;
        if src >= n_slots || dst >= n_slots {
            return Err(malformed("node slot id out of range"));
        }
        if out_len > meta.slot_len[dst] {
            return Err(malformed("node out_len exceeds its slot"));
        }
        if let Some(s) = save {
            if s >= n_slots {
                return Err(malformed("save slot id out of range"));
            }
            if out_len > meta.slot_len[s] {
                return Err(malformed("node out_len exceeds its save slot"));
            }
        }
        let kind = match r.u8()? {
            KIND_NOOP => NodeKind::NoOp,
            KIND_AVGPOOL => {
                let in_h = r.u32()? as usize;
                let in_w = r.u32()? as usize;
                let c = r.u32()? as usize;
                if dst == src {
                    return Err(malformed("avgpool writes its own source slot"));
                }
                let in_elems = in_h
                    .checked_mul(in_w)
                    .and_then(|p| p.checked_mul(c))
                    .ok_or_else(|| malformed("avgpool geometry overflow"))?;
                if in_h * in_w == 0 || in_elems > meta.slot_len[src] || c > meta.slot_len[dst] {
                    return Err(malformed("avgpool geometry exceeds slots"));
                }
                NodeKind::AvgPool { in_h, in_w, c }
            }
            KIND_ADD => {
                let other = r.u32()? as usize;
                let len = r.len64()?;
                let relu = r.bool()?;
                if other >= n_slots || other == dst {
                    return Err(malformed("add tag slot invalid"));
                }
                if len > meta.slot_len[src]
                    || len > meta.slot_len[dst]
                    || len > meta.slot_len[other]
                {
                    return Err(malformed("add length exceeds a slot"));
                }
                NodeKind::Add { other, len, relu }
            }
            KIND_QUANT => {
                let op = decode_quant(&mut r, &data, &meta, src, dst, out_len, false)?;
                NodeKind::Quant(op)
            }
            KIND_QUANT_FUSED => {
                let op = decode_quant(&mut r, &data, &meta, src, dst, out_len, true)?;
                NodeKind::Quant(op)
            }
            other => return Err(malformed(format!("unknown node kind tag {other}"))),
        };
        match &kind {
            NodeKind::NoOp => {}
            NodeKind::AvgPool { in_h, in_w, c } => {
                if defined[src] < in_h * in_w * c {
                    return Err(malformed("avgpool reads beyond this pass's data"));
                }
                defined[dst] = *c;
            }
            NodeKind::Add { other, len, .. } => {
                if defined[src] < *len || defined[*other] < *len {
                    return Err(malformed("add reads beyond this pass's data"));
                }
                if dst != src {
                    defined[dst] = *len;
                }
            }
            NodeKind::Quant(op) => {
                let own_sig = (
                    op.act_bits,
                    op.act_alpha.to_bits(),
                    op.act_eps.to_bits(),
                    op.cin,
                    op.pixel_bytes,
                    op.plane_bytes,
                );
                if op.in_plane_ready {
                    // a ready consumer never touches its f32 source, so
                    // the `defined` read check is waived — the plane
                    // signature check replaces it
                    if plane_sig[op.in_plane_slot] != Some(own_sig) {
                        return Err(malformed(
                            "layer reads a plane no prior node coded for it",
                        ));
                    }
                } else {
                    if defined[src] < op.in_len {
                        return Err(malformed("layer reads beyond this pass's data"));
                    }
                    plane_sig[op.in_plane_slot] = Some(own_sig);
                }
                if let Some(pa) = &op.post_add {
                    if defined[pa.other] < pa.len {
                        return Err(malformed(
                            "residual reads beyond this pass's data",
                        ));
                    }
                }
                if let Some(of) = &op.out_fuse {
                    plane_sig[of.plane_slot] = Some((
                        of.bits,
                        of.alpha.to_bits(),
                        of.eps.to_bits(),
                        of.cin,
                        of.pixel_bytes,
                        of.plane_bytes,
                    ));
                }
                // a fully-fused exit (no f32 reader, no residual
                // staging) never writes its f32 slot, so it defines
                // nothing there
                let write_f32 = op
                    .out_fuse
                    .as_ref()
                    .is_none_or(|of| of.keep_f32 || op.post_add.is_some());
                if write_f32 {
                    defined[dst] = out_len;
                }
            }
        }
        if let Some(s) = save {
            if defined[dst] < out_len {
                return Err(malformed("save copies beyond this pass's data"));
            }
            defined[s] = out_len;
        }
        nodes.push(PlanNode { src, dst, save, out_len, kind });
    }
    r.finish()?;
    if defined[meta.out_slot] < meta.out_len {
        return Err(malformed("output slot is not fully written by the plan"));
    }

    Ok(ExecPlan {
        bench: meta.bench,
        backend_name: meta.backend_name,
        kernel_tier: meta.kernel_tier,
        feat: meta.feat,
        slot_len: meta.slot_len,
        plane_len: meta.plane_len,
        plane_slots: meta.plane_slots,
        col_len: meta.col_len,
        nodes,
        out_slot: meta.out_slot,
        out_len: meta.out_len,
        output_perm: meta.output_perm,
        permute: meta.permute,
        cost,
        weight_bytes: meta.weight_bytes,
        weight_traffic_bytes: meta.weight_traffic_bytes,
        fusion: meta.fusion,
    })
}

/// Decode one quantized-layer record and re-derive every invariant the
/// executor's unchecked hot loops rely on.  `fused` selects the
/// [`KIND_QUANT_FUSED`] layout (the base record plus the fusion
/// extension).
#[allow(clippy::too_many_arguments)]
fn decode_quant(
    r: &mut PackReader<'_>,
    data: &DataView<'_>,
    meta: &Meta,
    src: usize,
    dst: usize,
    node_out_len: usize,
    fused: bool,
) -> Result<Box<QuantOp>, PackError> {
    let name = r.str()?;
    let fc = r.bool()?;
    let depthwise = r.bool()?;
    let k = r.len64()?;
    let kk = r.u32()? as usize;
    let in_len = r.len64()?;
    let out_h = r.u32()? as usize;
    let out_w = r.u32()? as usize;
    let cout = r.u32()? as usize;
    let act_alpha = r.f32()?;
    let act_eps = r.f32()?;
    let act_bits = r.u32()?;
    let cin = r.len64()?;
    let pixel_bytes = r.len64()?;
    let plane_bytes = r.len64()?;
    let seg_bits = r.len64()?;
    let col_bytes = r.len64()?;
    let relu_inline = r.bool()?;
    let post_add = if r.bool()? {
        let other = r.u32()? as usize;
        let len = r.len64()?;
        let relu = r.bool()?;
        if other >= meta.slot_len.len() || other == dst {
            return Err(malformed(format!("{name}: residual tag slot invalid")));
        }
        if len != node_out_len || len > meta.slot_len[other] {
            return Err(malformed(format!("{name}: residual length invalid")));
        }
        Some(PostAdd { other, len, relu })
    } else {
        None
    };

    let err = |msg: &str| Err(malformed(format!("{name}: {msg}")));
    if dst == src {
        return err("writes its own source slot");
    }
    if !matches!(act_bits, 2 | 4 | 8) {
        return err("activation bits not in {2,4,8}");
    }
    // the executor clamps into [0, act_alpha]: a NaN or negative alpha
    // would panic f32::clamp, so a pack carrying one is malformed
    if !act_alpha.is_finite() || act_alpha < 0.0 || !act_eps.is_finite() || act_eps <= 0.0
    {
        return err("non-finite PACT quantization parameters");
    }
    let pxs = act_bits as usize;
    if cout == 0 || cout > MAX_CHANNELS || k == 0 || k > MAX_K || cin == 0 || cin > MAX_K
        || kk == 0
    {
        return err("degenerate or oversized geometry");
    }
    if in_len > meta.slot_len[src] || in_len % cin != 0 {
        return err("input length inconsistent with source slot / C_in");
    }
    if pixel_bytes != (cin * pxs).div_ceil(8) {
        return err("pixel_bytes disagrees with cin * p_x");
    }
    let n_pixels = in_len / cin;
    if plane_bytes
        != n_pixels
            .checked_mul(pixel_bytes)
            .ok_or_else(|| malformed(format!("{name}: plane size overflow")))?
    {
        return err("plane_bytes disagrees with pixel count");
    }
    if plane_bytes > meta.plane_len {
        return err("plane exceeds the arena plane buffer");
    }
    if col_bytes != (k * pxs).div_ceil(8) {
        return err("col_bytes disagrees with K * p_x");
    }
    if col_bytes + COL_SLACK > meta.col_len {
        return err("column exceeds the arena column buffer");
    }
    let cin_g = if depthwise { 1 } else { cin };
    if fc {
        if in_len != k || cin != k {
            return err("fc input length != K");
        }
        if node_out_len != cout {
            return err("fc out_len != C_out");
        }
    } else {
        if seg_bits != cin_g * pxs {
            return err("seg_bits disagrees with cin_g * p_x");
        }
        if k != kk * cin_g {
            return err("K disagrees with kk * cin_g");
        }
        if depthwise && cout != cin {
            return err("depthwise C_out != C_in");
        }
        let out_pixels = out_h
            .checked_mul(out_w)
            .ok_or_else(|| malformed(format!("{name}: output size overflow")))?;
        if out_pixels
            .checked_mul(cout)
            .ok_or_else(|| malformed(format!("{name}: output size overflow")))?
            != node_out_len
        {
            return err("out_h * out_w * C_out != out_len");
        }
    }

    let n_groups = r.count(20, cout)?;
    let mut groups = Vec::with_capacity(n_groups);
    // the sub-conv groups must tile [0, cout) exactly: the executor
    // writes outputs only per group, so an uncovered channel would
    // leave stale arena data from a previous batch in the output (a
    // cross-request leak under the serving batcher's resident arena)
    let mut next_start = 0usize;
    for _ in 0..n_groups {
        let bits = r.u32()?;
        let start = r.len64()?;
        let len = r.len64()?;
        if !matches!(bits, 2 | 4 | 8) {
            return err("group bits not in {2,4,8}");
        }
        if len == 0 || start != next_start {
            return err("groups do not tile the channel range");
        }
        next_start = match start.checked_add(len) {
            Some(e) if e <= cout => e,
            _ => return err("group channel range out of bounds"),
        };
        groups.push(crate::deploy::SubConv { bits, start, len });
    }
    if next_start != cout {
        return err("groups do not cover every output channel");
    }

    let a_eps_b = data.slice(r)?;
    let b_fold_b = data.slice(r)?;
    let gather_b = data.slice(r)?;
    if a_eps_b.len() != cout * 4 || b_fold_b.len() != cout * 4 {
        return err("epilogue arrays are not C_out f32s");
    }
    let a_eps = F32Arr::from_le(a_eps_b)?;
    let b_fold = F32Arr::from_le(b_fold_b)?;
    let gather = I32Arr::from_le(gather_b)?;
    if fc {
        if !gather.is_empty() {
            return err("fc layer carries a gather table");
        }
    } else {
        if gather.len() != out_h * out_w * kk {
            return err("gather table size disagrees with geometry");
        }
        for &g in gather.iter() {
            if g != -1
                && (g < 0
                    || (g as usize)
                        .checked_add(pixel_bytes)
                        .is_none_or(|e| e > plane_bytes))
            {
                return err("gather entry outside the packed plane");
            }
        }
    }

    let kernel = match r.u8()? {
        KERNEL_REFERENCE => {
            let kern_k = r.len64()?;
            let kern_bits = r.u32()?;
            let qw_b = data.slice(r)?;
            if kern_k != k || kern_bits != act_bits {
                return err("reference kernel geometry mismatch");
            }
            if qw_b.len()
                != cout
                    .checked_mul(k)
                    .and_then(|n| n.checked_mul(4))
                    .ok_or_else(|| malformed(format!("{name}: weight size overflow")))?
            {
                return err("reference kernel rows are not C_out * K i32s");
            }
            reference_kernel_from_parts(k, act_bits, I32Arr::from_le(qw_b)?)
        }
        KERNEL_PACKED => {
            let kern_k = r.len64()?;
            let act_index = r.u8()? as usize;
            if kern_k != k || act_index != precision_index(act_bits) {
                return err("packed kernel geometry mismatch");
            }
            let n_rows = r.count(5, cout)?;
            if n_rows != cout {
                return err("packed kernel row count != C_out");
            }
            let mut rows = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                rows.push((r.u32()?, r.u8()?));
            }
            let bytes_b = data.slice(r)?;
            for &(offset, widx) in &rows {
                let Some(&bits) = PRECISIONS.get(widx as usize) else {
                    return err("packed row precision index out of range");
                };
                let row_bytes = (k * bits as usize).div_ceil(8);
                if (offset as usize)
                    .checked_add(row_bytes)
                    .is_none_or(|end| end > bytes_b.len())
                {
                    return err("packed row reaches past the flash image");
                }
            }
            // the simd backend serializes the identical flash image
            // under the same tag — only the dispatch tables differ,
            // and those come from the loading host, not the file
            if meta.backend_name == "simd" {
                simd_kernel_from_parts(k, act_index, rows, ByteArr::view(bytes_b))
            } else {
                packed_kernel_from_parts(k, act_index, rows, ByteArr::view(bytes_b))
            }
        }
        other => return Err(malformed(format!("{name}: unknown kernel tag {other}"))),
    };

    // Fusion extension ([`KIND_QUANT_FUSED`] only).  Every field is
    // re-validated against the geometry decoded above — the executor's
    // fused epilogue indexes planes unchecked, so nothing from the file
    // may reach it unexamined (validate-then-borrow).
    let (in_plane_slot, in_plane_ready, out_fuse) = if fused {
        let in_plane_slot = r.u32()? as usize;
        let in_plane_ready = r.bool()?;
        let out_fuse = if r.bool()? {
            let plane_slot = r.u32()? as usize;
            let bits = r.u32()?;
            let alpha = r.f32()?;
            let eps = r.f32()?;
            let of_cin = r.len64()?;
            let of_pixel_bytes = r.len64()?;
            let of_plane_bytes = r.len64()?;
            let keep_f32 = r.bool()?;
            if plane_slot >= meta.plane_slots || plane_slot == in_plane_slot {
                return err("fused output plane slot invalid");
            }
            if !matches!(bits, 2 | 4 | 8) {
                return err("fused output precision not in {2,4,8}");
            }
            if !alpha.is_finite() || alpha < 0.0 || !eps.is_finite() || eps <= 0.0 {
                return err("fused output clip/step not finite positive");
            }
            if of_cin == 0 || of_cin > MAX_K || node_out_len % of_cin != 0 {
                return err("fused output channel count does not tile the layer");
            }
            if of_pixel_bytes != (of_cin * bits as usize).div_ceil(8) {
                return err("fused output pixel stride disagrees with geometry");
            }
            if of_plane_bytes != (node_out_len / of_cin) * of_pixel_bytes {
                return err("fused output plane size disagrees with geometry");
            }
            if of_plane_bytes > meta.plane_len {
                return err("fused output plane exceeds the plane stride");
            }
            Some(OutFuse {
                plane_slot,
                bits,
                alpha,
                eps,
                cin: of_cin,
                pixel_bytes: of_pixel_bytes,
                plane_bytes: of_plane_bytes,
                keep_f32,
            })
        } else {
            None
        };
        if in_plane_slot >= meta.plane_slots {
            return err("input plane slot out of range");
        }
        (in_plane_slot, in_plane_ready, out_fuse)
    } else {
        (0, false, None)
    };

    Ok(Box::new(QuantOp {
        name,
        fc,
        depthwise,
        k,
        kk,
        in_len,
        out_h,
        out_w,
        cout,
        act_alpha,
        act_eps,
        act_bits,
        cin,
        pixel_bytes,
        plane_bytes,
        seg_bits,
        col_bytes,
        gather,
        groups,
        a_eps,
        b_fold,
        relu_inline,
        post_add,
        in_plane_slot,
        in_plane_ready,
        out_fuse,
        kernel,
    }))
}

// ---------------------------------------------------------------------------
// Inspect.
// ---------------------------------------------------------------------------

/// One quantized layer's size accounting, as stored in the artifact.
pub struct InspectLayer {
    pub name: String,
    pub kind: &'static str,
    pub cout: usize,
    pub k: usize,
    pub act_bits: u32,
    /// channels at 2/4/8 weight bits (indexed by `precision_index`)
    pub channels_at: [usize; 3],
    /// Eq. (7) packed flash bytes (per-channel rows, byte-padded)
    pub packed_bytes: usize,
    /// uniform-int8 bytes for the same weights
    pub int8_bytes: usize,
    /// f32 bytes for the same weights
    pub f32_bytes: usize,
    /// this layer's exit codes a consumer plane (fused requantize)
    pub fused_out: bool,
    /// this layer's f32 output slot write is elided entirely
    pub f32_elided: bool,
    /// this layer's input plane was coded by an earlier node (fused
    /// producer or shared residual plane)
    pub plane_reused: bool,
}

/// Artifact-level report of a `.cwm`: header facts plus the paper's
/// memory comparison (packed vs int8 vs f32) per layer and in total.
pub struct InspectReport {
    pub version: (u16, u16),
    pub flags: u32,
    pub file_bytes: usize,
    /// every section `(kind, payload bytes)`, unknown kinds included
    pub sections: Vec<(u32, usize)>,
    pub bench: String,
    pub backend: String,
    /// dispatch tier the plan's kernels resolve to on *this* host
    /// (`avx512`/`avx2`/`swar` for the simd backend, else the backend
    /// name — never stored in the artifact)
    pub kernel_tier: &'static str,
    /// construction parameters, when the writer recorded them
    pub provenance: Option<Provenance>,
    pub n_nodes: usize,
    pub layers: Vec<InspectLayer>,
    /// the `mpic::cost` Eq. (7) packed-weight accounting carried in the
    /// pack (what the cost model charged for weight traffic)
    pub cost_model_packed_bytes: u64,
    /// in-memory weight bytes of the kernels (backend-dependent)
    pub kernel_weight_bytes: usize,
    /// arena plane slots the plan requires (1 when unfused)
    pub plane_slots: usize,
    /// compile-time fused-requantize coverage carried in the pack
    pub fusion: FusionStats,
}

impl InspectReport {
    pub fn packed_total(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes).sum()
    }

    pub fn int8_total(&self) -> usize {
        self.layers.iter().map(|l| l.int8_bytes).sum()
    }

    pub fn f32_total(&self) -> usize {
        self.layers.iter().map(|l| l.f32_bytes).sum()
    }

    /// Does the per-channel accounting derived from the stored groups
    /// agree with the cost model's Eq. (7) packed-byte total?
    pub fn matches_cost_model(&self) -> bool {
        self.packed_total() as u64 == self.cost_model_packed_bytes
    }
}

/// Parse and fully validate a `.cwm`, then report its size accounting.
pub fn inspect(bytes: &[u8]) -> Result<InspectReport, PackError> {
    let container = Container::parse(bytes)?;
    let provenance = provenance_of(&container)?;
    let plan = decode_plan(&container)?;
    let mut layers = Vec::new();
    for node in &plan.nodes {
        if let NodeKind::Quant(op) = &node.kind {
            let mut channels_at = [0usize; 3];
            let mut packed = 0usize;
            for g in &op.groups {
                channels_at[precision_index(g.bits)] += g.len;
                packed += g.len * (op.k * g.bits as usize).div_ceil(8);
            }
            layers.push(InspectLayer {
                name: op.name.clone(),
                kind: if op.fc {
                    "fc"
                } else if op.depthwise {
                    "dwconv"
                } else {
                    "conv"
                },
                cout: op.cout,
                k: op.k,
                act_bits: op.act_bits,
                channels_at,
                packed_bytes: packed,
                int8_bytes: op.cout * op.k,
                f32_bytes: op.cout * op.k * 4,
                fused_out: op.out_fuse.is_some(),
                f32_elided: op
                    .out_fuse
                    .as_ref()
                    .is_some_and(|of| !of.keep_f32 && op.post_add.is_none()),
                plane_reused: op.in_plane_ready,
            });
        }
    }
    Ok(InspectReport {
        version: container.version,
        flags: container.flags,
        file_bytes: container.buf.len(),
        sections: container.sections.iter().map(|s| (s.kind, s.len)).collect(),
        bench: plan.bench.clone(),
        backend: plan.backend_name.to_string(),
        kernel_tier: plan.kernel_tier,
        provenance,
        n_nodes: plan.nodes.len(),
        layers,
        cost_model_packed_bytes: plan.weight_traffic_bytes,
        kernel_weight_bytes: plan.weight_bytes,
        plane_slots: plan.plane_slots,
        fusion: plan.fusion.clone(),
    })
}
