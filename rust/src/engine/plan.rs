//! Plan compilation and execution.
//!
//! [`ExecPlan::compile`] walks a [`DeployedModel`] **once** and bakes
//! everything input-independent into a self-contained, `Sync` plan:
//!
//! * **slot assignment** — every node reads/writes fixed arena slot ids
//!   (two ping-pong scratch slots + one per saved residual tag), so
//!   execution never touches a `HashMap` or clones an activation;
//! * **gather tables** — SAME-padding im2col source offsets per output
//!   pixel, expressed as **byte offsets into the packed activation
//!   plane** (each input pixel's `C_in` codes start on a byte boundary),
//!   computed once instead of re-deriving window/padding arithmetic per
//!   sample;
//! * **folded epilogues** — `a_fold[c] * eps_x` pre-multiplied per
//!   channel (bit-identical: the same two f32 factors are multiplied,
//!   just once instead of per output element);
//! * **backend kernels** — weights handed to the chosen
//!   [`KernelBackend`](super::KernelBackend) (scalar rows or sub-byte
//!   packed rows);
//! * **cost** — the full [`InferenceCost`] is accounted at compile time
//!   (costs are input-independent), so running a sample does zero cost
//!   bookkeeping.
//!
//! Execution is **batch-major** ([`ExecPlan::run_batch_planes`]): the
//! plan walks the node list once per *batch*, not once per sample.  Per
//! quantized layer it quantizes every sample's input into a packed
//! sub-byte plane (`p_x`-bit codes, `quant::pack_acts_subbyte` layout,
//! one byte-aligned run per pixel, one plane per sample at a fixed
//! stride in the [`Arena`]) in one pass — PACT scale and plane geometry
//! are read once per layer for the whole batch — then assembles, per
//! output pixel, one densely packed im2col column *per sample* and
//! hands all `B` columns to the kernel's batched entry point
//! (`dot_batch`/`dot_wide_batch`), where each fetched weight word rides
//! every column (weight-stationary SWAR; gather tables are read once
//! per pixel for the whole batch).  1x1 convolutions and FC layers skip
//! the column copy entirely: their columns *are* plane slices,
//! batch-addressed at the plane stride with zero copies.
//!
//! **Fused requantize.** Compilation ends with a fusion pass
//! (`fuse_requant`) over the built node list: it recovers the value
//! flow from slot reads/writes and, wherever a quantized layer's output
//! is consumed only by quantized layers that agree on one PACT
//! signature `(p_x, α, ε)` and plane geometry, rewrites the producer to
//! code the consumer's packed plane directly at its epilogue exit
//! (`OutFuse`) — the consumer skips its quantize pass entirely
//! (`in_plane_ready`), and when nothing else reads the f32 form the
//! producer's f32 slot write is elided too.  Residual taps whose
//! branches share the producer's `p_x` reuse **one** saved packed plane
//! (a dedicated plane slot, id ≥ 2, that stays live across intervening
//! layers); mismatched branches fall back to the f32 path.  A producer
//! with a residual add still stages f32 and quantizes the added result
//! into the consumer plane in the same post-add pass.  Plane slots 0/1
//! flip between adjacent fused pairs so a producer never overwrites the
//! plane it is reading.  Fusion is on for every backend except
//! `reference`, which stays on the two-pass path as the oracle
//! ([`ExecPlan::compile_with`] exposes the switch); coverage is
//! reported by [`ExecPlan::fusion`] ([`FusionStats`]).
//!
//! [`ExecPlan::run_samples`] shards a batch across `std::thread::scope`
//! workers **by batch-chunk** — each worker runs contiguous chunks of
//! up to [`MAX_BATCH_CHUNK`] samples through its own batch [`Arena`] —
//! and [`ExecPlan::run_sample`] is the one-sample batch.
//!
//! Numerical contract: for any backend and any batch size, outputs are
//! **bit-identical** to the scalar oracle `mpic::exec::run_sample` —
//! batching changes *when* work happens (quantize/gather/decode once
//! per batch instead of once per sample), never what is computed, and
//! the fused exit computes the exact f32 epilogue value the two-pass
//! path writes before coding it with the consumer's own quantize
//! arithmetic.  Asserted layer-type by layer-type in
//! `tests/engine_equivalence.rs`, batch-size by batch-size in
//! `tests/engine_batch_plane.rs`, and fused-vs-oracle in
//! `tests/engine_fused_requant.rs`.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::deploy::{DeployedLayer, DeployedModel, SubConv};
use crate::energy::CostLut;
use crate::modelpack::{F32Arr, I32Arr};
use crate::mpic::cost::{
    account_group, account_memory, account_structural, BatchCost, InferenceCost,
    LayerCost,
};
use crate::mpic::memory;
use crate::trace;

use super::arena::Arena;
use super::backend::KernelBackend;
use super::LayerKernel;

// single source of SAME-padding truth, shared with the scalar oracle
use crate::mpic::exec::same_pad;

/// Residual epilogue fused onto a quantized layer (`spec.add_from`).
pub(super) struct PostAdd {
    pub(super) other: usize,
    pub(super) len: usize,
    pub(super) relu: bool,
}

/// Fused requantize exit: the producer codes the consumer layer's
/// packed `p_x`-bit plane directly from the epilogue value `y`, using
/// the consumer's own PACT parameters and plane geometry — the exact
/// bytes the consumer's quantize pass would have produced from the f32
/// slot.  With `keep_f32` false (and no residual add staging), the
/// producer's f32 slot write is elided entirely.
pub(super) struct OutFuse {
    /// arena plane slot the consumer reads (`QuantOp::in_plane_slot`)
    pub(super) plane_slot: usize,
    /// consumer's `p_x` (code width)
    pub(super) bits: u32,
    /// consumer's PACT clip and step
    pub(super) alpha: f32,
    pub(super) eps: f32,
    /// consumer's plane geometry (pixel run length / packed bytes)
    pub(super) cin: usize,
    pub(super) pixel_bytes: usize,
    pub(super) plane_bytes: usize,
    /// also write the f32 slot: some consumer still needs the f32 form
    /// (residual tap read, avgpool, structural add, network output)
    pub(super) keep_f32: bool,
}

/// Compile-time fused-requantize coverage, reported per plan
/// ([`ExecPlan::fusion`]) and exported by `/metrics` and
/// `cwmix inspect`.  `act_bytes_*` are the per-sample activation bytes
/// moved across quantized producer→consumer edges (the Eq. (7)
/// activation-traffic share): f32 slot writes + f32 re-reads + packed
/// plane writes on the two-pass path versus the fused path.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FusionStats {
    /// quantized producer → quantized consumer value edges
    pub total_edges: usize,
    /// edges whose consumer plane is written without an f32 re-read
    pub fused_edges: usize,
    /// producers whose f32 slot write is elided entirely
    pub elided_f32: usize,
    /// consumers served by a shared saved packed plane beyond the
    /// first (residual plane reuse)
    pub reuse_hits: usize,
    /// per-sample activation bytes on these edges, two-pass path
    pub act_bytes_unfused: u64,
    /// same edges, fused path
    pub act_bytes_fused: u64,
}

impl FusionStats {
    /// `fused_edges / total_edges` (0 when the plan has no such edges).
    pub fn fused_ratio(&self) -> f64 {
        if self.total_edges == 0 {
            0.0
        } else {
            self.fused_edges as f64 / self.total_edges as f64
        }
    }

    /// Per-sample activation bytes the fusion pass removed.
    pub fn act_bytes_saved(&self) -> u64 {
        self.act_bytes_unfused.saturating_sub(self.act_bytes_fused)
    }
}

/// Measured execution profile of one plan node, accumulated by
/// [`ExecPlan::run_batch_planes_profiled`].
///
/// `quant_ns` is the PACT quantize+pack pass (zero for structural
/// nodes, for fused consumers whose plane arrives pre-coded, and for
/// the plain path); `exec_ns` is everything else the node does
/// (gather, kernel dot, epilogue, residual add).  `bytes_moved` is the
/// *modeled* traffic of the executed calls — f32 slot reads/writes,
/// packed-plane writes and the once-per-batch weight fetch — derived
/// from plan geometry, not hardware counters.
#[derive(Clone, Debug)]
pub struct NodeProfile {
    /// layer name (`spec.name`; structural nodes inherit the cost
    /// layer's name, tap/flatten fall back to the kind)
    pub name: String,
    /// `conv | dwconv | fc | avgpool | add | noop`
    pub kind: &'static str,
    /// index of this node's [`LayerCost`] in `InferenceCost::layers`
    /// (`None` for tap/flatten, which are never accounted)
    pub cost_ix: Option<usize>,
    /// executed batch passes that ran this node
    pub calls: u64,
    /// quantize+pack pass wall time
    pub quant_ns: u64,
    /// gather + kernel + epilogue wall time
    pub exec_ns: u64,
    /// modeled bytes moved across the executed calls
    pub bytes_moved: u64,
}

impl NodeProfile {
    /// Total measured wall time of this node.
    pub fn wall_ns(&self) -> u64 {
        self.quant_ns + self.exec_ns
    }
}

/// Accumulated engine profile: per-node wall time + bytes moved and an
/// executed-batch-size histogram.  Build one with [`ExecPlan::profile`]
/// and feed it to [`ExecPlan::run_batch_planes_profiled`]; the plain
/// [`ExecPlan::run_batch_planes`] path pays one `None` branch per node
/// and nothing else.
#[derive(Clone, Debug)]
pub struct PlanProfile {
    /// executed batch-plane passes
    pub batches: u64,
    /// samples across those passes
    pub samples: u64,
    /// wall time inside `run_batch_planes` (node loop + I/O staging)
    pub wall_ns: u64,
    /// `batch_hist[i]` = passes that executed `i + 1` samples (the
    /// last bucket also holds anything ≥ [`MAX_BATCH_CHUNK`])
    pub batch_hist: [u64; MAX_BATCH_CHUNK],
    /// one entry per plan node, in execution order
    pub nodes: Vec<NodeProfile>,
}

impl PlanProfile {
    /// Sum of per-node wall times — the share of [`Self::wall_ns`]
    /// attributed to a specific node (the rest is batch staging:
    /// input copies, output collection, permutation).
    pub fn node_wall_ns(&self) -> u64 {
        self.nodes.iter().map(|n| n.wall_ns()).sum()
    }
}

/// One quantized layer, fully precompiled.  The large arrays (gather
/// table, folded epilogues) are view-backed so a modelpack-loaded plan
/// borrows them zero-copy from the artifact buffer; a compiled plan
/// owns them.  Fields are `pub(super)` for the `engine::pack`
/// serializer — execution semantics live entirely in this module.
pub(super) struct QuantOp {
    /// layer name (`spec.name`) — diagnostics and `cwmix inspect`
    pub(super) name: String,
    pub(super) fc: bool,
    pub(super) depthwise: bool,
    /// weights per output channel
    pub(super) k: usize,
    /// kernel spatial positions (`kx * ky`)
    pub(super) kk: usize,
    pub(super) in_len: usize,
    pub(super) out_h: usize,
    pub(super) out_w: usize,
    pub(super) cout: usize,
    /// PACT clip (already floored at 1e-6) and step
    pub(super) act_alpha: f32,
    pub(super) act_eps: f32,
    /// input activation precision `p_x` — the packed plane's code width
    pub(super) act_bits: u32,
    /// input channels per pixel (K for FC: the whole input is one run)
    pub(super) cin: usize,
    /// bytes per packed input pixel (`ceil(cin * p_x / 8)`)
    pub(super) pixel_bytes: usize,
    /// total packed plane bytes (`n_pixels * pixel_bytes`)
    pub(super) plane_bytes: usize,
    /// bits each kernel position contributes to the column (`cin_g * p_x`)
    pub(super) seg_bits: usize,
    /// dense packed column bytes (`ceil(K * p_x / 8)`)
    pub(super) col_bytes: usize,
    /// per output pixel x kernel position: base **byte** offset of the
    /// source pixel in the packed plane, or -1 outside the image (zero
    /// padding)
    pub(super) gather: I32Arr,
    pub(super) groups: Vec<SubConv>,
    /// `a_fold[c] * act_eps` (same f32 product the oracle forms per
    /// element) and the additive epilogue term
    pub(super) a_eps: F32Arr,
    pub(super) b_fold: F32Arr,
    pub(super) relu_inline: bool,
    pub(super) post_add: Option<PostAdd>,
    /// arena plane slot this layer's packed input lives in
    pub(super) in_plane_slot: usize,
    /// the input plane was already written — by a fused producer or by
    /// a sibling consumer sharing a saved plane — so the quantize pass
    /// is skipped
    pub(super) in_plane_ready: bool,
    /// fused exit: code the consumer's plane at the epilogue
    pub(super) out_fuse: Option<OutFuse>,
    pub(super) kernel: Box<dyn LayerKernel>,
}

pub(super) enum NodeKind {
    Quant(Box<QuantOp>),
    AvgPool { in_h: usize, in_w: usize, c: usize },
    Add { other: usize, len: usize, relu: bool },
    /// tap / flatten: HWC row-major data is unchanged, dims only
    NoOp,
}

pub(super) struct PlanNode {
    pub(super) src: usize,
    pub(super) dst: usize,
    /// copy the node's output into this tag slot afterwards (`save_as`)
    pub(super) save: Option<usize>,
    pub(super) out_len: usize,
    pub(super) kind: NodeKind,
}

/// A compiled, reusable execution plan for one deployed model.
pub struct ExecPlan {
    pub(super) bench: String,
    pub(super) backend_name: &'static str,
    /// dispatch tier that executes this plan's kernels — equals
    /// `backend_name` except for the `simd` backend, which resolves
    /// `avx512`/`avx2`/`swar` once per process at load
    pub(super) kernel_tier: &'static str,
    pub(super) feat: usize,
    pub(super) slot_len: Vec<usize>,
    pub(super) plane_len: usize,
    /// packed-plane arena slots: 1 on the unfused path; fused plans use
    /// two flip slots (0/1) plus one dedicated slot per reused plane
    pub(super) plane_slots: usize,
    pub(super) col_len: usize,
    pub(super) nodes: Vec<PlanNode>,
    pub(super) out_slot: usize,
    pub(super) out_len: usize,
    pub(super) output_perm: Vec<usize>,
    pub(super) permute: bool,
    pub(super) cost: InferenceCost,
    pub(super) weight_bytes: usize,
    /// modeled per-sample packed weight traffic (Eq. (7) flash bytes),
    /// the batch-amortizable share of `InferenceCost::total_mem_bytes`
    pub(super) weight_traffic_bytes: u64,
    /// fused-requantize coverage decided at compile time
    pub(super) fusion: FusionStats,
}

/// Samples per batch-plane pass (and per worker arena): bounds arena
/// memory — every arena buffer scales with the batch capacity — while
/// keeping weight-decode amortization essentially at its asymptote
/// (the once-per-batch work is `1/B` of the total by B=32).
pub const MAX_BATCH_CHUNK: usize = 32;

const SCRATCH_A: usize = 0;
const SCRATCH_B: usize = 1;

/// Slack bytes past a packed column: the unaligned OR-assembly writes
/// one spill byte past the last data byte (always zero bits there).
pub(super) const COL_SLACK: usize = 2;

/// Pick the write slot for an out-of-place op: the scratch slot that is
/// not the source (tag slots are never written by compute nodes).
fn other_scratch(src: usize) -> usize {
    if src == SCRATCH_A {
        SCRATCH_B
    } else {
        SCRATCH_A
    }
}

impl ExecPlan {
    /// Compile `model` once against `backend`.  Requantize fusion is on
    /// for every backend except `reference`, which stays on the
    /// two-pass path as the bit-exactness oracle.
    pub fn compile(
        model: &DeployedModel,
        lut: &CostLut,
        backend: &dyn KernelBackend,
    ) -> Result<ExecPlan> {
        Self::compile_with(model, lut, backend, backend.name() != "reference")
    }

    /// [`Self::compile`] with the fused-requantize pass explicitly on
    /// or off — the unfused plan of the same backend is the oracle the
    /// fused plan is tested (and benchmarked) against.
    pub fn compile_with(
        model: &DeployedModel,
        lut: &CostLut,
        backend: &dyn KernelBackend,
        fuse: bool,
    ) -> Result<ExecPlan> {
        let (mut h, mut w, mut c) = match model.input_shape.len() {
            3 => (model.input_shape[0], model.input_shape[1], model.input_shape[2]),
            1 => (1, 1, model.input_shape[0]),
            _ => bail!("unsupported input rank {}", model.input_shape.len()),
        };
        let feat = h * w * c;
        let mut slot_len = vec![0usize, 0usize]; // scratch, sized below
        let mut max_len = feat;
        let mut plane_len = 0usize;
        let mut col_len = 0usize;
        let mut weight_bytes = 0usize;
        let mut weight_traffic_bytes = 0u64;
        let mut tags: std::collections::HashMap<String, (usize, (usize, usize, usize))> =
            std::collections::HashMap::new();
        let mut cur = SCRATCH_A;
        let mut nodes = Vec::with_capacity(model.nodes.len());
        let mut cost = InferenceCost::default();

        for node in &model.nodes {
            let spec = &node.spec;
            if let Some(tag) = &spec.input_from {
                let &(slot, dims) = tags
                    .get(tag)
                    .ok_or_else(|| anyhow!("missing input tag {tag}"))?;
                cur = slot;
                (h, w, c) = dims;
            }
            let in_len = h * w * c;
            let mut lc =
                LayerCost { name: spec.name.clone(), ..Default::default() };

            let (kind, dst) = match &node.layer {
                Some(dl) => {
                    let op = Self::compile_quant(dl, (h, w, c), lut, backend, &tags, &mut lc)?;
                    weight_bytes += op.kernel.weight_bytes();
                    weight_traffic_bytes += dl.packed_bytes() as u64;
                    plane_len = plane_len.max(op.plane_bytes);
                    col_len = col_len.max(op.col_bytes + COL_SLACK);
                    (h, w, c) = if op.fc {
                        (1, 1, op.cout)
                    } else {
                        (op.out_h, op.out_w, op.cout)
                    };
                    (NodeKind::Quant(op), other_scratch(cur))
                }
                None => match spec.kind.as_str() {
                    "tap" => (NodeKind::NoOp, cur),
                    "flatten" => {
                        (h, w, c) = (1, 1, in_len);
                        (NodeKind::NoOp, cur)
                    }
                    "avgpool" => {
                        let kind = NodeKind::AvgPool { in_h: h, in_w: w, c };
                        account_structural(&mut lc, in_len);
                        (h, w) = (1, 1);
                        (kind, other_scratch(cur))
                    }
                    "add" => {
                        let tag = spec
                            .add_from
                            .as_ref()
                            .ok_or_else(|| anyhow!("add w/o tag"))?;
                        let &(other, dims) = tags
                            .get(tag)
                            .ok_or_else(|| anyhow!("missing saved tag {tag}"))?;
                        let olen = dims.0 * dims.1 * dims.2;
                        if olen != in_len {
                            bail!("add size mismatch at {}", spec.name);
                        }
                        account_structural(&mut lc, in_len);
                        let kind = NodeKind::Add {
                            other,
                            len: in_len,
                            relu: spec.relu,
                        };
                        // in-place on scratch; copy-out-of a tag slot
                        let dst = if cur <= SCRATCH_B { cur } else { SCRATCH_A };
                        (kind, dst)
                    }
                    other => bail!("unexpected structural kind {other}"),
                },
            };

            let out_len = h * w * c;
            max_len = max_len.max(out_len);
            let save = match &spec.save_as {
                Some(tag) => {
                    let slot = slot_len.len();
                    slot_len.push(out_len);
                    tags.insert(tag.clone(), (slot, (h, w, c)));
                    Some(slot)
                }
                None => None,
            };
            if lc.total_cycles() > 0.0 || lc.mem_bytes > 0 {
                cost.layers.push(lc);
            }
            nodes.push(PlanNode { src: cur, dst, save, out_len, kind });
            cur = dst;
        }

        slot_len[SCRATCH_A] = max_len;
        slot_len[SCRATCH_B] = max_len;
        let (plane_slots, fusion) = if fuse {
            fuse_requant(&mut nodes, slot_len.len(), cur)
        } else {
            (1, FusionStats::default())
        };
        let out_len = h * w * c;
        let permute = !model.output_perm.is_empty()
            && model.output_perm.iter().enumerate().any(|(i, &p)| i != p);
        if permute && model.output_perm.len() != out_len {
            bail!(
                "output permutation length {} != output length {out_len}",
                model.output_perm.len()
            );
        }
        Ok(ExecPlan {
            bench: model.bench.clone(),
            backend_name: backend.name(),
            kernel_tier: backend.tier(),
            feat,
            slot_len,
            plane_len,
            plane_slots,
            col_len,
            nodes,
            out_slot: cur,
            out_len,
            output_perm: model.output_perm.clone(),
            permute,
            cost,
            weight_bytes,
            weight_traffic_bytes,
            fusion,
        })
    }

    fn compile_quant(
        dl: &DeployedLayer,
        (h, w, c): (usize, usize, usize),
        lut: &CostLut,
        backend: &dyn KernelBackend,
        tags: &std::collections::HashMap<String, (usize, (usize, usize, usize))>,
        lc: &mut LayerCost,
    ) -> Result<Box<QuantOp>> {
        let s = &dl.spec;
        let fc = s.kind == "fc";
        let depthwise = s.kind == "dwconv";
        let k = dl.k();
        let in_len = h * w * c;
        let (out_h, out_w, cout) = if fc {
            if in_len != k {
                bail!("fc {} input length {in_len} != K {k}", s.name);
            }
            (1, 1, s.cout)
        } else {
            if h != s.in_h || w != s.in_w || c != s.cin {
                bail!(
                    "conv {} geometry mismatch: input {h}x{w}x{c} vs spec {}x{}x{}",
                    s.name,
                    s.in_h,
                    s.in_w,
                    s.cin
                );
            }
            (s.out_h, s.out_w, s.cout)
        };
        let cin_g = if depthwise { 1 } else { s.cin };
        let kk = s.kx * s.ky;

        // packed activation plane geometry: every input pixel's C_in
        // codes start on a byte boundary (the FC input is one such run)
        let pxs = dl.act_bits as usize;
        let cin = if fc { k } else { s.cin };
        let pixel_bytes = (cin * pxs).div_ceil(8);
        let plane_bytes = (in_len / cin) * pixel_bytes;
        let seg_bits = cin_g * pxs;
        let col_bytes = (k * pxs).div_ceil(8);

        // gather table (conv/dwconv): per (output pixel, kernel
        // position) the source pixel's byte offset in the packed plane
        let gather = if fc {
            Vec::new()
        } else {
            let pad_y = same_pad(s.in_h, s.out_h, s.kx, s.stride);
            let pad_x = same_pad(s.in_w, s.out_w, s.ky, s.stride);
            let mut g = Vec::with_capacity(out_h * out_w * kk);
            for oy in 0..out_h {
                for ox in 0..out_w {
                    for ki in 0..s.kx {
                        let iy = oy as i64 * s.stride as i64 + ki as i64 - pad_y;
                        for kj in 0..s.ky {
                            let ix = ox as i64 * s.stride as i64 + kj as i64
                                - pad_x;
                            let inside = iy >= 0
                                && iy < s.in_h as i64
                                && ix >= 0
                                && ix < s.in_w as i64;
                            g.push(if inside {
                                ((iy as usize * s.in_w + ix as usize)
                                    * pixel_bytes)
                                    as i32
                            } else {
                                -1
                            });
                        }
                    }
                }
            }
            g
        };

        // PACT step, identical to quant::quantize_acts_pact
        let levels = ((1u32 << dl.act_bits) - 1) as f32;
        let act_alpha = dl.alpha.max(1e-6);
        let act_eps = act_alpha / levels;
        let a_eps: Vec<f32> = dl.a_fold.iter().map(|&a| a * act_eps).collect();

        // fused residual epilogue
        let post_add = match &s.add_from {
            Some(tag) => {
                let &(other, dims) = tags
                    .get(tag)
                    .ok_or_else(|| anyhow!("missing saved tag {tag}"))?;
                let len = dims.0 * dims.1 * dims.2;
                if len != out_h * out_w * cout {
                    bail!("residual size mismatch at {}", s.name);
                }
                Some(PostAdd { other, len, relu: s.relu })
            }
            None => None,
        };

        // input-independent cost, in the oracle's accounting order
        for g in &dl.groups {
            let macs = if fc {
                (g.len * k) as u64
            } else {
                (out_h * out_w * g.len * k) as u64
            };
            account_group(lc, lut, dl.act_bits, g.bits, macs);
        }
        account_memory(lc, memory::layer_traffic_bytes(s, dl.act_bits, dl.packed_bytes()));
        if let Some(pa) = &post_add {
            account_structural(lc, pa.len);
        }

        Ok(Box::new(QuantOp {
            name: s.name.clone(),
            fc,
            depthwise,
            k,
            kk,
            in_len,
            out_h,
            out_w,
            cout,
            act_alpha,
            act_eps,
            act_bits: dl.act_bits,
            cin,
            pixel_bytes,
            plane_bytes,
            seg_bits,
            col_bytes,
            gather: gather.into(),
            groups: dl.groups.clone(),
            a_eps: a_eps.into(),
            b_fold: dl.b_fold.clone().into(),
            relu_inline: s.relu && s.add_from.is_none(),
            post_add,
            in_plane_slot: 0,
            in_plane_ready: false,
            out_fuse: None,
            kernel: backend.prepare(dl),
        }))
    }

    // ---- accessors ---------------------------------------------------------

    pub fn bench(&self) -> &str {
        &self.bench
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Kernel dispatch tier (`avx512`/`avx2`/`swar` for the `simd`
    /// backend, otherwise the backend name) — recorded in `/metrics`
    /// and bench JSON so every number names its code path.
    pub fn kernel_tier(&self) -> &'static str {
        self.kernel_tier
    }

    /// Per-sample input length.
    pub fn feat(&self) -> usize {
        self.feat
    }

    /// Per-sample output length (natural channel order).
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// The precomputed cost of ONE inference (input-independent).
    pub fn cost(&self) -> &InferenceCost {
        &self.cost
    }

    /// Amortized cost report for a `batch`-sample batch-plane pass:
    /// per-group scheduling and packed weight traffic are paid once per
    /// batch under weight-stationary execution (see
    /// [`InferenceCost::batch_cost`]).
    pub fn batch_cost(&self, batch: usize) -> BatchCost {
        self.cost.batch_cost(batch, self.weight_traffic_bytes)
    }

    /// Bytes of weight storage across all layer kernels.
    pub fn weight_bytes(&self) -> usize {
        self.weight_bytes
    }

    /// Fused-requantize coverage decided at compile time (all zeros on
    /// an unfused plan).
    pub fn fusion(&self) -> &FusionStats {
        &self.fusion
    }

    /// A zeroed [`PlanProfile`] matching this plan's node list, ready
    /// for [`Self::run_batch_planes_profiled`].  Structural nodes take
    /// their name from the cost layer they were accounted under (the
    /// k-th accounted node is the k-th [`LayerCost`] — compile pushes
    /// them in the same order).
    pub fn profile(&self) -> PlanProfile {
        let mut cost_k = 0usize;
        let nodes = self
            .nodes
            .iter()
            .map(|node| {
                let (kind, accounted) = match &node.kind {
                    NodeKind::Quant(op) => (
                        if op.fc {
                            "fc"
                        } else if op.depthwise {
                            "dwconv"
                        } else {
                            "conv"
                        },
                        true,
                    ),
                    NodeKind::AvgPool { .. } => ("avgpool", true),
                    NodeKind::Add { .. } => ("add", true),
                    NodeKind::NoOp => ("noop", false),
                };
                let cost_ix = if accounted {
                    let i = cost_k;
                    cost_k += 1;
                    (i < self.cost.layers.len()).then_some(i)
                } else {
                    None
                };
                let name = match &node.kind {
                    NodeKind::Quant(op) => op.name.clone(),
                    _ => cost_ix
                        .map(|i| self.cost.layers[i].name.clone())
                        .unwrap_or_else(|| kind.to_string()),
                };
                NodeProfile {
                    name,
                    kind,
                    cost_ix,
                    calls: 0,
                    quant_ns: 0,
                    exec_ns: 0,
                    bytes_moved: 0,
                }
            })
            .collect();
        PlanProfile {
            batches: 0,
            samples: 0,
            wall_ns: 0,
            batch_hist: [0; MAX_BATCH_CHUNK],
            nodes,
        }
    }

    /// Allocate a one-sample worker arena for this plan.
    pub fn arena(&self) -> Arena {
        self.batch_arena(1)
    }

    /// Allocate a worker arena with batch-plane capacity for `cap`
    /// samples (every buffer holds `cap` stride-addressed regions).
    pub fn batch_arena(&self, cap: usize) -> Arena {
        Arena::new(
            &self.slot_len,
            self.plane_len,
            self.plane_slots,
            self.col_len,
            cap.max(1),
        )
    }

    // ---- execution ---------------------------------------------------------

    /// Run one sample using `arena` scratch; returns the output
    /// activations in natural (un-permuted) channel order.  This is the
    /// one-sample batch through [`Self::run_batch_planes`] — there is a
    /// single execution path.
    pub fn run_sample(
        &self,
        arena: &mut Arena,
        input: &[f32],
    ) -> Result<Vec<f32>> {
        let mut outs = self.run_batch_planes(arena, &[input])?;
        Ok(outs.pop().expect("one output per sample"))
    }

    /// Execute `samples` **batch-major** through `arena` (capacity must
    /// cover the batch): per quantized layer, all `B` activation planes
    /// are quantized/packed in one pass, gather tables are read once
    /// per output pixel for the whole batch, and the kernels' batched
    /// entry points ride each decoded weight word across all `B`
    /// columns.  Outputs are in input order, bit-identical to
    /// [`Self::run_sample`] per sample.
    pub fn run_batch_planes(
        &self,
        arena: &mut Arena,
        samples: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        self.run_batch_inner(arena, samples, None)
    }

    /// [`Self::run_batch_planes`] with per-node profiling: wall time,
    /// modeled bytes moved and executed-batch sizes accumulate into
    /// `prof` (create it with [`Self::profile`]).  Outputs stay
    /// bit-identical to the unprofiled path — the hooks only read
    /// clocks around node boundaries.
    pub fn run_batch_planes_profiled(
        &self,
        arena: &mut Arena,
        samples: &[&[f32]],
        prof: &mut PlanProfile,
    ) -> Result<Vec<Vec<f32>>> {
        if prof.nodes.len() != self.nodes.len() {
            bail!(
                "profile has {} node entries, plan has {} (use ExecPlan::profile)",
                prof.nodes.len(),
                self.nodes.len()
            );
        }
        self.run_batch_inner(arena, samples, Some(prof))
    }

    fn run_batch_inner(
        &self,
        arena: &mut Arena,
        samples: &[&[f32]],
        mut prof: Option<&mut PlanProfile>,
    ) -> Result<Vec<Vec<f32>>> {
        let b = samples.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        if b > arena.capacity() {
            bail!("batch of {b} exceeds arena capacity {}", arena.capacity());
        }
        for s in samples {
            if s.len() != self.feat {
                bail!("input length {} != {}", s.len(), self.feat);
            }
        }
        let _pass_span = trace::span_arg(trace::SpanName::EnginePass, 0, b as u64);
        let t_pass = prof.is_some().then(Instant::now);
        let Arena { slots, planes, col, acc, acc_wide, .. } = arena;
        let sl = &self.slot_len;
        for (j, s) in samples.iter().enumerate() {
            slots[SCRATCH_A][j * sl[SCRATCH_A]..][..self.feat].copy_from_slice(s);
        }

        for (ni, node) in self.nodes.iter().enumerate() {
            let _node_span = trace::span_arg(trace::SpanName::Node, 0, ni as u64);
            let t_node = prof.is_some().then(Instant::now);
            let quant_before = prof.as_deref().map(|p| p.nodes[ni].quant_ns);
            match &node.kind {
                NodeKind::NoOp => {}
                NodeKind::AvgPool { in_h, in_w, c } => {
                    let (dst, src) = pair(slots, node.dst, node.src);
                    for j in 0..b {
                        let dst = &mut dst[j * sl[node.dst]..][..*c];
                        let src = &src[j * sl[node.src]..];
                        dst.fill(0.0);
                        for y in 0..*in_h {
                            for x in 0..*in_w {
                                let base = (y * in_w + x) * c;
                                for ch in 0..*c {
                                    dst[ch] += src[base + ch];
                                }
                            }
                        }
                        let n = (in_h * in_w) as f32;
                        for v in dst.iter_mut() {
                            *v /= n;
                        }
                    }
                }
                NodeKind::Add { other, len, relu } => {
                    if node.dst != node.src {
                        let (dst, src) = pair(slots, node.dst, node.src);
                        for j in 0..b {
                            dst[j * sl[node.dst]..][..*len]
                                .copy_from_slice(&src[j * sl[node.src]..][..*len]);
                        }
                    }
                    let (dst, oth) = pair(slots, node.dst, *other);
                    for j in 0..b {
                        let dst = &mut dst[j * sl[node.dst]..][..*len];
                        let oth = &oth[j * sl[*other]..][..*len];
                        for (d, &o) in dst.iter_mut().zip(oth) {
                            *d += o;
                            if *relu {
                                *d = d.max(0.0);
                            }
                        }
                    }
                }
                NodeKind::Quant(op) => {
                    {
                        let (dst, src) = pair(slots, node.dst, node.src);
                        exec_quant_batch(
                            op,
                            src,
                            sl[node.src],
                            dst,
                            sl[node.dst],
                            planes,
                            self.plane_len,
                            col,
                            self.col_len,
                            &mut acc[..b],
                            &mut acc_wide[..b],
                            prof.as_deref_mut().map(|p| &mut p.nodes[ni]),
                        );
                    }
                    if let Some(pa) = &op.post_add {
                        let (dst, oth) = pair(slots, node.dst, pa.other);
                        for j in 0..b {
                            let dst = &mut dst[j * sl[node.dst]..][..pa.len];
                            let oth = &oth[j * sl[pa.other]..][..pa.len];
                            for (d, &o) in dst.iter_mut().zip(oth) {
                                *d += o;
                                if pa.relu {
                                    *d = d.max(0.0);
                                }
                            }
                        }
                        // deferred fused exit: the residual add had to
                        // run over the f32 staging slot first, so the
                        // consumer plane is coded here, from the exact
                        // values the two-pass path would re-read
                        if let Some(of) = &op.out_fuse {
                            let dst = &slots[node.dst][..];
                            let plane = &mut planes[of.plane_slot][..];
                            for j in 0..b {
                                quantize_into_plane(
                                    &dst[j * sl[node.dst]..][..pa.len],
                                    of.alpha,
                                    of.eps,
                                    of.bits as usize,
                                    of.cin,
                                    of.pixel_bytes,
                                    &mut plane[j * self.plane_len..]
                                        [..of.plane_bytes],
                                );
                            }
                        }
                    }
                }
            }
            if let Some(slot) = node.save {
                if slot != node.dst {
                    let (save, out) = pair(slots, slot, node.dst);
                    for j in 0..b {
                        save[j * sl[slot]..][..node.out_len]
                            .copy_from_slice(&out[j * sl[node.dst]..][..node.out_len]);
                    }
                }
            }
            if let Some(t) = t_node {
                let p = prof.as_deref_mut().expect("prof present when timed");
                let np = &mut p.nodes[ni];
                // exec_quant_batch already banked its quantize share
                // into quant_ns; keep wall = quant + exec additive
                let quant_delta = np.quant_ns - quant_before.unwrap_or(0);
                np.calls += 1;
                let wall = t.elapsed().as_nanos() as u64;
                np.exec_ns += wall.saturating_sub(quant_delta);
                np.bytes_moved += node_bytes_moved(node, b as u64);
            }
        }
        if let Some(t) = t_pass {
            let p = prof.as_deref_mut().expect("prof present when timed");
            p.batches += 1;
            p.samples += b as u64;
            p.wall_ns += t.elapsed().as_nanos() as u64;
            p.batch_hist[(b - 1).min(MAX_BATCH_CHUNK - 1)] += 1;
        }

        let mut outs = Vec::with_capacity(b);
        for j in 0..b {
            let out = &slots[self.out_slot][j * sl[self.out_slot]..][..self.out_len];
            if self.permute {
                // un-permute the output space (free relabeling on device)
                let mut natural = vec![0.0f32; self.out_len];
                for (new_c, &orig_c) in self.output_perm.iter().enumerate() {
                    natural[orig_c] = out[new_c];
                }
                outs.push(natural);
            } else {
                outs.push(out.to_vec());
            }
        }
        Ok(outs)
    }

    /// Run a batch of flattened samples across worker threads.
    ///
    /// Returns per-sample outputs and the cost of **one** inference:
    /// costs are input-independent, so the returned [`InferenceCost`]
    /// describes every individual sample, not the batch total.
    pub fn run_batch(
        &self,
        xs: &[f32],
        feat: usize,
    ) -> Result<(Vec<Vec<f32>>, InferenceCost)> {
        let n = if feat == 0 { 0 } else { xs.len() / feat };
        self.run_batch_threads(xs, feat, engine_threads(n))
    }

    /// [`Self::run_batch`] with an explicit worker count.
    pub fn run_batch_threads(
        &self,
        xs: &[f32],
        feat: usize,
        threads: usize,
    ) -> Result<(Vec<Vec<f32>>, InferenceCost)> {
        if feat == 0 || feat != self.feat {
            bail!("batch feature length {feat} != model input {}", self.feat);
        }
        if xs.len() % feat != 0 {
            bail!(
                "batch of {} values is not a whole number of {feat}-element \
                 samples",
                xs.len()
            );
        }
        let samples: Vec<&[f32]> = xs.chunks_exact(feat).collect();
        let outs = self.run_samples(&samples, threads)?;
        Ok((outs, self.cost.clone()))
    }

    /// Run an explicit list of samples (not necessarily contiguous in
    /// memory) across worker threads — the execution seam the serving
    /// micro-batcher uses: coalesced requests each own their input
    /// buffer, and this runs them through the batch-plane path without
    /// first copying them into a single contiguous slab.
    ///
    /// Sharding is **by batch-chunk**, not by sample: each worker runs
    /// contiguous chunks of up to [`MAX_BATCH_CHUNK`] samples through
    /// [`Self::run_batch_planes`] with its own batch [`Arena`].  Note
    /// the per-worker chunk is `n / threads` — a caller that fans a
    /// small batch out to `threads >= n` workers is back to one-sample
    /// passes with no weight-decode amortization, so amortization-aware
    /// callers cap `threads` (the serving batcher allows at most one
    /// worker per four riders).  Outputs are returned in input order
    /// and are bit-identical to calling [`Self::run_sample`] per
    /// sample.
    pub fn run_samples(
        &self,
        samples: &[&[f32]],
        threads: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let n = samples.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let threads = threads.clamp(1, n);
        if threads == 1 {
            return self.run_chunked(samples);
        }
        let chunk = n.div_ceil(threads);
        let ranges: Vec<(usize, usize)> = (0..threads)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
            .filter(|&(a, b)| a < b)
            .collect();
        let results: Vec<Result<Vec<Vec<f32>>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(a, b)| {
                    scope.spawn(move || self.run_chunked(&samples[a..b]))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("engine worker panicked"))
                .collect()
        });
        let mut outs = Vec::with_capacity(n);
        for r in results {
            outs.extend(r?);
        }
        Ok(outs)
    }

    /// One worker's share: batch-plane passes of up to
    /// [`MAX_BATCH_CHUNK`] samples through a single reused arena.
    fn run_chunked(&self, samples: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let mut arena = self.batch_arena(samples.len().min(MAX_BATCH_CHUNK));
        let mut outs = Vec::with_capacity(samples.len());
        for chunk in samples.chunks(MAX_BATCH_CHUNK) {
            outs.append(&mut self.run_batch_planes(&mut arena, chunk)?);
        }
        Ok(outs)
    }
}

/// Worker count for an `n`-sample batch: `CWMIX_ENGINE_THREADS` env
/// override, else `min(n, cores)`.
pub fn engine_threads(n: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    std::env::var("CWMIX_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(cores)
        .clamp(1, n.max(1))
}

/// The quantized layer behind node `i` (fusion-pass internal: indices
/// come from the value analysis, which only records quantized nodes).
fn quant_of(nodes: &[PlanNode], i: usize) -> &QuantOp {
    match &nodes[i].kind {
        NodeKind::Quant(op) => op,
        _ => unreachable!("value analysis recorded a non-quantized node"),
    }
}

fn quant_of_mut(nodes: &mut [PlanNode], i: usize) -> &mut QuantOp {
    match &mut nodes[i].kind {
        NodeKind::Quant(op) => op,
        _ => unreachable!("value analysis recorded a non-quantized node"),
    }
}

/// A consumer's PACT signature + plane geometry: fusion requires every
/// quantized consumer of a value to agree on all of it (clip and step
/// compared by bit pattern).
fn plane_sig(nodes: &[PlanNode], i: usize) -> (u32, u32, u32, usize, usize, usize) {
    let op = quant_of(nodes, i);
    (
        op.act_bits,
        op.act_alpha.to_bits(),
        op.act_eps.to_bits(),
        op.cin,
        op.pixel_bytes,
        op.plane_bytes,
    )
}

/// Everything the fusion pass learned about one value (one activation
/// tensor version) while replaying the node list's slot reads/writes.
#[derive(Default)]
struct ValInfo {
    /// node index of the quantized layer that produced it
    producer: Option<usize>,
    /// quantized layers reading it as their main (packed-plane) input
    quant_consumers: Vec<usize>,
    /// something reads the f32 form: a residual tap (`PostAdd`), a
    /// structural add/avgpool, or the network output
    f32_read: bool,
    /// nodes whose `save` copies it into a tag slot
    saves: Vec<usize>,
}

/// The fused-requantize pass (see module docs): recover the value flow
/// from the built nodes' slot reads/writes, then for every value whose
/// quantized consumers agree on one plane signature, rewrite the
/// producer to code the consumer plane at its epilogue exit and mark
/// the consumers' planes ready.
///
/// Plane-slot discipline (aliasing safety): a producer may code into
/// the flip slot (`0`/`1`, whichever it is not reading) **only** when
/// its single consumer is the immediately-next quantized node — no
/// other quantized layer runs in between, so nothing can clobber the
/// coded plane.  Every other fusible shape — a residual tap feeding
/// several branches, or a non-adjacent single consumer — gets a
/// dedicated plane slot (ids ≥ 2, one per value, never shared), which
/// stays live across intervening layers by construction.  When no f32
/// reader remains, the value's tag-slot saves are elided and the
/// producer skips its f32 slot write entirely.
fn fuse_requant(
    nodes: &mut [PlanNode],
    n_slots: usize,
    out_slot: usize,
) -> (usize, FusionStats) {
    // value analysis: which value lives in each slot as nodes execute
    const NO_VAL: usize = usize::MAX;
    let mut slot_val = vec![NO_VAL; n_slots];
    let mut vals: Vec<ValInfo> = vec![ValInfo::default()]; // 0 = network input
    slot_val[SCRATCH_A] = 0;
    for (i, node) in nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::NoOp => {} // tap/flatten: the value flows through
            NodeKind::AvgPool { .. } => {
                vals[slot_val[node.src]].f32_read = true;
                slot_val[node.dst] = vals.len();
                vals.push(ValInfo::default());
            }
            NodeKind::Add { other, .. } => {
                vals[slot_val[node.src]].f32_read = true;
                vals[slot_val[*other]].f32_read = true;
                slot_val[node.dst] = vals.len();
                vals.push(ValInfo::default());
            }
            NodeKind::Quant(op) => {
                vals[slot_val[node.src]].quant_consumers.push(i);
                if let Some(pa) = &op.post_add {
                    vals[slot_val[pa.other]].f32_read = true;
                }
                slot_val[node.dst] = vals.len();
                vals.push(ValInfo { producer: Some(i), ..ValInfo::default() });
            }
        }
        if let Some(s) = node.save {
            let v = slot_val[node.dst];
            slot_val[s] = v;
            vals[v].saves.push(i);
        }
    }
    vals[slot_val[out_slot]].f32_read = true;

    // the next quantized node after each node — the adjacency test for
    // flip-slot fusion
    let mut next_quant = vec![None; nodes.len()];
    let mut nq = None;
    for i in (0..nodes.len()).rev() {
        next_quant[i] = nq;
        if matches!(nodes[i].kind, NodeKind::Quant(_)) {
            nq = Some(i);
        }
    }

    let mut stats = FusionStats::default();
    let mut plane_slots = 1usize;
    let mut next_dedicated = 2usize;
    // values are created in node order, so by the time a value is
    // decided its producer's own input-plane slot is already final —
    // which the flip-slot choice below depends on
    for v in 0..vals.len() {
        let (consumers, f32_read, saves, producer) = {
            let info = &vals[v];
            (
                info.quant_consumers.clone(),
                info.f32_read,
                info.saves.clone(),
                info.producer,
            )
        };
        if consumers.is_empty() {
            continue;
        }
        let sig0 = plane_sig(nodes, consumers[0]);
        let sig_match = consumers.iter().all(|&c| plane_sig(nodes, c) == sig0);
        let Some(p) = producer else {
            // value produced outside the quantized graph (the network
            // input, or a pool output): nothing codes it for free, but
            // agreeing sibling consumers can still share one plane —
            // the first quantizes it, the rest reuse it
            if consumers.len() >= 2 && sig_match {
                let slot = next_dedicated;
                next_dedicated += 1;
                plane_slots = plane_slots.max(slot + 1);
                for (nth, &c) in consumers.iter().enumerate() {
                    let opc = quant_of_mut(nodes, c);
                    opc.in_plane_slot = slot;
                    opc.in_plane_ready = nth > 0;
                }
                stats.reuse_hits += consumers.len() - 1;
            }
            continue;
        };

        // two-pass traffic on this edge set (per sample): the
        // producer's f32 slot write plus every consumer's f32 re-read
        // and packed-plane write
        stats.total_edges += consumers.len();
        let n_out = nodes[p].out_len as u64;
        let mut unfused = 4 * n_out;
        for &c in &consumers {
            unfused += 4 * n_out + quant_of(nodes, c).plane_bytes as u64;
        }
        stats.act_bytes_unfused += unfused;
        if !sig_match {
            // mixed consumer precisions (residual-reuse fallback): the
            // f32 path stays, every consumer quantizes for itself
            stats.act_bytes_fused += unfused;
            continue;
        }

        let p_in = quant_of(nodes, p).in_plane_slot;
        let p_has_post = quant_of(nodes, p).post_add.is_some();
        let slot = if consumers.len() == 1 && next_quant[p] == Some(consumers[0]) {
            if p_in == 0 { 1 } else { 0 }
        } else {
            let s = next_dedicated;
            next_dedicated += 1;
            s
        };
        plane_slots = plane_slots.max(slot + 1);
        for &c in &consumers {
            let opc = quant_of_mut(nodes, c);
            opc.in_plane_slot = slot;
            opc.in_plane_ready = true;
        }
        if !f32_read {
            // no f32 reader anywhere: the tag-slot copies of this
            // value are dead too
            for &s in &saves {
                nodes[s].save = None;
            }
        }
        let (bits, cin, pixel_bytes, plane_bytes) = {
            let c0 = quant_of(nodes, consumers[0]);
            (c0.act_bits, c0.cin, c0.pixel_bytes, c0.plane_bytes)
        };
        {
            let opp = quant_of_mut(nodes, p);
            opp.out_fuse = Some(OutFuse {
                plane_slot: slot,
                bits,
                alpha: f32::from_bits(sig0.1),
                eps: f32::from_bits(sig0.2),
                cin,
                pixel_bytes,
                plane_bytes,
                keep_f32: f32_read,
            });
        }
        stats.fused_edges += consumers.len();
        if consumers.len() > 1 {
            stats.reuse_hits += consumers.len() - 1;
        }
        if !f32_read && !p_has_post {
            stats.elided_f32 += 1;
        }
        // fused traffic: one plane write, plus the f32 staging slot
        // when a residual add or an f32 reader still needs it
        let staged = if f32_read || p_has_post { 4 * n_out } else { 0 };
        stats.act_bytes_fused += staged + plane_bytes as u64;
    }
    (plane_slots, stats)
}

/// Disjoint mutable access to two arena slots.
fn pair<'a>(
    slots: &'a mut [Vec<f32>],
    a: usize,
    b: usize,
) -> (&'a mut [f32], &'a mut [f32]) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = slots.split_at_mut(b);
        (&mut lo[a][..], &mut hi[0][..])
    } else {
        let (lo, hi) = slots.split_at_mut(a);
        (&mut hi[0][..], &mut lo[b][..])
    }
}

/// Disjoint mutable access to two arena planes (a fused producer reads
/// its input plane while coding the consumer's — the fusion pass
/// guarantees the slots differ).
fn plane_pair<'a>(
    planes: &'a mut [Vec<u8>],
    a: usize,
    b: usize,
) -> (&'a mut [u8], &'a mut [u8]) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = planes.split_at_mut(b);
        (&mut lo[a][..], &mut hi[0][..])
    } else {
        let (lo, hi) = planes.split_at_mut(a);
        (&mut hi[0][..], &mut lo[b][..])
    }
}

/// OR `nbits` bits from `src` (starting at its bit 0) into `dst`
/// starting at bit `pos`.  Target bits must be zero beforehand; `src`
/// slack bits past `nbits` must be zero (the packed plane guarantees
/// both).  May touch one spill byte past the written range — callers
/// keep [`COL_SLACK`] zeroed bytes after the column.
fn or_bits(dst: &mut [u8], pos: usize, src: &[u8], nbits: usize) {
    let shift = (pos % 8) as u32;
    let nbytes = nbits.div_ceil(8);
    let mut byte = pos / 8;
    if shift == 0 {
        dst[byte..byte + nbytes].copy_from_slice(&src[..nbytes]);
        return;
    }
    for &b in &src[..nbytes] {
        dst[byte] |= b << shift;
        dst[byte + 1] |= b >> (8 - shift);
        byte += 1;
    }
}

/// PACT-quantize `vals` and pack them into `plane` (zeroed first):
/// identical arithmetic to `quant::quantize_acts_pact`, identical
/// layout to `quant::pack_acts_subbyte` (one byte-aligned run per
/// pixel).  Shared by the per-layer quantize pass and the deferred
/// (post-residual) fused exit, so both code the same bytes.
fn quantize_into_plane(
    vals: &[f32],
    alpha: f32,
    eps: f32,
    bits: usize,
    cin: usize,
    pixel_bytes: usize,
    plane: &mut [u8],
) {
    plane.fill(0);
    for (p, pix) in vals.chunks_exact(cin).enumerate() {
        let base = p * pixel_bytes * 8;
        for (ci, &v) in pix.iter().enumerate() {
            let code = ((v.clamp(0.0, alpha)) / eps).round_ties_even() as u32 as u8;
            let bit = base + ci * bits;
            plane[bit / 8] |= code << (bit % 8);
        }
    }
}

/// Borrowed fused-exit state for one layer: the consumer's plane
/// (pre-zeroed per sample) and its coding parameters.
struct FusedOut<'a> {
    buf: &'a mut [u8],
    stride: usize,
    alpha: f32,
    eps: f32,
    bits: usize,
    cin: usize,
    pixel_bytes: usize,
}

impl FusedOut<'_> {
    /// Code `y` as output element `g` of sample `j` — the exact bytes
    /// the consumer's own quantize pass would produce from the f32
    /// slot.  Covers conv→conv (`cin' = cout`: the element's pixel and
    /// channel fall out of `g`) and →FC (`cin' = K`: one run, pixel 0).
    #[inline]
    fn put(&mut self, j: usize, g: usize, y: f32) {
        let code =
            ((y.clamp(0.0, self.alpha)) / self.eps).round_ties_even() as u32 as u8;
        let bit = (g / self.cin) * self.pixel_bytes * 8 + (g % self.cin) * self.bits;
        self.buf[j * self.stride + bit / 8] |= code << (bit % 8);
    }
}

/// Modeled bytes moved by one execution of `node` on a `b`-sample
/// batch: f32 slot reads/writes, the packed-plane write when the node
/// quantizes its own input, and one packed weight-stream read per
/// batch (decoded once, ridden across all `b` columns).
fn node_bytes_moved(node: &PlanNode, b: u64) -> u64 {
    match &node.kind {
        NodeKind::NoOp => 0,
        NodeKind::AvgPool { in_h, in_w, c } => ((in_h * in_w * c + c) * 4) as u64 * b,
        NodeKind::Add { len, .. } => (len * 3 * 4) as u64 * b,
        NodeKind::Quant(op) => {
            let quant = if op.in_plane_ready {
                0
            } else {
                op.in_len * 4 + op.plane_bytes
            };
            (quant + node.out_len * 4) as u64 * b + op.kernel.weight_bytes() as u64
        }
    }
}

/// Epilogue writeback: the f32 slot (unless elided by fusion) and/or
/// the consumer's packed plane.
#[inline]
fn emit(
    dst: &mut [f32],
    dst_stride: usize,
    write_f32: bool,
    fused: &mut Option<FusedOut<'_>>,
    j: usize,
    g: usize,
    y: f32,
) {
    if write_f32 {
        dst[j * dst_stride + g] = y;
    }
    if let Some(f) = fused {
        f.put(j, g, y);
    }
}

/// One quantized layer on a `B`-sample batch (`B = acc.len()`),
/// batch-major: quantize all `B` planes → gather `B` packed columns per
/// output pixel → batched weight-stationary dot → epilogue per sample.
/// Per sample the arithmetic and its order are identical to the
/// one-sample path, so results are bit-identical to `run_sample`.
#[allow(clippy::too_many_arguments)]
fn exec_quant_batch(
    op: &QuantOp,
    src: &[f32],
    src_stride: usize,
    dst: &mut [f32],
    dst_stride: usize,
    planes: &mut [Vec<u8>],
    plane_stride: usize,
    col: &mut [u8],
    col_stride: usize,
    acc: &mut [i32],
    acc_wide: &mut [i64],
    prof: Option<&mut NodeProfile>,
) {
    let b = acc.len();
    let pxs = op.act_bits as usize;
    if !op.in_plane_ready {
        // PACT quantization of every sample's input buffer, fused with
        // sub-byte packing: one pass over the batch, PACT scale and
        // plane geometry read once for all B samples.  Skipped entirely
        // when a fused producer (or a sibling consumer sharing a saved
        // plane) already coded this layer's input plane.
        let t_q = prof.is_some().then(Instant::now);
        let xp = &mut planes[op.in_plane_slot][..];
        for j in 0..b {
            quantize_into_plane(
                &src[j * src_stride..][..op.in_len],
                op.act_alpha,
                op.act_eps,
                pxs,
                op.cin,
                op.pixel_bytes,
                &mut xp[j * plane_stride..][..op.plane_bytes],
            );
        }
        if let (Some(p), Some(t)) = (prof, t_q) {
            p.quant_ns += t.elapsed().as_nanos() as u64;
        }
    }
    // fused exit: the epilogue codes the consumer's plane in this same
    // pass (a residual add defers coding to the post-add pass instead,
    // so the f32 staging slot is always written in that case)
    let write_f32 = match &op.out_fuse {
        Some(of) => of.keep_f32 || op.post_add.is_some(),
        None => true,
    };
    let (xplane, mut fused): (&[u8], Option<FusedOut<'_>>) = match &op.out_fuse {
        Some(of) if op.post_add.is_none() => {
            let (out, inp) = plane_pair(planes, of.plane_slot, op.in_plane_slot);
            for j in 0..b {
                out[j * plane_stride..][..of.plane_bytes].fill(0);
            }
            (
                inp,
                Some(FusedOut {
                    buf: out,
                    stride: plane_stride,
                    alpha: of.alpha,
                    eps: of.eps,
                    bits: of.bits as usize,
                    cin: of.cin,
                    pixel_bytes: of.pixel_bytes,
                }),
            )
        }
        _ => (&planes[op.in_plane_slot][..], None),
    };

    if op.fc {
        // the packed planes ARE the FC columns — the whole batch is
        // addressed zero-copy at the plane stride
        for g in &op.groups {
            for c in g.start..g.start + g.len {
                op.kernel.dot_wide_batch(c, xplane, plane_stride, acc_wide);
                for (j, &av) in acc_wide.iter().enumerate() {
                    let mut y = av as f32 * op.a_eps[c] + op.b_fold[c];
                    if op.relu_inline {
                        y = y.max(0.0);
                    }
                    emit(dst, dst_stride, write_f32, &mut fused, j, c, y);
                }
            }
        }
        return;
    }

    let kk = op.kk;
    if op.depthwise {
        // depthwise: filter c reads only input channel c — extract the
        // kk-point window per (pixel, channel) into one dense column
        // per sample.  Pixels start byte-aligned and p_x divides 8, so
        // a channel's code never straddles a byte.
        let mask = ((1u16 << op.act_bits) - 1) as u8;
        for pix in 0..op.out_h * op.out_w {
            let tbl = &op.gather[pix * kk..(pix + 1) * kk];
            let orow = pix * op.cout;
            for g in &op.groups {
                for c in g.start..g.start + g.len {
                    let cbit = c * pxs;
                    let (cbyte, cshift) = (cbit / 8, (cbit % 8) as u32);
                    for j in 0..b {
                        let colb = &mut col[j * col_stride..][..op.col_bytes];
                        colb.fill(0);
                        let plane = &xplane[j * plane_stride..];
                        for (t, &base) in tbl.iter().enumerate() {
                            if base >= 0 {
                                let code = (plane[base as usize + cbyte] >> cshift) & mask;
                                let dbit = t * pxs;
                                colb[dbit / 8] |= code << (dbit % 8);
                            }
                        }
                    }
                    op.kernel.dot_batch(c, col, col_stride, acc);
                    for (j, &av) in acc.iter().enumerate() {
                        let mut y = av as f32 * op.a_eps[c] + op.b_fold[c];
                        if op.relu_inline {
                            y = y.max(0.0);
                        }
                        emit(dst, dst_stride, write_f32, &mut fused, j, orow + c, y);
                    }
                }
            }
        }
        return;
    }

    // standard conv: assemble the packed receptive-field columns once
    // per output pixel — B columns side by side, reused by all C_out
    // channels; the gather table is read once for the whole batch
    if op.seg_bits % 8 == 0 {
        // byte-aligned segments: straight byte copies per kernel
        // position; a 1x1 conv's columns are plane slices (zero-copy,
        // batch-addressed at the plane stride)
        let seg_bytes = op.seg_bits / 8;
        for pix in 0..op.out_h * op.out_w {
            let tbl = &op.gather[pix * kk..(pix + 1) * kk];
            let (cols, stride): (&[u8], usize) = if kk == 1 && tbl[0] >= 0 {
                (&xplane[tbl[0] as usize..], plane_stride)
            } else {
                for j in 0..b {
                    let colj = &mut col[j * col_stride..];
                    let plane = &xplane[j * plane_stride..];
                    for (t, &base) in tbl.iter().enumerate() {
                        let d = t * seg_bytes;
                        if base < 0 {
                            colj[d..d + seg_bytes].fill(0);
                        } else {
                            let s = base as usize;
                            colj[d..d + seg_bytes]
                                .copy_from_slice(&plane[s..s + seg_bytes]);
                        }
                    }
                }
                (&*col, col_stride)
            };
            let orow = pix * op.cout;
            for g in &op.groups {
                for c in g.start..g.start + g.len {
                    op.kernel.dot_batch(c, cols, stride, acc);
                    for (j, &av) in acc.iter().enumerate() {
                        let mut y = av as f32 * op.a_eps[c] + op.b_fold[c];
                        if op.relu_inline {
                            y = y.max(0.0);
                        }
                        emit(dst, dst_stride, write_f32, &mut fused, j, orow + c, y);
                    }
                }
            }
        }
    } else {
        // cin * p_x not a byte multiple: shifted OR assembly keeps each
        // sample's column dense so the SWAR kernels see a gap-free lane
        // stream (col_stride leaves COL_SLACK bytes of spill room per
        // column)
        for pix in 0..op.out_h * op.out_w {
            let tbl = &op.gather[pix * kk..(pix + 1) * kk];
            for j in 0..b {
                let colj = &mut col[j * col_stride..][..op.col_bytes + COL_SLACK];
                colj.fill(0);
                let plane = &xplane[j * plane_stride..];
                for (t, &base) in tbl.iter().enumerate() {
                    if base >= 0 {
                        let s = base as usize;
                        or_bits(colj, t * op.seg_bits, &plane[s..s + op.pixel_bytes], op.seg_bits);
                    }
                }
            }
            let orow = pix * op.cout;
            for g in &op.groups {
                for c in g.start..g.start + g.len {
                    op.kernel.dot_batch(c, col, col_stride, acc);
                    for (j, &av) in acc.iter().enumerate() {
                        let mut y = av as f32 * op.a_eps[c] + op.b_fold[c];
                        if op.relu_inline {
                            y = y.max(0.0);
                        }
                        emit(dst, dst_stride, write_f32, &mut fused, j, orow + c, y);
                    }
                }
            }
        }
    }
}
