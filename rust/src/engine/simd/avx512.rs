//! AVX-512 tier: sixteen samples per `i32` register, eight per `i64`.
//!
//! Structurally identical to the AVX2 tier (see `avx2.rs` — scalar
//! bounds-checked column fetches, scalar weight decode, vector MAC
//! only, SWAR accumulation order per sample) at twice the width.
//! Ragged batch remainders cascade to the AVX2 cell, which in turn
//! cascades its own remainder to SWAR — this tier is only installed
//! when both feature bits were detected, so the whole cascade is
//! runtime-proven.

use std::arch::x86_64::*;

use super::avx2;
use crate::engine::backend::{
    extract_code, extract_weight, load_le, sext, RowDotBatch, RowDotWideBatch,
};

/// Generates one `(p_x, p_w)` AVX-512 cell pair; `$fb`/`$fbw` are the
/// matching AVX2 cells the `B mod 16` / `B mod 8` remainders cascade
/// to.  Safety argument as in `avx2.rs`: the `unsafe` inner fns are
/// only reachable through tables installed after
/// `is_x86_feature_detected!("avx512f")` (and `"avx2"`) returned true.
macro_rules! avx512_kernel {
    ($batch:ident, $batch_impl:ident, $wide:ident, $wide_impl:ident,
     $px:literal, $pw:literal, $fb:path, $fbw:path) => {
        pub(super) fn $batch(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i32],
        ) {
            // SAFETY: installed behind runtime AVX-512 detection
            unsafe { $batch_impl(cols, stride, wrow, k, out) }
        }

        #[target_feature(enable = "avx512f")]
        unsafe fn $batch_impl(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i32],
        ) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let b = out.len();
            let full = k / LANES;
            let xmask = _mm512_set1_epi32(XMASK as i32);
            let mut j = 0;
            while j + 16 <= b {
                let base = j * stride;
                let mut acc = _mm512_setzero_si512();
                for i in 0..full {
                    let ww = load_le(wrow, i * WSTEP, WSTEP);
                    let xoff = base + i * XSTEP;
                    let xv = _mm512_set_epi32(
                        load_le(cols, xoff + 15 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 14 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 13 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 12 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 11 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 10 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 9 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 8 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 7 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 6 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 5 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 4 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 3 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 2 * stride, XSTEP) as i32,
                        load_le(cols, xoff + stride, XSTEP) as i32,
                        load_le(cols, xoff, XSTEP) as i32,
                    );
                    for lane in 0..LANES as u32 {
                        let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW);
                        let x = _mm512_and_si512(
                            _mm512_srl_epi32(xv, _mm_cvtsi32_si128((lane * PX) as i32)),
                            xmask,
                        );
                        acc = _mm512_add_epi32(
                            acc,
                            _mm512_mullo_epi32(x, _mm512_set1_epi32(w)),
                        );
                    }
                }
                let mut sums = [0i32; 16];
                _mm512_storeu_epi32(sums.as_mut_ptr(), acc);
                for (t, s) in sums.iter().enumerate() {
                    let mut a = *s;
                    let col = &cols[(j + t) * stride..];
                    for jj in full * LANES..k {
                        a += extract_code(col, jj, PX) as i32 * extract_weight(wrow, jj, PW);
                    }
                    out[j + t] = a;
                }
                j += 16;
            }
            if j < b {
                $fb(&cols[j * stride..], stride, wrow, k, &mut out[j..]);
            }
        }

        pub(super) fn $wide(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i64],
        ) {
            // SAFETY: installed behind runtime AVX-512 detection
            unsafe { $wide_impl(cols, stride, wrow, k, out) }
        }

        #[target_feature(enable = "avx512f")]
        unsafe fn $wide_impl(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i64],
        ) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let b = out.len();
            let full = k / LANES;
            let xmask = _mm512_set1_epi64(XMASK as i64);
            let mut j = 0;
            while j + 8 <= b {
                let base = j * stride;
                let mut acc = _mm512_setzero_si512();
                for i in 0..full {
                    let ww = load_le(wrow, i * WSTEP, WSTEP);
                    let xoff = base + i * XSTEP;
                    let xv = _mm512_set_epi64(
                        load_le(cols, xoff + 7 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 6 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 5 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 4 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 3 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 2 * stride, XSTEP) as i64,
                        load_le(cols, xoff + stride, XSTEP) as i64,
                        load_le(cols, xoff, XSTEP) as i64,
                    );
                    for lane in 0..LANES as u32 {
                        let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW);
                        let x = _mm512_and_si512(
                            _mm512_srl_epi64(xv, _mm_cvtsi32_si128((lane * PX) as i32)),
                            xmask,
                        );
                        // mul_epi32: low-32 sign-extended multiply per
                        // 64-bit lane — exact, as in the AVX2 tier
                        acc = _mm512_add_epi64(
                            acc,
                            _mm512_mul_epi32(x, _mm512_set1_epi64(w as i64)),
                        );
                    }
                }
                let mut sums = [0i64; 8];
                _mm512_storeu_epi64(sums.as_mut_ptr(), acc);
                for (t, s) in sums.iter().enumerate() {
                    let mut a = *s;
                    let col = &cols[(j + t) * stride..];
                    for jj in full * LANES..k {
                        a += extract_code(col, jj, PX) as i64
                            * extract_weight(wrow, jj, PW) as i64;
                    }
                    out[j + t] = a;
                }
                j += 8;
            }
            if j < b {
                $fbw(&cols[j * stride..], stride, wrow, k, &mut out[j..]);
            }
        }
    };
}

avx512_kernel!(b_x2_w2, b_x2_w2_impl, wb_x2_w2, wb_x2_w2_impl, 2, 2, avx2::b_x2_w2, avx2::wb_x2_w2);
avx512_kernel!(b_x2_w4, b_x2_w4_impl, wb_x2_w4, wb_x2_w4_impl, 2, 4, avx2::b_x2_w4, avx2::wb_x2_w4);
avx512_kernel!(b_x2_w8, b_x2_w8_impl, wb_x2_w8, wb_x2_w8_impl, 2, 8, avx2::b_x2_w8, avx2::wb_x2_w8);
avx512_kernel!(b_x4_w2, b_x4_w2_impl, wb_x4_w2, wb_x4_w2_impl, 4, 2, avx2::b_x4_w2, avx2::wb_x4_w2);
avx512_kernel!(b_x4_w4, b_x4_w4_impl, wb_x4_w4, wb_x4_w4_impl, 4, 4, avx2::b_x4_w4, avx2::wb_x4_w4);
avx512_kernel!(b_x4_w8, b_x4_w8_impl, wb_x4_w8, wb_x4_w8_impl, 4, 8, avx2::b_x4_w8, avx2::wb_x4_w8);
avx512_kernel!(b_x8_w2, b_x8_w2_impl, wb_x8_w2, wb_x8_w2_impl, 8, 2, avx2::b_x8_w2, avx2::wb_x8_w2);
avx512_kernel!(b_x8_w4, b_x8_w4_impl, wb_x8_w4, wb_x8_w4_impl, 8, 4, avx2::b_x8_w4, avx2::wb_x8_w4);
avx512_kernel!(b_x8_w8, b_x8_w8_impl, wb_x8_w8, wb_x8_w8_impl, 8, 8, avx2::b_x8_w8, avx2::wb_x8_w8);

pub(super) const KERNELS_BATCH: [[RowDotBatch; 3]; 3] = [
    [b_x2_w2, b_x2_w4, b_x2_w8],
    [b_x4_w2, b_x4_w4, b_x4_w8],
    [b_x8_w2, b_x8_w4, b_x8_w8],
];

pub(super) const KERNELS_WIDE_BATCH: [[RowDotWideBatch; 3]; 3] = [
    [wb_x2_w2, wb_x2_w4, wb_x2_w8],
    [wb_x4_w2, wb_x4_w4, wb_x4_w8],
    [wb_x8_w2, wb_x8_w4, wb_x8_w8],
];
