//! Runtime-dispatched x86 vector kernels for the `simd` backend.
//!
//! The SWAR cells in `engine::backend` decode packed sub-byte operands
//! one 32-bit register at a time — MPIC's `sdotp` modeled in scalar
//! code.  This module keeps that decode structure but turns the
//! **batch axis into the vector axis**: one AVX2 register holds eight
//! samples' i32 accumulators (four i64 on the FC path; AVX-512 doubles
//! both), each fetched-and-decoded weight lane is broadcast and ridden
//! across all of them, and per sample the accumulation order (register
//! ascending, lane ascending, then the scalar tail) is exactly the
//! SWAR order — so every tier is bit-identical to the `reference`
//! oracle by construction, not by tolerance.
//!
//! **Tier selection happens once per process** ([`active`]): the
//! highest of AVX-512 → AVX2 → SWAR that
//! `is_x86_feature_detected!` confirms, overridable with
//! `CWMIX_SIMD=off|avx2|avx512|auto` (CI runs the equivalence suites
//! under both `auto` and `off` so the scalar fallback stays exercised
//! on vector-capable runners).  A vector kernel is only ever installed
//! in the active tables *after* its feature bit was detected — that
//! runtime proof is the safety argument for every `unsafe` intrinsic
//! block below.  Non-x86 hosts always resolve to the SWAR tier, which
//! aliases the `engine::backend` batch tables verbatim.
//!
//! **No over-read, by construction.**  The FC path hands kernels
//! zero-copy packed planes whose last column ends flush at the buffer
//! end, so the vector kernels never issue wide loads over column data:
//! they assemble registers from bounds-checked scalar `load_le`
//! fetches (exactly `XSTEP ≤ 4` bytes each) and vectorize only the
//! multiply-accumulate.  Ragged batch remainders cascade down one tier
//! (AVX-512 → AVX2 → SWAR) on a column sub-slice, which preserves
//! per-column accumulation order trivially.

use std::sync::OnceLock;

use super::backend::{RowDotBatch, RowDotWideBatch, DOT_KERNELS_BATCH, DOT_KERNELS_WIDE_BATCH};

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "x86_64")]
mod avx512;

/// One dispatch tier.  Ordered by preference; `auto` picks the highest
/// the CPU supports.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Tier {
    /// universal fallback: the scalar SWAR batch cells
    Swar,
    /// 256-bit: 8 samples/register (i32), 4 (i64)
    Avx2,
    /// 512-bit: 16 samples/register (i32), 8 (i64)
    Avx512,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Swar => "swar",
            Tier::Avx2 => "avx2",
            Tier::Avx512 => "avx512",
        }
    }
}

/// The kernel tables of one tier, indexed like the SWAR tables:
/// `[precision_index(p_x)][precision_index(p_w)]`.
pub(in crate::engine) struct Tables {
    pub(in crate::engine) tier: Tier,
    pub(in crate::engine) batch: &'static [[RowDotBatch; 3]; 3],
    pub(in crate::engine) wide_batch: &'static [[RowDotWideBatch; 3]; 3],
}

static SWAR_TABLES: Tables = Tables {
    tier: Tier::Swar,
    batch: &DOT_KERNELS_BATCH,
    wide_batch: &DOT_KERNELS_WIDE_BATCH,
};

#[cfg(target_arch = "x86_64")]
static AVX2_TABLES: Tables = Tables {
    tier: Tier::Avx2,
    batch: &avx2::KERNELS_BATCH,
    wide_batch: &avx2::KERNELS_WIDE_BATCH,
};

#[cfg(target_arch = "x86_64")]
static AVX512_TABLES: Tables = Tables {
    tier: Tier::Avx512,
    batch: &avx512::KERNELS_BATCH,
    wide_batch: &avx512::KERNELS_WIDE_BATCH,
};

/// Pure tier policy, separated from detection + env so it unit-tests
/// without process-global state: `env` is the `CWMIX_SIMD` value,
/// `avx2`/`avx512` the detection results.  Returns the tier and an
/// optional warning (requested tier unavailable / unknown value).
/// A tier is only ever *granted* when its feature bit is true — the
/// override can force a lower tier, never fake a higher one.
fn tier_from(env: Option<&str>, avx2: bool, avx512: bool) -> (Tier, Option<String>) {
    let auto = || {
        if avx512 && avx2 {
            Tier::Avx512
        } else if avx2 {
            Tier::Avx2
        } else {
            Tier::Swar
        }
    };
    match env {
        None | Some("") | Some("auto") => (auto(), None),
        Some("off") | Some("swar") => (Tier::Swar, None),
        Some("avx2") => {
            if avx2 {
                (Tier::Avx2, None)
            } else {
                (
                    Tier::Swar,
                    Some("CWMIX_SIMD=avx2: AVX2 not detected, using swar".into()),
                )
            }
        }
        Some("avx512") => {
            if avx512 && avx2 {
                (Tier::Avx512, None)
            } else {
                let (t, _) = tier_from(None, avx2, false);
                (
                    t,
                    Some(format!(
                        "CWMIX_SIMD=avx512: AVX-512 not detected, using {}",
                        t.name()
                    )),
                )
            }
        }
        Some(other) => (
            auto(),
            Some(format!(
                "CWMIX_SIMD={other:?} not recognized (off|avx2|avx512|auto), using auto"
            )),
        ),
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> (bool, bool) {
    (
        is_x86_feature_detected!("avx2"),
        is_x86_feature_detected!("avx512f"),
    )
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> (bool, bool) {
    (false, false)
}

fn tables_for(tier: Tier) -> &'static Tables {
    match tier {
        Tier::Swar => &SWAR_TABLES,
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => &AVX2_TABLES,
        #[cfg(target_arch = "x86_64")]
        Tier::Avx512 => &AVX512_TABLES,
        // tier_from never grants a vector tier without its feature bit,
        // and detection is compile-time false off x86
        #[cfg(not(target_arch = "x86_64"))]
        _ => &SWAR_TABLES,
    }
}

/// The process-wide active tier tables: detection + `CWMIX_SIMD` are
/// consulted exactly once, at the first model load, and every kernel
/// built afterwards shares the result — a plan's tier can never change
/// under it.
pub(in crate::engine) fn active() -> &'static Tables {
    static ACTIVE: OnceLock<&'static Tables> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let (avx2, avx512) = detect();
        let (tier, warning) = tier_from(std::env::var("CWMIX_SIMD").ok().as_deref(), avx2, avx512);
        if let Some(w) = warning {
            eprintln!("cwmix: {w}");
        }
        tables_for(tier)
    })
}

/// Name of the tier [`active`] resolved (or would resolve) to.
pub fn active_tier_name() -> &'static str {
    active().tier.name()
}

/// Every tier runnable on this host, for the exactness suites: SWAR
/// always, plus each vector tier whose feature bit is detected —
/// independent of `CWMIX_SIMD`, so the suites cover tiers the override
/// disabled for dispatch.
#[cfg(test)]
pub(in crate::engine) fn available_tables() -> Vec<&'static Tables> {
    let mut v = vec![&SWAR_TABLES];
    #[cfg(target_arch = "x86_64")]
    {
        let (avx2, avx512) = detect();
        if avx2 {
            v.push(&AVX2_TABLES);
        }
        if avx2 && avx512 {
            v.push(&AVX512_TABLES);
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_picks_highest_detected_tier() {
        assert_eq!(tier_from(None, false, false).0, Tier::Swar);
        assert_eq!(tier_from(None, true, false).0, Tier::Avx2);
        assert_eq!(tier_from(None, true, true).0, Tier::Avx512);
        assert_eq!(tier_from(Some("auto"), true, true).0, Tier::Avx512);
        assert_eq!(tier_from(Some(""), true, false).0, Tier::Avx2);
        // avx512 bit without avx2 never happens on real silicon, but
        // the policy must not grant a tier whose kernels cascade to it
        assert_eq!(tier_from(None, false, true).0, Tier::Swar);
    }

    #[test]
    fn off_forces_swar_everywhere() {
        for (a2, a512) in [(false, false), (true, false), (true, true)] {
            let (tier, warn) = tier_from(Some("off"), a2, a512);
            assert_eq!(tier, Tier::Swar);
            assert!(warn.is_none());
        }
        assert_eq!(tier_from(Some("swar"), true, true).0, Tier::Swar);
    }

    #[test]
    fn forced_tier_granted_only_when_detected() {
        assert_eq!(tier_from(Some("avx2"), true, true).0, Tier::Avx2);
        let (tier, warn) = tier_from(Some("avx2"), false, false);
        assert_eq!(tier, Tier::Swar);
        assert!(warn.unwrap().contains("not detected"));
        assert_eq!(tier_from(Some("avx512"), true, true).0, Tier::Avx512);
        let (tier, warn) = tier_from(Some("avx512"), true, false);
        assert_eq!(tier, Tier::Avx2);
        assert!(warn.unwrap().contains("avx2"));
    }

    #[test]
    fn unknown_value_warns_and_falls_back_to_auto() {
        let (tier, warn) = tier_from(Some("neon"), true, false);
        assert_eq!(tier, Tier::Avx2);
        assert!(warn.unwrap().contains("neon"));
    }

    #[test]
    fn active_tier_is_consistent_and_named() {
        // whatever the host + env resolve to, the name round-trips and
        // the tables carry the matching tier tag
        let t = active();
        assert_eq!(t.tier.name(), active_tier_name());
        assert!(["swar", "avx2", "avx512"].contains(&active_tier_name()));
    }

    #[test]
    fn available_tables_start_with_swar() {
        let tables = available_tables();
        assert_eq!(tables[0].tier, Tier::Swar);
        // tiers are listed in ascending width order, no duplicates
        for pair in tables.windows(2) {
            assert!((pair[0].tier as u8) < (pair[1].tier as u8));
        }
    }
}
