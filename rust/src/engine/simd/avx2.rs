//! AVX2 tier: eight samples per `i32` register, four per `i64`.
//!
//! Each kernel is the weight-stationary SWAR batch cell with the batch
//! axis vectorized.  Column words are assembled with bounds-checked
//! scalar [`load_le`] fetches (never a wide load — the zero-copy FC
//! planes end flush at the buffer end), weight lanes are sign-decoded
//! scalar once per register and broadcast, and only the
//! multiply-accumulate runs vector-wide.  Per sample the accumulation
//! order (register ascending, lane ascending, scalar tail) is the SWAR
//! order, and `i32`/`i64` adds are exact, so every result is
//! bit-identical to the scalar cell.  Ragged batch remainders
//! (`B mod 8` / `B mod 4` columns) cascade to the SWAR cell on a
//! column sub-slice.

use std::arch::x86_64::*;

use crate::engine::backend::{
    extract_code, extract_weight, load_le, sext, RowDotBatch, RowDotWideBatch,
    DOT_KERNELS_BATCH as SWAR_BATCH, DOT_KERNELS_WIDE_BATCH as SWAR_WIDE_BATCH,
};
use crate::precision_index;

/// Generates one `(p_x, p_w)` AVX2 cell pair: the batched `i32` dot
/// (8 columns per `__m256i`) and the batched `i64` dot (4 columns).
/// The safe wrappers are what the dispatch tables hold; the `unsafe`
/// inner fns are only reachable through tables that `engine::simd`
/// installs after `is_x86_feature_detected!("avx2")` returned true.
macro_rules! avx2_kernel {
    ($batch:ident, $batch_impl:ident, $wide:ident, $wide_impl:ident,
     $px:literal, $pw:literal) => {
        pub(super) fn $batch(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i32],
        ) {
            // SAFETY: installed behind runtime AVX2 detection (module doc)
            unsafe { $batch_impl(cols, stride, wrow, k, out) }
        }

        #[target_feature(enable = "avx2")]
        unsafe fn $batch_impl(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i32],
        ) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let b = out.len();
            let full = k / LANES;
            let xmask = _mm256_set1_epi32(XMASK as i32);
            let mut j = 0;
            while j + 8 <= b {
                let base = j * stride;
                let mut acc = _mm256_setzero_si256();
                for i in 0..full {
                    let ww = load_le(wrow, i * WSTEP, WSTEP);
                    let xoff = base + i * XSTEP;
                    let xv = _mm256_set_epi32(
                        load_le(cols, xoff + 7 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 6 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 5 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 4 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 3 * stride, XSTEP) as i32,
                        load_le(cols, xoff + 2 * stride, XSTEP) as i32,
                        load_le(cols, xoff + stride, XSTEP) as i32,
                        load_le(cols, xoff, XSTEP) as i32,
                    );
                    for lane in 0..LANES as u32 {
                        let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW);
                        let x = _mm256_and_si256(
                            _mm256_srl_epi32(xv, _mm_cvtsi32_si128((lane * PX) as i32)),
                            xmask,
                        );
                        acc = _mm256_add_epi32(
                            acc,
                            _mm256_mullo_epi32(x, _mm256_set1_epi32(w)),
                        );
                    }
                }
                let mut sums = [0i32; 8];
                _mm256_storeu_si256(sums.as_mut_ptr() as *mut __m256i, acc);
                for (t, s) in sums.iter().enumerate() {
                    let mut a = *s;
                    let col = &cols[(j + t) * stride..];
                    for jj in full * LANES..k {
                        a += extract_code(col, jj, PX) as i32 * extract_weight(wrow, jj, PW);
                    }
                    out[j + t] = a;
                }
                j += 8;
            }
            if j < b {
                SWAR_BATCH[precision_index(PX)][precision_index(PW)](
                    &cols[j * stride..],
                    stride,
                    wrow,
                    k,
                    &mut out[j..],
                );
            }
        }

        pub(super) fn $wide(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i64],
        ) {
            // SAFETY: installed behind runtime AVX2 detection (module doc)
            unsafe { $wide_impl(cols, stride, wrow, k, out) }
        }

        #[target_feature(enable = "avx2")]
        unsafe fn $wide_impl(
            cols: &[u8],
            stride: usize,
            wrow: &[u8],
            k: usize,
            out: &mut [i64],
        ) {
            const PX: u32 = $px;
            const PW: u32 = $pw;
            const LANES: usize = (32 / if PX > PW { PX } else { PW }) as usize;
            const XSTEP: usize = LANES * PX as usize / 8;
            const WSTEP: usize = LANES * PW as usize / 8;
            const XMASK: u32 = (1u32 << PX) - 1;
            const WMASK: u32 = (1u32 << PW) - 1;
            let b = out.len();
            let full = k / LANES;
            let xmask = _mm256_set1_epi64x(XMASK as i64);
            let mut j = 0;
            while j + 4 <= b {
                let base = j * stride;
                let mut acc = _mm256_setzero_si256();
                for i in 0..full {
                    let ww = load_le(wrow, i * WSTEP, WSTEP);
                    let xoff = base + i * XSTEP;
                    let xv = _mm256_set_epi64x(
                        load_le(cols, xoff + 3 * stride, XSTEP) as i64,
                        load_le(cols, xoff + 2 * stride, XSTEP) as i64,
                        load_le(cols, xoff + stride, XSTEP) as i64,
                        load_le(cols, xoff, XSTEP) as i64,
                    );
                    for lane in 0..LANES as u32 {
                        let w = sext(((ww >> (lane * PW)) & WMASK) as i32, PW);
                        let x = _mm256_and_si256(
                            _mm256_srl_epi64(xv, _mm_cvtsi32_si128((lane * PX) as i32)),
                            xmask,
                        );
                        // mul_epi32 sign-extends each 64-bit lane's low
                        // 32 bits: x < 2^8 stays positive, w keeps its
                        // sign — the product is exact in i64
                        acc = _mm256_add_epi64(
                            acc,
                            _mm256_mul_epi32(x, _mm256_set1_epi64x(w as i64)),
                        );
                    }
                }
                let mut sums = [0i64; 4];
                _mm256_storeu_si256(sums.as_mut_ptr() as *mut __m256i, acc);
                for (t, s) in sums.iter().enumerate() {
                    let mut a = *s;
                    let col = &cols[(j + t) * stride..];
                    for jj in full * LANES..k {
                        a += extract_code(col, jj, PX) as i64
                            * extract_weight(wrow, jj, PW) as i64;
                    }
                    out[j + t] = a;
                }
                j += 4;
            }
            if j < b {
                SWAR_WIDE_BATCH[precision_index(PX)][precision_index(PW)](
                    &cols[j * stride..],
                    stride,
                    wrow,
                    k,
                    &mut out[j..],
                );
            }
        }
    };
}

avx2_kernel!(b_x2_w2, b_x2_w2_impl, wb_x2_w2, wb_x2_w2_impl, 2, 2);
avx2_kernel!(b_x2_w4, b_x2_w4_impl, wb_x2_w4, wb_x2_w4_impl, 2, 4);
avx2_kernel!(b_x2_w8, b_x2_w8_impl, wb_x2_w8, wb_x2_w8_impl, 2, 8);
avx2_kernel!(b_x4_w2, b_x4_w2_impl, wb_x4_w2, wb_x4_w2_impl, 4, 2);
avx2_kernel!(b_x4_w4, b_x4_w4_impl, wb_x4_w4, wb_x4_w4_impl, 4, 4);
avx2_kernel!(b_x4_w8, b_x4_w8_impl, wb_x4_w8, wb_x4_w8_impl, 4, 8);
avx2_kernel!(b_x8_w2, b_x8_w2_impl, wb_x8_w2, wb_x8_w2_impl, 8, 2);
avx2_kernel!(b_x8_w4, b_x8_w4_impl, wb_x8_w4, wb_x8_w4_impl, 8, 4);
avx2_kernel!(b_x8_w8, b_x8_w8_impl, wb_x8_w8, wb_x8_w8_impl, 8, 8);

pub(super) const KERNELS_BATCH: [[RowDotBatch; 3]; 3] = [
    [b_x2_w2, b_x2_w4, b_x2_w8],
    [b_x4_w2, b_x4_w4, b_x4_w8],
    [b_x8_w2, b_x8_w4, b_x8_w8],
];

pub(super) const KERNELS_WIDE_BATCH: [[RowDotWideBatch; 3]; 3] = [
    [wb_x2_w2, wb_x2_w4, wb_x2_w8],
    [wb_x4_w2, wb_x4_w4, wb_x4_w8],
    [wb_x8_w2, wb_x8_w4, wb_x8_w8],
];
