//! Minimal pure-`std` HTTP/1.1 front end for the inference server.
//!
//! `std::net::TcpListener` + a thread per connection (bounded by
//! `max_conns`), keep-alive, `Content-Length` bodies only (no chunked
//! encoding — clients here are load generators and simple SDKs), JSON
//! in and out through [`minijson`](crate::minijson).  No new
//! dependencies, in the spirit of the pure-Rust-JSON decision the
//! coordinator already made for manifests and result stores.
//!
//! Routes:
//!
//! * `POST /v1/infer/<bench>` — body `{"input": [f32; feat]}`; replies
//!   `{"model", "batch", "output"}` where `batch` is the micro-batch
//!   size the request rode in.  Error mapping: `503` shed / shutting
//!   down / breaker open (the latter with `Retry-After`), `504`
//!   deadline exceeded, `500` engine error or crashed worker.
//! * `GET /healthz` — liveness: 200 while the process serves HTTP at
//!   all.
//! * `GET /readyz` — readiness: 200 while at least one model's
//!   circuit breaker admits traffic; per-model breaker detail in the
//!   body; 503 once shutdown begins (load balancers drain first).
//! * `GET /v1/models` — registry description.
//! * `GET /metrics` — per-model + total counters, p50/p99/p99.9
//!   latency, batch-size histogram, shed count, kernel dispatch gauges
//!   (backend + SIMD tier), supervision gauges (worker respawns,
//!   breaker state, deadline expiries, slow-client closes, injected
//!   write stalls).  `?format=prometheus` returns the same data as
//!   Prometheus text exposition (`cwmix_*` families, `model` labels).
//! * `GET /v1/trace?last=N` — the newest `N` recorded spans as
//!   chrome://tracing JSON ([`crate::trace::export_last`]); empty
//!   unless tracing is enabled (`--trace` / `CWMIX_TRACE=1`).
//! * `POST /admin/shutdown` — begin a clean shutdown: stop accepting,
//!   drain batchers, join workers.
//!
//! Every infer request is stamped with a process-unique **request id**
//! at admission; the id is returned in the reply body
//! (`"request_id"`), keys all of the request's trace spans, appears in
//! the supervisor's panic log line if a worker dies with the request
//! in flight, and is emitted in a `key=value` per-request log line
//! (5xx always, except 503 shed storms; others sampled via
//! `CWMIX_LOG_SAMPLE=N`, default off).
//!
//! **Failure containment:** every socket has a read *and* write
//! timeout, so a peer that stops reading (or trickles a request) is
//! classified — mid-request stalls count as `slow_client_closes`, idle
//! keep-alive expiries as `idle_reaped` — and its thread reclaimed.
//! A request already in flight when shutdown lands still gets its
//! reply (drain-then-close; see `handle_connection`).
//!
//! Request parsing is factored over `io::Read`
//! ([`HttpReader`]) so the grammar is unit-testable without sockets;
//! oversized headers/bodies and malformed framing map to 4xx replies,
//! never panics (`minijson` is hardened against malformed bodies for
//! the same reason).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::minijson::{parse_bytes, Json};
use crate::trace::{self, SpanName};

use super::batcher::{ReplyError, SubmitError};
use super::faults::Faults;
use super::metrics::{self, Metrics};
use super::registry::ModelRegistry;
use super::supervisor::BreakerState;

/// Front-end configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 lets the OS pick (the bound port is in
    /// [`Server::addr`]).
    pub addr: String,
    /// Reject request bodies larger than this (HTTP 413).
    pub max_body_bytes: usize,
    /// Concurrent connections; excess gets an immediate 503.
    pub max_conns: usize,
    /// Per-connection read timeout (idle keep-alive reaper; also the
    /// trickle-request bound).
    pub read_timeout: Duration,
    /// Per-connection write timeout: a peer that stops reading cannot
    /// hold a handler thread past this.
    pub write_timeout: Duration,
    /// Fault-injection plan (disarmed by default; `slow_socket` and
    /// `write_stall` fire here).
    pub faults: Arc<Faults>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8080".to_string(),
            max_body_bytes: 1 << 20,
            max_conns: 64,
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            faults: Faults::disarmed(),
        }
    }
}

const MAX_HEADER_BYTES: usize = 16 << 10;

/// Slack on top of the batcher's own deadline window
/// (`max_wait + infer_budget`) before the HTTP handler gives up on a
/// reply.  The batcher answers expired requests itself at dequeue, so
/// this ceiling only trips when the worker is wedged mid-respawn — it
/// degrades to a 504 instead of a permanently wedged connection.
const REPLY_TIMEOUT_SLACK: Duration = Duration::from_secs(10);

/// Once shutdown begins, a handler gives the peer this long to finish
/// writing a request already in flight before closing (drain-then-close).
const SHUTDOWN_DRAIN_WINDOW: Duration = Duration::from_millis(100);

/// Post-error drain bound (see [`HttpReader::drain`]): covers honest
/// clients that overshot `max_body_bytes` by a lot; a peer announcing
/// gigabytes past this may still see an RST instead of the 4xx.
const DRAIN_BYTES: usize = 8 << 20;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    pub close: bool,
}

/// A framing/protocol error that maps to an HTTP status.
#[derive(Debug)]
pub enum HttpError {
    /// Connection-level I/O failure or clean EOF mid-request.
    Io(io::Error),
    /// Protocol violation: (status, message) to send before closing.
    Bad(u16, String),
}

impl From<io::Error> for HttpError {
    fn from(e: io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

/// A framed message before request/response-specific parsing.
struct RawMessage {
    start_line: String,
    body: Vec<u8>,
    /// `Connection:` header, if present.
    close: Option<bool>,
}

/// Buffered HTTP/1.1 request reader with keep-alive carry-over.
pub struct HttpReader<R: Read> {
    r: R,
    buf: Vec<u8>,
    max_body: usize,
}

impl<R: Read> HttpReader<R> {
    pub fn new(r: R, max_body: usize) -> HttpReader<R> {
        HttpReader { r, buf: Vec::with_capacity(4096), max_body }
    }

    /// Read one request.  `Ok(None)` = clean EOF between requests (the
    /// peer closed an idle keep-alive connection).
    pub fn next_request(&mut self) -> Result<Option<Request>, HttpError> {
        let Some(msg) = self.next_message()? else { return Ok(None) };
        let mut parts = msg.start_line.split(' ');
        let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v)) if !m.is_empty() && p.starts_with('/') => {
                (m.to_string(), p.to_string(), v)
            }
            _ => {
                return Err(HttpError::Bad(
                    400,
                    format!("malformed request line {:?}", msg.start_line),
                ))
            }
        };
        if version != "HTTP/1.1" && version != "HTTP/1.0" {
            return Err(HttpError::Bad(505, format!("unsupported {version}")));
        }
        let close = msg.close.unwrap_or(version == "HTTP/1.0");
        Ok(Some(Request { method, path, body: msg.body, close }))
    }

    /// Read one *response* (status line instead of request line) —
    /// the framing half the loopback client reuses.
    pub fn next_response(&mut self) -> Result<Option<(u16, Vec<u8>)>, HttpError> {
        let Some(msg) = self.next_message()? else { return Ok(None) };
        let mut parts = msg.start_line.split(' ');
        let status = match (parts.next(), parts.next()) {
            (Some(v), Some(code)) if v.starts_with("HTTP/") => {
                code.parse::<u16>().map_err(|_| {
                    HttpError::Bad(400, format!("bad status line {:?}", msg.start_line))
                })?
            }
            _ => {
                return Err(HttpError::Bad(
                    400,
                    format!("bad status line {:?}", msg.start_line),
                ))
            }
        };
        Ok(Some((status, msg.body)))
    }

    /// Shared framing: start line + headers + `Content-Length` body.
    fn next_message(&mut self) -> Result<Option<RawMessage>, HttpError> {
        let header_end = loop {
            if let Some(pos) = find_subslice(&self.buf, b"\r\n\r\n") {
                break pos + 4;
            }
            if self.buf.len() > MAX_HEADER_BYTES {
                return Err(HttpError::Bad(431, "header too large".into()));
            }
            if self.fill()? == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                return Err(HttpError::Io(io::ErrorKind::UnexpectedEof.into()));
            }
        };
        let head = std::str::from_utf8(&self.buf[..header_end])
            .map_err(|_| HttpError::Bad(400, "non-UTF-8 header".into()))?;
        let mut lines = head.split("\r\n");
        let start_line = lines.next().unwrap_or("").to_string();
        let mut content_length = 0usize;
        let mut close = None;
        for line in lines {
            let Some((k, v)) = line.split_once(':') else { continue };
            let v = v.trim();
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .parse()
                    .map_err(|_| HttpError::Bad(400, format!("bad content-length {v:?}")))?;
            } else if k.eq_ignore_ascii_case("connection") {
                close = Some(v.eq_ignore_ascii_case("close"));
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                return Err(HttpError::Bad(501, "chunked bodies unsupported".into()));
            }
        }
        if content_length > self.max_body {
            return Err(HttpError::Bad(
                413,
                format!("body {content_length} B > limit {} B", self.max_body),
            ));
        }
        self.buf.drain(..header_end);
        while self.buf.len() < content_length {
            if self.fill()? == 0 {
                return Err(HttpError::Io(io::ErrorKind::UnexpectedEof.into()));
            }
        }
        let body: Vec<u8> = self.buf.drain(..content_length).collect();
        Ok(Some(RawMessage { start_line, body, close }))
    }

    fn fill(&mut self) -> io::Result<usize> {
        let mut chunk = [0u8; 4096];
        let n = self.r.read(&mut chunk)?;
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(n)
    }

    /// True when a request is partially buffered — a read timeout now
    /// means a *slow client* (started a request, stopped sending), not
    /// an idle keep-alive connection.
    pub fn mid_request(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Best-effort read-and-discard of up to `max` bytes (stops at
    /// EOF or any error, including the read timeout).  Closing a
    /// socket with unread data makes the kernel send RST, which can
    /// destroy an in-flight error reply before the peer reads it —
    /// draining first keeps 4xx replies deliverable.
    pub fn drain(&mut self, max: usize) {
        let mut sink = [0u8; 4096];
        let mut left = max;
        while left > 0 {
            match self.r.read(&mut sink) {
                Ok(0) | Err(_) => return,
                Ok(n) => left = left.saturating_sub(n),
            }
        }
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Serialize one JSON response; `retry_after` adds a `Retry-After`
/// header (seconds) — the breaker's 503s carry one.
fn write_response(
    w: &mut impl Write,
    status: u16,
    body: &Json,
    close: bool,
    retry_after: Option<u64>,
) -> io::Result<()> {
    let body = body.dumps();
    let conn = if close { "close" } else { "keep-alive" };
    let retry = match retry_after {
        Some(s) => format!("Retry-After: {s}\r\n"),
        None => String::new(),
    };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n{retry}\r\n{body}",
        status_reason(status),
        body.len(),
    )?;
    w.flush()
}

/// Serialize one plain-text response (the Prometheus exposition).
fn write_text(w: &mut impl Write, status: u16, text: &str, close: bool) -> io::Result<()> {
    let conn = if close { "close" } else { "keep-alive" };
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{text}",
        status_reason(status),
        text.len(),
    )?;
    w.flush()
}

/// Serialize a dispatched reply of either body kind.
fn write_reply(
    w: &mut impl Write,
    status: u16,
    body: &Body,
    close: bool,
    retry_after: Option<u64>,
) -> io::Result<()> {
    match body {
        Body::Json(j) => write_response(w, status, j, close, retry_after),
        Body::Text(t) => write_text(w, status, t, close),
    }
}

fn err_body(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

struct ServerState {
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    /// the bound address — the shutdown path pokes it to unblock accept()
    addr: SocketAddr,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
    started: Instant,
    /// Connections closed on a peer that went quiet *mid-request* or
    /// stopped reading its reply (the slow-client reaper).
    slow_client_closes: AtomicU64,
    /// Idle keep-alive connections reaped by the read timeout.
    idle_reaped: AtomicU64,
    /// Replies deliberately stalled mid-write by the `write_stall`
    /// failpoint (each one also forces `Connection: close`).
    write_stalls: AtomicU64,
}

/// A running server: accept loop + handler threads.
pub struct Server {
    addr: SocketAddr,
    state: Arc<ServerState>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

/// Bind and start serving `registry` under `cfg`.
pub fn serve(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let state = Arc::new(ServerState {
        registry,
        cfg,
        addr,
        shutdown: AtomicBool::new(false),
        active_conns: AtomicUsize::new(0),
        started: Instant::now(),
        slow_client_closes: AtomicU64::new(0),
        idle_reaped: AtomicU64::new(0),
        write_stalls: AtomicU64::new(0),
    });
    let accept_state = Arc::clone(&state);
    let acceptor = std::thread::Builder::new()
        .name("cwmix-accept".into())
        .spawn(move || accept_loop(&listener, &accept_state))
        .context("spawning acceptor")?;
    Ok(Server { addr, state, acceptor: Some(acceptor) })
}

impl Server {
    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a shutdown has been requested.
    pub fn shutting_down(&self) -> bool {
        self.state.shutdown.load(Ordering::Acquire)
    }

    /// Request a clean shutdown (as `POST /admin/shutdown` does).
    pub fn request_shutdown(&self) {
        self.state.shutdown.store(true, Ordering::Release);
        // poke the blocking accept() so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
    }

    /// Block until the server has shut down cleanly: acceptor joined,
    /// in-flight connections drained (bounded wait), batchers stopped.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // bounded drain: handlers hold keep-alive conns at most
        // read_timeout; allow that plus slack, then give up and report
        let deadline = Instant::now() + self.state.cfg.read_timeout + Duration::from_secs(2);
        while self.state.active_conns.load(Ordering::Acquire) > 0 {
            if Instant::now() > deadline {
                anyhow::bail!(
                    "{} connection(s) still active at shutdown",
                    self.state.active_conns.load(Ordering::Acquire)
                );
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.state.registry.shutdown();
        Ok(())
    }

    /// [`Self::request_shutdown`] + [`Self::join`] — test convenience.
    pub fn stop(self) -> Result<()> {
        self.request_shutdown();
        self.join()
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<ServerState>) {
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // e.g. EMFILE under fd exhaustion: back off instead of
                // hot-looping the acceptor while handlers hold the fds
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if state.active_conns.load(Ordering::Acquire) >= state.cfg.max_conns {
            // over the connection cap: shed at the door
            let mut s = stream;
            let _ =
                write_response(&mut s, 503, &err_body("too many connections"), true, None);
            continue;
        }
        state.active_conns.fetch_add(1, Ordering::AcqRel);
        let conn_state = Arc::clone(state);
        let res = std::thread::Builder::new()
            .name("cwmix-conn".into())
            .spawn(move || {
                handle_connection(stream, &conn_state);
                conn_state.active_conns.fetch_sub(1, Ordering::AcqRel);
            });
        if res.is_err() {
            state.active_conns.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn handle_connection(stream: TcpStream, state: &Arc<ServerState>) {
    let _ = stream.set_read_timeout(Some(state.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(state.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = HttpReader::new(stream, state.cfg.max_body_bytes);
    let mut draining = false;
    loop {
        if state.shutdown.load(Ordering::Acquire) && !draining {
            // drain-then-close: shutdown must not drop a request the
            // peer already sent (or is about to finish sending).  Give
            // one short read window to pick it up, answer it with
            // `Connection: close`, then leave.  The clones share one
            // socket, so the writer sets the reader's timeout too.
            draining = true;
            let _ = writer.set_read_timeout(Some(SHUTDOWN_DRAIN_WINDOW));
        }
        match reader.next_request() {
            Ok(Some(req)) => {
                if let Some(d) = state.cfg.faults.slow_socket() {
                    // injected network latency (fault plan)
                    std::thread::sleep(d);
                }
                let (status, body, retry_after) = route(state, &req);
                let stall = state.cfg.faults.write_stall();
                let close = req.close
                    || draining
                    || stall.is_some()
                    || state.shutdown.load(Ordering::Acquire);
                let res = match stall {
                    Some(d) => {
                        // fault plan: flush half the serialized reply,
                        // stall, then finish — the bytes on the wire
                        // must still frame one intact response, and the
                        // forced close keeps the stalled writer from
                        // pinning a keep-alive slot
                        state.write_stalls.fetch_add(1, Ordering::Relaxed);
                        let mut bytes = Vec::new();
                        write_reply(&mut bytes, status, &body, close, retry_after)
                            .expect("Vec writes are infallible");
                        let split = bytes.len() / 2;
                        writer
                            .write_all(&bytes[..split])
                            .and_then(|()| writer.flush())
                            .and_then(|()| {
                                std::thread::sleep(d);
                                writer.write_all(&bytes[split..])
                            })
                            .and_then(|()| writer.flush())
                    }
                    None => write_reply(&mut writer, status, &body, close, retry_after),
                };
                match res {
                    Ok(()) if !close => {}
                    Ok(()) => break,
                    Err(e) => {
                        if is_timeout(&e) {
                            // peer stopped reading its reply
                            state.slow_client_closes.fetch_add(1, Ordering::Relaxed);
                        }
                        break;
                    }
                }
            }
            Ok(None) => break, // peer closed an idle connection
            Err(HttpError::Bad(status, msg)) => {
                // protocol errors close the connection: framing is gone
                let _ = write_response(&mut writer, status, &err_body(&msg), true, None);
                let _ = writer.shutdown(std::net::Shutdown::Write);
                reader.drain(DRAIN_BYTES);
                break;
            }
            Err(HttpError::Io(e)) => {
                if is_timeout(&e) && !draining {
                    // the reaper: classify what the timeout caught
                    if reader.mid_request() {
                        state.slow_client_closes.fetch_add(1, Ordering::Relaxed);
                        let _ = write_response(
                            &mut writer,
                            408,
                            &err_body("request timed out"),
                            true,
                            None,
                        );
                    } else {
                        state.idle_reaped.fetch_add(1, Ordering::Relaxed);
                    }
                }
                break; // timeout / reset / EOF
            }
        }
    }
}

/// A dispatched JSON reply: status, JSON body, optional `Retry-After`
/// seconds.
type Reply = (u16, Json, Option<u64>);

/// A wire reply body: JSON everywhere except the Prometheus text
/// exposition.
enum Body {
    Json(Json),
    Text(String),
}

/// What `route` hands the connection handler.
type WireReply = (u16, Body, Option<u64>);

fn reply(status: u16, body: Json) -> Reply {
    (status, body, None)
}

/// `?key=value` lookup in a raw query string.
fn query_param<'a>(query: Option<&'a str>, key: &str) -> Option<&'a str> {
    query?.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// Dispatch one request.  Infallible by construction: every error is a
/// status + body pair.
fn route(state: &Arc<ServerState>, req: &Request) -> WireReply {
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (req.path.as_str(), None),
    };
    if req.method == "GET"
        && path == "/metrics"
        && query_param(query, "format") == Some("prometheus")
    {
        return (200, Body::Text(prometheus_body(state)), None);
    }
    let (status, body, retry) = route_json(state, req, path, query);
    (status, Body::Json(body), retry)
}

fn route_json(
    state: &Arc<ServerState>,
    req: &Request,
    path: &str,
    query: Option<&str>,
) -> Reply {
    match (req.method.as_str(), path) {
        ("GET", "/v1/models") => reply(200, state.registry.describe()),
        ("GET", "/metrics") => reply(200, metrics_body(state)),
        ("GET", "/v1/trace") => {
            let last = query_param(query, "last")
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(512);
            reply(200, trace::export_last(last))
        }
        ("GET", "/healthz") => reply(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("uptime_s", Json::num(state.started.elapsed().as_secs_f64())),
            ]),
        ),
        ("GET", "/readyz") => readyz(state),
        ("POST", "/admin/shutdown") => {
            state.shutdown.store(true, Ordering::Release);
            // poke our own listening socket so accept() observes the flag
            let _ = TcpStream::connect(state.addr);
            reply(200, Json::obj(vec![("ok", Json::Bool(true))]))
        }
        (_, path) if path.starts_with("/v1/infer/") => {
            let name = path.strip_prefix("/v1/infer/").unwrap_or_default();
            if req.method != "POST" {
                return reply(405, err_body("use POST"));
            }
            infer(state, name, &req.body)
        }
        ("GET", _) | ("POST", _) => reply(404, err_body("no such route")),
        _ => reply(405, err_body("unsupported method")),
    }
}

/// `GET /readyz`: 200 while at least one model's breaker admits
/// traffic (a single faulted model must not pull the whole node out of
/// rotation — its own requests already answer 503).  503 during
/// shutdown, so load balancers drain before the listener goes away.
fn readyz(state: &Arc<ServerState>) -> Reply {
    if state.shutdown.load(Ordering::Acquire) {
        return reply(
            503,
            Json::obj(vec![
                ("ready", Json::Bool(false)),
                ("reason", Json::str("shutting down")),
            ]),
        );
    }
    let mut models = Vec::new();
    let mut any_ready = false;
    for e in state.registry.entries() {
        let b = e.batcher().supervision().breaker_state();
        let ready = b != BreakerState::Open;
        any_ready |= ready;
        models.push((
            e.name().to_string(),
            Json::obj(vec![
                ("ready", Json::Bool(ready)),
                ("breaker", Json::str(b.name())),
            ]),
        ));
    }
    let status = if any_ready { 200 } else { 503 };
    reply(
        status,
        Json::obj(vec![
            ("ready", Json::Bool(any_ready)),
            ("models", Json::Obj(models.into_iter().collect())),
        ]),
    )
}

fn metrics_body(state: &Arc<ServerState>) -> Json {
    let mut models = Vec::new();
    let mut total_requests = 0u64;
    let mut total_shed = 0u64;
    let mut total_model_bytes = 0u64;
    for e in state.registry.entries() {
        total_requests += e.metrics().requests();
        total_shed += e.metrics().shed();
        total_model_bytes += e.plan().weight_bytes() as u64;
        let mut snap = e.metrics().snapshot();
        // registry-level gauges ride each model's snapshot: resident
        // weight bytes and what this model's cold start cost
        if let Json::Obj(o) = &mut snap {
            o.insert(
                "model_bytes".to_string(),
                Json::num(e.plan().weight_bytes() as f64),
            );
            let s = e.startup();
            o.insert("startup_source".to_string(), Json::str(s.source));
            o.insert("startup_us".to_string(), Json::num(s.micros as f64));
            if let Some(b) = s.artifact_bytes {
                o.insert("artifact_bytes".to_string(), Json::num(b as f64));
            }
            for (k, v) in metrics::fusion_gauges(e.plan().fusion()) {
                o.insert(k.to_string(), v);
            }
            for (k, v) in
                metrics::kernel_gauges(e.plan().backend_name(), e.plan().kernel_tier())
            {
                o.insert(k.to_string(), v);
            }
            // supervision gauges read live (the breaker transitions
            // lazily — asking it is what advances open → half-open)
            let sup = e.batcher().supervision();
            let b = sup.breaker_state();
            o.insert("breaker_state".to_string(), Json::num(b.code() as f64));
            o.insert("breaker_state_name".to_string(), Json::str(b.name()));
            o.insert(
                "breaker_opens".to_string(),
                Json::num(sup.breaker_opens() as f64),
            );
        }
        models.push((e.name().to_string(), snap));
    }
    Json::obj(vec![
        ("uptime_s", Json::num(state.started.elapsed().as_secs_f64())),
        ("requests", Json::num(total_requests as f64)),
        ("shed", Json::num(total_shed as f64)),
        ("model_bytes", Json::num(total_model_bytes as f64)),
        (
            "slow_client_closes",
            Json::num(state.slow_client_closes.load(Ordering::Relaxed) as f64),
        ),
        (
            "idle_reaped",
            Json::num(state.idle_reaped.load(Ordering::Relaxed) as f64),
        ),
        (
            "write_stalls",
            Json::num(state.write_stalls.load(Ordering::Relaxed) as f64),
        ),
        ("models", Json::Obj(models.into_iter().collect())),
    ])
}

/// The `/metrics?format=prometheus` exposition: every per-model family
/// from [`metrics::prometheus_text`], plus the server-level gauges
/// (uptime, resident model bytes, breaker state).
fn prometheus_body(state: &Arc<ServerState>) -> String {
    let entries: Vec<_> = state.registry.entries().collect();
    let pairs: Vec<(&str, &Metrics)> =
        entries.iter().map(|e| (e.name(), e.metrics().as_ref())).collect();
    let mut out = metrics::prometheus_text(&pairs);
    out.push_str("# TYPE cwmix_uptime_seconds gauge\n");
    metrics::prom_sample(
        &mut out,
        "cwmix_uptime_seconds",
        &[],
        state.started.elapsed().as_secs_f64(),
    );
    out.push_str("# TYPE cwmix_model_bytes gauge\n");
    for e in &entries {
        metrics::prom_sample(
            &mut out,
            "cwmix_model_bytes",
            &[("model", e.name())],
            e.plan().weight_bytes() as f64,
        );
    }
    out.push_str("# TYPE cwmix_breaker_state gauge\n");
    for e in &entries {
        metrics::prom_sample(
            &mut out,
            "cwmix_breaker_state",
            &[("model", e.name())],
            e.batcher().supervision().breaker_state().code() as f64,
        );
    }
    out
}

/// `CWMIX_LOG_SAMPLE=N`: log every Nth non-5xx request line (0 = off).
fn log_sample_every() -> u64 {
    static N: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("CWMIX_LOG_SAMPLE").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
    })
}

/// Structured per-request log line.  5xx failures always log (a crashed
/// worker must be attributable) **except** 503 — overload shed is a
/// storm by design and would drown the log exactly when it matters;
/// everything else is sampled by [`log_sample_every`].
fn log_request(model: &str, id: u64, status: u16, latency_us: u64, batch: usize) {
    let always = status >= 500 && status != 503;
    if !always {
        let every = log_sample_every();
        if every == 0 {
            return;
        }
        static CTR: AtomicU64 = AtomicU64::new(0);
        if CTR.fetch_add(1, Ordering::Relaxed) % every != 0 {
            return;
        }
    }
    eprintln!(
        "request model={model} id={id} status={status} latency_us={latency_us} \
         batch={batch}"
    );
}

/// Stamp the request id into a JSON reply body — every infer reply
/// carries the correlation key, success and error alike.
fn id_body(mut body: Json, id: u64) -> Json {
    if let Json::Obj(o) = &mut body {
        o.insert("request_id".to_string(), Json::num(id as f64));
    }
    body
}

fn infer(state: &Arc<ServerState>, name: &str, body: &[u8]) -> Reply {
    // admission stamps the id: it exists before any validation, so even
    // a 400 reply is correlatable with the client's attempt
    let id = trace::next_request_id();
    let start = Instant::now();
    let (status, body, retry) = {
        let _req_span = trace::span(SpanName::Request, id);
        infer_inner(state, name, body, id)
    };
    let batch =
        body.opt("batch").and_then(|b| b.as_f64().ok()).unwrap_or(0.0) as usize;
    log_request(name, id, status, start.elapsed().as_micros() as u64, batch);
    (status, id_body(body, id), retry)
}

fn infer_inner(state: &Arc<ServerState>, name: &str, body: &[u8], id: u64) -> Reply {
    let Some(entry) = state.registry.get(name) else {
        return reply(404, err_body(&format!("unknown model {name:?}")));
    };
    let parsed = match parse_bytes(body) {
        Ok(v) => v,
        Err(e) => return reply(400, err_body(&format!("bad JSON body: {e}"))),
    };
    let input: Vec<f32> = match parsed.get("input").and_then(|v| {
        v.as_arr()?.iter().map(|x| x.as_f64().map(|f| f as f32)).collect()
    }) {
        Ok(v) => v,
        Err(e) => return reply(400, err_body(&format!("bad \"input\": {e}"))),
    };
    let submitted = {
        let _adm_span = trace::span(SpanName::Admission, id);
        entry.batcher().submit(input, id)
    };
    let rx = match submitted {
        Ok(rx) => rx,
        Err(SubmitError::Overloaded) => {
            return reply(503, err_body("overloaded: queue full"))
        }
        Err(SubmitError::BreakerOpen { retry_after_s }) => {
            return (
                503,
                Json::obj(vec![
                    ("error", Json::str("circuit breaker open")),
                    ("retry_after_s", Json::num(retry_after_s as f64)),
                ]),
                Some(retry_after_s),
            )
        }
        Err(SubmitError::ShuttingDown) => return reply(503, err_body("shutting down")),
        Err(SubmitError::BadInput(m)) => return reply(400, err_body(&m)),
    };
    // bounded wait past the request's own deadline window: the batcher
    // answers expired requests at dequeue, so this only trips while a
    // panicked worker is mid-respawn — degrade to 504, never a wedged
    // connection
    let timeout = state.registry.policy().deadline() + REPLY_TIMEOUT_SLACK;
    match rx.recv_timeout(timeout) {
        Ok(Ok(r)) => reply(
            200,
            Json::obj(vec![
                ("model", Json::str(name)),
                ("batch", Json::num(r.batch as f64)),
                ("output", Json::arr_f32(&r.output)),
            ]),
        ),
        // no record_error for Expired/Engine: the batcher already
        // counted those once per rider
        Ok(Err(ReplyError::Expired)) => reply(504, err_body("deadline exceeded")),
        Ok(Err(ReplyError::ShuttingDown)) => reply(503, err_body("shutting down")),
        Ok(Err(ReplyError::Engine(m))) => reply(500, err_body(&m)),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            entry.metrics().record_error();
            reply(504, err_body("inference timed out"))
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            // the worker panicked with this request in its in-flight
            // batch; it respawns — the client should just retry
            entry.metrics().record_error();
            reply(500, err_body("worker crashed; retry"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn reader(bytes: &[u8]) -> HttpReader<Cursor<Vec<u8>>> {
        HttpReader::new(Cursor::new(bytes.to_vec()), 1024)
    }

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/infer/ic HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello";
        let req = reader(raw).next_request().unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer/ic");
        assert_eq!(req.body, b"hello");
        assert!(!req.close);
    }

    #[test]
    fn keep_alive_pipelining_carries_over() {
        let raw =
            b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcGET /b HTTP/1.1\r\n\r\n".to_vec();
        let mut r = HttpReader::new(Cursor::new(raw), 1024);
        let a = r.next_request().unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.body.as_slice()), ("/a", &b"abc"[..]));
        let b = r.next_request().unwrap().unwrap();
        assert_eq!(b.method, "GET");
        assert_eq!(b.path, "/b");
        assert!(r.next_request().unwrap().is_none(), "clean EOF after last request");
    }

    #[test]
    fn connection_close_flag() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(reader(raw).next_request().unwrap().unwrap().close);
        let raw10 = b"GET / HTTP/1.0\r\n\r\n";
        assert!(reader(raw10).next_request().unwrap().unwrap().close);
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 99999\r\n\r\n";
        match reader(raw).next_request() {
            Err(HttpError::Bad(413, _)) => {}
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn malformed_request_line_is_400() {
        for raw in [&b"NONSENSE\r\n\r\n"[..], b"GET nopath HTTP/1.1\r\n\r\n"] {
            match reader(raw).next_request() {
                Err(HttpError::Bad(400, _)) => {}
                other => panic!("{raw:?}: expected 400, got {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_body_is_io_error() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        match reader(raw).next_request() {
            Err(HttpError::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn chunked_encoding_is_501() {
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        match reader(raw).next_request() {
            Err(HttpError::Bad(501, _)) => {}
            other => panic!("expected 501, got {other:?}"),
        }
    }

    #[test]
    fn parses_response_status_line() {
        let raw = b"HTTP/1.1 503 Service Unavailable\r\nContent-Length: 2\r\n\r\nhi";
        let (status, body) =
            HttpReader::new(Cursor::new(raw.to_vec()), 1024).next_response().unwrap().unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, b"hi");
    }

    #[test]
    fn response_roundtrips_through_reader() {
        let mut out = Vec::new();
        write_response(&mut out, 200, &err_body("x"), false, None).unwrap();
        let (status, body) =
            HttpReader::new(Cursor::new(out), 1024).next_response().unwrap().unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"error\":\"x\"}");
    }

    #[test]
    fn response_shape() {
        let mut out = Vec::new();
        write_response(&mut out, 200, &err_body("x"), false, None).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 13\r\n"), "{text}");
        assert!(text.ends_with("{\"error\":\"x\"}"), "{text}");
    }

    #[test]
    fn retry_after_header_is_emitted() {
        let mut out = Vec::new();
        write_response(&mut out, 503, &err_body("open"), true, Some(7)).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Retry-After: 7\r\n"), "{text}");
        // still parses as one well-framed response
        let (status, body) = HttpReader::new(Cursor::new(text.into_bytes()), 1024)
            .next_response()
            .unwrap()
            .unwrap();
        assert_eq!(status, 503);
        assert_eq!(body, b"{\"error\":\"open\"}");
    }

    #[test]
    fn mid_request_distinguishes_idle_from_slow() {
        let mut r = reader(b"POST /v1/infer/ic HTTP/1.1\r\nContent-Le");
        assert!(!r.mid_request(), "nothing buffered yet");
        // a truncated read leaves partial bytes buffered
        let _ = r.next_request();
        assert!(r.mid_request(), "partial request must read as slow, not idle");
    }
}
