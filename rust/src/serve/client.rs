//! Minimal blocking HTTP/1.1 client for loopback use.
//!
//! Just enough protocol for the serve subsystem's own consumers — the
//! `bench_serve` closed-loop load generator, the `serve_smoke` CI
//! round-trip bin and the integration tests: keep-alive over one
//! `TcpStream`, `Content-Length` framing, JSON bodies.  Not a general
//! HTTP client (no TLS, redirects, chunked encoding) and deliberately
//! not public API beyond this crate's tooling needs.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::minijson::{parse, Json};

use super::http::{HttpError, HttpReader};

/// One keep-alive connection to a `cwmix serve` instance.
pub struct Conn {
    writer: TcpStream,
    reader: HttpReader<TcpStream>,
}

/// Response status + parsed JSON body.
pub struct ClientResponse {
    pub status: u16,
    pub body: Json,
}

impl Conn {
    /// Connect with a sane default timeout (10 s).
    pub fn connect(addr: SocketAddr) -> Result<Conn> {
        let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(10))
            .with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Conn { writer, reader: HttpReader::new(stream, 64 << 20) })
    }

    /// Send one request and read the reply.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<ClientResponse> {
        let body = body.unwrap_or("");
        write!(
            self.writer,
            "{method} {path} HTTP/1.1\r\nHost: cwmix\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len(),
        )?;
        self.writer.flush()?;
        self.read_response()
    }

    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None)
    }

    /// GET a route that answers plain text (the prometheus exposition)
    /// — status + unparsed body.
    pub fn get_text(&mut self, path: &str) -> Result<(u16, String)> {
        write!(
            self.writer,
            "GET {path} HTTP/1.1\r\nHost: cwmix\r\nContent-Length: 0\r\n\
             Connection: keep-alive\r\n\r\n",
        )?;
        self.writer.flush()?;
        let (status, body) = self.read_raw()?;
        Ok((status, String::from_utf8(body).context("non-UTF-8 body")?))
    }

    pub fn post(&mut self, path: &str, body: &str) -> Result<ClientResponse> {
        self.request("POST", path, Some(body))
    }

    fn read_raw(&mut self) -> Result<(u16, Vec<u8>)> {
        match self.reader.next_response() {
            Ok(Some((status, body))) => Ok((status, body)),
            Ok(None) => bail!("connection closed before response"),
            Err(HttpError::Bad(_, m)) => bail!("malformed response: {m}"),
            Err(HttpError::Io(e)) => Err(e).context("reading response"),
        }
    }

    fn read_response(&mut self) -> Result<ClientResponse> {
        let (status, body) = self.read_raw()?;
        let text = std::str::from_utf8(&body).context("non-UTF-8 body")?;
        let body = if text.is_empty() { Json::Null } else { parse(text)? };
        Ok(ClientResponse { status, body })
    }
}

/// Build the `POST /v1/infer/<bench>` request body for one sample.
pub fn infer_body(input: &[f32]) -> String {
    Json::obj(vec![("input", Json::arr_f32(input))]).dumps()
}

/// Pull `"output"` out of an infer reply as `f32`s.
pub fn output_of(body: &Json) -> Result<Vec<f32>> {
    body.get("output")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect()
}
