//! Resident multi-model inference server with dynamic micro-batching.
//!
//! Until this module, every execution caller was one-shot: compile an
//! [`ExecPlan`](crate::engine::ExecPlan), run, exit.  `serve` is the
//! first resident process in the stack — the scale axis of the ROADMAP
//! north star — and it exists to exploit the engine's batch
//! amortisation across **independent** requests:
//!
//! ```text
//!            TcpListener (http.rs)
//!   conn ──▶ handler ──submit()──▶ ┌────────────────────┐
//!   conn ──▶ handler ──submit()──▶ │ bounded queue      │ per model
//!   conn ──▶ handler ──submit()──▶ │ (shed when full)   │
//!                                  └──────┬─────────────┘
//!                                         ▼ coalesce (max_batch / max_wait_us)
//!                                  batcher worker ──run_batch_planes()──▶ ExecPlan
//!                                         │      (zero-copy, resident  (registry.rs,
//!                                         ▼       batch arena)          compiled once)
//!                                  per-request replies + metrics
//! ```
//!
//! * [`ModelRegistry`] — one immutable [`ExecPlan`] per served model,
//!   compiled at startup and shared (`Arc`) by every handler and
//!   batcher.
//! * [`Batcher`] — the dynamic micro-batcher: pending single-sample
//!   requests for the same plan coalesce into one batch-plane engine
//!   call (zero input copies, worker-resident batch arena) under a
//!   `max_batch`/`max_wait_us` policy; the bounded queue sheds with an
//!   explicit `503` instead of growing without bound.  Batched outputs
//!   are bit-identical to per-sample `run_sample` calls.
//! * [`http`] — pure-`std` HTTP/1.1 front end (`POST /v1/infer/<bench>`,
//!   `GET /v1/models`, `GET /metrics`, `POST /admin/shutdown`), JSON
//!   via the hardened [`minijson`](crate::minijson).
//! * [`Metrics`] — request/shed counters, p50/p99 latency, batch-size
//!   histogram, scraped by `GET /metrics`.
//! * [`client`] — the loopback client used by `bench_serve`,
//!   `serve_smoke` and the integration tests.
//!
//! Entry points: `cwmix serve` (CLI), [`http::serve`] (library),
//! `benches/bench_serve.rs` (closed-loop load generator emitting
//! `BENCH_serve.json`).

pub mod batcher;
pub mod client;
pub mod http;
pub mod metrics;
pub mod registry;

pub use batcher::{BatchPolicy, Batcher, InferReply, SubmitError};
pub use http::{serve, ServeConfig, Server};
pub use metrics::Metrics;
pub use registry::{ModelEntry, ModelRegistry, RegistryConfig, StartupStats};
