//! Resident multi-model inference server with dynamic micro-batching.
//!
//! Until this module, every execution caller was one-shot: compile an
//! [`ExecPlan`](crate::engine::ExecPlan), run, exit.  `serve` is the
//! first resident process in the stack — the scale axis of the ROADMAP
//! north star — and it exists to exploit the engine's batch
//! amortisation across **independent** requests:
//!
//! ```text
//!            TcpListener (http.rs)
//!   conn ──▶ handler ──submit()──▶ ┌────────────────────┐
//!   conn ──▶ handler ──submit()──▶ │ bounded queue      │ per model
//!   conn ──▶ handler ──submit()──▶ │ (shed when full)   │
//!                                  └──────┬─────────────┘
//!                                         ▼ coalesce (max_batch / max_wait_us)
//!                                  batcher worker ──run_batch_planes()──▶ ExecPlan
//!                                         │      (zero-copy, resident  (registry.rs,
//!                                         ▼       batch arena)          compiled once)
//!                                  per-request replies + metrics
//! ```
//!
//! * [`ModelRegistry`] — one immutable [`ExecPlan`] per served model,
//!   compiled at startup and shared (`Arc`) by every handler and
//!   batcher.
//! * [`Batcher`] — the dynamic micro-batcher: pending single-sample
//!   requests for the same plan coalesce into one batch-plane engine
//!   call (zero input copies, worker-resident batch arena) under a
//!   `max_batch`/`max_wait_us` policy; the bounded queue sheds with an
//!   explicit `503` instead of growing without bound.  Batched outputs
//!   are bit-identical to per-sample `run_sample` calls.
//! * [`http`] — pure-`std` HTTP/1.1 front end (`POST /v1/infer/<bench>`,
//!   `GET /v1/models`, `GET /healthz`, `GET /readyz`, `GET /metrics`,
//!   `POST /admin/shutdown`), JSON via the hardened
//!   [`minijson`](crate::minijson); socket read/write timeouts with a
//!   slow-client/idle-connection reaper.
//! * [`supervisor`] — panic isolation for batcher workers:
//!   `catch_unwind` + bounded-backoff respawn, a per-model circuit
//!   breaker (K consecutive panics → 503 + `Retry-After`), and the
//!   poison-free lock helpers every serve lock goes through.
//! * [`faults`] — deterministic fault injection (`CWMIX_FAULTS` /
//!   `--faults`): seeded failpoints for engine panic/stall, queue-full,
//!   slow sockets, mid-reply write stalls, and registry
//!   load/corruption, compiled to no-ops
//!   when disarmed.  The chaos suite (`tests/serve_chaos.rs`,
//!   `tools/chaos_smoke.sh`) drives them over real sockets.
//! * [`Metrics`] — request/shed counters, lock-free log-bucketed
//!   latency histogram (p50/p99/p99.9), batch-size histogram,
//!   supervision gauges (panics, respawns, deadline expiries, breaker
//!   rejects), scraped by `GET /metrics` as JSON or
//!   `?format=prometheus` text exposition.
//! * [`client`] — the loopback client used by `bench_serve`,
//!   `serve_smoke`, `chaos_smoke` and the integration tests.
//!
//! Observability (DESIGN.md §9): every request is stamped with a
//! process-unique id at admission ([`crate::trace::next_request_id`])
//! that keys its trace spans (`request` → `admission` → `queue_wait` →
//! `batch_ride` → `engine_pass`), rides the reply body and the
//! structured per-request log line, and is listed in the supervisor's
//! panic line when a worker dies with it in flight.  Spans are
//! exported by `GET /v1/trace?last=N` (chrome://tracing JSON); the
//! whole surface costs one predicted branch per site when tracing is
//! disabled (the default).
//!
//! Every request carries a deadline (`max_wait + infer_budget`)
//! enforced at dequeue: expired requests answer 504 without riding a
//! batch, so a recovered worker sheds a stalled backlog instead of
//! executing work nobody is waiting for.
//!
//! Entry points: `cwmix serve` (CLI), [`http::serve`] (library),
//! `benches/bench_serve.rs` (closed-loop load generator emitting
//! `BENCH_serve.json`).

pub mod batcher;
pub mod client;
pub mod faults;
pub mod http;
pub mod metrics;
pub mod registry;
pub mod supervisor;

pub use batcher::{
    BatchPolicy, Batcher, InferReply, ReplyError, SubmitError, WorkerOpts,
};
pub use faults::{EngineFault, Faults};
pub use http::{serve, ServeConfig, Server};
pub use metrics::Metrics;
pub use registry::{ModelEntry, ModelRegistry, RegistryConfig, StartupStats};
pub use supervisor::{BreakerState, Supervision, SupervisorCfg};
