//! Multi-model registry: compile once at startup, share everywhere.
//!
//! A [`ModelRegistry`] holds one immutable, precompiled
//! [`ExecPlan`] per served model — compiled exactly once at startup
//! (the plan/execute split's whole point) and shared behind an `Arc`
//! by every connection handler and the model's [`Batcher`] worker.
//! All mutable execution state lives in per-worker batch
//! [`Arena`](crate::engine::Arena)s (the batcher's resident arena, or
//! per-thread arenas inside `run_samples`), so plans need no interior
//! mutability.
//!
//! Models come from the same sources as `cwmix simulate`: geometry
//! from the artifacts manifest when `artifacts/<bench>/manifest.json`
//! exists, else the builtin zoo — and weights are **always** seeded
//! synthetic state (trained parameters only exist inside an `xla`
//! trainer session; there is no weights-on-disk format yet).  The
//! server therefore runs on the default feature set with no training
//! artifacts at all, and serves reference-quality numerics, not
//! trained accuracy.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::deploy;
use crate::engine::{backend_by_name, ExecPlan};
use crate::minijson::Json;
use crate::models::{zoo, Manifest};
use crate::quant::Assignment;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;

/// Startup configuration for the registry.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Benchmarks to serve (`ic|kws|vww|ad`).
    pub benches: Vec<String>,
    /// Kernel backend (`packed|reference`).
    pub backend: String,
    /// Assignment spec: `stripy` (striped 2/4/8 mix) or `w<N>x<M>`.
    pub assignment: String,
    /// Synthetic-state seed (weights are always synthetic; see the
    /// module docs).
    pub seed: u64,
    /// Artifacts directory; a bench with a manifest there uses its
    /// *geometry* (weights stay synthetic).
    pub artifacts: PathBuf,
    /// Micro-batching policy applied to every model.
    pub policy: BatchPolicy,
}

impl Default for RegistryConfig {
    fn default() -> RegistryConfig {
        RegistryConfig {
            benches: zoo::BENCHES.iter().map(|b| b.to_string()).collect(),
            backend: "packed".to_string(),
            assignment: "stripy".to_string(),
            seed: 0,
            artifacts: PathBuf::from("artifacts"),
            policy: BatchPolicy::default(),
        }
    }
}

/// Parse an assignment spec against a manifest.
pub fn parse_assignment(spec: &str, manifest: &Manifest) -> Result<Assignment> {
    if spec == "stripy" {
        return Ok(zoo::stripy_assignment(manifest));
    }
    if let Some(rest) = spec.strip_prefix('w') {
        if let Some((w, x)) = rest.split_once('x') {
            let wbits: u32 = w.parse().context("weight bits")?;
            let xbits: u32 = x.parse().context("activation bits")?;
            return Ok(Assignment::fixed(
                &manifest.qnames(),
                &manifest.qcouts(),
                wbits,
                xbits,
            ));
        }
    }
    bail!("unknown assignment spec {spec:?} (stripy|w<N>x<M>, e.g. w4x8)")
}

/// One served model: the shared plan, its batcher and its metrics.
pub struct ModelEntry {
    name: String,
    plan: Arc<ExecPlan>,
    batcher: Batcher,
    metrics: Arc<Metrics>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    pub fn batcher(&self) -> &Batcher {
        &self.batcher
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// `GET /v1/models` row.
    pub fn describe(&self, policy: &BatchPolicy) -> Json {
        let cost = self.plan.cost();
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("backend", Json::str(self.plan.backend_name())),
            ("feat", Json::num(self.plan.feat() as f64)),
            ("out_len", Json::num(self.plan.out_len() as f64)),
            ("weight_bytes", Json::num(self.plan.weight_bytes() as f64)),
            ("est_latency_us", Json::num(cost.latency_us())),
            ("est_energy_uj", Json::num(cost.total_energy_uj())),
            ("max_batch", Json::num(policy.max_batch as f64)),
        ])
    }
}

/// All served models, keyed by bench name.
pub struct ModelRegistry {
    entries: BTreeMap<String, ModelEntry>,
    policy: BatchPolicy,
}

impl ModelRegistry {
    /// Compile every requested model and start its batcher.
    pub fn build(cfg: &RegistryConfig) -> Result<ModelRegistry> {
        if cfg.benches.is_empty() {
            bail!("no benches to serve");
        }
        let backend = backend_by_name(&cfg.backend)?;
        let mut entries = BTreeMap::new();
        for bench in &cfg.benches {
            if entries.contains_key(bench) {
                bail!("bench {bench} listed twice");
            }
            let manifest = if cfg.artifacts.join(bench).join("manifest.json").exists() {
                Manifest::load(&cfg.artifacts, bench)?
            } else {
                zoo::builtin_manifest(bench)?
            };
            let (params, bn) = zoo::synthetic_state(&manifest, cfg.seed);
            let assignment = parse_assignment(&cfg.assignment, &manifest)?;
            let deployed = deploy::build(&manifest, &params, &bn, &assignment)
                .with_context(|| format!("deploying {bench}"))?;
            let plan = Arc::new(
                ExecPlan::compile(&deployed, &manifest.lut, backend)
                    .with_context(|| format!("compiling {bench}"))?,
            );
            let metrics = Arc::new(Metrics::default());
            let batcher = Batcher::start(
                Arc::clone(&plan),
                Arc::clone(&metrics),
                cfg.policy.clone(),
            );
            entries.insert(
                bench.clone(),
                ModelEntry { name: bench.clone(), plan, batcher, metrics },
            );
        }
        Ok(ModelRegistry { entries, policy: cfg.policy.clone() })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.get(name)
    }

    pub fn entries(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.values()
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// `GET /v1/models` body.
    pub fn describe(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::Arr(self.entries.values().map(|e| e.describe(&self.policy)).collect()),
        )])
    }

    /// Stop every batcher (drains queues, joins workers).  Idempotent.
    pub fn shutdown(&self) {
        for e in self.entries.values() {
            e.batcher.shutdown();
        }
    }
}
