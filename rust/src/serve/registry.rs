//! Multi-model registry: compile (or cold-load) once at startup,
//! share everywhere.
//!
//! A [`ModelRegistry`] holds one immutable, precompiled
//! [`ExecPlan`] per served model — built exactly once at startup
//! (the plan/execute split's whole point) and shared behind an `Arc`
//! by every connection handler and the model's [`Batcher`] worker.
//! All mutable execution state lives in per-worker batch
//! [`Arena`](crate::engine::Arena)s (the batcher's resident arena, or
//! per-thread arenas inside `run_samples`), so plans need no interior
//! mutability.
//!
//! Two startup paths per model:
//!
//! * **modelpack cold start** — when
//!   [`RegistryConfig::modelpack_dir`] is set and `<dir>/<bench>.cwm`
//!   exists, the plan is loaded with
//!   [`ExecPlan::from_modelpack`]: a validate-then-borrow pass over
//!   the artifact (no recompilation, no weight re-packing), serving
//!   outputs bit-identical to an in-process compile.  A pack that is
//!   unreadable, corrupt, or built for a different bench/backend
//!   falls back to compilation with a warning — a stale artifact
//!   directory must never take the server down or change its
//!   numerics.
//! * **compile** — the original path: geometry from the artifacts
//!   manifest when `artifacts/<bench>/manifest.json` exists, else the
//!   builtin zoo, with seeded synthetic weights (trained parameters
//!   only exist inside an `xla` trainer session).
//!
//! Either way the per-model [`StartupStats`] (source, wall time,
//! artifact bytes) are exported through `/metrics` so operators can
//! see what a cold start actually cost.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::deploy;
use crate::engine::{backend_by_name, ExecPlan, KernelBackend};
use crate::minijson::Json;
use crate::models::{zoo, Manifest};
use crate::quant::Assignment;

use super::batcher::{BatchPolicy, Batcher, WorkerOpts};
use super::faults::Faults;
use super::metrics::Metrics;
use super::supervisor::SupervisorCfg;

/// Startup configuration for the registry.
#[derive(Clone, Debug)]
pub struct RegistryConfig {
    /// Benchmarks to serve (`ic|kws|vww|ad`).
    pub benches: Vec<String>,
    /// Kernel backend (`packed|reference|simd`).
    pub backend: String,
    /// Assignment spec: `stripy` (striped 2/4/8 mix) or `w<N>x<M>`.
    pub assignment: String,
    /// Synthetic-state seed (weights are always synthetic; see the
    /// module docs).
    pub seed: u64,
    /// Artifacts directory; a bench with a manifest there uses its
    /// *geometry* (weights stay synthetic).
    pub artifacts: PathBuf,
    /// Compiled-model artifact directory: a bench with a
    /// `<bench>.cwm` there cold-starts from it instead of compiling
    /// (`cwmix serve --modelpack-dir`, populated by `cwmix compile`).
    pub modelpack_dir: Option<PathBuf>,
    /// Micro-batching policy applied to every model.
    pub policy: BatchPolicy,
    /// Fault-injection plan shared by every model's load path and
    /// batcher worker (disarmed by default).
    pub faults: Arc<Faults>,
    /// Supervision knobs (breaker K, cooldowns, respawn backoff)
    /// applied to every model's worker.
    pub supervisor: SupervisorCfg,
}

impl Default for RegistryConfig {
    fn default() -> RegistryConfig {
        RegistryConfig {
            benches: zoo::BENCHES.iter().map(|b| b.to_string()).collect(),
            backend: "packed".to_string(),
            assignment: "stripy".to_string(),
            seed: 0,
            artifacts: PathBuf::from("artifacts"),
            modelpack_dir: None,
            policy: BatchPolicy::default(),
            faults: Faults::disarmed(),
            supervisor: SupervisorCfg::default(),
        }
    }
}

/// How one model's plan came to be at startup.
#[derive(Clone, Copy, Debug)]
pub struct StartupStats {
    /// `"modelpack"` (cold-loaded from a `.cwm`) or `"compile"`.
    pub source: &'static str,
    /// Wall time of the load or compile, microseconds.
    pub micros: u64,
    /// `.cwm` file size when loaded from a modelpack.
    pub artifact_bytes: Option<u64>,
}

/// Parse an assignment spec against a manifest.
pub fn parse_assignment(spec: &str, manifest: &Manifest) -> Result<Assignment> {
    if spec == "stripy" {
        return Ok(zoo::stripy_assignment(manifest));
    }
    if let Some(rest) = spec.strip_prefix('w') {
        if let Some((w, x)) = rest.split_once('x') {
            let wbits: u32 = w.parse().context("weight bits")?;
            let xbits: u32 = x.parse().context("activation bits")?;
            return Ok(Assignment::fixed(
                &manifest.qnames(),
                &manifest.qcouts(),
                wbits,
                xbits,
            ));
        }
    }
    bail!("unknown assignment spec {spec:?} (stripy|w<N>x<M>, e.g. w4x8)")
}

/// One served model: the shared plan, its batcher and its metrics.
pub struct ModelEntry {
    name: String,
    plan: Arc<ExecPlan>,
    batcher: Batcher,
    metrics: Arc<Metrics>,
    startup: StartupStats,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    pub fn batcher(&self) -> &Batcher {
        &self.batcher
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    pub fn startup(&self) -> StartupStats {
        self.startup
    }

    /// `GET /v1/models` row.
    pub fn describe(&self, policy: &BatchPolicy) -> Json {
        let cost = self.plan.cost();
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("backend", Json::str(self.plan.backend_name())),
            ("kernel_tier", Json::str(self.plan.kernel_tier())),
            ("feat", Json::num(self.plan.feat() as f64)),
            ("out_len", Json::num(self.plan.out_len() as f64)),
            ("weight_bytes", Json::num(self.plan.weight_bytes() as f64)),
            ("est_latency_us", Json::num(cost.latency_us())),
            ("est_energy_uj", Json::num(cost.total_energy_uj())),
            ("max_batch", Json::num(policy.max_batch as f64)),
            ("startup_source", Json::str(self.startup.source)),
            ("startup_us", Json::num(self.startup.micros as f64)),
        ])
    }
}

/// All served models, keyed by bench name.
pub struct ModelRegistry {
    entries: BTreeMap<String, ModelEntry>,
    policy: BatchPolicy,
}

/// Build one model from scratch: geometry from the artifacts manifest
/// when present (else the builtin zoo), seeded synthetic state, the
/// assignment spec, the §III-C deploy transform, and `ExecPlan::compile`.
/// This is the **single** compile path shared by the registry's
/// fallback, `cwmix compile` and `cwmix simulate`-style tooling — packs
/// and serve-time fallbacks are constructed identically by definition,
/// so they cannot drift apart.
pub fn build_model(
    bench: &str,
    backend: &dyn KernelBackend,
    assignment: &str,
    seed: u64,
    artifacts: &Path,
) -> Result<(Manifest, deploy::DeployedModel, ExecPlan)> {
    let manifest = if artifacts.join(bench).join("manifest.json").exists() {
        Manifest::load(artifacts, bench)?
    } else {
        zoo::builtin_manifest(bench)?
    };
    let (params, bn) = zoo::synthetic_state(&manifest, seed);
    let a = parse_assignment(assignment, &manifest)?;
    let deployed = deploy::build(&manifest, &params, &bn, &a)
        .with_context(|| format!("deploying {bench}"))?;
    let plan = ExecPlan::compile(&deployed, &manifest.lut, backend)
        .with_context(|| format!("compiling {bench}"))?;
    Ok((manifest, deployed, plan))
}

/// Reload `pack` and prove it executes **bit-identically** to `plan`
/// on a deterministic probe sample — the shared emit-time check
/// (`cwmix compile` refuses to keep an artifact that fails it; the
/// cold-start bench asserts it while measuring).  Returns the loaded
/// plan for callers that want to keep exercising it.
pub fn verify_pack_roundtrip(plan: &ExecPlan, pack: &[u8], bench: &str) -> Result<ExecPlan> {
    let loaded = ExecPlan::from_modelpack(pack)
        .with_context(|| format!("reloading the {bench} pack"))?;
    let ds = crate::data::make_dataset(bench, crate::data::Split::Test, 1, 0);
    let feat = plan.feat();
    let mut arena = plan.arena();
    let want = plan.run_sample(&mut arena, &ds.x[..feat])?;
    let mut arena = loaded.arena();
    let got = loaded.run_sample(&mut arena, &ds.x[..feat])?;
    if got != want {
        bail!("{bench}: modelpack round-trip diverged from the compiled plan");
    }
    Ok(loaded)
}

/// Load one model's plan from a `.cwm` artifact and cross-check it
/// against what the registry was asked to serve: bench, backend, and
/// (when the pack records provenance — `cwmix compile` always writes
/// it) the assignment spec and synthetic-state seed.  Any mismatch
/// refuses the pack so a stale artifact can never silently serve
/// different numerics than the flags requested.
fn load_modelpack(
    path: &Path,
    bench: &str,
    backend: &str,
    cfg: &RegistryConfig,
) -> Result<(ExecPlan, u64)> {
    let mut bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    // fault hooks: an injected load error, or a deterministic one-byte
    // corruption the hostile-input-hardened loader must then reject —
    // either way the caller's fallback-to-compile path is what is
    // actually under test
    if let Some(msg) = cfg.faults.registry_load_error(bench) {
        bail!("{msg}");
    }
    if cfg.faults.corrupt_artifact(bench, &mut bytes) {
        eprintln!("model {bench}: artifact_corrupt fault flipped a byte of the pack");
    }
    let (plan, prov) = ExecPlan::from_modelpack_with_provenance(&bytes)
        .with_context(|| format!("loading {}", path.display()))?;
    if plan.bench() != bench {
        bail!("pack is for bench {:?}, not {bench:?}", plan.bench());
    }
    if plan.backend_name() != backend {
        bail!(
            "pack was compiled for backend {:?}, server wants {backend:?}",
            plan.backend_name()
        );
    }
    if let Some(prov) = prov {
        if prov.assignment != cfg.assignment || prov.seed != cfg.seed {
            bail!(
                "pack was compiled for assignment {:?} seed {}, server wants \
                 {:?} seed {}",
                prov.assignment,
                prov.seed,
                cfg.assignment,
                cfg.seed
            );
        }
    }
    Ok((plan, bytes.len() as u64))
}

impl ModelRegistry {
    /// Build every requested model (modelpack cold start when
    /// available, else compile) and start its batcher.
    pub fn build(cfg: &RegistryConfig) -> Result<ModelRegistry> {
        if cfg.benches.is_empty() {
            bail!("no benches to serve");
        }
        let backend = backend_by_name(&cfg.backend)?;
        let mut entries = BTreeMap::new();
        for bench in &cfg.benches {
            if entries.contains_key(bench) {
                bail!("bench {bench} listed twice");
            }
            let t0 = Instant::now();
            let pack_path = cfg.modelpack_dir.as_ref().map(|d| d.join(format!("{bench}.cwm")));
            let pack_path = match pack_path {
                Some(p) if p.exists() => Some(p),
                Some(p) => {
                    // the operator explicitly asked for cold starts; a
                    // missing artifact deserves as loud a note as a
                    // corrupt one, not a silent recompile
                    eprintln!(
                        "model {bench}: no modelpack at {} — compiling instead",
                        p.display()
                    );
                    None
                }
                None => None,
            };
            let mut startup = None;
            if let Some(path) = &pack_path {
                match load_modelpack(path, bench, backend.name(), cfg) {
                    Ok((plan, artifact_bytes)) => {
                        let micros = t0.elapsed().as_micros() as u64;
                        println!(
                            "model {bench}: cold start from {} ({artifact_bytes} B) \
                             in {micros} us",
                            path.display()
                        );
                        startup = Some((
                            plan,
                            StartupStats {
                                source: "modelpack",
                                micros,
                                artifact_bytes: Some(artifact_bytes),
                            },
                        ));
                    }
                    Err(e) => {
                        // a stale/corrupt artifact must not take the
                        // server down or silently change numerics
                        eprintln!(
                            "model {bench}: modelpack {} unusable ({e:#}); \
                             falling back to compile",
                            path.display()
                        );
                    }
                }
            }
            let (plan, startup) = match startup {
                Some(ps) => ps,
                None => {
                    let t0 = Instant::now();
                    let (_, _, plan) = build_model(
                        bench,
                        backend,
                        &cfg.assignment,
                        cfg.seed,
                        &cfg.artifacts,
                    )?;
                    let stats = StartupStats {
                        source: "compile",
                        micros: t0.elapsed().as_micros() as u64,
                        artifact_bytes: None,
                    };
                    (plan, stats)
                }
            };
            let plan = Arc::new(plan);
            let metrics = Arc::new(Metrics::default());
            let batcher = Batcher::start(
                Arc::clone(&plan),
                Arc::clone(&metrics),
                cfg.policy.clone(),
                WorkerOpts {
                    model: bench.clone(),
                    faults: Arc::clone(&cfg.faults),
                    supervisor: cfg.supervisor.clone(),
                },
            );
            entries.insert(
                bench.clone(),
                ModelEntry { name: bench.clone(), plan, batcher, metrics, startup },
            );
        }
        Ok(ModelRegistry { entries, policy: cfg.policy.clone() })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.get(name)
    }

    pub fn entries(&self) -> impl Iterator<Item = &ModelEntry> {
        self.entries.values()
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// `GET /v1/models` body.
    pub fn describe(&self) -> Json {
        Json::obj(vec![(
            "models",
            Json::Arr(self.entries.values().map(|e| e.describe(&self.policy)).collect()),
        )])
    }

    /// Stop every batcher (drains queues, joins workers).  Idempotent.
    pub fn shutdown(&self) {
        for e in self.entries.values() {
            e.batcher.shutdown();
        }
    }
}
