//! Lock-free serving metrics: counters, a batch-size histogram,
//! batch-efficiency gauges (mean *ridden* batch size, batch-plane hit
//! ratio — how much of the engine's cross-sample amortization the
//! traffic actually realizes) and a log-bucketed latency histogram,
//! scraped as JSON by `GET /metrics` or as Prometheus text exposition
//! by `GET /metrics?format=prometheus` ([`prometheus_text`]).
//!
//! Everything is plain relaxed atomics — there is **no lock anywhere**
//! on the record path and no sort under the scrape.  Latency
//! percentiles come from a fixed [`LatencyHist`]: exact unit buckets
//! below 32 µs, then [`LAT_SUB`] sub-buckets per power-of-two octave
//! (HDR-histogram style), so any reported quantile is within
//! `1/(2·LAT_SUB)` ≈ 3% of the true value while `record` is one
//! `fetch_add`.  The histogram accumulates over the process lifetime
//! (the `latency_window` JSON key reports the total count observed,
//! not a ring length — the key is kept for dashboard stability).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::minijson::Json;

/// Batch sizes `>= BATCH_HIST_MAX` share the last histogram bucket.
pub const BATCH_HIST_MAX: usize = 32;

/// Latency-histogram resolution: sub-buckets per octave.  Values below
/// `2 * LAT_SUB` land in exact unit buckets (width 1); above that,
/// bucket width is `2^e` for the octave starting at `LAT_SUB << e`,
/// i.e. relative quantile error ≤ `1/(2·LAT_SUB)`.
pub const LAT_SUB: usize = 16;

/// Bucket count covering the full clamped `u32` microsecond range:
/// `LAT_SUB` exact leading buckets + 28 octaves × `LAT_SUB`.
const LAT_BUCKETS: usize = LAT_SUB + 28 * LAT_SUB;

/// Bucket index for a microsecond latency (clamped to `u32`).
fn lat_bucket(us: u64) -> usize {
    let v = us.min(u32::MAX as u64) as u32;
    if (v as usize) < LAT_SUB {
        return v as usize;
    }
    let e = (31 - v.leading_zeros()) as usize - 4;
    LAT_SUB + e * LAT_SUB + ((v >> e) as usize - LAT_SUB)
}

/// Inclusive lower bound of bucket `i`.
fn lat_bucket_lo(i: usize) -> u64 {
    if i < LAT_SUB {
        i as u64
    } else {
        let e = (i - LAT_SUB) / LAT_SUB;
        ((LAT_SUB + (i - LAT_SUB) % LAT_SUB) as u64) << e
    }
}

/// Width of bucket `i` (1 for the exact range, else the octave step).
fn lat_bucket_width(i: usize) -> u64 {
    if i < LAT_SUB {
        1
    } else {
        1u64 << ((i - LAT_SUB) / LAT_SUB)
    }
}

/// Fixed log-bucketed latency histogram; see the module docs.
struct LatencyHist {
    buckets: [AtomicU64; LAT_BUCKETS],
}

impl LatencyHist {
    fn new() -> LatencyHist {
        LatencyHist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn record(&self, us: u64) {
        self.buckets[lat_bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// `(p50_us, p99_us, p999_us, n)` derived from the buckets.  A
    /// quantile's representative value is the bucket midpoint (the
    /// exact value for width-1 buckets); the rank convention matches
    /// sorted-array indexing `sorted[round(q * (n-1))]`.
    fn summary(&self) -> (u64, u64, u64, u64) {
        let counts: Vec<u64> =
            self.buckets.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return (0, 0, 0, 0);
        }
        let at = |q: f64| -> u64 {
            let rank = (q * (total - 1) as f64).round() as u64;
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate() {
                cum += c;
                if cum > rank {
                    return lat_bucket_lo(i) + lat_bucket_width(i) / 2;
                }
            }
            lat_bucket_lo(LAT_BUCKETS - 1)
        };
        (at(0.50), at(0.99), at(0.999), total)
    }
}

/// Per-model (or aggregate) serving metrics.
pub struct Metrics {
    /// requests accepted into the queue
    requests: AtomicU64,
    /// requests refused because the queue was full (overload shed)
    shed: AtomicU64,
    /// requests answered with an error after admission
    errors: AtomicU64,
    /// engine calls executed by the batcher
    batches: AtomicU64,
    /// samples executed (sum of batch sizes)
    samples: AtomicU64,
    /// sum of batch² over executed batches — numerator of the
    /// per-sample ("ridden") mean batch size Σb²/Σb
    samples_sq: AtomicU64,
    /// samples that rode a coalesced batch (size ≥ 2), i.e. shared
    /// their batch-plane pass with at least one other sample
    coalesced: AtomicU64,
    /// executed batch-size histogram; bucket `i` = size `i + 1`, and
    /// the last bucket (`BATCH_HIST_MAX`) absorbs every size `>=`
    /// [`BATCH_HIST_MAX`] — its JSON label is `"32+"`.  The snapshot is
    /// **sparse**: all-zero buckets are omitted, including the
    /// clamp bucket (a dashboard reads a missing key as 0).
    batch_hist: [AtomicU64; BATCH_HIST_MAX],
    lat: LatencyHist,
    /// worker panics caught by the supervisor
    worker_panics: AtomicU64,
    /// worker respawns performed by the supervisor
    worker_respawns: AtomicU64,
    /// requests answered 504 at dequeue (deadline already passed)
    deadline_expired: AtomicU64,
    /// submits refused because the circuit breaker was open
    breaker_rejects: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            samples_sq: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            lat: LatencyHist::new(),
            worker_panics: AtomicU64::new(0),
            worker_respawns: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            breaker_rejects: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_respawn(&self) {
        self.worker_respawns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_breaker_reject(&self) {
        self.breaker_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// One executed batch of `size` samples.
    pub fn record_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.samples.fetch_add(size as u64, Ordering::Relaxed);
        self.samples_sq.fetch_add((size * size) as u64, Ordering::Relaxed);
        if size >= 2 {
            self.coalesced.fetch_add(size as u64, Ordering::Relaxed);
        }
        let bucket = size.min(BATCH_HIST_MAX) - 1;
        self.batch_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// End-to-end latency of one answered request (admission → reply):
    /// one relaxed `fetch_add` into a log bucket, no lock, panic-immune.
    pub fn record_latency_us(&self, us: u64) {
        self.lat.record(us);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    pub fn worker_respawns(&self) -> u64 {
        self.worker_respawns.load(Ordering::Relaxed)
    }

    pub fn deadline_expired(&self) -> u64 {
        self.deadline_expired.load(Ordering::Relaxed)
    }

    pub fn breaker_rejects(&self) -> u64 {
        self.breaker_rejects.load(Ordering::Relaxed)
    }

    /// Mean executed batch size (0 when nothing ran yet).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Mean batch size a *sample* rode in (`Σb² / Σb`): the
    /// sample-weighted view of coalescing, which is what amortization
    /// scales with — a stream of 7-sample batches plus stray singles
    /// reads ~7 here even though `mean_batch` is dragged down.
    pub fn mean_ridden_batch(&self) -> f64 {
        let s = self.samples.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.samples_sq.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Fraction of executed samples that shared their batch-plane pass
    /// with at least one other sample (rode a batch of ≥ 2) — how often
    /// the engine's cross-sample amortization actually engaged.
    pub fn batch_plane_hit_ratio(&self) -> f64 {
        let s = self.samples.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.coalesced.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// JSON snapshot for `/metrics`.  `latency_window` is the total
    /// number of latencies observed (histogram population, not a ring
    /// length); `batch_size_hist` is sparse — see the field docs.
    pub fn snapshot(&self) -> Json {
        let (p50, p99, p999, window) = self.lat.summary();
        let hist: Vec<(String, Json)> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then(|| {
                    let label = if i + 1 == BATCH_HIST_MAX {
                        format!("{}+", BATCH_HIST_MAX)
                    } else {
                        format!("{}", i + 1)
                    };
                    (label, Json::num(n as f64))
                })
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::num(self.requests() as f64)),
            ("shed", Json::num(self.shed() as f64)),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("samples", Json::num(self.samples.load(Ordering::Relaxed) as f64)),
            ("mean_batch", Json::num(self.mean_batch())),
            ("mean_ridden_batch", Json::num(self.mean_ridden_batch())),
            ("batch_plane_hit_ratio", Json::num(self.batch_plane_hit_ratio())),
            ("latency_p50_us", Json::num(p50 as f64)),
            ("latency_p99_us", Json::num(p99 as f64)),
            ("latency_p999_us", Json::num(p999 as f64)),
            ("latency_window", Json::num(window as f64)),
            ("batch_size_hist", Json::Obj(hist.into_iter().collect())),
            ("worker_panics", Json::num(self.worker_panics() as f64)),
            ("worker_respawns", Json::num(self.worker_respawns() as f64)),
            ("deadline_expired_total", Json::num(self.deadline_expired() as f64)),
            ("breaker_rejects", Json::num(self.breaker_rejects() as f64)),
        ])
    }
}

/// Plan-level fused-requantize gauges for a `/metrics` body: compile-time
/// facts of the served [`ExecPlan`](crate::engine::ExecPlan), not runtime
/// counters — they change only when the plan changes, and give an
/// operator the fusion coverage (`fused edges / total quantized edges`)
/// and residual-plane reuse the engine is running with.
pub fn fusion_gauges(f: &crate::engine::FusionStats) -> Vec<(&'static str, Json)> {
    vec![
        ("requant_fused_ratio", Json::num(f.fused_ratio())),
        ("residual_plane_reuse_hits", Json::num(f.reuse_hits as f64)),
    ]
}

/// Kernel-dispatch gauges for a `/metrics` body: which backend a model's
/// plan compiled against and which SIMD tier its kernels dispatched to
/// at load (`swar` for the universal fallback and for non-simd
/// backends, where the tier is just the backend name).  Compile-time
/// facts like [`fusion_gauges`], not runtime counters.
pub fn kernel_gauges(backend: &str, tier: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("kernel_backend", Json::str(backend)),
        ("kernel_tier", Json::str(tier)),
    ]
}

/// Append one Prometheus text-exposition sample: `name{labels} value`.
/// Integral values print without a fraction; label values are emitted
/// verbatim (callers pass model/quantile names that need no escaping).
pub fn prom_sample(out: &mut String, name: &str, labels: &[(&str, &str)], v: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, val)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(val);
            out.push('"');
        }
        out.push('}');
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        out.push_str(&format!(" {}\n", v as i64));
    } else {
        out.push_str(&format!(" {v}\n"));
    }
}

/// Prometheus text exposition (`GET /metrics?format=prometheus`) over a
/// set of `(model, metrics)` pairs.  Name-major: each family's
/// `# TYPE` header appears once, followed by one sample per model with
/// a `model="…"` label.  The metric names below are a stable scrape
/// interface — `prometheus_names_are_stable` pins them.
pub fn prometheus_text(models: &[(&str, &Metrics)]) -> String {
    type Get = fn(&Metrics) -> f64;
    const COUNTERS: &[(&str, &str, Get)] = &[
        ("cwmix_requests_total", "requests accepted into the queue", |m| {
            m.requests() as f64
        }),
        ("cwmix_shed_total", "requests refused at admission (queue full)", |m| {
            m.shed() as f64
        }),
        ("cwmix_errors_total", "requests answered with an error after admission", |m| {
            m.errors() as f64
        }),
        ("cwmix_batches_total", "engine calls executed by the batcher", |m| {
            m.batches.load(Ordering::Relaxed) as f64
        }),
        ("cwmix_samples_total", "samples executed (sum of batch sizes)", |m| {
            m.samples.load(Ordering::Relaxed) as f64
        }),
        ("cwmix_worker_panics_total", "worker panics caught by the supervisor", |m| {
            m.worker_panics() as f64
        }),
        ("cwmix_worker_respawns_total", "worker respawns by the supervisor", |m| {
            m.worker_respawns() as f64
        }),
        ("cwmix_deadline_expired_total", "requests answered 504 at dequeue", |m| {
            m.deadline_expired() as f64
        }),
        ("cwmix_breaker_rejects_total", "submits refused by the open breaker", |m| {
            m.breaker_rejects() as f64
        }),
    ];
    const GAUGES: &[(&str, &str, Get)] = &[
        ("cwmix_mean_batch", "mean executed batch size", |m| m.mean_batch()),
        ("cwmix_mean_ridden_batch", "sample-weighted mean batch size", |m| {
            m.mean_ridden_batch()
        }),
        (
            "cwmix_batch_plane_hit_ratio",
            "fraction of samples that rode a coalesced batch",
            |m| m.batch_plane_hit_ratio(),
        ),
    ];
    let mut out = String::new();
    for (kind, fams) in [("counter", COUNTERS), ("gauge", GAUGES)] {
        for (name, help, get) in fams {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
            for (model, m) in models {
                prom_sample(&mut out, name, &[("model", model)], get(m));
            }
        }
    }
    out.push_str(
        "# HELP cwmix_latency_us end-to-end request latency (microseconds)\n\
         # TYPE cwmix_latency_us summary\n",
    );
    for (model, m) in models {
        let (p50, p99, p999, n) = m.lat.summary();
        for (q, v) in [("0.5", p50), ("0.99", p99), ("0.999", p999)] {
            prom_sample(
                &mut out,
                "cwmix_latency_us",
                &[("model", model), ("quantile", q)],
                v as f64,
            );
        }
        prom_sample(&mut out, "cwmix_latency_us_count", &[("model", model)], n as f64);
    }
    out.push_str(
        "# HELP cwmix_batch_size executed batch sizes\n\
         # TYPE cwmix_batch_size histogram\n",
    );
    for (model, m) in models {
        let mut cum = 0u64;
        for i in 0..BATCH_HIST_MAX - 1 {
            cum += m.batch_hist[i].load(Ordering::Relaxed);
            let le = format!("{}", i + 1);
            prom_sample(
                &mut out,
                "cwmix_batch_size_bucket",
                &[("model", model), ("le", &le)],
                cum as f64,
            );
        }
        cum += m.batch_hist[BATCH_HIST_MAX - 1].load(Ordering::Relaxed);
        prom_sample(
            &mut out,
            "cwmix_batch_size_bucket",
            &[("model", model), ("le", "+Inf")],
            cum as f64,
        );
        prom_sample(
            &mut out,
            "cwmix_batch_size_sum",
            &[("model", model)],
            m.samples.load(Ordering::Relaxed) as f64,
        );
        prom_sample(
            &mut out,
            "cwmix_batch_size_count",
            &[("model", model)],
            m.batches.load(Ordering::Relaxed) as f64,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histogram() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_shed();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch(BATCH_HIST_MAX + 10); // clamps into the last bucket
        assert_eq!(m.requests(), 2);
        assert_eq!(m.shed(), 1);
        let snap = m.snapshot();
        let hist = snap.get("batch_size_hist").unwrap().as_obj().unwrap();
        assert_eq!(hist["1"].as_f64().unwrap(), 1.0);
        assert_eq!(hist["4"].as_f64().unwrap(), 2.0);
        assert_eq!(hist["32+"].as_f64().unwrap(), 1.0);
        assert_eq!(snap.get("batches").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn mean_batch_over_executions() {
        let m = Metrics::default();
        assert_eq!(m.mean_batch(), 0.0);
        m.record_batch(2);
        m.record_batch(6);
        assert_eq!(m.mean_batch(), 4.0);
    }

    #[test]
    fn batch_efficiency_gauges() {
        let m = Metrics::default();
        // nothing executed yet: both gauges well-defined at 0
        assert_eq!(m.mean_ridden_batch(), 0.0);
        assert_eq!(m.batch_plane_hit_ratio(), 0.0);
        // 7 single-sample batches + one 7-sample batch: 14 samples,
        // half of which rode a coalesced batch-plane pass
        for _ in 0..7 {
            m.record_batch(1);
        }
        m.record_batch(7);
        assert_eq!(m.batch_plane_hit_ratio(), 0.5);
        // per-sample ridden mean (7*1 + 49)/14 = 4, vs mean_batch 1.75
        assert_eq!(m.mean_ridden_batch(), 4.0);
        assert_eq!(m.mean_batch(), 1.75);
        let snap = m.snapshot();
        assert_eq!(snap.get("mean_ridden_batch").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(
            snap.get("batch_plane_hit_ratio").unwrap().as_f64().unwrap(),
            0.5
        );
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::default();
        for us in 1..=100u64 {
            m.record_latency_us(us);
        }
        let snap = m.snapshot();
        let p50 = snap.get("latency_p50_us").unwrap().as_f64().unwrap();
        let p99 = snap.get("latency_p99_us").unwrap().as_f64().unwrap();
        assert!((49.0..=52.0).contains(&p50), "p50 {p50}");
        assert!((98.0..=100.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn latency_hist_exact_below_resolution() {
        // values under 2 * LAT_SUB land in width-1 buckets: quantiles
        // of a constant stream are exact, and the window is the total
        // population (the histogram never evicts)
        let m = Metrics::default();
        for _ in 0..5000 {
            m.record_latency_us(10);
        }
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_p50_us").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(snap.get("latency_p99_us").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(snap.get("latency_p999_us").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(snap.get("latency_window").unwrap().as_f64().unwrap(), 5000.0);
    }

    #[test]
    fn latency_bucket_scheme_round_trips() {
        // every index must own a contiguous value range: lo(i) maps
        // back to i, and lo(i) + width(i) is lo(i + 1)
        for i in 0..LAT_BUCKETS - 1 {
            assert_eq!(lat_bucket(lat_bucket_lo(i)), i, "lo of bucket {i}");
            assert_eq!(
                lat_bucket_lo(i) + lat_bucket_width(i),
                lat_bucket_lo(i + 1),
                "bucket {i} not contiguous"
            );
        }
        // clamp: anything ≥ u32::MAX lands in the last bucket
        assert_eq!(lat_bucket(u64::MAX), lat_bucket(u32::MAX as u64));
    }

    #[test]
    fn latency_p999_tracks_tail() {
        let m = Metrics::default();
        for _ in 0..999 {
            m.record_latency_us(100);
        }
        m.record_latency_us(100_000);
        let snap = m.snapshot();
        let p99 = snap.get("latency_p99_us").unwrap().as_f64().unwrap();
        let p999 = snap.get("latency_p999_us").unwrap().as_f64().unwrap();
        assert!((95.0..=105.0).contains(&p99), "p99 {p99}");
        // one-in-a-thousand outlier visible only at p999, within the
        // 1/(2·LAT_SUB) relative bucket error
        assert!((95_000.0..=105_000.0).contains(&p999), "p999 {p999}");
    }

    #[test]
    fn batch_hist_boundary_size_clamps_with_label() {
        let m = Metrics::default();
        m.record_batch(BATCH_HIST_MAX); // exactly at the clamp boundary
        let snap = m.snapshot();
        let hist = snap.get("batch_size_hist").unwrap().as_obj().unwrap();
        assert_eq!(hist.len(), 1, "sparse: only the hit bucket is emitted");
        assert_eq!(hist["32+"].as_f64().unwrap(), 1.0);
        // the clamp bucket is indistinguishable from larger sizes
        m.record_batch(BATCH_HIST_MAX + 1);
        let snap = m.snapshot();
        let hist = snap.get("batch_size_hist").unwrap().as_obj().unwrap();
        assert_eq!(hist["32+"].as_f64().unwrap(), 2.0);
    }

    #[test]
    fn prometheus_names_are_stable() {
        let m = Metrics::default();
        m.record_request();
        m.record_batch(3);
        m.record_latency_us(42);
        let text = prometheus_text(&[("kws", &m)]);
        for name in [
            "# TYPE cwmix_requests_total counter",
            "# TYPE cwmix_shed_total counter",
            "# TYPE cwmix_errors_total counter",
            "# TYPE cwmix_batches_total counter",
            "# TYPE cwmix_samples_total counter",
            "# TYPE cwmix_worker_panics_total counter",
            "# TYPE cwmix_worker_respawns_total counter",
            "# TYPE cwmix_deadline_expired_total counter",
            "# TYPE cwmix_breaker_rejects_total counter",
            "# TYPE cwmix_mean_batch gauge",
            "# TYPE cwmix_mean_ridden_batch gauge",
            "# TYPE cwmix_batch_plane_hit_ratio gauge",
            "# TYPE cwmix_latency_us summary",
            "# TYPE cwmix_batch_size histogram",
        ] {
            assert!(text.contains(name), "missing exposition line: {name}");
        }
        assert!(text.contains("cwmix_requests_total{model=\"kws\"} 1\n"));
        assert!(text.contains("cwmix_latency_us{model=\"kws\",quantile=\"0.5\"} 42\n"));
        assert!(text.contains("cwmix_latency_us_count{model=\"kws\"} 1\n"));
        // histogram buckets are cumulative and end at +Inf
        assert!(text.contains("cwmix_batch_size_bucket{model=\"kws\",le=\"3\"} 1\n"));
        assert!(text.contains("cwmix_batch_size_bucket{model=\"kws\",le=\"+Inf\"} 1\n"));
        assert!(text.contains("cwmix_batch_size_sum{model=\"kws\"} 3\n"));
        assert!(text.contains("cwmix_batch_size_count{model=\"kws\"} 1\n"));
    }

    #[test]
    fn prometheus_multi_model_is_name_major() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.record_request();
        let text = prometheus_text(&[("a", &a), ("b", &b)]);
        let ra = text.find("cwmix_requests_total{model=\"a\"} 1").unwrap();
        let rb = text.find("cwmix_requests_total{model=\"b\"} 0").unwrap();
        let shed = text.find("# TYPE cwmix_shed_total").unwrap();
        assert!(ra < rb && rb < shed, "samples grouped under one TYPE header");
    }

    #[test]
    fn kernel_gauges_name_backend_and_tier() {
        let g = kernel_gauges("simd", "avx2");
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].1.as_str().unwrap(), "simd");
        assert_eq!(g[1].0, "kernel_tier");
        assert_eq!(g[1].1.as_str().unwrap(), "avx2");
    }

    #[test]
    fn zero_size_batch_ignored() {
        let m = Metrics::default();
        m.record_batch(0);
        let snap = m.snapshot();
        assert_eq!(snap.get("batches").unwrap().as_f64().unwrap(), 0.0);
    }
}
