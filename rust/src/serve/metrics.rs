//! Lock-light serving metrics: counters, a batch-size histogram,
//! batch-efficiency gauges (mean *ridden* batch size, batch-plane hit
//! ratio — how much of the engine's cross-sample amortization the
//! traffic actually realizes) and a latency reservoir, scraped as JSON
//! by `GET /metrics`.
//!
//! Counters and the histogram are plain relaxed atomics (every request
//! touches them on the hot path).  Latency percentiles need ordered
//! data, so [`Metrics`] keeps a fixed-size ring of the most recent
//! request latencies behind a `Mutex` — recording is a push into a
//! preallocated slot, and the sort cost is paid only when `/metrics` is
//! scraped.  p50/p99 over the last [`LATENCY_RING`] requests is what an
//! operator dashboards; a full streaming quantile sketch would be
//! overkill for this surface.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::minijson::Json;

use super::supervisor::lock_unpoisoned;

/// Batch sizes `>= BATCH_HIST_MAX` share the last histogram bucket.
pub const BATCH_HIST_MAX: usize = 32;

/// Latency reservoir length (most recent requests).
pub const LATENCY_RING: usize = 4096;

/// Recent-latency ring: fixed storage, overwrites oldest.
struct LatencyRing {
    us: Vec<u32>,
    pos: usize,
    filled: bool,
}

impl LatencyRing {
    fn record(&mut self, us: u64) {
        let v = us.min(u32::MAX as u64) as u32;
        if self.us.len() < LATENCY_RING {
            self.us.push(v);
        } else {
            self.us[self.pos] = v;
            self.filled = true;
        }
        self.pos = (self.pos + 1) % LATENCY_RING;
    }

    /// (p50_us, p99_us, n) over the retained window.
    fn percentiles(&self) -> (u32, u32, usize) {
        let n = if self.filled { LATENCY_RING } else { self.us.len() };
        if n == 0 {
            return (0, 0, 0);
        }
        let mut sorted = self.us[..n].to_vec();
        sorted.sort_unstable();
        let at = |p: f64| sorted[((n - 1) as f64 * p).round() as usize];
        (at(0.50), at(0.99), n)
    }
}

/// Per-model (or aggregate) serving metrics.
pub struct Metrics {
    /// requests accepted into the queue
    requests: AtomicU64,
    /// requests refused because the queue was full (overload shed)
    shed: AtomicU64,
    /// requests answered with an error after admission
    errors: AtomicU64,
    /// engine calls executed by the batcher
    batches: AtomicU64,
    /// samples executed (sum of batch sizes)
    samples: AtomicU64,
    /// sum of batch² over executed batches — numerator of the
    /// per-sample ("ridden") mean batch size Σb²/Σb
    samples_sq: AtomicU64,
    /// samples that rode a coalesced batch (size ≥ 2), i.e. shared
    /// their batch-plane pass with at least one other sample
    coalesced: AtomicU64,
    /// executed batch-size histogram; bucket `i` = size `i + 1`
    batch_hist: [AtomicU64; BATCH_HIST_MAX],
    lat: Mutex<LatencyRing>,
    /// worker panics caught by the supervisor
    worker_panics: AtomicU64,
    /// worker respawns performed by the supervisor
    worker_respawns: AtomicU64,
    /// requests answered 504 at dequeue (deadline already passed)
    deadline_expired: AtomicU64,
    /// submits refused because the circuit breaker was open
    breaker_rejects: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            samples_sq: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            batch_hist: std::array::from_fn(|_| AtomicU64::new(0)),
            lat: Mutex::new(LatencyRing { us: Vec::new(), pos: 0, filled: false }),
            worker_panics: AtomicU64::new(0),
            worker_respawns: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            breaker_rejects: AtomicU64::new(0),
        }
    }
}

impl Metrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_worker_respawn(&self) {
        self.worker_respawns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_breaker_reject(&self) {
        self.breaker_rejects.fetch_add(1, Ordering::Relaxed);
    }

    /// One executed batch of `size` samples.
    pub fn record_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.samples.fetch_add(size as u64, Ordering::Relaxed);
        self.samples_sq.fetch_add((size * size) as u64, Ordering::Relaxed);
        if size >= 2 {
            self.coalesced.fetch_add(size as u64, Ordering::Relaxed);
        }
        let bucket = size.min(BATCH_HIST_MAX) - 1;
        self.batch_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// End-to-end latency of one answered request (admission → reply).
    /// Poison-free: a latency record must survive any past panic.
    pub fn record_latency_us(&self, us: u64) {
        lock_unpoisoned(&self.lat).record(us);
    }

    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    pub fn worker_respawns(&self) -> u64 {
        self.worker_respawns.load(Ordering::Relaxed)
    }

    pub fn deadline_expired(&self) -> u64 {
        self.deadline_expired.load(Ordering::Relaxed)
    }

    pub fn breaker_rejects(&self) -> u64 {
        self.breaker_rejects.load(Ordering::Relaxed)
    }

    /// Mean executed batch size (0 when nothing ran yet).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Mean batch size a *sample* rode in (`Σb² / Σb`): the
    /// sample-weighted view of coalescing, which is what amortization
    /// scales with — a stream of 7-sample batches plus stray singles
    /// reads ~7 here even though `mean_batch` is dragged down.
    pub fn mean_ridden_batch(&self) -> f64 {
        let s = self.samples.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.samples_sq.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Fraction of executed samples that shared their batch-plane pass
    /// with at least one other sample (rode a batch of ≥ 2) — how often
    /// the engine's cross-sample amortization actually engaged.
    pub fn batch_plane_hit_ratio(&self) -> f64 {
        let s = self.samples.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.coalesced.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// JSON snapshot for `/metrics`.
    pub fn snapshot(&self) -> Json {
        let (p50, p99, window) = lock_unpoisoned(&self.lat).percentiles();
        let hist: Vec<(String, Json)> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then(|| {
                    let label = if i + 1 == BATCH_HIST_MAX {
                        format!("{}+", BATCH_HIST_MAX)
                    } else {
                        format!("{}", i + 1)
                    };
                    (label, Json::num(n as f64))
                })
            })
            .collect();
        Json::obj(vec![
            ("requests", Json::num(self.requests() as f64)),
            ("shed", Json::num(self.shed() as f64)),
            ("errors", Json::num(self.errors.load(Ordering::Relaxed) as f64)),
            ("batches", Json::num(self.batches.load(Ordering::Relaxed) as f64)),
            ("samples", Json::num(self.samples.load(Ordering::Relaxed) as f64)),
            ("mean_batch", Json::num(self.mean_batch())),
            ("mean_ridden_batch", Json::num(self.mean_ridden_batch())),
            ("batch_plane_hit_ratio", Json::num(self.batch_plane_hit_ratio())),
            ("latency_p50_us", Json::num(p50 as f64)),
            ("latency_p99_us", Json::num(p99 as f64)),
            ("latency_window", Json::num(window as f64)),
            ("batch_size_hist", Json::Obj(hist.into_iter().collect())),
            ("worker_panics", Json::num(self.worker_panics() as f64)),
            ("worker_respawns", Json::num(self.worker_respawns() as f64)),
            ("deadline_expired_total", Json::num(self.deadline_expired() as f64)),
            ("breaker_rejects", Json::num(self.breaker_rejects() as f64)),
        ])
    }
}

/// Plan-level fused-requantize gauges for a `/metrics` body: compile-time
/// facts of the served [`ExecPlan`](crate::engine::ExecPlan), not runtime
/// counters — they change only when the plan changes, and give an
/// operator the fusion coverage (`fused edges / total quantized edges`)
/// and residual-plane reuse the engine is running with.
pub fn fusion_gauges(f: &crate::engine::FusionStats) -> Vec<(&'static str, Json)> {
    vec![
        ("requant_fused_ratio", Json::num(f.fused_ratio())),
        ("residual_plane_reuse_hits", Json::num(f.reuse_hits as f64)),
    ]
}

/// Kernel-dispatch gauges for a `/metrics` body: which backend a model's
/// plan compiled against and which SIMD tier its kernels dispatched to
/// at load (`swar` for the universal fallback and for non-simd
/// backends, where the tier is just the backend name).  Compile-time
/// facts like [`fusion_gauges`], not runtime counters.
pub fn kernel_gauges(backend: &str, tier: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("kernel_backend", Json::str(backend)),
        ("kernel_tier", Json::str(tier)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histogram() {
        let m = Metrics::default();
        m.record_request();
        m.record_request();
        m.record_shed();
        m.record_batch(1);
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch(BATCH_HIST_MAX + 10); // clamps into the last bucket
        assert_eq!(m.requests(), 2);
        assert_eq!(m.shed(), 1);
        let snap = m.snapshot();
        let hist = snap.get("batch_size_hist").unwrap().as_obj().unwrap();
        assert_eq!(hist["1"].as_f64().unwrap(), 1.0);
        assert_eq!(hist["4"].as_f64().unwrap(), 2.0);
        assert_eq!(hist["32+"].as_f64().unwrap(), 1.0);
        assert_eq!(snap.get("batches").unwrap().as_f64().unwrap(), 4.0);
    }

    #[test]
    fn mean_batch_over_executions() {
        let m = Metrics::default();
        assert_eq!(m.mean_batch(), 0.0);
        m.record_batch(2);
        m.record_batch(6);
        assert_eq!(m.mean_batch(), 4.0);
    }

    #[test]
    fn batch_efficiency_gauges() {
        let m = Metrics::default();
        // nothing executed yet: both gauges well-defined at 0
        assert_eq!(m.mean_ridden_batch(), 0.0);
        assert_eq!(m.batch_plane_hit_ratio(), 0.0);
        // 7 single-sample batches + one 7-sample batch: 14 samples,
        // half of which rode a coalesced batch-plane pass
        for _ in 0..7 {
            m.record_batch(1);
        }
        m.record_batch(7);
        assert_eq!(m.batch_plane_hit_ratio(), 0.5);
        // per-sample ridden mean (7*1 + 49)/14 = 4, vs mean_batch 1.75
        assert_eq!(m.mean_ridden_batch(), 4.0);
        assert_eq!(m.mean_batch(), 1.75);
        let snap = m.snapshot();
        assert_eq!(snap.get("mean_ridden_batch").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(
            snap.get("batch_plane_hit_ratio").unwrap().as_f64().unwrap(),
            0.5
        );
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::default();
        for us in 1..=100u64 {
            m.record_latency_us(us);
        }
        let snap = m.snapshot();
        let p50 = snap.get("latency_p50_us").unwrap().as_f64().unwrap();
        let p99 = snap.get("latency_p99_us").unwrap().as_f64().unwrap();
        assert!((49.0..=52.0).contains(&p50), "p50 {p50}");
        assert!((98.0..=100.0).contains(&p99), "p99 {p99}");
    }

    #[test]
    fn latency_ring_wraps() {
        let m = Metrics::default();
        for _ in 0..LATENCY_RING {
            m.record_latency_us(1_000_000); // old, should be evicted
        }
        for _ in 0..LATENCY_RING {
            m.record_latency_us(10);
        }
        let snap = m.snapshot();
        assert_eq!(snap.get("latency_p99_us").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(
            snap.get("latency_window").unwrap().as_f64().unwrap(),
            LATENCY_RING as f64
        );
    }

    #[test]
    fn kernel_gauges_name_backend_and_tier() {
        let g = kernel_gauges("simd", "avx2");
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].1.as_str().unwrap(), "simd");
        assert_eq!(g[1].0, "kernel_tier");
        assert_eq!(g[1].1.as_str().unwrap(), "avx2");
    }

    #[test]
    fn zero_size_batch_ignored() {
        let m = Metrics::default();
        m.record_batch(0);
        let snap = m.snapshot();
        assert_eq!(snap.get("batches").unwrap().as_f64().unwrap(), 0.0);
    }
}
