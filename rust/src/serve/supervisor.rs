//! Worker supervision: panic isolation, bounded-backoff respawn, and a
//! per-model circuit breaker.
//!
//! Before this module, one engine panic permanently killed a model's
//! batcher worker — every later request to that model wedged until the
//! HTTP reply timeout — and the panic poisoned the queue mutex, so even
//! *touching* the queue from an HTTP thread cascaded the panic.  The
//! supervisor turns an engine panic into a bounded, observable event:
//!
//! ```text
//!        supervisor thread (one per model)
//!   ┌──▶ catch_unwind( worker_loop )
//!   │        │ Ok(())          → clean shutdown, exit
//!   │        │ Err(panic)      → riders of the in-flight batch see an
//!   │        ▼                   error; queued requests stay queued
//!   │    on_panic(): consecutive += 1
//!   │        │ consecutive ≥ K, or panic while half-open
//!   │        ▼
//!   │    breaker OPEN for cooldown·2^(opens-1) (capped):
//!   │      submit() → 503 + Retry-After, no queueing
//!   │        │ cooldown elapsed → HALF-OPEN: probe traffic admitted
//!   └── backoff (base·2^(consecutive-1), capped), then respawn with a
//!       FRESH arena; first successful batch → consecutive = 0,
//!       breaker CLOSED
//! ```
//!
//! **Poison-free locking:** a panicking worker must never make the
//! queue unusable for threads that merely submit.  [`lock_unpoisoned`]
//! and the condvar wrappers recover the inner guard from a poisoned
//! lock (`PoisonError::into_inner`) — correct here because every
//! critical section over the shared queue leaves it structurally valid
//! at every await/panic point (push/drain of whole `Pending` entries,
//! no partial states).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::metrics::Metrics;

/// Lock a mutex, recovering the guard if a panicking holder poisoned
/// it.  See the module docs for why this is sound for serve's locks.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait` with poison recovery.
pub fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` with poison recovery; returns
/// `(guard, timed_out)`.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

/// Supervision knobs (per model).
#[derive(Clone, Debug)]
pub struct SupervisorCfg {
    /// Consecutive worker panics that open the circuit breaker.
    pub breaker_k: u32,
    /// First breaker-open duration; doubles per consecutive open.
    pub cooldown_ms: u64,
    /// Ceiling on the doubled cooldown.
    pub cooldown_cap_ms: u64,
    /// First respawn backoff; doubles per consecutive panic.
    pub backoff_base_ms: u64,
    /// Ceiling on the doubled backoff.
    pub backoff_cap_ms: u64,
}

impl Default for SupervisorCfg {
    fn default() -> SupervisorCfg {
        SupervisorCfg {
            breaker_k: 3,
            cooldown_ms: 1_000,
            cooldown_cap_ms: 30_000,
            backoff_base_ms: 10,
            backoff_cap_ms: 2_000,
        }
    }
}

/// Circuit-breaker state, exported by `/readyz` and `/metrics`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation.
    Closed,
    /// Cooling down after the open; probe traffic is admitted.
    HalfOpen,
    /// Refusing requests (503 + `Retry-After`).
    Open,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half_open",
            BreakerState::Open => "open",
        }
    }

    /// Numeric gauge encoding: 0 closed, 1 half-open, 2 open.
    pub fn code(self) -> u32 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }
}

struct BreakerInner {
    state: BreakerState,
    /// Valid while `state == Open`.
    open_until: Instant,
    /// Consecutive opens (cooldown doubling); reset when the breaker
    /// closes.
    opens_run: u32,
}

/// Per-model supervision state: panic counters + the circuit breaker.
/// Shared between the supervisor thread (records outcomes) and the
/// submit/HTTP paths (admission + gauges).
pub struct Supervision {
    cfg: SupervisorCfg,
    consecutive: AtomicU32,
    panics: AtomicU64,
    respawns: AtomicU64,
    opens_total: AtomicU64,
    breaker: Mutex<BreakerInner>,
}

impl Supervision {
    pub fn new(cfg: SupervisorCfg) -> Supervision {
        Supervision {
            cfg,
            consecutive: AtomicU32::new(0),
            panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            opens_total: AtomicU64::new(0),
            breaker: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                open_until: Instant::now(),
                opens_run: 0,
            }),
        }
    }

    pub fn cfg(&self) -> &SupervisorCfg {
        &self.cfg
    }

    /// Total worker panics caught.
    pub fn panics(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    /// Total worker respawns performed.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Total breaker opens.
    pub fn breaker_opens(&self) -> u64 {
        self.opens_total.load(Ordering::Relaxed)
    }

    /// Current breaker state; an expired `Open` lazily becomes
    /// `HalfOpen` (probe traffic allowed).
    pub fn breaker_state(&self) -> BreakerState {
        let mut b = lock_unpoisoned(&self.breaker);
        if b.state == BreakerState::Open && Instant::now() >= b.open_until {
            b.state = BreakerState::HalfOpen;
        }
        b.state
    }

    /// Admission check for `submit`: `Err(retry_after_s)` while the
    /// breaker is open.  Half-open admits (the probe that can close
    /// the breaker again).
    pub fn admit(&self) -> Result<(), u64> {
        let mut b = lock_unpoisoned(&self.breaker);
        if b.state == BreakerState::Open {
            let now = Instant::now();
            if now >= b.open_until {
                b.state = BreakerState::HalfOpen;
            } else {
                let left = b.open_until - now;
                return Err(left.as_secs().max(1));
            }
        }
        Ok(())
    }

    /// A batch executed successfully: panics are no longer
    /// consecutive, and a half-open breaker closes.
    pub fn on_success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
        let mut b = lock_unpoisoned(&self.breaker);
        if b.state == BreakerState::HalfOpen {
            b.state = BreakerState::Closed;
        }
        if b.state == BreakerState::Closed {
            b.opens_run = 0;
        }
    }

    /// The worker panicked.  Returns the consecutive-panic count; the
    /// breaker opens at `breaker_k` consecutive panics, or immediately
    /// when the panic burned a half-open probe.
    pub fn on_panic(&self) -> u32 {
        self.panics.fetch_add(1, Ordering::Relaxed);
        let consecutive = self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        let mut b = lock_unpoisoned(&self.breaker);
        let probe_burned = b.state == BreakerState::HalfOpen;
        if consecutive >= self.cfg.breaker_k || probe_burned {
            b.opens_run = b.opens_run.saturating_add(1);
            let mult = 1u64 << (b.opens_run - 1).min(10);
            let cooldown = self
                .cfg
                .cooldown_ms
                .saturating_mul(mult)
                .min(self.cfg.cooldown_cap_ms);
            b.state = BreakerState::Open;
            b.open_until = Instant::now() + Duration::from_millis(cooldown);
            self.opens_total.fetch_add(1, Ordering::Relaxed);
        }
        consecutive
    }

    fn record_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    /// Respawn backoff for the current consecutive-panic run:
    /// `base · 2^(consecutive-1)`, capped.
    fn backoff(&self, consecutive: u32) -> Duration {
        let mult = 1u64 << consecutive.saturating_sub(1).min(16);
        Duration::from_millis(
            self.cfg
                .backoff_base_ms
                .saturating_mul(mult)
                .min(self.cfg.backoff_cap_ms),
        )
    }
}

/// Best-effort panic-payload message for the log line.
fn payload_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Run `body` (one worker lifetime) under supervision: a panic is
/// caught, recorded, backed off and respawned; a normal return (clean
/// shutdown) ends supervision.  `is_shutdown` keeps the backoff sleep
/// responsive — during shutdown the supervisor exits instead of
/// respawning, and the batcher's drain path answers what is queued.
///
/// `ctx` is sampled **at panic time** and spliced into the panic log
/// line — the batcher passes the in-flight request ids, so a chaos
/// failure is attributable to the exact requests that rode the fatal
/// batch (`key=value` form, e.g. `inflight=[12,13]`).
pub fn supervise<F, S, C>(
    name: &str,
    sup: &Supervision,
    metrics: &Metrics,
    is_shutdown: S,
    ctx: C,
    mut body: F,
) where
    F: FnMut(),
    S: Fn() -> bool,
    C: Fn() -> String,
{
    loop {
        match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(()) => return,
            Err(payload) => {
                let consecutive = sup.on_panic();
                metrics.record_worker_panic();
                let c = ctx();
                eprintln!(
                    "worker {name}: panic #{} (consecutive {consecutive}){}{c}: {}",
                    sup.panics(),
                    if c.is_empty() { "" } else { " " },
                    payload_msg(payload.as_ref()),
                );
                if is_shutdown() {
                    return;
                }
                // bounded exponential backoff, sliced so shutdown is
                // never blocked behind a long sleep
                let deadline = Instant::now() + sup.backoff(consecutive);
                loop {
                    if is_shutdown() {
                        return;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    std::thread::sleep((deadline - now).min(Duration::from_millis(10)));
                }
                sup.record_respawn();
                metrics.record_worker_respawn();
                eprintln!("worker {name}: respawning (respawn #{})", sup.respawns());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn cfg() -> SupervisorCfg {
        SupervisorCfg {
            breaker_k: 3,
            cooldown_ms: 40,
            cooldown_cap_ms: 400,
            backoff_base_ms: 1,
            backoff_cap_ms: 8,
        }
    }

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panics() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_unpoisoned(&m), 7, "value still accessible");
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }

    #[test]
    fn breaker_opens_after_k_consecutive_panics() {
        let sup = Supervision::new(cfg());
        assert_eq!(sup.breaker_state(), BreakerState::Closed);
        sup.on_panic();
        sup.on_panic();
        assert_eq!(sup.breaker_state(), BreakerState::Closed, "k-1 panics stay closed");
        assert!(sup.admit().is_ok());
        sup.on_panic();
        assert_eq!(sup.breaker_state(), BreakerState::Open);
        let ra = sup.admit().expect_err("open breaker must refuse");
        assert!(ra >= 1);
        assert_eq!(sup.breaker_opens(), 1);
    }

    #[test]
    fn success_resets_the_consecutive_run() {
        let sup = Supervision::new(cfg());
        sup.on_panic();
        sup.on_panic();
        sup.on_success();
        sup.on_panic();
        sup.on_panic();
        assert_eq!(
            sup.breaker_state(),
            BreakerState::Closed,
            "successes break the consecutive run"
        );
    }

    #[test]
    fn breaker_half_opens_then_closes_on_success() {
        let sup = Supervision::new(cfg());
        for _ in 0..3 {
            sup.on_panic();
        }
        assert_eq!(sup.breaker_state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(sup.breaker_state(), BreakerState::HalfOpen);
        assert!(sup.admit().is_ok(), "half-open admits the probe");
        sup.on_success();
        assert_eq!(sup.breaker_state(), BreakerState::Closed);
    }

    #[test]
    fn panic_during_half_open_reopens_with_longer_cooldown() {
        let sup = Supervision::new(cfg());
        for _ in 0..3 {
            sup.on_panic();
        }
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(sup.breaker_state(), BreakerState::HalfOpen);
        // the probe burns: one panic reopens immediately (no K needed)
        sup.on_panic();
        assert_eq!(sup.breaker_state(), BreakerState::Open);
        assert_eq!(sup.breaker_opens(), 2);
        // doubled cooldown: still open after the first cooldown length
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(sup.breaker_state(), BreakerState::Open);
    }

    #[test]
    fn supervise_respawns_until_body_stops_panicking() {
        let sup = Supervision::new(cfg());
        let metrics = Metrics::default();
        let n = AtomicU32::new(0);
        supervise(
            "test",
            &sup,
            &metrics,
            || false,
            String::new,
            || {
                if n.fetch_add(1, Ordering::Relaxed) < 2 {
                    panic!("injected");
                }
            },
        );
        assert_eq!(n.load(Ordering::Relaxed), 3, "2 panics + 1 clean run");
        assert_eq!(sup.panics(), 2);
        assert_eq!(sup.respawns(), 2);
    }

    #[test]
    fn supervise_exits_without_respawn_on_shutdown() {
        let sup = Supervision::new(cfg());
        let metrics = Metrics::default();
        let down = AtomicBool::new(true);
        supervise(
            "test",
            &sup,
            &metrics,
            || down.load(Ordering::Relaxed),
            String::new,
            || panic!("injected"),
        );
        assert_eq!(sup.panics(), 1);
        assert_eq!(sup.respawns(), 0, "no respawn during shutdown");
    }

    #[test]
    fn backoff_is_bounded() {
        let sup = Supervision::new(cfg());
        assert_eq!(sup.backoff(1), Duration::from_millis(1));
        assert_eq!(sup.backoff(2), Duration::from_millis(2));
        assert_eq!(sup.backoff(4), Duration::from_millis(8));
        assert_eq!(sup.backoff(30), Duration::from_millis(8), "capped");
    }
}
