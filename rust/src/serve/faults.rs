//! Deterministic fault injection for the serve subsystem.
//!
//! A [`Faults`] plan is a catalog of **failpoints** — named places in
//! the request lifecycle where the server can be made to misbehave on
//! purpose — armed from a spec string (`CWMIX_FAULTS` env var or the
//! `cwmix serve --faults` flag) and threaded as an `Arc` through the
//! registry, batcher and HTTP layers.  Disarmed (the default: an empty
//! plan), every hook is a branch on an empty `Vec` that the optimizer
//! sinks to nothing — `bench_serve` runs against the same binary the
//! chaos suite does, and the perf gate holds because the hooks cost
//! nothing until a spec arms them.
//!
//! Spec grammar (comma-separated failpoints):
//!
//! ```text
//!   <kind>:<model>:<trigger>[:<arg>]
//!
//!   kind    engine_panic | engine_stall | queue_full | slow_socket
//!           | write_stall | registry_load_error | artifact_corrupt
//!   model   bench name, or * for any model
//!   trigger once | always | times=N | nth=N | prob=P
//!   arg     milliseconds for engine_stall / slow_socket / write_stall
//!           (default 100)
//! ```
//!
//! Examples: `engine_panic:ic:once` (the chaos-smoke CI spec),
//! `engine_stall:ad:always:300`, `engine_panic:ic:times=3,queue_full:kws:nth=2`.
//!
//! **Determinism:** every trigger is a pure function of the
//! failpoint's evaluation counter (an atomic, incremented per check)
//! and — for `prob=P` — a seeded per-point xorshift stream, so a chaos
//! run replays identically under the same spec + seed.  No wall clock,
//! no global RNG.
//!
//! The failpoints and where they fire:
//!
//! * `engine_panic` — the batcher worker panics just before the engine
//!   call (the supervisor must catch, respawn, and keep other models
//!   live).
//! * `engine_stall` — the worker sleeps `arg` ms before the engine
//!   call (queued requests age past their deadline → 504 at dequeue).
//! * `queue_full` — `Batcher::submit` behaves as if the bounded queue
//!   were full (explicit 503 shed path).
//! * `slow_socket` — the HTTP handler sleeps `arg` ms before routing a
//!   parsed request (injected network latency).
//! * `write_stall` — the HTTP handler flushes a partial reply, sleeps
//!   `arg` ms mid-write, then finishes and closes the connection (a
//!   client that stops draining, or a path-MTU black hole, on the
//!   *reply* half of the socket — the read half is `slow_socket`'s
//!   job).  The server must neither corrupt the reply nor let the
//!   stalled writer pin its handler slot beyond the write deadline.
//! * `registry_load_error` — a modelpack load fails with an injected
//!   error (the registry must fall back to compile, loudly).
//! * `artifact_corrupt` — a deterministic byte of the `.cwm` bytes is
//!   flipped after read (the hostile-input-hardened loader must reject
//!   it and the registry must fall back to compile).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// What the engine-call failpoint asks the worker to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineFault {
    /// Panic the worker thread (supervised respawn path).
    Panic,
    /// Sleep this long before executing the batch.
    Stall(Duration),
}

/// Failpoint kinds (see the module docs for where each fires).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    EnginePanic,
    EngineStall,
    QueueFull,
    SlowSocket,
    WriteStall,
    RegistryLoadError,
    ArtifactCorrupt,
}

impl Kind {
    fn name(self) -> &'static str {
        match self {
            Kind::EnginePanic => "engine_panic",
            Kind::EngineStall => "engine_stall",
            Kind::QueueFull => "queue_full",
            Kind::SlowSocket => "slow_socket",
            Kind::WriteStall => "write_stall",
            Kind::RegistryLoadError => "registry_load_error",
            Kind::ArtifactCorrupt => "artifact_corrupt",
        }
    }
}

/// When a matched failpoint actually fires, as a pure function of its
/// evaluation counter.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    /// First evaluation only.
    Once,
    /// Every evaluation.
    Always,
    /// The first N evaluations.
    Times(u64),
    /// Exactly the Nth evaluation (1-based).
    Nth(u64),
    /// Evaluation `i` fires iff the seeded per-point stream's `i`-th
    /// draw is below P.
    Prob(f64),
}

/// One armed failpoint.
struct Point {
    kind: Kind,
    /// `None` = `*` (any model).
    model: Option<String>,
    trigger: Trigger,
    /// Milliseconds for stall/slow kinds.
    arg_ms: u64,
    /// Evaluations so far (0-based index handed to the trigger).
    hits: AtomicU64,
    /// Times this point actually fired (diagnostics).
    fired: AtomicU64,
    /// Per-point deterministic stream seed (for `prob=`).
    seed: u64,
}

impl Point {
    fn matches(&self, model: &str) -> bool {
        match &self.model {
            None => true,
            Some(m) => m == model,
        }
    }

    /// Count one evaluation and decide whether this one fires.
    fn evaluate(&self) -> bool {
        let i = self.hits.fetch_add(1, Ordering::Relaxed);
        let fire = match self.trigger {
            Trigger::Once => i == 0,
            Trigger::Always => true,
            Trigger::Times(n) => i < n,
            Trigger::Nth(n) => i + 1 == n,
            Trigger::Prob(p) => unit_draw(self.seed, i) < p,
        };
        if fire {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }
}

/// Draw `i` of a seeded xorshift64* stream, mapped to [0, 1).
fn unit_draw(seed: u64, i: u64) -> f64 {
    let mut x = (seed ^ i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) | 1;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    let x = x.wrapping_mul(0x2545_f491_4f6c_dd1d);
    (x >> 11) as f64 / (1u64 << 53) as f64
}

/// FNV-1a-64 over a label — stable per-point seed derivation.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// An armed (or empty = disarmed) fault-injection plan.  Cheap to
/// share (`Arc`) and cheap to consult: every hook first checks
/// [`Faults::armed`], which is `!points.is_empty()`.
#[derive(Default)]
pub struct Faults {
    points: Vec<Point>,
}

impl std::fmt::Debug for Faults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.points.is_empty() {
            write!(f, "Faults(disarmed)")
        } else {
            write!(f, "Faults({})", self.describe())
        }
    }
}

impl Faults {
    /// The no-op plan: every hook returns "no fault" after one branch.
    pub fn disarmed() -> Arc<Faults> {
        Arc::new(Faults::default())
    }

    /// Parse a spec string (see the module docs for the grammar).
    pub fn parse(spec: &str, seed: u64) -> Result<Faults> {
        let mut points = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let fields: Vec<&str> = entry.split(':').collect();
            if fields.len() < 3 || fields.len() > 4 {
                bail!(
                    "failpoint {entry:?}: want <kind>:<model>:<trigger>[:<ms>]"
                );
            }
            let kind = match fields[0] {
                "engine_panic" => Kind::EnginePanic,
                "engine_stall" => Kind::EngineStall,
                "queue_full" => Kind::QueueFull,
                "slow_socket" => Kind::SlowSocket,
                "write_stall" => Kind::WriteStall,
                "registry_load_error" => Kind::RegistryLoadError,
                "artifact_corrupt" => Kind::ArtifactCorrupt,
                other => bail!("unknown failpoint kind {other:?}"),
            };
            let model = match fields[1] {
                "" | "*" => None,
                m => Some(m.to_string()),
            };
            let trigger = parse_trigger(fields[2])
                .with_context(|| format!("failpoint {entry:?}"))?;
            let arg_ms = match fields.get(3) {
                Some(ms) => ms
                    .parse()
                    .with_context(|| format!("failpoint {entry:?}: bad ms arg"))?,
                None => 100,
            };
            points.push(Point {
                kind,
                trigger,
                arg_ms,
                hits: AtomicU64::new(0),
                fired: AtomicU64::new(0),
                seed: seed ^ fnv1a(entry.as_bytes()),
                model,
            });
        }
        Ok(Faults { points })
    }

    /// Arm from `CWMIX_FAULTS` / `CWMIX_FAULTS_SEED`.  No env var =
    /// disarmed; a malformed spec is a hard error (a typo'd chaos run
    /// must not silently test nothing).
    pub fn from_env() -> Result<Arc<Faults>> {
        let Ok(spec) = std::env::var("CWMIX_FAULTS") else {
            return Ok(Faults::disarmed());
        };
        let seed = match std::env::var("CWMIX_FAULTS_SEED") {
            Ok(s) => s.parse().context("bad CWMIX_FAULTS_SEED")?,
            Err(_) => 0,
        };
        Ok(Arc::new(Faults::parse(&spec, seed).context("CWMIX_FAULTS")?))
    }

    /// Whether any failpoint is armed (the hooks' fast-path check).
    pub fn armed(&self) -> bool {
        !self.points.is_empty()
    }

    /// Human-readable catalog for the startup log.
    pub fn describe(&self) -> String {
        self.points
            .iter()
            .map(|p| {
                format!(
                    "{}:{}:{:?}",
                    p.kind.name(),
                    p.model.as_deref().unwrap_or("*"),
                    p.trigger
                )
            })
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// First matching point of `kind` for `model` that fires.
    fn fire(&self, kind: Kind, model: &str) -> Option<&Point> {
        self.points
            .iter()
            .find(|p| p.kind == kind && p.matches(model) && p.evaluate())
    }

    /// Engine-call failpoint (batcher worker, just before execution).
    pub fn engine(&self, model: &str) -> Option<EngineFault> {
        if !self.armed() {
            return None;
        }
        if self.fire(Kind::EnginePanic, model).is_some() {
            return Some(EngineFault::Panic);
        }
        self.fire(Kind::EngineStall, model)
            .map(|p| EngineFault::Stall(Duration::from_millis(p.arg_ms)))
    }

    /// Admission failpoint: behave as if the bounded queue were full.
    pub fn queue_full(&self, model: &str) -> bool {
        self.armed() && self.fire(Kind::QueueFull, model).is_some()
    }

    /// HTTP handler failpoint: injected latency before routing.
    pub fn slow_socket(&self) -> Option<Duration> {
        if !self.armed() {
            return None;
        }
        self.fire(Kind::SlowSocket, "*")
            .map(|p| Duration::from_millis(p.arg_ms))
    }

    /// HTTP reply failpoint: stall this long between two flushes of the
    /// response bytes (the write half of the socket; `slow_socket`
    /// covers the read half).
    pub fn write_stall(&self) -> Option<Duration> {
        if !self.armed() {
            return None;
        }
        self.fire(Kind::WriteStall, "*")
            .map(|p| Duration::from_millis(p.arg_ms))
    }

    /// Modelpack-load failpoint: an injected load error for `bench`.
    pub fn registry_load_error(&self, bench: &str) -> Option<String> {
        if !self.armed() {
            return None;
        }
        self.fire(Kind::RegistryLoadError, bench)
            .map(|_| format!("injected registry_load_error for {bench}"))
    }

    /// Artifact-corruption failpoint: deterministically flip one byte
    /// of `bytes` (position derived from the point's seed).  Returns
    /// true when a corruption was applied.
    pub fn corrupt_artifact(&self, bench: &str, bytes: &mut [u8]) -> bool {
        if !self.armed() || bytes.is_empty() {
            return false;
        }
        match self.fire(Kind::ArtifactCorrupt, bench) {
            Some(p) => {
                let at = (p.seed as usize) % bytes.len();
                bytes[at] ^= 0xa5;
                true
            }
            None => false,
        }
    }
}

fn parse_trigger(s: &str) -> Result<Trigger> {
    if s == "once" {
        return Ok(Trigger::Once);
    }
    if s == "always" {
        return Ok(Trigger::Always);
    }
    if let Some(n) = s.strip_prefix("times=") {
        return Ok(Trigger::Times(n.parse().context("times=N")?));
    }
    if let Some(n) = s.strip_prefix("nth=") {
        let n: u64 = n.parse().context("nth=N")?;
        if n == 0 {
            bail!("nth= is 1-based");
        }
        return Ok(Trigger::Nth(n));
    }
    if let Some(p) = s.strip_prefix("prob=") {
        let p: f64 = p.parse().context("prob=P")?;
        if !(0.0..=1.0).contains(&p) {
            bail!("prob= wants [0, 1]");
        }
        return Ok(Trigger::Prob(p));
    }
    bail!("unknown trigger {s:?} (once|always|times=N|nth=N|prob=P)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_is_all_noops() {
        let f = Faults::disarmed();
        assert!(!f.armed());
        assert!(f.engine("ic").is_none());
        assert!(!f.queue_full("ic"));
        assert!(f.slow_socket().is_none());
        assert!(f.write_stall().is_none());
        assert!(f.registry_load_error("ic").is_none());
        let mut b = vec![1u8, 2, 3];
        assert!(!f.corrupt_artifact("ic", &mut b));
        assert_eq!(b, vec![1, 2, 3]);
    }

    #[test]
    fn once_fires_exactly_once_per_point() {
        let f = Faults::parse("engine_panic:ic:once", 0).unwrap();
        assert_eq!(f.engine("ic"), Some(EngineFault::Panic));
        assert_eq!(f.engine("ic"), None);
        assert_eq!(f.engine("ic"), None);
    }

    #[test]
    fn model_matching_and_wildcard() {
        let f = Faults::parse("engine_panic:ic:always", 0).unwrap();
        assert_eq!(f.engine("kws"), None, "other models unaffected");
        assert_eq!(f.engine("ic"), Some(EngineFault::Panic));
        let any = Faults::parse("queue_full:*:always", 0).unwrap();
        assert!(any.queue_full("ic"));
        assert!(any.queue_full("kws"));
    }

    #[test]
    fn times_and_nth_triggers() {
        let f = Faults::parse("engine_panic:ic:times=3", 0).unwrap();
        for _ in 0..3 {
            assert_eq!(f.engine("ic"), Some(EngineFault::Panic));
        }
        assert_eq!(f.engine("ic"), None);

        let f = Faults::parse("queue_full:ic:nth=2", 0).unwrap();
        assert!(!f.queue_full("ic"));
        assert!(f.queue_full("ic"));
        assert!(!f.queue_full("ic"));
    }

    #[test]
    fn stall_carries_duration() {
        let f = Faults::parse("engine_stall:ad:always:250", 0).unwrap();
        assert_eq!(
            f.engine("ad"),
            Some(EngineFault::Stall(Duration::from_millis(250)))
        );
    }

    #[test]
    fn write_stall_carries_duration_and_respects_trigger() {
        let f = Faults::parse("write_stall:*:once:150", 0).unwrap();
        assert_eq!(f.write_stall(), Some(Duration::from_millis(150)));
        assert_eq!(f.write_stall(), None, "once: second reply unaffected");
        // default arg
        let f = Faults::parse("write_stall:*:always", 0).unwrap();
        assert_eq!(f.write_stall(), Some(Duration::from_millis(100)));
    }

    #[test]
    fn panic_point_shadows_stall_point() {
        let f =
            Faults::parse("engine_panic:ic:once,engine_stall:ic:always:50", 0).unwrap();
        assert_eq!(f.engine("ic"), Some(EngineFault::Panic));
        assert_eq!(
            f.engine("ic"),
            Some(EngineFault::Stall(Duration::from_millis(50)))
        );
    }

    #[test]
    fn prob_stream_is_seed_deterministic() {
        let a = Faults::parse("queue_full:ic:prob=0.5", 42).unwrap();
        let b = Faults::parse("queue_full:ic:prob=0.5", 42).unwrap();
        let sa: Vec<bool> = (0..64).map(|_| a.queue_full("ic")).collect();
        let sb: Vec<bool> = (0..64).map(|_| b.queue_full("ic")).collect();
        assert_eq!(sa, sb, "same seed must replay identically");
        assert!(sa.iter().any(|&x| x) && sa.iter().any(|&x| !x));
        let c = Faults::parse("queue_full:ic:prob=0.5", 43).unwrap();
        let sc: Vec<bool> = (0..64).map(|_| c.queue_full("ic")).collect();
        assert_ne!(sa, sc, "different seed, different stream");
    }

    #[test]
    fn corrupt_flips_one_deterministic_byte() {
        let f = Faults::parse("artifact_corrupt:ic:once", 7).unwrap();
        let orig: Vec<u8> = (0..64).collect();
        let mut b = orig.clone();
        assert!(f.corrupt_artifact("ic", &mut b));
        let diffs: Vec<usize> =
            (0..64).filter(|&i| b[i] != orig[i]).collect();
        assert_eq!(diffs.len(), 1);
        // once: the second evaluation leaves bytes alone
        let mut b2 = orig.clone();
        assert!(!f.corrupt_artifact("ic", &mut b2));
        assert_eq!(b2, orig);
    }

    #[test]
    fn malformed_specs_are_errors() {
        for bad in [
            "nonsense:ic:once",
            "engine_panic:ic",
            "engine_panic:ic:sometimes",
            "engine_panic:ic:nth=0",
            "engine_panic:ic:prob=1.5",
            "engine_stall:ic:always:abc",
            "engine_panic:ic:once:10:extra",
        ] {
            assert!(Faults::parse(bad, 0).is_err(), "{bad:?} should not parse");
        }
        // empty spec = disarmed, not an error
        assert!(!Faults::parse("", 0).unwrap().armed());
    }

    #[test]
    fn describe_lists_every_point() {
        let f =
            Faults::parse("engine_panic:ic:once,queue_full:*:always", 0).unwrap();
        let d = f.describe();
        assert!(d.contains("engine_panic:ic"), "{d}");
        assert!(d.contains("queue_full:*"), "{d}");
    }
}
