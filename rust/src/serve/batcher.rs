//! Dynamic micro-batching over one precompiled `ExecPlan`.
//!
//! Every model entry owns one [`Batcher`]: a bounded MPSC queue plus a
//! dedicated worker thread that coalesces pending single-sample requests
//! into one [`ExecPlan::run_samples`] call.  The policy is the classic
//! two-knob one:
//!
//! * **`max_batch`** — execute as soon as this many requests are
//!   pending;
//! * **`max_wait_us`** — never hold the *oldest* pending request longer
//!   than this before executing whatever has accumulated (a lone
//!   request therefore flushes after at most `max_wait_us`).
//!
//! Under load the worker is always behind the queue, so batches fill to
//! `max_batch` without ever sleeping — the wait bound only shapes the
//! lightly-loaded tail.  Batching amortises the engine's per-call costs
//! (thread fan-out, per-layer activation-plane quantization setup)
//! across *unrelated* requests, the serving-side analogue of the packed
//! plane amortising quantization across consumers within a layer.
//!
//! **Admission control:** the queue is bounded (`queue_cap`).  A submit
//! against a full queue is *shed* — the caller gets
//! [`SubmitError::Overloaded`] immediately and the HTTP layer answers
//! `503` instead of letting latency grow without bound.
//!
//! Worker-side execution uses [`ExecPlan::run_samples`], so batched
//! outputs are bit-identical to per-sample [`ExecPlan::run_sample`]
//! calls (`tests/serve_batcher.rs` asserts it end-to-end).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::ExecPlan;

use super::metrics::Metrics;

/// Micro-batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Coalesce at most this many requests into one engine call.
    pub max_batch: usize,
    /// Flush the oldest pending request after at most this long.
    pub max_wait_us: u64,
    /// Bounded-queue admission limit; submits beyond it are shed.
    pub queue_cap: usize,
    /// Engine worker threads per executed batch.
    pub threads: usize,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait_us: 2_000,
            queue_cap: 256,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        }
    }
}

/// A successfully executed request.
pub struct InferReply {
    /// Output activations, bit-identical to `ExecPlan::run_sample`.
    pub output: Vec<f32>,
    /// Size of the micro-batch this request rode in.
    pub batch: usize,
}

/// Why a submit was refused at the door.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — request shed (HTTP 503).
    Overloaded,
    /// Batcher is shutting down.
    ShuttingDown,
    /// Input failed validation (wrong length) — never enqueued, so one
    /// bad request cannot poison a coalesced batch.
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full, request shed"),
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

/// What the worker sends back: the reply or an engine error string.
pub type ReplyResult = Result<InferReply, String>;

struct Pending {
    input: Vec<f32>,
    reply: mpsc::Sender<ReplyResult>,
    enqueued: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    shutdown: AtomicBool,
    policy: BatchPolicy,
    plan: Arc<ExecPlan>,
    metrics: Arc<Metrics>,
}

/// Bounded queue + coalescing worker for one model.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the coalescing worker for `plan`.
    pub fn start(plan: Arc<ExecPlan>, metrics: Arc<Metrics>, policy: BatchPolicy) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            policy,
            plan,
            metrics,
        });
        let w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("cwmix-batcher".into())
            .spawn(move || worker_loop(&w))
            .expect("spawning batcher worker");
        Batcher { shared, worker: Mutex::new(Some(worker)) }
    }

    /// Enqueue one sample.  Returns the reply channel, or refuses at
    /// the door (shed / shutdown / bad input).  The worker always
    /// answers every admitted request, so `recv()` on the returned
    /// channel cannot deadlock while the batcher is alive.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<ReplyResult>, SubmitError> {
        let feat = self.shared.plan.feat();
        if input.len() != feat {
            return Err(SubmitError::BadInput(format!(
                "input length {} != model input {feat}",
                input.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            // the shutdown check happens under the queue lock: shutdown()
            // drains the queue under the same lock *after* setting the
            // flag, so a request can never slip in unanswered behind the
            // worker's exit
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err(SubmitError::ShuttingDown);
            }
            if q.len() >= self.shared.policy.queue_cap {
                self.shared.metrics.record_shed();
                return Err(SubmitError::Overloaded);
            }
            q.push_back(Pending { input, reply: tx, enqueued: Instant::now() });
        }
        self.shared.metrics.record_request();
        self.shared.notify.notify_one();
        Ok(rx)
    }

    /// Pending queue depth (diagnostics / tests).
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Stop accepting work, drain what is queued, join the worker.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify.notify_all();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
        // answer anything that raced past the worker's final drain
        let stragglers: Vec<Pending> =
            self.shared.queue.lock().unwrap().drain(..).collect();
        for p in stragglers {
            let _ = p.reply.send(Err("server shutting down".to_string()));
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    let max_batch = shared.policy.max_batch.max(1);
    let wait = Duration::from_micros(shared.policy.max_wait_us);
    loop {
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            // sleep until there is work (or shutdown with an empty queue)
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.notify.wait(q).unwrap();
            }
            // coalesce: hold the oldest request at most `max_wait_us`
            // (measured from ITS enqueue — time spent while we were
            // executing the previous batch counts toward the bound)
            let deadline = q.front().unwrap().enqueued + wait;
            while q.len() < max_batch && !shared.shutdown.load(Ordering::Acquire) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    shared.notify.wait_timeout(q, deadline - now).unwrap();
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.len().min(max_batch);
            q.drain(..take).collect()
        };
        execute(shared, batch);
    }
}

fn execute(shared: &Shared, batch: Vec<Pending>) {
    if batch.is_empty() {
        return;
    }
    let n = batch.len();
    shared.metrics.record_batch(n);
    let samples: Vec<&[f32]> = batch.iter().map(|p| p.input.as_slice()).collect();
    let threads = shared.policy.threads.clamp(1, n);
    match shared.plan.run_samples(&samples, threads) {
        Ok(outs) => {
            for (p, output) in batch.iter().zip(outs) {
                let us = p.enqueued.elapsed().as_micros() as u64;
                shared.metrics.record_latency_us(us);
                // a vanished receiver just means the client hung up
                let _ = p.reply.send(Ok(InferReply { output, batch: n }));
            }
        }
        Err(e) => {
            // submit() validates lengths, so this is an engine-internal
            // failure: every rider gets the error
            let msg = format!("engine error: {e:#}");
            for p in &batch {
                shared.metrics.record_error();
                let _ = p.reply.send(Err(msg.clone()));
            }
        }
    }
}
