//! Dynamic micro-batching over one precompiled `ExecPlan`, under
//! supervision.
//!
//! Every model entry owns one [`Batcher`]: a bounded MPSC queue plus a
//! dedicated worker thread that coalesces pending single-sample
//! requests into one batch-plane engine call.  The policy is the
//! classic two-knob one:
//!
//! * **`max_batch`** — execute as soon as this many requests are
//!   pending;
//! * **`max_wait_us`** — never hold the *oldest* pending request longer
//!   than this before executing whatever has accumulated (a lone
//!   request therefore flushes after at most `max_wait_us`).
//!
//! Under load the worker is always behind the queue, so batches fill to
//! `max_batch` without ever sleeping — the wait bound only shapes the
//! lightly-loaded tail.  The coalesced batch is handed **zero-copy**
//! into the engine's batch-plane path: each rider's input buffer is
//! borrowed in place (`&[f32]` list, no contiguous-slab copy), and with
//! `threads <= 1` the worker runs [`ExecPlan::run_batch_planes`]
//! against its own **resident batch arena** — no per-batch allocation
//! at all.
//!
//! **Request lifecycle (this is the robustness surface):**
//!
//! * *Admission*: the queue is bounded (`queue_cap`); a full queue
//!   sheds with [`SubmitError::Overloaded`] → HTTP 503.  A model whose
//!   circuit breaker is open refuses with
//!   [`SubmitError::BreakerOpen`] → 503 + `Retry-After`.  Wrong-length
//!   inputs are refused at the door.
//! * *Deadline*: every admitted request carries
//!   `enqueued + max_wait_us + infer_budget_us`.  Expired requests are
//!   answered [`ReplyError::Expired`] (HTTP 504) **at dequeue**,
//!   without riding a batch — a stalled worker sheds its backlog as
//!   explicit timeouts instead of executing work nobody is waiting for.
//! * *Supervision*: the worker runs under
//!   [`supervisor::supervise`] — an engine panic fails only the
//!   in-flight batch (riders observe a dropped reply channel → HTTP
//!   500), the worker respawns with a **fresh arena** after bounded
//!   backoff, and `breaker_k` consecutive panics open the per-model
//!   circuit breaker.  All queue locking is poison-free
//!   ([`lock_unpoisoned`]), so a panicking worker can never cascade
//!   panics into HTTP threads that merely touch the queue.
//! * *Shutdown*: drain-then-close.  The worker executes everything
//!   admitted before exiting, and [`Batcher::shutdown`] serves any
//!   request that raced in behind the worker's exit — an admitted
//!   request gets a real reply or an explicit
//!   [`ReplyError::ShuttingDown`], never a dropped sender.
//!
//! Batched outputs are bit-identical to per-sample
//! [`ExecPlan::run_sample`] calls by the engine's batch-plane contract
//! (`tests/serve_batcher.rs` asserts it end-to-end, including that a
//! coalesced batch equals N independent single-sample requests;
//! `tests/serve_chaos.rs` asserts the replies stay bit-identical
//! *across a worker respawn*).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{Arena, ExecPlan, MAX_BATCH_CHUNK};
use crate::trace::{self, SpanName};

use super::faults::{EngineFault, Faults};
use super::metrics::Metrics;
use super::supervisor::{
    self, lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned, Supervision,
    SupervisorCfg,
};

/// Micro-batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Coalesce at most this many requests into one engine call.
    pub max_batch: usize,
    /// Flush the oldest pending request after at most this long.
    pub max_wait_us: u64,
    /// Bounded-queue admission limit; submits beyond it are shed.
    pub queue_cap: usize,
    /// Engine worker threads per executed batch — an upper bound: the
    /// batcher never fans out past one worker per `MIN_RIDE` riders,
    /// so small coalesced batches keep their weight-stationary
    /// amortization instead of being sharded into single-sample passes.
    pub threads: usize,
    /// Post-queue execution budget: a request's deadline is
    /// `enqueued + max_wait_us + infer_budget_us`, enforced at dequeue
    /// (expired requests answer 504 without riding a batch).
    pub infer_budget_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait_us: 2_000,
            queue_cap: 256,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
            infer_budget_us: 30_000_000,
        }
    }
}

impl BatchPolicy {
    /// The full per-request deadline window (queue wait + execution).
    pub fn deadline(&self) -> Duration {
        Duration::from_micros(self.max_wait_us.saturating_add(self.infer_budget_us))
    }
}

/// Non-policy worker wiring: identity, fault plan, supervision knobs.
#[derive(Clone)]
pub struct WorkerOpts {
    /// Model name — fault matching, log lines, breaker gauges.
    pub model: String,
    /// Fault-injection plan (disarmed by default).
    pub faults: Arc<Faults>,
    /// Supervision knobs (breaker K, cooldowns, respawn backoff).
    pub supervisor: SupervisorCfg,
}

impl Default for WorkerOpts {
    fn default() -> WorkerOpts {
        WorkerOpts {
            model: "model".to_string(),
            faults: Faults::disarmed(),
            supervisor: SupervisorCfg::default(),
        }
    }
}

/// A successfully executed request.
pub struct InferReply {
    /// Output activations, bit-identical to `ExecPlan::run_sample`.
    pub output: Vec<f32>,
    /// Size of the micro-batch this request rode in.
    pub batch: usize,
}

/// Why a submit was refused at the door.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — request shed (HTTP 503).
    Overloaded,
    /// Circuit breaker open after repeated worker panics — refuse with
    /// a retry hint instead of queueing into a known-bad model
    /// (HTTP 503 + `Retry-After`).
    BreakerOpen {
        /// Seconds until the breaker half-opens.
        retry_after_s: u64,
    },
    /// Batcher is shutting down.
    ShuttingDown,
    /// Input failed validation (wrong length) — never enqueued, so one
    /// bad request cannot poison a coalesced batch.
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full, request shed"),
            SubmitError::BreakerOpen { retry_after_s } => {
                write!(f, "circuit breaker open, retry in {retry_after_s}s")
            }
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

/// Why an *admitted* request got an error reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplyError {
    /// Deadline passed before the request could ride a batch
    /// (HTTP 504).
    Expired,
    /// Shutdown landed before the request could execute (HTTP 503).
    ShuttingDown,
    /// The engine call failed (HTTP 500).
    Engine(String),
}

impl std::fmt::Display for ReplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyError::Expired => write!(f, "deadline exceeded before execution"),
            ReplyError::ShuttingDown => write!(f, "server shutting down"),
            ReplyError::Engine(m) => write!(f, "engine error: {m}"),
        }
    }
}

/// What the worker sends back: the reply or a typed error.
pub type ReplyResult = Result<InferReply, ReplyError>;

struct Pending {
    /// Request id stamped at admission ([`crate::trace::next_request_id`])
    /// — the correlation key across trace spans, log lines and replies.
    id: u64,
    input: Vec<f32>,
    reply: mpsc::Sender<ReplyResult>,
    enqueued: Instant,
    deadline: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    shutdown: AtomicBool,
    policy: BatchPolicy,
    plan: Arc<ExecPlan>,
    metrics: Arc<Metrics>,
    model: String,
    faults: Arc<Faults>,
    sup: Supervision,
    /// Request ids riding the batch currently inside the engine —
    /// sampled by the supervisor's panic log line so a worker death is
    /// attributable to specific requests.  Deliberately left populated
    /// when `execute` panics (that is the read the supervisor makes).
    inflight: Mutex<Vec<u64>>,
}

/// Bounded queue + supervised coalescing worker for one model.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the supervised coalescing worker for `plan`.
    pub fn start(
        plan: Arc<ExecPlan>,
        metrics: Arc<Metrics>,
        policy: BatchPolicy,
        opts: WorkerOpts,
    ) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            policy,
            plan,
            metrics,
            model: opts.model,
            faults: opts.faults,
            sup: Supervision::new(opts.supervisor),
            inflight: Mutex::new(Vec::new()),
        });
        let w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("cwmix-batcher".into())
            .spawn(move || {
                let s = Arc::clone(&w);
                let c = Arc::clone(&w);
                supervisor::supervise(
                    &w.model,
                    &w.sup,
                    &w.metrics,
                    || w.shutdown.load(Ordering::Acquire),
                    move || format!("inflight={:?}", *lock_unpoisoned(&c.inflight)),
                    move || worker_loop(&s),
                );
            })
            .expect("spawning batcher worker");
        Batcher { shared, worker: Mutex::new(Some(worker)) }
    }

    /// Enqueue one sample under request id `id` (stamped by the caller
    /// at admission — [`crate::trace::next_request_id`]).  Returns the
    /// reply channel, or refuses at the door (shed / breaker /
    /// shutdown / bad input).  Every admitted request is answered — by
    /// the worker, or by the shutdown drain — so `recv()` on the
    /// returned channel cannot deadlock while the batcher is alive.
    pub fn submit(
        &self,
        input: Vec<f32>,
        id: u64,
    ) -> Result<mpsc::Receiver<ReplyResult>, SubmitError> {
        let feat = self.shared.plan.feat();
        if input.len() != feat {
            return Err(SubmitError::BadInput(format!(
                "input length {} != model input {feat}",
                input.len()
            )));
        }
        if let Err(retry_after_s) = self.shared.sup.admit() {
            self.shared.metrics.record_breaker_reject();
            return Err(SubmitError::BreakerOpen { retry_after_s });
        }
        if self.shared.faults.queue_full(&self.shared.model) {
            self.shared.metrics.record_shed();
            return Err(SubmitError::Overloaded);
        }
        let (tx, rx) = mpsc::channel();
        {
            // the shutdown check happens under the queue lock: shutdown()
            // drains the queue under the same lock *after* setting the
            // flag, so a request can never slip in unanswered behind the
            // worker's exit
            let mut q = lock_unpoisoned(&self.shared.queue);
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err(SubmitError::ShuttingDown);
            }
            if q.len() >= self.shared.policy.queue_cap {
                self.shared.metrics.record_shed();
                return Err(SubmitError::Overloaded);
            }
            let now = Instant::now();
            q.push_back(Pending {
                id,
                input,
                reply: tx,
                enqueued: now,
                deadline: now + self.shared.policy.deadline(),
            });
        }
        self.shared.metrics.record_request();
        self.shared.notify.notify_one();
        Ok(rx)
    }

    /// Pending queue depth (diagnostics / tests).
    pub fn queued(&self) -> usize {
        lock_unpoisoned(&self.shared.queue).len()
    }

    /// Supervision state: panic/respawn counters + breaker (gauges for
    /// `/metrics` and `/readyz`).
    pub fn supervision(&self) -> &Supervision {
        &self.shared.sup
    }

    /// Stop accepting work, drain what is queued, join the worker.
    /// Drain-then-close: requests that raced in behind the worker's
    /// exit are *executed* here (or answered `ShuttingDown` if the
    /// engine is unusable) — an admitted request never sees a silently
    /// dropped sender.  Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify.notify_all();
        if let Some(h) = lock_unpoisoned(&self.worker).take() {
            let _ = h.join();
        }
        let max_batch = self.shared.policy.max_batch.max(1);
        loop {
            let batch: Vec<Pending> = {
                let mut q = lock_unpoisoned(&self.shared.queue);
                let take = q.len().min(max_batch);
                q.drain(..take).collect()
            };
            if batch.is_empty() {
                break;
            }
            // the worker (and its resident arena) is gone; serve the
            // stragglers with a one-off arena.  Armed faults can still
            // panic this engine call — contain it so shutdown cannot
            // cascade, the riders then observe the dropped senders.
            let shared = Arc::clone(&self.shared);
            let n = batch.len().min(MAX_BATCH_CHUNK);
            let _ = catch_unwind(AssertUnwindSafe(move || {
                let mut arena = shared.plan.batch_arena(n);
                execute(&shared, &mut arena, batch);
            }));
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    let max_batch = shared.policy.max_batch.max(1);
    let wait = Duration::from_micros(shared.policy.max_wait_us);
    // resident batch arena: the single-worker execution path reuses it
    // across batches, so steady-state serving allocates nothing but the
    // reply vectors.  A respawned worker builds a fresh one — whatever
    // state a panic left behind is discarded with the old stack.
    let mut arena = shared.plan.batch_arena(max_batch.min(MAX_BATCH_CHUNK));
    loop {
        let drained: Vec<Pending> = {
            let mut q = lock_unpoisoned(&shared.queue);
            // sleep until there is work (or shutdown with an empty queue)
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = wait_unpoisoned(&shared.notify, q);
            }
            // coalesce: hold the oldest request at most `max_wait_us`
            // (measured from ITS enqueue — time spent while we were
            // executing the previous batch counts toward the bound)
            let deadline = q.front().unwrap().enqueued + wait;
            while q.len() < max_batch && !shared.shutdown.load(Ordering::Acquire) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timed_out) =
                    wait_timeout_unpoisoned(&shared.notify, q, deadline - now);
                q = guard;
                if timed_out {
                    break;
                }
            }
            let take = q.len().min(max_batch);
            q.drain(..take).collect()
        };
        // deadline enforcement at dequeue: an expired request answers
        // 504 NOW instead of riding a batch nobody is waiting for —
        // this is what lets a stalled worker shed its backlog the
        // moment it recovers
        let now = Instant::now();
        let (batch, expired): (Vec<Pending>, Vec<Pending>) =
            drained.into_iter().partition(|p| now < p.deadline);
        for p in expired {
            shared.metrics.record_deadline_expired();
            let _ = p.reply.send(Err(ReplyError::Expired));
        }
        execute(shared, &mut arena, batch);
    }
}

/// Minimum samples per engine worker before fanning out: splitting a
/// coalesced batch into near-single-sample shards would forfeit the
/// weight-stationary amortization batching exists to buy, so parallel
/// workers are only added once each can ride at least this many
/// samples through one batch-plane pass.
const MIN_RIDE: usize = 4;

/// The batch-plane pass sizes `n` samples execute in at `threads`
/// workers — mirrors `run_samples`' contiguous batch-chunk sharding
/// (ranges of `n.div_ceil(threads)`, each run in passes of at most
/// `MAX_BATCH_CHUNK`).  This is what the batch-efficiency gauges
/// record: the amortization actually performed, not the coalesced
/// submission size.
fn pass_sizes(n: usize, threads: usize) -> Vec<usize> {
    let chunk = n.div_ceil(threads);
    let mut out = Vec::new();
    let mut a = 0;
    while a < n {
        let range = (a + chunk).min(n) - a;
        let mut left = range;
        while left > 0 {
            let pass = left.min(MAX_BATCH_CHUNK);
            out.push(pass);
            left -= pass;
        }
        a += range;
    }
    out
}

fn execute(shared: &Shared, arena: &mut Arena, batch: Vec<Pending>) {
    if batch.is_empty() {
        return;
    }
    // dequeue closes every rider's queue-wait span and opens its
    // batch-ride span (single `enabled` branch when tracing is off)
    let ride_start = Instant::now();
    if trace::enabled() {
        for p in &batch {
            trace::record_since(SpanName::QueueWait, p.id, 0, p.enqueued);
        }
    }
    {
        let mut inflight = lock_unpoisoned(&shared.inflight);
        inflight.clear();
        inflight.extend(batch.iter().map(|p| p.id));
    }
    // fault hooks, in the worker so the supervisor owns the blast
    // radius: a panic here unwinds through catch_unwind (riders of
    // THIS batch error out, the queue and other models are untouched);
    // a stall ages the queue so deadlines trip at the next dequeue
    match shared.faults.engine(&shared.model) {
        Some(EngineFault::Panic) => {
            panic!("injected engine_panic fault ({})", shared.model)
        }
        Some(EngineFault::Stall(d)) => std::thread::sleep(d),
        None => {}
    }
    let n = batch.len();
    // zero-copy seam: every rider's input buffer is borrowed in place
    let samples: Vec<&[f32]> = batch.iter().map(|p| p.input.as_slice()).collect();
    let threads = shared.policy.threads.clamp(1, n.div_ceil(MIN_RIDE));
    for pass in pass_sizes(n, threads) {
        shared.metrics.record_batch(pass);
    }
    let result = if threads == 1 {
        // single engine worker: whole coalesced batch through the
        // resident arena, chunked only past the arena's capacity
        let mut outs = Vec::with_capacity(n);
        let mut err = None;
        for chunk in samples.chunks(arena.capacity()) {
            match shared.plan.run_batch_planes(arena, chunk) {
                Ok(mut o) => outs.append(&mut o),
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        match err {
            None => Ok(outs),
            Some(e) => Err(e),
        }
    } else {
        shared.plan.run_samples(&samples, threads)
    };
    match result {
        Ok(outs) => {
            shared.sup.on_success();
            for (p, output) in batch.iter().zip(outs) {
                let us = p.enqueued.elapsed().as_micros() as u64;
                shared.metrics.record_latency_us(us);
                // a vanished receiver just means the client hung up
                let _ = p.reply.send(Ok(InferReply { output, batch: n }));
            }
        }
        Err(e) => {
            // submit() validates lengths, so this is an engine-internal
            // failure: every rider gets the error
            let msg = format!("{e:#}");
            for p in &batch {
                shared.metrics.record_error();
                let _ = p.reply.send(Err(ReplyError::Engine(msg.clone())));
            }
        }
    }
    if trace::enabled() {
        for p in &batch {
            trace::record_since(SpanName::BatchRide, p.id, n as u64, ride_start);
        }
    }
    lock_unpoisoned(&shared.inflight).clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_sizes_match_sharding() {
        // single worker: one pass up to the chunk bound
        assert_eq!(pass_sizes(1, 1), vec![1]);
        assert_eq!(pass_sizes(8, 1), vec![8]);
        assert_eq!(pass_sizes(MAX_BATCH_CHUNK + 4, 1), vec![MAX_BATCH_CHUNK, 4]);
        // fan-out: contiguous ranges of n.div_ceil(threads)
        assert_eq!(pass_sizes(8, 2), vec![4, 4]);
        assert_eq!(pass_sizes(10, 3), vec![4, 4, 2]);
        // every sharding covers exactly n samples
        for n in 1..=70 {
            for t in 1..=8 {
                assert_eq!(pass_sizes(n, t).iter().sum::<usize>(), n, "n={n} t={t}");
            }
        }
    }

    #[test]
    fn fan_out_respects_min_ride() {
        // up to MIN_RIDE riders: never more than one worker
        for n in 1..=MIN_RIDE {
            assert_eq!(16usize.clamp(1, n.div_ceil(MIN_RIDE)), 1, "n={n}");
        }
        // 8 riders on a many-core box: two workers of 4, not 8 of 1
        let threads = 16usize.clamp(1, 8usize.div_ceil(MIN_RIDE));
        assert_eq!(threads, 2);
        assert_eq!(pass_sizes(8, threads), vec![4, 4]);
    }

    #[test]
    fn deadline_window_is_wait_plus_budget() {
        let p = BatchPolicy {
            max_wait_us: 2_000,
            infer_budget_us: 8_000,
            ..BatchPolicy::default()
        };
        assert_eq!(p.deadline(), Duration::from_micros(10_000));
    }
}
