//! Dynamic micro-batching over one precompiled `ExecPlan`.
//!
//! Every model entry owns one [`Batcher`]: a bounded MPSC queue plus a
//! dedicated worker thread that coalesces pending single-sample requests
//! into one batch-plane engine call.  The policy is the classic
//! two-knob one:
//!
//! * **`max_batch`** — execute as soon as this many requests are
//!   pending;
//! * **`max_wait_us`** — never hold the *oldest* pending request longer
//!   than this before executing whatever has accumulated (a lone
//!   request therefore flushes after at most `max_wait_us`).
//!
//! Under load the worker is always behind the queue, so batches fill to
//! `max_batch` without ever sleeping — the wait bound only shapes the
//! lightly-loaded tail.  The coalesced batch is handed **zero-copy**
//! into the engine's batch-plane path: each rider's input buffer is
//! borrowed in place (`&[f32]` list, no contiguous-slab copy), and with
//! `threads <= 1` the worker runs [`ExecPlan::run_batch_planes`]
//! against its own **resident batch arena** — no per-batch allocation
//! at all.  Inside that pass the engine quantizes all riders' activation
//! planes in one sweep and rides each decoded weight word across every
//! rider's column, so unrelated requests amortise exactly like a
//! training-style batch.
//!
//! **Admission control:** the queue is bounded (`queue_cap`).  A submit
//! against a full queue is *shed* — the caller gets
//! [`SubmitError::Overloaded`] immediately and the HTTP layer answers
//! `503` instead of letting latency grow without bound.
//!
//! Batched outputs are bit-identical to per-sample
//! [`ExecPlan::run_sample`] calls by the engine's batch-plane contract
//! (`tests/serve_batcher.rs` asserts it end-to-end, including that a
//! coalesced batch equals N independent single-sample requests).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::engine::{Arena, ExecPlan, MAX_BATCH_CHUNK};

use super::metrics::Metrics;

/// Micro-batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// Coalesce at most this many requests into one engine call.
    pub max_batch: usize,
    /// Flush the oldest pending request after at most this long.
    pub max_wait_us: u64,
    /// Bounded-queue admission limit; submits beyond it are shed.
    pub queue_cap: usize,
    /// Engine worker threads per executed batch — an upper bound: the
    /// batcher never fans out past one worker per `MIN_RIDE` riders,
    /// so small coalesced batches keep their weight-stationary
    /// amortization instead of being sharded into single-sample passes.
    pub threads: usize,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait_us: 2_000,
            queue_cap: 256,
            threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        }
    }
}

/// A successfully executed request.
pub struct InferReply {
    /// Output activations, bit-identical to `ExecPlan::run_sample`.
    pub output: Vec<f32>,
    /// Size of the micro-batch this request rode in.
    pub batch: usize,
}

/// Why a submit was refused at the door.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — request shed (HTTP 503).
    Overloaded,
    /// Batcher is shutting down.
    ShuttingDown,
    /// Input failed validation (wrong length) — never enqueued, so one
    /// bad request cannot poison a coalesced batch.
    BadInput(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "queue full, request shed"),
            SubmitError::ShuttingDown => write!(f, "server shutting down"),
            SubmitError::BadInput(m) => write!(f, "bad input: {m}"),
        }
    }
}

/// What the worker sends back: the reply or an engine error string.
pub type ReplyResult = Result<InferReply, String>;

struct Pending {
    input: Vec<f32>,
    reply: mpsc::Sender<ReplyResult>,
    enqueued: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    shutdown: AtomicBool,
    policy: BatchPolicy,
    plan: Arc<ExecPlan>,
    metrics: Arc<Metrics>,
}

/// Bounded queue + coalescing worker for one model.
pub struct Batcher {
    shared: Arc<Shared>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the coalescing worker for `plan`.
    pub fn start(plan: Arc<ExecPlan>, metrics: Arc<Metrics>, policy: BatchPolicy) -> Batcher {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            policy,
            plan,
            metrics,
        });
        let w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("cwmix-batcher".into())
            .spawn(move || worker_loop(&w))
            .expect("spawning batcher worker");
        Batcher { shared, worker: Mutex::new(Some(worker)) }
    }

    /// Enqueue one sample.  Returns the reply channel, or refuses at
    /// the door (shed / shutdown / bad input).  The worker always
    /// answers every admitted request, so `recv()` on the returned
    /// channel cannot deadlock while the batcher is alive.
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<ReplyResult>, SubmitError> {
        let feat = self.shared.plan.feat();
        if input.len() != feat {
            return Err(SubmitError::BadInput(format!(
                "input length {} != model input {feat}",
                input.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        {
            // the shutdown check happens under the queue lock: shutdown()
            // drains the queue under the same lock *after* setting the
            // flag, so a request can never slip in unanswered behind the
            // worker's exit
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err(SubmitError::ShuttingDown);
            }
            if q.len() >= self.shared.policy.queue_cap {
                self.shared.metrics.record_shed();
                return Err(SubmitError::Overloaded);
            }
            q.push_back(Pending { input, reply: tx, enqueued: Instant::now() });
        }
        self.shared.metrics.record_request();
        self.shared.notify.notify_one();
        Ok(rx)
    }

    /// Pending queue depth (diagnostics / tests).
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Stop accepting work, drain what is queued, join the worker.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.notify.notify_all();
        if let Some(h) = self.worker.lock().unwrap().take() {
            let _ = h.join();
        }
        // answer anything that raced past the worker's final drain
        let stragglers: Vec<Pending> =
            self.shared.queue.lock().unwrap().drain(..).collect();
        for p in stragglers {
            let _ = p.reply.send(Err("server shutting down".to_string()));
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    let max_batch = shared.policy.max_batch.max(1);
    let wait = Duration::from_micros(shared.policy.max_wait_us);
    // resident batch arena: the single-worker execution path reuses it
    // across batches, so steady-state serving allocates nothing but the
    // reply vectors
    let mut arena = shared.plan.batch_arena(max_batch.min(MAX_BATCH_CHUNK));
    loop {
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            // sleep until there is work (or shutdown with an empty queue)
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.notify.wait(q).unwrap();
            }
            // coalesce: hold the oldest request at most `max_wait_us`
            // (measured from ITS enqueue — time spent while we were
            // executing the previous batch counts toward the bound)
            let deadline = q.front().unwrap().enqueued + wait;
            while q.len() < max_batch && !shared.shutdown.load(Ordering::Acquire) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) =
                    shared.notify.wait_timeout(q, deadline - now).unwrap();
                q = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.len().min(max_batch);
            q.drain(..take).collect()
        };
        execute(shared, &mut arena, batch);
    }
}

/// Minimum samples per engine worker before fanning out: splitting a
/// coalesced batch into near-single-sample shards would forfeit the
/// weight-stationary amortization batching exists to buy, so parallel
/// workers are only added once each can ride at least this many
/// samples through one batch-plane pass.
const MIN_RIDE: usize = 4;

/// The batch-plane pass sizes `n` samples execute in at `threads`
/// workers — mirrors `run_samples`' contiguous batch-chunk sharding
/// (ranges of `n.div_ceil(threads)`, each run in passes of at most
/// `MAX_BATCH_CHUNK`).  This is what the batch-efficiency gauges
/// record: the amortization actually performed, not the coalesced
/// submission size.
fn pass_sizes(n: usize, threads: usize) -> Vec<usize> {
    let chunk = n.div_ceil(threads);
    let mut out = Vec::new();
    let mut a = 0;
    while a < n {
        let range = (a + chunk).min(n) - a;
        let mut left = range;
        while left > 0 {
            let pass = left.min(MAX_BATCH_CHUNK);
            out.push(pass);
            left -= pass;
        }
        a += range;
    }
    out
}

fn execute(shared: &Shared, arena: &mut Arena, batch: Vec<Pending>) {
    if batch.is_empty() {
        return;
    }
    let n = batch.len();
    // zero-copy seam: every rider's input buffer is borrowed in place
    let samples: Vec<&[f32]> = batch.iter().map(|p| p.input.as_slice()).collect();
    let threads = shared.policy.threads.clamp(1, n.div_ceil(MIN_RIDE));
    for pass in pass_sizes(n, threads) {
        shared.metrics.record_batch(pass);
    }
    let result = if threads == 1 {
        // single engine worker: whole coalesced batch through the
        // resident arena, chunked only past the arena's capacity
        let mut outs = Vec::with_capacity(n);
        let mut err = None;
        for chunk in samples.chunks(arena.capacity()) {
            match shared.plan.run_batch_planes(arena, chunk) {
                Ok(mut o) => outs.append(&mut o),
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        match err {
            None => Ok(outs),
            Some(e) => Err(e),
        }
    } else {
        shared.plan.run_samples(&samples, threads)
    };
    match result {
        Ok(outs) => {
            for (p, output) in batch.iter().zip(outs) {
                let us = p.enqueued.elapsed().as_micros() as u64;
                shared.metrics.record_latency_us(us);
                // a vanished receiver just means the client hung up
                let _ = p.reply.send(Ok(InferReply { output, batch: n }));
            }
        }
        Err(e) => {
            // submit() validates lengths, so this is an engine-internal
            // failure: every rider gets the error
            let msg = format!("engine error: {e:#}");
            for p in &batch {
                shared.metrics.record_error();
                let _ = p.reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pass_sizes_match_sharding() {
        // single worker: one pass up to the chunk bound
        assert_eq!(pass_sizes(1, 1), vec![1]);
        assert_eq!(pass_sizes(8, 1), vec![8]);
        assert_eq!(pass_sizes(MAX_BATCH_CHUNK + 4, 1), vec![MAX_BATCH_CHUNK, 4]);
        // fan-out: contiguous ranges of n.div_ceil(threads)
        assert_eq!(pass_sizes(8, 2), vec![4, 4]);
        assert_eq!(pass_sizes(10, 3), vec![4, 4, 2]);
        // every sharding covers exactly n samples
        for n in 1..=70 {
            for t in 1..=8 {
                assert_eq!(pass_sizes(n, t).iter().sum::<usize>(), n, "n={n} t={t}");
            }
        }
    }

    #[test]
    fn fan_out_respects_min_ride() {
        // up to MIN_RIDE riders: never more than one worker
        for n in 1..=MIN_RIDE {
            assert_eq!(16usize.clamp(1, n.div_ceil(MIN_RIDE)), 1, "n={n}");
        }
        // 8 riders on a many-core box: two workers of 4, not 8 of 1
        let threads = 16usize.clamp(1, 8usize.div_ceil(MIN_RIDE));
        assert_eq!(threads, 2);
        assert_eq!(pass_sizes(8, threads), vec![4, 4]);
    }
}
