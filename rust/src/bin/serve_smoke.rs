//! CI smoke client for `cwmix serve`.
//!
//! ```bash
//! cwmix serve --addr 127.0.0.1:0 &          # prints "listening on ..."
//! cargo run --release --bin serve_smoke -- 127.0.0.1:<port>
//! ```
//!
//! Round-trips one `POST /v1/infer/<bench>` request per served model
//! and asserts the reply is **bit-identical** to a locally compiled
//! `ExecPlan::run_sample` on the same deterministic input — the same
//! builtin-zoo + synthetic-state + stripy-assignment construction the
//! server's default registry uses, so expected outputs need no fixture
//! files.  Then checks `/metrics` accounting and posts
//! `/admin/shutdown`; the harness asserts the server process itself
//! exits 0 (clean shutdown).
//!
//! With `CWMIX_SMOKE_EXPECT_STARTUP=modelpack` (the modelpack-smoke CI
//! job, against `cwmix serve --modelpack-dir`) it additionally asserts
//! that **every** model's `/metrics` `startup_source` gauge says the
//! plan cold-started from its `.cwm` artifact — combined with the
//! bit-identical round-trip above, that is the end-to-end proof that
//! serving from an artifact equals serving from an in-process compile.
//!
//! Exit code 0 = every check passed.

use std::net::{SocketAddr, ToSocketAddrs};

use anyhow::{bail, Context, Result};

use cwmix::data::{make_dataset, Split};
use cwmix::serve::client::{infer_body, output_of, Conn};
use cwmix::serve::{ModelRegistry, RegistryConfig};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [addr] = args.as_slice() else {
        bail!("usage: serve_smoke <host:port>");
    };
    let addr: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .context("no address")?;

    let mut conn = Conn::connect(addr)?;
    let models = conn.get("/v1/models")?;
    if models.status != 200 {
        bail!("GET /v1/models -> {}", models.status);
    }
    let served: Vec<String> = models
        .body
        .get("models")?
        .as_arr()?
        .iter()
        .map(|m| m.get("name").and_then(|n| n.as_str().map(str::to_string)))
        .collect::<Result<_>>()?;
    if served.is_empty() {
        bail!("server lists no models");
    }
    println!("serve_smoke: {} model(s): {}", served.len(), served.join(", "));

    // the server's default registry construction, replicated locally as
    // the expected-output oracle (no batcher needed: run_sample only)
    let reg_cfg = RegistryConfig { benches: served.clone(), ..RegistryConfig::default() };
    let local = ModelRegistry::build(&reg_cfg)?;

    for bench in &served {
        let entry = local.get(bench).context("local registry missing bench")?;
        let plan = entry.plan();
        let feat = plan.feat();
        let ds = make_dataset(bench, Split::Test, 1, 0);
        let input = &ds.x[..feat];
        let mut arena = plan.arena();
        let want = plan.run_sample(&mut arena, input)?;

        let resp = conn.post(&format!("/v1/infer/{bench}"), &infer_body(input))?;
        if resp.status != 200 {
            bail!("POST /v1/infer/{bench} -> {}: {}", resp.status, resp.body.dumps());
        }
        let got = output_of(&resp.body)?;
        if got != want {
            bail!("{bench}: served output diverged from ExecPlan::run_sample");
        }
        println!("  {bench}: {} outputs bit-identical", got.len());
    }

    // error path must answer, not hang
    let not_found = conn.post("/v1/infer/nonesuch", &infer_body(&[0.0]))?;
    if not_found.status != 404 {
        bail!("unknown model -> {} (want 404)", not_found.status);
    }

    let metrics = conn.get("/metrics")?;
    if metrics.status != 200 {
        bail!("GET /metrics -> {}", metrics.status);
    }
    let total = metrics.body.get("requests")?.as_f64()?;
    if total < served.len() as f64 {
        bail!("metrics report {total} requests after {} infers", served.len());
    }
    if let Ok(want_source) = std::env::var("CWMIX_SMOKE_EXPECT_STARTUP") {
        for bench in &served {
            let m = metrics.body.get("models")?.get(bench)?;
            let source = m.get("startup_source")?.as_str()?;
            if source != want_source {
                bail!("{bench}: startup_source {source:?}, expected {want_source:?}");
            }
            let model_bytes = m.get("model_bytes")?.as_f64()?;
            if model_bytes <= 0.0 {
                bail!("{bench}: model_bytes gauge is {model_bytes}");
            }
            println!(
                "  {bench}: startup_source={source} startup_us={} model_bytes={model_bytes}",
                m.get("startup_us")?.as_f64()?
            );
        }
    }

    let bye = conn.post("/admin/shutdown", "")?;
    if bye.status != 200 {
        bail!("POST /admin/shutdown -> {}", bye.status);
    }
    println!("serve_smoke: all checks passed, shutdown requested");
    Ok(())
}
