//! CI chaos client for `cwmix serve` under an armed fault plan.
//!
//! ```bash
//! CWMIX_FAULTS=engine_panic:ic:once CWMIX_TRACE=1 \
//!     cwmix serve --addr 127.0.0.1:0 &
//! cargo run --release --bin chaos_smoke -- 127.0.0.1:<port> ic
//! ```
//!
//! The acceptance sequence for supervised serving, run against a real
//! server process (the library-level equivalents live in
//! `tests/serve_chaos.rs` — this binary proves the same story holds
//! across a process boundary with the fault plan armed via the env
//! var):
//!
//! 1. `/readyz` answers 200 with every breaker closed.
//! 2. The first infer on the faulted model rides the injected panic —
//!    an explicit 5xx, never a hang, never a dead server — and the
//!    reply still carries its admission-stamped `request_id`; with
//!    tracing armed (`CWMIX_TRACE=1`, as the harness script sets), the
//!    spans recorded before the worker died (request / admission /
//!    queue_wait) are scrapeable from `GET /v1/trace`.
//! 3. `/metrics` shows the supervisor at work: `worker_panics` = 1,
//!    `worker_respawns` ≥ 1 for the faulted model (polled — the
//!    respawn races the 5xx reply by a backoff).
//! 4. Post-respawn infers on the faulted model are **bit-identical**
//!    to a locally compiled `ExecPlan::run_sample` — the respawned
//!    worker's fresh arena serves the same numerics.
//! 5. Every other model serves bit-identically with zero panics: the
//!    failure domain is one worker, not the process.
//! 6. The breaker stayed closed (one panic < K) and the supervision
//!    gauges are all present for the scrape.
//! 7. `/admin/shutdown` answers 200; the harness script asserts the
//!    server process itself exits 0.
//!
//! Exit code 0 = every check passed.

use std::net::{SocketAddr, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use cwmix::data::{make_dataset, Split};
use cwmix::minijson::Json;
use cwmix::serve::client::{infer_body, output_of, Conn};
use cwmix::serve::{ModelRegistry, RegistryConfig};

fn gauge(metrics: &Json, bench: &str, key: &str) -> Result<f64> {
    metrics.get("models")?.get(bench)?.get(key)?.as_f64()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (addr, faulted) = match args.as_slice() {
        [addr] => (addr.clone(), "ic".to_string()),
        [addr, faulted] => (addr.clone(), faulted.clone()),
        _ => bail!("usage: chaos_smoke <host:port> [faulted-model]"),
    };
    let addr: SocketAddr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .context("no address")?;

    let mut conn = Conn::connect(addr)?;
    let models = conn.get("/v1/models")?;
    if models.status != 200 {
        bail!("GET /v1/models -> {}", models.status);
    }
    let served: Vec<String> = models
        .body
        .get("models")?
        .as_arr()?
        .iter()
        .map(|m| m.get("name").and_then(|n| n.as_str().map(str::to_string)))
        .collect::<Result<_>>()?;
    if !served.contains(&faulted) {
        bail!("server does not serve the faulted model {faulted:?}: {served:?}");
    }
    println!(
        "chaos_smoke: {} model(s), faulted={faulted}: {}",
        served.len(),
        served.join(", ")
    );

    // 1. healthy + ready before the fault fires
    let rz = conn.get("/readyz")?;
    if rz.status != 200 {
        bail!("GET /readyz -> {} before any fault", rz.status);
    }

    // local oracle: the server's default registry construction
    let reg_cfg = RegistryConfig { benches: served.clone(), ..RegistryConfig::default() };
    let local = ModelRegistry::build(&reg_cfg)?;
    let expected = |bench: &str| -> Result<(Vec<f32>, Vec<f32>)> {
        let plan = local.get(bench).context("local registry missing bench")?.plan();
        let feat = plan.feat();
        let ds = make_dataset(bench, Split::Test, 1, 0);
        let input = ds.x[..feat].to_vec();
        let mut arena = plan.arena();
        let want = plan.run_sample(&mut arena, &input)?;
        Ok((input, want))
    };

    // 2. the injected panic: an explicit error reply, not a dead server
    let (input, want) = expected(&faulted)?;
    let r = conn.post(&format!("/v1/infer/{faulted}"), &infer_body(&input))?;
    if r.status < 500 {
        bail!(
            "{faulted}: first infer should ride the injected panic, got {}: {}",
            r.status,
            r.body.dumps()
        );
    }
    println!("  {faulted}: injected panic answered {} (explicit, no hang)", r.status);

    // 2b. the 5xx reply still carries its admission-stamped request id,
    //     and the spans recorded before the worker died are scrapeable
    let rid = r.body.get("request_id")?.as_f64()?;
    if rid < 1.0 {
        bail!("{faulted}: panicked reply lost its request id: {}", r.body.dumps());
    }
    let t = conn.get("/v1/trace?last=4096")?;
    if t.status != 200 {
        bail!("GET /v1/trace -> {}", t.status);
    }
    let mine: Vec<String> = t
        .body
        .get("traceEvents")?
        .as_arr()?
        .iter()
        .filter(|e| {
            e.opt("args")
                .and_then(|a| a.opt("req"))
                .and_then(|r| r.as_f64().ok())
                .map(|r| r == rid)
                .unwrap_or(false)
        })
        .map(|e| e.get("name").and_then(|n| n.as_str().map(str::to_string)))
        .collect::<Result<_>>()?;
    for want in ["request", "admission", "queue_wait"] {
        // batch_ride died with the worker — only the pre-crash chain survives
        if !mine.iter().any(|n| n == want) {
            bail!("{faulted}: request {rid} missing a {want:?} span: {mine:?}");
        }
    }
    println!("  {faulted}: request {rid} left {} spans in /v1/trace", mine.len());

    // 3. the supervisor respawned the worker (poll: the respawn lags
    //    the error reply by the backoff)
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = conn.get("/metrics")?;
        if m.status != 200 {
            bail!("GET /metrics -> {}", m.status);
        }
        if gauge(&m.body, &faulted, "worker_respawns")? >= 1.0 {
            let panics = gauge(&m.body, &faulted, "worker_panics")?;
            if panics != 1.0 {
                bail!("{faulted}: worker_panics {panics}, expected exactly 1");
            }
            break;
        }
        if Instant::now() > deadline {
            bail!("{faulted}: worker never respawned (metrics: {})", m.body.dumps());
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    println!("  {faulted}: worker respawned");

    // 4. recovery is bit-identical to the local oracle
    let r = conn.post(&format!("/v1/infer/{faulted}"), &infer_body(&input))?;
    if r.status != 200 {
        bail!("{faulted}: post-respawn infer -> {}: {}", r.status, r.body.dumps());
    }
    if output_of(&r.body)? != want {
        bail!("{faulted}: post-respawn output diverged from ExecPlan::run_sample");
    }
    println!("  {faulted}: post-respawn reply bit-identical");

    // 5. the failure domain was one worker: every other model clean
    let m = conn.get("/metrics")?;
    for bench in served.iter().filter(|b| **b != faulted) {
        let (input, want) = expected(bench)?;
        let r = conn.post(&format!("/v1/infer/{bench}"), &infer_body(&input))?;
        if r.status != 200 {
            bail!("{bench}: infer -> {}: {}", r.status, r.body.dumps());
        }
        if output_of(&r.body)? != want {
            bail!("{bench}: output diverged from ExecPlan::run_sample");
        }
        let panics = gauge(&m.body, bench, "worker_panics")?;
        if panics != 0.0 {
            bail!("{bench}: worker_panics {panics} on an unfaulted model");
        }
        println!("  {bench}: unaffected, bit-identical");
    }

    // 6. breaker gauges: closed (one panic < K), present for scrapes
    let m = conn.get("/metrics")?;
    for (key, val) in
        [("breaker_state", 0.0), ("breaker_opens", 0.0), ("deadline_expired_total", 0.0)]
    {
        let got = gauge(&m.body, &faulted, key)?;
        if got != val {
            bail!("{faulted}: {key} = {got}, expected {val}");
        }
    }
    let name = m
        .body
        .get("models")?
        .get(&faulted)?
        .get("breaker_state_name")?
        .as_str()?
        .to_string();
    if name != "closed" {
        bail!("{faulted}: breaker_state_name {name:?}, expected \"closed\"");
    }

    // 7. clean shutdown (the harness asserts the process exits 0)
    let bye = conn.post("/admin/shutdown", "")?;
    if bye.status != 200 {
        bail!("POST /admin/shutdown -> {}", bye.status);
    }
    println!("chaos_smoke: all checks passed, shutdown requested");
    Ok(())
}
