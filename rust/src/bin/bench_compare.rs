//! Perf-trajectory regression gate over two `BENCH_engine.json` files.
//!
//! ```bash
//! cargo run --release --bin bench_compare -- \
//!     BENCH_engine.json BENCH_engine.fresh.json [tolerance]
//! ```
//!
//! Compares the committed trajectory (`baseline`) against a fresh
//! `cargo bench --bench bench_engine` run and **fails (exit 1) when any
//! model x backend cell regressed by more than `tolerance`** (default
//! 0.20 = 20%, the ROADMAP gate).
//!
//! Raw milliseconds are machine-dependent, so cells are normalised
//! before comparison: each engine backend's single-thread ms/inf is
//! divided by the *same run's* seed-scalar ms/inf (the within-run
//! speedup is what the trajectory tracks), and each `(p_x, p_w)` combo
//! cell compares the packed/reference ratio.  The multithreaded cell is
//! reported but not gated — its ratio to the single-thread seed scales
//! with the runner's core count.  A cell regresses when its normalised
//! value grows by more than `tolerance` relative to the baseline.
//!
//! A missing baseline or a JSON `version` mismatch skips the gate with
//! a note (exit 0) — the first committed trajectory establishes the
//! baseline and a format bump resets it.  A missing or unreadable
//! *fresh* file is an error (the bench step failed to produce it), and
//! so is a baseline cell that vanished from the fresh run: losing
//! trajectory coverage must not pass silently.

use std::path::Path;
use std::process::ExitCode;

use anyhow::{bail, Result};
use cwmix::minijson::{parse_file, Json};

/// A normalised trajectory cell: `(label, value)` where smaller is
/// better and the value is machine-independent.
fn cells(doc: &Json) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for (bench, obj) in doc.get("benches")?.as_obj()? {
        let seed = obj.get("seed_scalar_ms_per_inf")?.as_f64()?;
        if seed <= 0.0 {
            bail!("{bench}: non-positive seed baseline");
        }
        // single-thread cells only: the multithreaded cell's ratio to
        // the (single-thread) seed scales with the runner's core count,
        // which baseline and fresh machines need not share — it stays
        // in the JSON for humans but is not gated
        for key in ["engine_reference_ms_per_inf", "engine_packed_ms_per_inf"] {
            let ms = obj.get(key)?.as_f64()?;
            out.push((format!("{bench}/{key}"), ms / seed));
        }
    }
    // per-(p_x, p_w) cells: packed relative to reference, same run
    if let Some(combos) = doc.opt("combos") {
        for (combo, obj) in combos.as_obj()? {
            let reference = obj.get("reference_ms_per_inf")?.as_f64()?;
            let packed = obj.get("packed_ms_per_inf")?.as_f64()?;
            if reference <= 0.0 {
                bail!("{combo}: non-positive reference baseline");
            }
            out.push((format!("combo/{combo}"), packed / reference));
        }
    }
    Ok(out)
}

fn compare(baseline: &Json, fresh: &Json, tolerance: f64) -> Result<Vec<String>> {
    let base: std::collections::BTreeMap<String, f64> = cells(baseline)?.into_iter().collect();
    let mut regressions = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for (label, new_v) in cells(fresh)? {
        seen.insert(label.clone());
        let Some(&old_v) = base.get(&label) else {
            println!("  new cell {label} = {new_v:.4} (no baseline, skipped)");
            continue;
        };
        let ratio = new_v / old_v;
        let flag = if ratio > 1.0 + tolerance { "  << REGRESSION" } else { "" };
        println!("  {label}: {old_v:.4} -> {new_v:.4} ({ratio:.3}x){flag}");
        if ratio > 1.0 + tolerance {
            regressions.push(format!(
                "{label}: {old_v:.4} -> {new_v:.4} ({:.1}% worse)",
                (ratio - 1.0) * 100.0
            ));
        }
    }
    // coverage must not shrink silently: a baseline cell that vanished
    // from the fresh run is a failure, not a free pass
    for label in base.keys() {
        if !seen.contains(label) {
            regressions.push(format!("{label}: present in baseline, missing from fresh run"));
        }
    }
    Ok(regressions)
}

fn run() -> Result<ExitCode> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.len() < 2 || args.len() > 3 {
        bail!("usage: bench_compare <baseline.json> <fresh.json> [tolerance]");
    }
    let tolerance: f64 = match args.get(2) {
        Some(t) => t.parse()?,
        None => 0.20,
    };
    let (base_path, fresh_path) = (Path::new(&args[0]), Path::new(&args[1]));
    if !base_path.exists() {
        println!(
            "no committed baseline at {} — skipping the regression gate \
             (commit a fresh BENCH_engine.json to establish the trajectory)",
            base_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }
    let baseline = parse_file(base_path)?;
    let fresh = parse_file(fresh_path)?;
    let (bv, fv) = (baseline.get("version")?.as_f64()?, fresh.get("version")?.as_f64()?);
    if bv != fv {
        println!(
            "trajectory format changed (baseline v{bv}, fresh v{fv}) — \
             skipping the gate; commit the fresh file to reset the baseline"
        );
        return Ok(ExitCode::SUCCESS);
    }
    println!(
        "bench_compare: normalised cells, tolerance {:.0}%",
        tolerance * 100.0
    );
    let regressions = compare(&baseline, &fresh, tolerance)?;
    if regressions.is_empty() {
        println!("no cell regressed by more than {:.0}%", tolerance * 100.0);
        return Ok(ExitCode::SUCCESS);
    }
    eprintln!("\n{} cell(s) regressed:", regressions.len());
    for r in &regressions {
        eprintln!("  {r}");
    }
    Ok(ExitCode::FAILURE)
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("bench_compare: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use cwmix::minijson::parse;

    fn doc(seed: f64, reference: f64, packed: f64) -> Json {
        parse(&format!(
            r#"{{"version": 2, "benches": {{"ic": {{
                "seed_scalar_ms_per_inf": {seed},
                "engine_reference_ms_per_inf": {reference},
                "engine_packed_ms_per_inf": {packed},
                "engine_packed_mt_ms_per_inf": {packed}
            }}}},
            "combos": {{"x2w2": {{
                "reference_ms_per_inf": {reference},
                "packed_ms_per_inf": {packed}
            }}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn same_run_is_clean() {
        let a = doc(10.0, 5.0, 2.0);
        assert!(compare(&a, &a, 0.2).unwrap().is_empty());
    }

    #[test]
    fn machine_speed_cancels_out() {
        // a uniformly 3x slower machine does not trip the gate
        let base = doc(10.0, 5.0, 2.0);
        let fresh = doc(30.0, 15.0, 6.0);
        assert!(compare(&base, &fresh, 0.2).unwrap().is_empty());
    }

    #[test]
    fn relative_regression_trips() {
        // packed got 50% slower relative to the same run's seed
        let base = doc(10.0, 5.0, 2.0);
        let fresh = doc(10.0, 5.0, 3.0);
        let regs = compare(&base, &fresh, 0.2).unwrap();
        assert!(!regs.is_empty());
        assert!(regs.iter().any(|r| r.contains("engine_packed_ms_per_inf")));
        // ... but a 50% tolerance lets it through
        assert!(compare(&base, &fresh, 0.55).unwrap().is_empty());
    }

    #[test]
    fn vanished_cell_trips() {
        // a baseline cell missing from the fresh run must fail, not pass
        let base = doc(10.0, 5.0, 2.0);
        let mut fresh = doc(10.0, 5.0, 2.0);
        if let Json::Obj(o) = &mut fresh {
            o.remove("combos");
        }
        let regs = compare(&base, &fresh, 0.2).unwrap();
        assert!(regs.iter().any(|r| r.contains("missing from fresh run")));
    }

    #[test]
    fn cell_normalisation_shape() {
        let c = cells(&doc(10.0, 5.0, 2.0)).unwrap();
        // 2 single-thread backend cells + 1 combo cell; the mt cell is
        // present in the JSON but not gated
        assert_eq!(c.len(), 3);
        assert!(c.iter().any(|(l, v)| l == "combo/x2w2" && (*v - 0.4).abs() < 1e-9));
        assert!(!c.iter().any(|(l, _)| l.contains("mt")));
    }
}
