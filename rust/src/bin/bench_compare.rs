//! Perf-trajectory regression gate over committed vs fresh bench JSON.
//!
//! ```bash
//! cargo run --release --bin bench_compare -- \
//!     BENCH_engine.json BENCH_engine.fresh.json [tolerance] \
//!     [--serve BENCH_serve.json BENCH_serve.fresh.json]
//! ```
//!
//! Compares the committed trajectories (`baseline`) against fresh
//! `cargo bench` runs and **fails (exit 1) when any normalised cell
//! regressed by more than `tolerance`** (default 0.20 = 20%, the
//! ROADMAP gate).
//!
//! Raw milliseconds and req/s are machine-dependent, so cells are
//! normalised before comparison:
//!
//! * engine: each backend's single-thread ms/inf is divided by the
//!   *same run's* seed-scalar ms/inf; each `(p_x, p_w)` combo cell
//!   compares the packed/reference ratio; each batch-plane cell (schema
//!   v3) divides the packed per-sample time at batch size B by the same
//!   run's seed scalar; each cold-start cell (schema v4) divides the
//!   modelpack load time by the same run's compile time for that model
//!   — the ratio the `.cwm` path exists to keep small; each fused cell
//!   (schema v5) divides the fused-requantize per-sample time by the
//!   same run's two-pass time for that model — the ratio the fusion
//!   pass exists to keep below one; each simd cell (schema v6) divides
//!   the simd backend's batched per-sample time by the same run's
//!   packed time for that model — the ratio the vector tiers exist to
//!   keep below one (~1.0 when the host dispatched to `swar`); each
//!   profile cell (schema v7) divides the profiled `run_batch_planes`
//!   per-sample time by the same run's plain time for that model — the
//!   ratio the near-free measurement hooks exist to keep near one.  The
//!   multithreaded cell is reported but not gated — its ratio to the
//!   single-thread seed scales with the runner's core count.
//!
//! On top of the baseline diff, the *fresh* engine doc carries its own
//! within-run simd gate: whenever it records a real SIMD tier
//! (`simd_tier` ≠ `swar`), every `speedup_simd_vs_packed` cell must
//! stay ≥ 1.0 (with a 5% timer grace).  It needs no baseline, and
//! skips with a note on hosts whose tier is `swar` (non-x86, or
//! `CWMIX_SIMD=off`).
//! * serve: the micro-batching config relative to the *same run's*
//!   `batch1` config — inverse throughput speedup and the p99 ratio.
//!
//! A cell regresses when its normalised value grows by more than
//! `tolerance` relative to the baseline.
//!
//! A missing baseline or a JSON `version` mismatch skips that suite's
//! gate with a note (exit 0) — the first committed trajectory
//! establishes the baseline and a format bump resets it (CI's
//! `commit-baseline` job re-commits on either condition).  A missing or
//! unreadable *fresh* file is an error (the bench step failed to
//! produce it), and so is a baseline cell that vanished from the fresh
//! run: losing trajectory coverage must not pass silently.

use std::path::Path;
use std::process::ExitCode;

use anyhow::{bail, Result};
use cwmix::minijson::{parse_file, Json};

/// Normalised engine-trajectory cells: `(label, value)` where smaller
/// is better and the value is machine-independent.
fn engine_cells(doc: &Json) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for (bench, obj) in doc.get("benches")?.as_obj()? {
        let seed = obj.get("seed_scalar_ms_per_inf")?.as_f64()?;
        if seed <= 0.0 {
            bail!("{bench}: non-positive seed baseline");
        }
        // single-thread cells only: the multithreaded cell's ratio to
        // the (single-thread) seed scales with the runner's core count,
        // which baseline and fresh machines need not share — it stays
        // in the JSON for humans but is not gated
        for key in ["engine_reference_ms_per_inf", "engine_packed_ms_per_inf"] {
            let ms = obj.get(key)?.as_f64()?;
            out.push((format!("{bench}/{key}"), ms / seed));
        }
    }
    // per-(p_x, p_w) cells: packed relative to reference, same run
    if let Some(combos) = doc.opt("combos") {
        for (combo, obj) in combos.as_obj()? {
            let reference = obj.get("reference_ms_per_inf")?.as_f64()?;
            let packed = obj.get("packed_ms_per_inf")?.as_f64()?;
            if reference <= 0.0 {
                bail!("{combo}: non-positive reference baseline");
            }
            out.push((format!("combo/{combo}"), packed / reference));
        }
    }
    // cold-start cells (schema v4): modelpack load time over the same
    // run's compile time for the same model — machine speed cancels,
    // a regression means loading lost its edge over recompiling
    if let Some(cells) = doc.opt("cold_start") {
        for (bench, obj) in cells.as_obj()? {
            let compile = obj.get("compile_ms")?.as_f64()?;
            let load = obj.get("modelpack_load_ms")?.as_f64()?;
            if compile <= 0.0 {
                bail!("cold/{bench}: non-positive compile baseline");
            }
            out.push((format!("cold/{bench}"), load / compile));
        }
    }
    // fused-requantize cells (schema v5): fused per-sample time over
    // the same run's two-pass time on the same model — machine speed
    // cancels, a regression means the fused exit stopped paying for
    // itself
    if let Some(cells) = doc.opt("fused") {
        for (bench, obj) in cells.as_obj()? {
            let fused = obj.get("fused_ms_per_sample")?.as_f64()?;
            let unfused = obj.get("unfused_ms_per_sample")?.as_f64()?;
            if unfused <= 0.0 {
                bail!("fused/{bench}: non-positive two-pass baseline");
            }
            out.push((format!("fused/{bench}"), fused / unfused));
        }
    }
    // simd cells (schema v6): simd batched per-sample time over the
    // same run's packed time on the same model — machine speed cancels;
    // a regression means the vector tiers lost their edge (or the
    // runner pool lost its SIMD tier, which is a real coverage loss)
    if let Some(cells) = doc.opt("simd") {
        for (bench, obj) in cells.as_obj()? {
            let simd = obj.get("simd_ms_per_sample")?.as_f64()?;
            let packed = obj.get("packed_ms_per_sample")?.as_f64()?;
            if packed <= 0.0 {
                bail!("simd/{bench}: non-positive packed baseline");
            }
            out.push((format!("simd/{bench}"), simd / packed));
        }
    }
    // profiling-hook cells (schema v7): profiled per-sample time over
    // the same run's plain time on the same model — machine speed
    // cancels; a regression means the measurement hooks stopped being
    // (near-)free and the always-on `None` branch promise broke
    if let Some(cells) = doc.opt("profile") {
        for (bench, obj) in cells.as_obj()? {
            let profiled = obj.get("profiled_ms_per_sample")?.as_f64()?;
            let plain = obj.get("plain_ms_per_sample")?.as_f64()?;
            if plain <= 0.0 {
                bail!("profile/{bench}: non-positive plain baseline");
            }
            out.push((format!("profile/{bench}"), profiled / plain));
        }
    }
    // batch-plane cells (schema v3): packed per-sample time at batch
    // size B over the same run's seed scalar on the same model
    if let Some(cells) = doc.opt("batch_cells") {
        let bench = doc.get("batch_bench")?.as_str()?.to_string();
        let seed = doc
            .get("benches")?
            .get(&bench)?
            .get("seed_scalar_ms_per_inf")?
            .as_f64()?;
        if seed <= 0.0 {
            bail!("batch_bench {bench}: non-positive seed baseline");
        }
        for (label, obj) in cells.as_obj()? {
            let ms = obj.get("packed_ms_per_sample")?.as_f64()?;
            out.push((format!("batch/{label}"), ms / seed));
        }
    }
    Ok(out)
}

/// Normalised serve-trajectory cells: the micro-batching config
/// relative to the same run's no-coalescing `batch1` config.
fn serve_cells(doc: &Json) -> Result<Vec<(String, f64)>> {
    let b1 = doc.get("batch1")?;
    let micro = doc.get("micro_batch")?;
    let b1_rps = b1.get("throughput_rps")?.as_f64()?;
    let micro_rps = micro.get("throughput_rps")?.as_f64()?;
    let b1_p99 = b1.get("p99_ms")?.as_f64()?;
    let micro_p99 = micro.get("p99_ms")?.as_f64()?;
    if b1_rps <= 0.0 || micro_rps <= 0.0 || b1_p99 <= 0.0 {
        bail!("serve trajectory has non-positive throughput/latency");
    }
    Ok(vec![
        // inverse of the micro-batching speedup: grows when coalescing
        // stops paying off
        ("serve/throughput_batch1_over_micro".to_string(), b1_rps / micro_rps),
        ("serve/p99_micro_over_batch1".to_string(), micro_p99 / b1_p99),
    ])
}

/// Within-run simd gate on the fresh engine doc (no baseline needed):
/// when the run dispatched to a real SIMD tier, the batched simd cells
/// must not be slower than packed.  Hosts whose tier is `swar`
/// (non-x86, or forced off) skip with a note — there is nothing to
/// assert about the fallback racing itself.
fn simd_speedup_failures(doc: &Json) -> Result<Vec<String>> {
    let Some(cells) = doc.opt("simd") else {
        println!("fresh engine doc has no simd cells — skipping the simd gate");
        return Ok(Vec::new());
    };
    let tier = match doc.opt("simd_tier") {
        Some(t) => t.as_str()?.to_string(),
        None => "swar".to_string(),
    };
    if tier == "swar" {
        println!("simd tier is swar on this host — skipping the simd speedup gate");
        return Ok(Vec::new());
    }
    println!("simd speedup (tier {tier}, fresh run, want >= 1.0):");
    let mut failures = Vec::new();
    for (bench, obj) in cells.as_obj()? {
        let speedup = obj.get("speedup_simd_vs_packed")?.as_f64()?;
        println!("  simd/{bench}: {speedup:.3}x vs packed");
        // 5% grace, matching bench_engine's batch-plateau allowance,
        // so timer noise on a genuinely-even cell cannot flake CI
        if speedup < 0.95 {
            failures.push(format!(
                "simd/{bench}: {speedup:.3}x — simd batched kernels slower than \
                 packed under the {tier} tier"
            ));
        }
    }
    Ok(failures)
}

fn compare(
    base: &[(String, f64)],
    fresh: &[(String, f64)],
    tolerance: f64,
) -> Vec<String> {
    let base: std::collections::BTreeMap<&str, f64> =
        base.iter().map(|(l, v)| (l.as_str(), *v)).collect();
    let mut regressions = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for (label, new_v) in fresh {
        seen.insert(label.as_str());
        let Some(&old_v) = base.get(label.as_str()) else {
            println!("  new cell {label} = {new_v:.4} (no baseline, skipped)");
            continue;
        };
        let ratio = new_v / old_v;
        let flag = if ratio > 1.0 + tolerance { "  << REGRESSION" } else { "" };
        println!("  {label}: {old_v:.4} -> {new_v:.4} ({ratio:.3}x){flag}");
        if ratio > 1.0 + tolerance {
            regressions.push(format!(
                "{label}: {old_v:.4} -> {new_v:.4} ({:.1}% worse)",
                (ratio - 1.0) * 100.0
            ));
        }
    }
    // coverage must not shrink silently: a baseline cell that vanished
    // from the fresh run is a failure, not a free pass
    for label in base.keys() {
        if !seen.contains(label) {
            regressions.push(format!("{label}: present in baseline, missing from fresh run"));
        }
    }
    regressions
}

/// Gate one suite (engine or serve).  Returns the regression list, or
/// an empty list when the gate is skipped (no baseline / version bump).
fn gate_suite(
    name: &str,
    base_path: &Path,
    fresh_path: &Path,
    tolerance: f64,
    cells: fn(&Json) -> Result<Vec<(String, f64)>>,
) -> Result<Vec<String>> {
    if !base_path.exists() {
        println!(
            "no committed {name} baseline at {} — skipping the regression \
             gate (commit a fresh trajectory to establish it)",
            base_path.display()
        );
        return Ok(Vec::new());
    }
    let baseline = parse_file(base_path)?;
    let fresh = parse_file(fresh_path)?;
    let (bv, fv) = (baseline.get("version")?.as_f64()?, fresh.get("version")?.as_f64()?);
    if bv != fv {
        println!(
            "{name} trajectory format changed (baseline v{bv}, fresh v{fv}) — \
             skipping the gate; commit the fresh file to reset the baseline"
        );
        return Ok(Vec::new());
    }
    println!("{name} cells:");
    Ok(compare(&cells(&baseline)?, &cells(&fresh)?, tolerance))
}

fn run() -> Result<ExitCode> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // positional: <engine_base> <engine_fresh> [tolerance];
    // optional:   --serve <serve_base> <serve_fresh>
    let mut positional = Vec::new();
    let mut serve_paths: Option<(String, String)> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--serve" {
            if i + 2 >= args.len() {
                bail!("--serve needs <baseline.json> <fresh.json>");
            }
            serve_paths = Some((args[i + 1].clone(), args[i + 2].clone()));
            i += 3;
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    if positional.len() < 2 || positional.len() > 3 {
        bail!(
            "usage: bench_compare <baseline.json> <fresh.json> [tolerance] \
             [--serve <baseline.json> <fresh.json>]"
        );
    }
    let tolerance: f64 = match positional.get(2) {
        Some(t) => t.parse()?,
        None => 0.20,
    };
    println!(
        "bench_compare: normalised cells, tolerance {:.0}%",
        tolerance * 100.0
    );
    let mut regressions = gate_suite(
        "engine",
        Path::new(&positional[0]),
        Path::new(&positional[1]),
        tolerance,
        engine_cells,
    )?;
    // the fresh-run simd gate runs even when the baseline diff was
    // skipped (it is a within-run ratio, not a trajectory)
    regressions.extend(simd_speedup_failures(&parse_file(Path::new(&positional[1]))?)?);
    if let Some((base, fresh)) = &serve_paths {
        regressions.extend(gate_suite(
            "serve",
            Path::new(base),
            Path::new(fresh),
            tolerance,
            serve_cells,
        )?);
    }
    if regressions.is_empty() {
        println!("no cell regressed by more than {:.0}%", tolerance * 100.0);
        return Ok(ExitCode::SUCCESS);
    }
    eprintln!("\n{} cell(s) regressed:", regressions.len());
    for r in &regressions {
        eprintln!("  {r}");
    }
    Ok(ExitCode::FAILURE)
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("bench_compare: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use cwmix::minijson::parse;

    fn doc(seed: f64, reference: f64, packed: f64) -> Json {
        parse(&format!(
            r#"{{"version": 5, "benches": {{"ic": {{
                "seed_scalar_ms_per_inf": {seed},
                "engine_reference_ms_per_inf": {reference},
                "engine_packed_ms_per_inf": {packed},
                "engine_packed_mt_ms_per_inf": {packed}
            }}}},
            "combos": {{"x2w2": {{
                "reference_ms_per_inf": {reference},
                "packed_ms_per_inf": {packed}
            }}}},
            "batch_bench": "ic",
            "batch_cells": {{
                "b1": {{"packed_ms_per_sample": {packed}}},
                "b8": {{"packed_ms_per_sample": {packed}}}
            }}}}"#
        ))
        .unwrap()
    }

    fn doc_with_cold(seed: f64, reference: f64, packed: f64, load_ms: f64) -> Json {
        let mut d = doc(seed, reference, packed);
        let cold = parse(&format!(
            r#"{{"ic": {{"compile_ms": 10.0, "modelpack_load_ms": {load_ms},
                 "pack_bytes": 1000, "speedup_load_vs_compile": 1.0}}}}"#
        ))
        .unwrap();
        if let Json::Obj(o) = &mut d {
            o.insert("cold_start".to_string(), cold);
        }
        d
    }

    fn doc_with_fused(seed: f64, reference: f64, packed: f64, fused_ms: f64) -> Json {
        let mut d = doc(seed, reference, packed);
        let fused = parse(&format!(
            r#"{{"ic": {{"fused_ms_per_sample": {fused_ms},
                 "unfused_ms_per_sample": 2.0, "requant_fused_ratio": 0.5,
                 "act_bytes_saved_per_sample": 1000}}}}"#
        ))
        .unwrap();
        if let Json::Obj(o) = &mut d {
            o.insert("fused".to_string(), fused);
        }
        d
    }

    fn doc_with_simd(tier: &str, speedup: f64) -> Json {
        let mut d = doc(10.0, 5.0, 2.0);
        let simd = parse(&format!(
            r#"{{"ic": {{"simd_ms_per_sample": {}, "packed_ms_per_sample": 2.0,
                 "speedup_simd_vs_packed": {speedup}}}}}"#,
            2.0 / speedup
        ))
        .unwrap();
        if let Json::Obj(o) = &mut d {
            o.insert("simd_tier".to_string(), Json::str(tier));
            o.insert("simd".to_string(), simd);
        }
        d
    }

    fn serve_doc(b1_rps: f64, micro_rps: f64, b1_p99: f64, micro_p99: f64) -> Json {
        parse(&format!(
            r#"{{"version": 1,
                "batch1": {{"throughput_rps": {b1_rps}, "p99_ms": {b1_p99}}},
                "micro_batch": {{"throughput_rps": {micro_rps}, "p99_ms": {micro_p99}}}}}"#
        ))
        .unwrap()
    }

    fn diff(base: &Json, fresh: &Json, tol: f64) -> Vec<String> {
        compare(&engine_cells(base).unwrap(), &engine_cells(fresh).unwrap(), tol)
    }

    #[test]
    fn same_run_is_clean() {
        let a = doc(10.0, 5.0, 2.0);
        assert!(diff(&a, &a, 0.2).is_empty());
    }

    #[test]
    fn machine_speed_cancels_out() {
        // a uniformly 3x slower machine does not trip the gate
        let base = doc(10.0, 5.0, 2.0);
        let fresh = doc(30.0, 15.0, 6.0);
        assert!(diff(&base, &fresh, 0.2).is_empty());
    }

    #[test]
    fn relative_regression_trips() {
        // packed got 50% slower relative to the same run's seed
        let base = doc(10.0, 5.0, 2.0);
        let fresh = doc(10.0, 5.0, 3.0);
        let regs = diff(&base, &fresh, 0.2);
        assert!(!regs.is_empty());
        assert!(regs.iter().any(|r| r.contains("engine_packed_ms_per_inf")));
        // batch cells normalise by the same seed, so they trip too
        assert!(regs.iter().any(|r| r.contains("batch/b8")));
        // ... but a 55% tolerance lets it through
        assert!(diff(&base, &fresh, 0.55).is_empty());
    }

    #[test]
    fn vanished_cell_trips() {
        // a baseline cell missing from the fresh run must fail, not pass
        let base = doc(10.0, 5.0, 2.0);
        let mut fresh = doc(10.0, 5.0, 2.0);
        if let Json::Obj(o) = &mut fresh {
            o.remove("combos");
        }
        let regs = diff(&base, &fresh, 0.2);
        assert!(regs.iter().any(|r| r.contains("missing from fresh run")));
    }

    #[test]
    fn cell_normalisation_shape() {
        let c = engine_cells(&doc(10.0, 5.0, 2.0)).unwrap();
        // 2 single-thread backend cells + 1 combo cell + 2 batch cells;
        // the mt cell is present in the JSON but not gated
        assert_eq!(c.len(), 5);
        assert!(c.iter().any(|(l, v)| l == "combo/x2w2" && (*v - 0.4).abs() < 1e-9));
        assert!(c.iter().any(|(l, v)| l == "batch/b8" && (*v - 0.2).abs() < 1e-9));
        assert!(!c.iter().any(|(l, _)| l.contains("mt")));
    }

    #[test]
    fn cold_start_cells_normalise_and_gate() {
        // load/compile = 0.1 in the baseline
        let base = doc_with_cold(10.0, 5.0, 2.0, 1.0);
        let cells = engine_cells(&base).unwrap();
        assert!(cells.iter().any(|(l, v)| l == "cold/ic" && (*v - 0.1).abs() < 1e-9));
        // same ratio on a slower machine is clean …
        let slow = doc_with_cold(30.0, 15.0, 6.0, 1.0);
        assert!(diff(&base, &slow, 0.2).is_empty());
        // … but load losing its edge over compile trips the gate
        let regressed = doc_with_cold(10.0, 5.0, 2.0, 5.0);
        let regs = diff(&base, &regressed, 0.2);
        assert!(regs.iter().any(|r| r.contains("cold/ic")));
    }

    #[test]
    fn fused_cells_normalise_and_gate() {
        // fused/two-pass = 0.75 in the baseline
        let base = doc_with_fused(10.0, 5.0, 2.0, 1.5);
        let cells = engine_cells(&base).unwrap();
        assert!(cells.iter().any(|(l, v)| l == "fused/ic" && (*v - 0.75).abs() < 1e-9));
        // same ratio on a slower machine is clean … (the within-run
        // two-pass denominator in doc_with_fused is fixed, so scale
        // only the fused cell consistently)
        assert!(diff(&base, &base, 0.2).is_empty());
        // … but the fused exit losing its edge trips the gate
        let regressed = doc_with_fused(10.0, 5.0, 2.0, 2.4);
        let regs = diff(&base, &regressed, 0.2);
        assert!(regs.iter().any(|r| r.contains("fused/ic")));
    }

    #[test]
    fn simd_cells_normalise_and_gate() {
        // simd/packed = 0.5 in the baseline
        let base = doc_with_simd("avx2", 2.0);
        let cells = engine_cells(&base).unwrap();
        assert!(cells.iter().any(|(l, v)| l == "simd/ic" && (*v - 0.5).abs() < 1e-9));
        // same ratio elsewhere is clean
        assert!(diff(&base, &base, 0.2).is_empty());
        // the runner losing its vector edge (tier back to swar) trips
        let regressed = doc_with_simd("swar", 1.0);
        let regs = diff(&base, &regressed, 0.2);
        assert!(regs.iter().any(|r| r.contains("simd/ic")));
    }

    #[test]
    fn simd_speedup_gate_skips_swar_and_trips_slowdowns() {
        // swar tier: nothing to assert about the fallback racing itself
        assert!(simd_speedup_failures(&doc_with_simd("swar", 0.5)).unwrap().is_empty());
        // pre-v6 docs have no simd section: skip, not error
        assert!(simd_speedup_failures(&doc(10.0, 5.0, 2.0)).unwrap().is_empty());
        // a real tier slower than packed fails the gate
        let fails = simd_speedup_failures(&doc_with_simd("avx2", 0.8)).unwrap();
        assert!(fails.iter().any(|f| f.contains("simd/ic")));
        // faster passes, and the 5% grace absorbs an even cell's noise
        assert!(simd_speedup_failures(&doc_with_simd("avx2", 1.5)).unwrap().is_empty());
        assert!(simd_speedup_failures(&doc_with_simd("avx512", 0.97))
            .unwrap()
            .is_empty());
    }

    #[test]
    fn profile_cells_normalise_and_gate() {
        let with_profile = |profiled: f64| {
            let mut d = doc(10.0, 5.0, 2.0);
            let prof = parse(&format!(
                r#"{{"ic": {{"plain_ms_per_sample": 2.0,
                     "profiled_ms_per_sample": {profiled},
                     "overhead_profiled_vs_plain": {},
                     "spearman_measured_vs_model": 0.9}}}}"#,
                profiled / 2.0
            ))
            .unwrap();
            if let Json::Obj(o) = &mut d {
                o.insert("profile".to_string(), prof);
            }
            d
        };
        // profiled/plain = 1.02 in the baseline
        let base = with_profile(2.04);
        let cells = engine_cells(&base).unwrap();
        assert!(cells.iter().any(|(l, v)| l == "profile/ic" && (*v - 1.02).abs() < 1e-9));
        assert!(diff(&base, &base, 0.2).is_empty());
        // hooks growing to 1.5x plain trips the gate
        let regs = diff(&base, &with_profile(3.06), 0.2);
        assert!(regs.iter().any(|r| r.contains("profile/ic")));
    }

    #[test]
    fn v2_docs_without_batch_cells_still_parse() {
        // pre-v3 baselines have no batch_cells; the extractor must not
        // demand them (the version gate handles the schema bump, but a
        // malformed doc should fail loudly, not silently)
        let mut base = doc(10.0, 5.0, 2.0);
        if let Json::Obj(o) = &mut base {
            o.remove("batch_cells");
            o.remove("batch_bench");
        }
        assert_eq!(engine_cells(&base).unwrap().len(), 3);
    }

    #[test]
    fn serve_cells_normalise_within_run() {
        let c = serve_cells(&serve_doc(100.0, 250.0, 20.0, 10.0)).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.iter().any(|(l, v)| l.ends_with("batch1_over_micro") && (*v - 0.4).abs() < 1e-9));
        assert!(c.iter().any(|(l, v)| l.ends_with("micro_over_batch1") && (*v - 0.5).abs() < 1e-9));
    }

    #[test]
    fn serve_regression_trips() {
        // micro-batching throughput advantage halved: inverse speedup
        // cell doubles
        let base = serve_cells(&serve_doc(100.0, 250.0, 20.0, 10.0)).unwrap();
        let fresh = serve_cells(&serve_doc(100.0, 125.0, 20.0, 10.0)).unwrap();
        let regs = compare(&base, &fresh, 0.2);
        assert!(regs.iter().any(|r| r.contains("throughput_batch1_over_micro")));
        // machine speed cancels: both configs 2x slower is clean
        let slow = serve_cells(&serve_doc(50.0, 125.0, 40.0, 20.0)).unwrap();
        assert!(compare(&base, &slow, 0.2).is_empty());
    }
}
