//! PJRT runtime: loads the AOT'd HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched.  Pattern (from
//! /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! Graphs are compiled **once** per process and cached; every training
//! step is then a single `execute` call with the step's literals.  All
//! graphs were lowered with `return_tuple=True`, so results come back as
//! one tuple literal that we decompose here.
//!
//! Python is never involved: the artifacts are plain files produced by
//! `make artifacts` at build time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::tensor::{Tensor, TensorI32};

/// A compiled, executable graph.
pub struct CompiledGraph {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// Input literal for [`CompiledGraph::run`].
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
}

impl CompiledGraph {
    /// Execute with the given inputs; returns the decomposed output tuple
    /// as host tensors (all graphs return flat tuples of f32 arrays).
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Tensor>> {
        let mut lits = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::F32(t) => lits.push(t.to_literal()?),
                Arg::I32(t) => lits.push(t.to_literal()?),
            }
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }
}

/// Compiles and caches graphs from an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: PathBuf,
    cache: Mutex<HashMap<String, Arc<CompiledGraph>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime rooted at `artifacts/`.
    pub fn cpu(artifacts: &Path) -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            artifacts: artifacts.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    /// Load + compile `artifacts/<bench>/<graph>.hlo.txt` (cached).
    pub fn graph(&self, bench: &str, graph: &str) -> Result<Arc<CompiledGraph>> {
        let key = format!("{bench}/{graph}");
        if let Some(g) = self.cache.lock().unwrap().get(&key) {
            return Ok(g.clone());
        }
        let path = self.artifacts.join(bench).join(format!("{graph}.hlo.txt"));
        let compiled = self.compile_file(&path, &key)?;
        let arc = Arc::new(compiled);
        self.cache.lock().unwrap().insert(key, arc.clone());
        Ok(arc)
    }

    /// Compile an HLO-text file outside the bench/graph naming scheme.
    pub fn compile_file(&self, path: &Path, name: &str) -> Result<CompiledGraph> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        Ok(CompiledGraph { name: name.to_string(), exe })
    }

    /// Number of graphs compiled so far (diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
