//! Terminal ASCII scatter plots for the Fig. 3 Pareto fronts.
//!
//! The bench harnesses print the same series the paper plots (score vs
//! energy / score vs size, one marker per searched model) so the Pareto
//! shape is inspectable straight from `cargo bench` output; the exact
//! numbers also go to CSV via [`crate::report`].

/// One plotted series: a name, a marker character and (x, y) points.
pub struct Series {
    pub name: String,
    pub marker: char,
    pub points: Vec<(f32, f32)>,
}

impl Series {
    pub fn new(name: &str, marker: char, points: Vec<(f32, f32)>) -> Self {
        Series { name: name.to_string(), marker, points }
    }
}

/// Render series into a `width` x `height` character grid with axes.
pub fn scatter(
    title: &str,
    xlabel: &str,
    ylabel: &str,
    series: &[Series],
    width: usize,
    height: usize,
) -> String {
    let pts: Vec<(f32, f32)> = series.iter().flat_map(|s| s.points.iter().cloned()).collect();
    if pts.is_empty() {
        return format!("{title}: (no points)\n");
    }
    let (mut xmin, mut xmax) = (f32::INFINITY, f32::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f32::INFINITY, f32::NEG_INFINITY);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for &(x, y) in &s.points {
            let cx = (((x - xmin) / (xmax - xmin)) * (width as f32 - 1.0))
                .round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height as f32 - 1.0))
                .round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = s.marker;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("  {title}\n"));
    out.push_str(&format!("  {ylabel}\n"));
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * i as f32 / (height as f32 - 1.0);
        out.push_str(&format!("  {yv:8.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("  {:>8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "  {:>10}{:<w$.3}{:>.3}\n",
        "",
        xmin,
        xmax,
        w = width - 5
    ));
    out.push_str(&format!("  x: {xlabel}   "));
    for s in series {
        out.push_str(&format!("[{}] {}  ", s.marker, s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_legend() {
        let s = vec![
            Series::new("ours", 'o', vec![(0.0, 0.0), (1.0, 1.0)]),
            Series::new("edmips", 'x', vec![(0.5, 0.2)]),
        ];
        let out = scatter("t", "energy", "acc", &s, 40, 10);
        assert!(out.contains('o'));
        assert!(out.contains('x'));
        assert!(out.contains("ours"));
        assert!(out.contains("edmips"));
    }

    #[test]
    fn empty_series_ok() {
        let out = scatter("t", "x", "y", &[], 10, 5);
        assert!(out.contains("no points"));
    }

    #[test]
    fn degenerate_range_ok() {
        let s = vec![Series::new("a", '*', vec![(1.0, 2.0), (1.0, 2.0)])];
        let out = scatter("t", "x", "y", &s, 20, 5);
        assert!(out.contains('*'));
    }
}
