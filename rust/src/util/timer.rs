//! Wall-clock stopwatch used by the bench harnesses and EXPERIMENTS.md
//! §Perf measurements (no criterion in the offline crate set — the bench
//! binaries implement warmup + repeated timing themselves on top of this).

use std::time::Instant;

/// Simple stopwatch with lap support.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Measure `f` with `warmup` unrecorded runs then `iters` timed runs.
/// Returns (mean_ms, min_ms, max_ms) — the shape criterion would report.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len().max(1) as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    (mean, min, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
    }

    #[test]
    fn measure_counts_iters() {
        let mut n = 0usize;
        let (mean, min, max) = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert!(min <= mean && mean <= max);
    }
}
