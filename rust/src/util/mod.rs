//! Shared utilities: deterministic RNG, statistics, timing, ASCII plots.

pub mod plot;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg32;
pub use stats::{auc_from_scores, mean, std_dev};
pub use timer::Stopwatch;
