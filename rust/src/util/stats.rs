//! Small statistics helpers: mean/std, argmax, and the ROC-AUC used to
//! score the Anomaly Detection benchmark (the paper reports AUC for AD).

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
        / (xs.len() - 1) as f32;
    var.sqrt()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Average ranks (1-based, ties share the mean of their positions).
fn average_ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            rank[idx[k]] = avg;
        }
        i = j + 1;
    }
    rank
}

/// Spearman rank correlation with average-rank tie handling.
///
/// Computed as the Pearson correlation of the two rank vectors (the
/// tie-correct definition, not the `1 - 6Σd²/...` shortcut which is
/// only valid without ties).  Returns 0 for n < 2 or when either input
/// is constant (no rank variance).  Used by `cwmix profile` to score
/// how well the analytical [`InferenceCost`](crate::cost::InferenceCost)
/// model ranks layers against measured per-node wall time.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let rx = average_ranks(xs);
    let ry = average_ranks(ys);
    let mx = rx.iter().sum::<f64>() / n as f64;
    let my = ry.iter().sum::<f64>() / n as f64;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = rx[i] - mx;
        let dy = ry[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx * vy).sqrt()
}

/// Area under the ROC curve via the Mann–Whitney U statistic.
///
/// `scores` are anomaly scores (higher = more anomalous), `labels` are
/// 1 = anomaly, 0 = normal.  Ties contribute 1/2, matching scikit-learn's
/// `roc_auc_score`.
pub fn auc_from_scores(scores: &[f32], labels: &[u8]) -> f32 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // rank positives (average ranks over ties)
    let n = scores.len();
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            rank[idx[k]] = avg;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l == 1).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&i| labels[i] == 1).map(|i| rank[i]).sum();
    let u = rank_sum - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    (u / (n_pos as f64 * n_neg as f64)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn spearman_monotone_is_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [10.0, 100.0, 1000.0, 10000.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_reversed_is_minus_one() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&xs, &ys) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_ties_and_degenerate() {
        // constant input has no rank variance -> defined as 0
        assert_eq!(spearman(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(spearman(&[5.0], &[7.0]), 0.0);
        // ties share average ranks; correlation stays in [-1, 1]
        let s = spearman(&[1.0, 2.0, 2.0, 3.0], &[1.0, 2.0, 3.0, 4.0]);
        assert!(s > 0.8 && s <= 1.0);
    }

    #[test]
    fn auc_perfect_separation() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        assert!((auc_from_scores(&scores, &labels) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn auc_random_is_half() {
        let scores = [0.1, 0.2, 0.3, 0.4];
        let labels = [0, 1, 1, 0];
        assert!((auc_from_scores(&scores, &labels) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn auc_with_ties() {
        // one tie pair across classes -> contributes 1/2
        let scores = [0.5, 0.5];
        let labels = [0, 1];
        assert!((auc_from_scores(&scores, &labels) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn auc_inverted_is_zero() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [0, 0, 1, 1];
        assert!(auc_from_scores(&scores, &labels) < 1e-6);
    }
}
