//! Small statistics helpers: mean/std, argmax, and the ROC-AUC used to
//! score the Anomaly Detection benchmark (the paper reports AUC for AD).

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>()
        / (xs.len() - 1) as f32;
    var.sqrt()
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Area under the ROC curve via the Mann–Whitney U statistic.
///
/// `scores` are anomaly scores (higher = more anomalous), `labels` are
/// 1 = anomaly, 0 = normal.  Ties contribute 1/2, matching scikit-learn's
/// `roc_auc_score`.
pub fn auc_from_scores(scores: &[f32], labels: &[u8]) -> f32 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    // rank positives (average ranks over ties)
    let n = scores.len();
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            rank[idx[k]] = avg;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l == 1).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    let rank_sum: f64 = (0..n).filter(|&i| labels[i] == 1).map(|i| rank[i]).sum();
    let u = rank_sum - (n_pos as f64 * (n_pos as f64 + 1.0)) / 2.0;
    (u / (n_pos as f64 * n_neg as f64)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn auc_perfect_separation() {
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        assert!((auc_from_scores(&scores, &labels) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn auc_random_is_half() {
        let scores = [0.1, 0.2, 0.3, 0.4];
        let labels = [0, 1, 1, 0];
        assert!((auc_from_scores(&scores, &labels) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn auc_with_ties() {
        // one tie pair across classes -> contributes 1/2
        let scores = [0.5, 0.5];
        let labels = [0, 1];
        assert!((auc_from_scores(&scores, &labels) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn auc_inverted_is_zero() {
        let scores = [0.9, 0.8, 0.2, 0.1];
        let labels = [0, 0, 1, 1];
        assert!(auc_from_scores(&scores, &labels) < 1e-6);
    }
}
