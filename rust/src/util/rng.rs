//! Deterministic PCG32 random number generator.
//!
//! The offline crate set has no `rand`, so the coordinator carries its own
//! generator.  PCG-XSH-RR 64/32 (O'Neill 2014): tiny state, good spectral
//! properties, and — critically for reproducibility of every experiment in
//! EXPERIMENTS.md — fully deterministic across platforms for a given seed.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64 (two draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire rejection).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here, data generation is off the hot path).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
