//! Fig. 3 / Fig. 4 style reporting: ASCII Pareto plots, CSV series, and
//! the per-layer assignment dump with precision fractions.

use std::fmt::Write as _;

use crate::coordinator::pareto::{iso_score_saving, pareto_front};
use crate::coordinator::results::StoredResult;
use crate::nas::Target;
use crate::quant::Assignment;
use crate::util::plot::{scatter, Series};

/// (cost, score) extraction for stored results.
pub fn points_of(rs: &[StoredResult], target: Target) -> Vec<(f64, f32)> {
    rs.iter()
        .map(|r| {
            let cost = match target {
                Target::Size => r.size_bits / 1e6,
                Target::Energy => r.energy_pj * 1e-6,
            };
            (cost, r.test_score)
        })
        .collect()
}

/// Render one Fig. 3 panel: scatter + per-series table + headline
/// iso-accuracy savings (ours vs EdMIPS), exactly the quantities §IV-B
/// quotes.
pub fn fig3_panel(
    bench: &str,
    target: Target,
    ours: &[StoredResult],
    edmips: &[StoredResult],
    fixed: &[StoredResult],
) -> String {
    let xlabel = match target {
        Target::Size => "model size [Mbit]",
        Target::Energy => "energy [uJ]",
    };
    let po = points_of(ours, target);
    let pe = points_of(edmips, target);
    let pf = points_of(fixed, target);
    let mut out = String::new();
    let title = format!("Fig.3 {bench} / {}", target.name());
    out.push_str(&scatter(
        &title,
        xlabel,
        "score",
        &[
            Series::new("ours (channel-wise)", 'o', f32pts(&po)),
            Series::new("EdMIPS (layer-wise)", 'x', f32pts(&pe)),
            Series::new("fixed wNxM", '+', f32pts(&pf)),
        ],
        64,
        16,
    ));
    out.push('\n');
    let table = |name: &str, rs: &[StoredResult], pts: &[(f64, f32)]| {
        let mut s = format!("  {name}:\n");
        let front = pareto_front(pts);
        for (i, r) in rs.iter().enumerate() {
            let mark = if front.contains(&i) { "*" } else { " " };
            let _ = writeln!(
                s,
                "   {mark} {:<28} cost={:>10.4} score={:.4}",
                r.label, pts[i].0, pts[i].1
            );
        }
        s
    };
    out.push_str(&table("ours", ours, &po));
    out.push_str(&table("edmips", edmips, &pe));
    out.push_str(&table("fixed", fixed, &pf));

    let front_of = |pts: &[(f64, f32)]| -> Vec<(f64, f32)> {
        pareto_front(pts).into_iter().map(|i| pts[i]).collect()
    };
    if let Some(s) = iso_score_saving(&front_of(&po), &front_of(&pe), 0.002) {
        let _ = writeln!(
            out,
            "  iso-accuracy {} saving vs EdMIPS: {:.1}%  (paper: up to {}%)",
            target.name(),
            s * 100.0,
            paper_headline(bench, target)
        );
    } else {
        let _ = writeln!(out, "  no iso-accuracy saving vs EdMIPS on this run");
    }
    out
}

fn f32pts(p: &[(f64, f32)]) -> Vec<(f32, f32)> {
    p.iter().map(|&(c, s)| (c as f32, s)).collect()
}

/// The paper's §IV-B headline number for a panel (for side-by-side).
pub fn paper_headline(bench: &str, target: Target) -> &'static str {
    match (bench, target) {
        ("ic", Target::Energy) => "26.4",
        ("ic", Target::Size) => "35",
        ("kws", Target::Energy) => "27.2",
        ("kws", Target::Size) => "15.6",
        ("vww", Target::Energy) => "~0 (limited)",
        ("vww", Target::Size) => "63.4",
        ("ad", Target::Energy) => "11.6 (low-AUC regime)",
        ("ad", Target::Size) => "46.1",
        _ => "?",
    }
}

/// Fig. 4 style dump: per-layer activation bits + weight-precision
/// fractions (percent of channels at 2/4/8 bit).
pub fn fig4_dump(label: &str, a: &Assignment) -> String {
    let mut out = format!("Fig.4-style assignment dump: {label}\n");
    out.push_str("  layer        act  | %w2   %w4   %w8\n");
    for l in &a.layers {
        let f = l.fractions();
        let _ = writeln!(
            out,
            "  {:<12} x{}  | {:>4.0}% {:>4.0}% {:>4.0}%",
            l.name,
            l.act_bits,
            f[0] * 100.0,
            f[1] * 100.0,
            f[2] * 100.0
        );
    }
    out
}

/// CSV export of a series (one row per model) for external plotting.
pub fn csv_series(name: &str, rs: &[StoredResult], target: Target) -> String {
    let mut out = String::from("series,label,cost,score,size_bits,energy_pj\n");
    for (r, (c, s)) in rs.iter().zip(points_of(rs, target)) {
        let _ = writeln!(
            out,
            "{name},{},{c},{s},{},{}",
            r.label, r.size_bits, r.energy_pj
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::LayerAssignment;

    fn sr(label: &str, score: f32, size: f64, energy: f64) -> StoredResult {
        StoredResult {
            label: label.into(),
            test_score: score,
            size_bits: size,
            energy_pj: energy,
            assignment: Assignment {
                layers: vec![LayerAssignment {
                    name: "c1".into(),
                    act_bits: 8,
                    weight_bits: vec![2, 4, 8, 8],
                }],
            },
        }
    }

    #[test]
    fn fig3_panel_renders() {
        let ours = vec![sr("o-lo", 0.8, 1e6, 2e6), sr("o-hi", 0.9, 3e6, 6e6)];
        let ed = vec![sr("e", 0.8, 2e6, 4e6)];
        let fx = vec![sr("w8x8", 0.88, 4e6, 8e6)];
        let s = fig3_panel("ic", Target::Size, &ours, &ed, &fx);
        assert!(s.contains("ours"));
        assert!(s.contains("iso-accuracy"));
    }

    #[test]
    fn fig4_fractions() {
        let a = Assignment {
            layers: vec![LayerAssignment {
                name: "c1".into(),
                act_bits: 4,
                weight_bits: vec![2, 2, 4, 8],
            }],
        };
        let s = fig4_dump("test", &a);
        assert!(s.contains("x4"));
        assert!(s.contains("50%"));
    }

    #[test]
    fn csv_has_rows() {
        let rs = vec![sr("a", 0.5, 1.0, 2.0)];
        let csv = csv_series("ours", &rs, Target::Energy);
        assert_eq!(csv.lines().count(), 2);
    }
}
