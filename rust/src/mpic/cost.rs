//! Cycle and energy accounting for a simulated inference.
//!
//! This refines the NAS regularizer (Eq. 8, MAC energy only) with the
//! terms the paper's hardware measurement implicitly contains:
//!
//! * MAC cycles/energy from the [`crate::energy::CostLut`] (identical to
//!   the table baked into the search graphs — asserted by tests);
//! * L2→L1 load/store traffic ([`super::memory`]);
//! * per-sub-convolution scheduling overhead (§III-C: "the only overhead
//!   of our method ... is the control flow to schedule the three
//!   sub-layers", measured here as a fixed per-group cycle cost).

use crate::energy::lut::F_CLK_HZ;
use crate::energy::CostLut;

/// Scheduling overhead per sub-convolution launch (loop setup, pointer
/// arithmetic, precision-mode CSR write on MPIC) — cycles.
pub const SUBCONV_OVERHEAD_CYCLES: f64 = 60.0;

/// Cycles per element for structural elementwise work (residual adds,
/// pooling accumulation): 4-lane SIMD ALU ops on MPIC.
pub const ELEMWISE_CYCLES_PER_ELEM: f64 = 0.25;

/// Energy per byte moved L2→L1 (pJ) — MPIC-class single-cluster SRAM.
pub const PJ_PER_L2_BYTE: f64 = 3.5;

/// Idle/control energy per cycle outside the MAC datapath (pJ).
pub const PJ_CTRL_PER_CYCLE: f64 = 0.8;

/// Per-layer accounting.
#[derive(Clone, Debug, Default)]
pub struct LayerCost {
    pub name: String,
    /// (weight-bits, MACs) per sub-convolution group
    pub macs_by_group: Vec<(u32, u64)>,
    pub mac_cycles: f64,
    pub overhead_cycles: f64,
    pub mem_bytes: u64,
    pub mac_energy_pj: f64,
    pub mem_energy_pj: f64,
    pub ctrl_energy_pj: f64,
}

impl LayerCost {
    pub fn total_cycles(&self) -> f64 {
        self.mac_cycles + self.overhead_cycles
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.mem_energy_pj + self.ctrl_energy_pj
    }
}

/// Whole-network accounting for one inference.
#[derive(Clone, Debug, Default)]
pub struct InferenceCost {
    pub layers: Vec<LayerCost>,
}

/// Amortized cost report for a `batch`-sample **batch-plane,
/// weight-stationary** pass (`engine::ExecPlan::run_batch_planes`).
///
/// MAC work, activation traffic and structural elementwise work scale
/// with the batch size `B`; two terms are paid **once per batch**
/// instead of once per sample:
///
/// * per sub-convolution scheduling overhead (loop setup, pointer
///   arithmetic, the precision-mode CSR write on MPIC) — the batched
///   kernels enter each `(layer, group)` once and ride every sample's
///   column inside it;
/// * packed weight traffic — each Eq. (7) flash word is fetched and
///   decoded once per batch and ridden across all `B` activation
///   columns.
#[derive(Clone, Debug)]
pub struct BatchCost {
    pub batch: usize,
    /// cycles for the whole batch
    pub cycles: f64,
    pub cycles_per_sample: f64,
    /// energy for the whole batch (pJ)
    pub energy_pj: f64,
    pub energy_pj_per_sample: f64,
    /// L2 traffic for the whole batch
    pub mem_bytes: u64,
    /// scheduling cycles amortized away vs `B` independent samples
    pub saved_sched_cycles: f64,
    /// weight bytes amortized away vs `B` independent samples
    pub saved_weight_bytes: u64,
}

impl InferenceCost {
    pub fn total_cycles(&self) -> f64 {
        self.layers.iter().map(|l| l.total_cycles()).sum()
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.total_energy_pj()).sum()
    }

    /// MAC-only energy — directly comparable to Eq. (8) reporting.
    pub fn mac_energy_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.mac_energy_pj).sum()
    }

    pub fn total_energy_uj(&self) -> f64 {
        self.total_energy_pj() * 1e-6
    }

    /// Latency at the MPIC clock.
    pub fn latency_us(&self) -> f64 {
        self.total_cycles() / F_CLK_HZ * 1e6
    }

    pub fn total_macs(&self) -> u64 {
        self.layers
            .iter()
            .flat_map(|l| l.macs_by_group.iter().map(|&(_, m)| m))
            .sum()
    }

    pub fn total_mem_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.mem_bytes).sum()
    }

    /// Sub-convolution scheduling cycles of one inference — the share
    /// of `total_cycles` paid **once per batch** under weight-stationary
    /// batch-plane execution.
    pub fn sched_cycles(&self) -> f64 {
        let groups: usize = self.layers.iter().map(|l| l.macs_by_group.len()).sum();
        groups as f64 * SUBCONV_OVERHEAD_CYCLES
    }

    /// Amortized cost of a `batch`-sample batch-plane pass.
    /// `weight_traffic_bytes` is the per-inference packed weight traffic
    /// (the Eq. (7) flash bytes inside [`Self::total_mem_bytes`]),
    /// fetched once per batch instead of once per sample.
    pub fn batch_cost(&self, batch: usize, weight_traffic_bytes: u64) -> BatchCost {
        let batch = batch.max(1);
        let bf = batch as f64;
        let saved_sched_cycles = (bf - 1.0) * self.sched_cycles();
        let saved_weight_bytes = (batch as u64 - 1) * weight_traffic_bytes;
        let cycles = bf * self.total_cycles() - saved_sched_cycles;
        let mem_bytes = batch as u64 * self.total_mem_bytes() - saved_weight_bytes;
        // saved scheduling cycles take their control energy with them;
        // saved weight traffic takes its L2 energy
        let energy_pj = bf * self.total_energy_pj()
            - saved_sched_cycles * PJ_CTRL_PER_CYCLE
            - saved_weight_bytes as f64 * PJ_PER_L2_BYTE;
        BatchCost {
            batch,
            cycles,
            cycles_per_sample: cycles / bf,
            energy_pj,
            energy_pj_per_sample: energy_pj / bf,
            mem_bytes,
            saved_sched_cycles,
            saved_weight_bytes,
        }
    }
}

/// Account one sub-convolution group.
pub fn account_group(
    cost: &mut LayerCost,
    lut: &CostLut,
    act_bits: u32,
    w_bits: u32,
    macs: u64,
) {
    cost.macs_by_group.push((w_bits, macs));
    let cyc = macs as f64 * lut.cycles(act_bits, w_bits) as f64;
    cost.mac_cycles += cyc;
    cost.overhead_cycles += SUBCONV_OVERHEAD_CYCLES;
    cost.mac_energy_pj += macs as f64 * lut.energy_pj(act_bits, w_bits) as f64;
    cost.ctrl_energy_pj += (cyc + SUBCONV_OVERHEAD_CYCLES) * PJ_CTRL_PER_CYCLE;
}

/// Account memory traffic for a layer.
pub fn account_memory(cost: &mut LayerCost, bytes: u64) {
    cost.mem_bytes += bytes;
    cost.mem_energy_pj += bytes as f64 * PJ_PER_L2_BYTE;
}

/// Account structural elementwise work (residual add, pooling) over
/// `elems` tensor elements.
pub fn account_structural(cost: &mut LayerCost, elems: usize) {
    cost.overhead_cycles += elems as f64 * ELEMWISE_CYCLES_PER_ELEM;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_accounting_adds_up() {
        let lut = CostLut::default();
        let mut lc = LayerCost { name: "l".into(), ..Default::default() };
        account_group(&mut lc, &lut, 8, 8, 1000);
        account_group(&mut lc, &lut, 8, 2, 1000);
        assert_eq!(lc.macs_by_group.len(), 2);
        // 8x8: 0.25 cyc/MAC; 8x2 same throughput on MPIC
        assert!((lc.mac_cycles - 500.0).abs() < 1e-9);
        assert!((lc.overhead_cycles - 2.0 * SUBCONV_OVERHEAD_CYCLES).abs() < 1e-9);
        assert!(lc.mac_energy_pj > 0.0);
    }

    #[test]
    fn inference_totals() {
        let lut = CostLut::default();
        let mut a = LayerCost { name: "a".into(), ..Default::default() };
        account_group(&mut a, &lut, 8, 4, 500);
        account_memory(&mut a, 100);
        let ic = InferenceCost { layers: vec![a] };
        assert!(ic.total_energy_pj() > ic.mac_energy_pj());
        assert!(ic.latency_us() > 0.0);
        assert_eq!(ic.total_macs(), 500);
    }

    fn two_group_cost() -> InferenceCost {
        let lut = CostLut::default();
        let mut a = LayerCost { name: "a".into(), ..Default::default() };
        account_group(&mut a, &lut, 8, 8, 1000);
        account_group(&mut a, &lut, 8, 2, 1000);
        account_memory(&mut a, 400); // 150 of which are packed weights
        account_structural(&mut a, 64);
        InferenceCost { layers: vec![a] }
    }

    #[test]
    fn batch_cost_of_one_equals_per_sample() {
        let ic = two_group_cost();
        let bc = ic.batch_cost(1, 150);
        assert_eq!(bc.batch, 1);
        assert!((bc.cycles - ic.total_cycles()).abs() < 1e-9);
        assert!((bc.energy_pj - ic.total_energy_pj()).abs() < 1e-6);
        assert_eq!(bc.mem_bytes, ic.total_mem_bytes());
        assert_eq!(bc.saved_sched_cycles, 0.0);
        assert_eq!(bc.saved_weight_bytes, 0);
    }

    #[test]
    fn batch_cost_amortizes_sched_and_weight_traffic() {
        let ic = two_group_cost();
        assert_eq!(ic.sched_cycles(), 2.0 * SUBCONV_OVERHEAD_CYCLES);
        let b4 = ic.batch_cost(4, 150);
        // scheduling paid once: 3 of 4 samples' group overhead saved
        assert!((b4.saved_sched_cycles - 3.0 * 2.0 * SUBCONV_OVERHEAD_CYCLES).abs() < 1e-9);
        assert_eq!(b4.saved_weight_bytes, 3 * 150);
        assert_eq!(b4.mem_bytes, 4 * 400 - 3 * 150);
        // per-sample cost is monotonically non-increasing in B
        let mut prev = ic.batch_cost(1, 150).cycles_per_sample;
        for b in [2usize, 4, 8, 32] {
            let bc = ic.batch_cost(b, 150);
            assert!(bc.cycles_per_sample <= prev + 1e-9, "B={b}");
            assert!(bc.energy_pj_per_sample < ic.total_energy_pj(), "B={b}");
            prev = bc.cycles_per_sample;
        }
    }
}
