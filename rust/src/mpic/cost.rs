//! Cycle and energy accounting for a simulated inference.
//!
//! This refines the NAS regularizer (Eq. 8, MAC energy only) with the
//! terms the paper's hardware measurement implicitly contains:
//!
//! * MAC cycles/energy from the [`crate::energy::CostLut`] (identical to
//!   the table baked into the search graphs — asserted by tests);
//! * L2→L1 load/store traffic ([`super::memory`]);
//! * per-sub-convolution scheduling overhead (§III-C: "the only overhead
//!   of our method ... is the control flow to schedule the three
//!   sub-layers", measured here as a fixed per-group cycle cost).

use crate::energy::lut::F_CLK_HZ;
use crate::energy::CostLut;

/// Scheduling overhead per sub-convolution launch (loop setup, pointer
/// arithmetic, precision-mode CSR write on MPIC) — cycles.
pub const SUBCONV_OVERHEAD_CYCLES: f64 = 60.0;

/// Cycles per element for structural elementwise work (residual adds,
/// pooling accumulation): 4-lane SIMD ALU ops on MPIC.
pub const ELEMWISE_CYCLES_PER_ELEM: f64 = 0.25;

/// Energy per byte moved L2→L1 (pJ) — MPIC-class single-cluster SRAM.
pub const PJ_PER_L2_BYTE: f64 = 3.5;

/// Idle/control energy per cycle outside the MAC datapath (pJ).
pub const PJ_CTRL_PER_CYCLE: f64 = 0.8;

/// Per-layer accounting.
#[derive(Clone, Debug, Default)]
pub struct LayerCost {
    pub name: String,
    /// (weight-bits, MACs) per sub-convolution group
    pub macs_by_group: Vec<(u32, u64)>,
    pub mac_cycles: f64,
    pub overhead_cycles: f64,
    pub mem_bytes: u64,
    pub mac_energy_pj: f64,
    pub mem_energy_pj: f64,
    pub ctrl_energy_pj: f64,
}

impl LayerCost {
    pub fn total_cycles(&self) -> f64 {
        self.mac_cycles + self.overhead_cycles
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.mem_energy_pj + self.ctrl_energy_pj
    }
}

/// Whole-network accounting for one inference.
#[derive(Clone, Debug, Default)]
pub struct InferenceCost {
    pub layers: Vec<LayerCost>,
}

impl InferenceCost {
    pub fn total_cycles(&self) -> f64 {
        self.layers.iter().map(|l| l.total_cycles()).sum()
    }

    pub fn total_energy_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.total_energy_pj()).sum()
    }

    /// MAC-only energy — directly comparable to Eq. (8) reporting.
    pub fn mac_energy_pj(&self) -> f64 {
        self.layers.iter().map(|l| l.mac_energy_pj).sum()
    }

    pub fn total_energy_uj(&self) -> f64 {
        self.total_energy_pj() * 1e-6
    }

    /// Latency at the MPIC clock.
    pub fn latency_us(&self) -> f64 {
        self.total_cycles() / F_CLK_HZ * 1e6
    }

    pub fn total_macs(&self) -> u64 {
        self.layers
            .iter()
            .flat_map(|l| l.macs_by_group.iter().map(|&(_, m)| m))
            .sum()
    }
}

/// Account one sub-convolution group.
pub fn account_group(
    cost: &mut LayerCost,
    lut: &CostLut,
    act_bits: u32,
    w_bits: u32,
    macs: u64,
) {
    cost.macs_by_group.push((w_bits, macs));
    let cyc = macs as f64 * lut.cycles(act_bits, w_bits) as f64;
    cost.mac_cycles += cyc;
    cost.overhead_cycles += SUBCONV_OVERHEAD_CYCLES;
    cost.mac_energy_pj += macs as f64 * lut.energy_pj(act_bits, w_bits) as f64;
    cost.ctrl_energy_pj += (cyc + SUBCONV_OVERHEAD_CYCLES) * PJ_CTRL_PER_CYCLE;
}

/// Account memory traffic for a layer.
pub fn account_memory(cost: &mut LayerCost, bytes: u64) {
    cost.mem_bytes += bytes;
    cost.mem_energy_pj += bytes as f64 * PJ_PER_L2_BYTE;
}

/// Account structural elementwise work (residual add, pooling) over
/// `elems` tensor elements.
pub fn account_structural(cost: &mut LayerCost, elems: usize) {
    cost.overhead_cycles += elems as f64 * ELEMWISE_CYCLES_PER_ELEM;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_accounting_adds_up() {
        let lut = CostLut::default();
        let mut lc = LayerCost { name: "l".into(), ..Default::default() };
        account_group(&mut lc, &lut, 8, 8, 1000);
        account_group(&mut lc, &lut, 8, 2, 1000);
        assert_eq!(lc.macs_by_group.len(), 2);
        // 8x8: 0.25 cyc/MAC; 8x2 same throughput on MPIC
        assert!((lc.mac_cycles - 500.0).abs() < 1e-9);
        assert!((lc.overhead_cycles - 2.0 * SUBCONV_OVERHEAD_CYCLES).abs() < 1e-9);
        assert!(lc.mac_energy_pj > 0.0);
    }

    #[test]
    fn inference_totals() {
        let lut = CostLut::default();
        let mut a = LayerCost { name: "a".into(), ..Default::default() };
        account_group(&mut a, &lut, 8, 4, 500);
        account_memory(&mut a, 100);
        let ic = InferenceCost { layers: vec![a] };
        assert!(ic.total_energy_pj() > ic.mac_energy_pj());
        assert!(ic.latency_us() > 0.0);
        assert_eq!(ic.total_macs(), 500);
    }
}
