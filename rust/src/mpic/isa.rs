//! Mixed-precision SIMD MAC semantics of the MPIC dot-product unit.
//!
//! MPIC extends RI5CY's `pv.sdotsp` family: one instruction multiplies a
//! register of packed unsigned activations (2/4/8 bit) with a register of
//! packed signed weights (2/4/8 bit) and accumulates into a 32-bit
//! accumulator.  The number of lanes is fixed by the *wider* operand
//! (both operands occupy the same lane grid after the precision decoder):
//! 8-bit → 4 lanes, 4-bit → 8 lanes, 2-bit → 16 lanes per 32-bit word.
//!
//! [`simd_dotp`] models one such instruction; [`dotp_oracle`] is the
//! plain scalar reference the property tests compare against.

/// Lanes per instruction, MPIC-style: 32-bit registers, lane width set by
/// the wider operand: max(p) bits per lane element.
pub fn lanes_mpic(px: u32, pw: u32) -> usize {
    (32 / px.max(pw)) as usize
}

/// One SIMD dot-product step over `lanes_mpic` elements.
///
/// `xs` are unsigned activation codes in `[0, 2^px)`, `ws` are signed
/// weight codes in `[-(2^(pw-1)), 2^(pw-1))`; shorter slices emulate the
/// tail of a channel.  Returns the updated 32-bit accumulator (wrapping,
/// like the hardware).
pub fn simd_dotp(acc: i32, xs: &[u32], ws: &[i32], px: u32, pw: u32) -> i32 {
    debug_assert!(xs.len() == ws.len());
    debug_assert!(xs.len() <= lanes_mpic(px, pw));
    let mut a = acc;
    for (&x, &w) in xs.iter().zip(ws) {
        debug_assert!(x < (1 << px), "activation code {x} out of {px}-bit range");
        debug_assert!(
            (-(1 << (pw - 1))..(1 << (pw - 1))).contains(&w),
            "weight code {w} out of {pw}-bit range"
        );
        a = a.wrapping_add((x as i32).wrapping_mul(w));
    }
    a
}

/// Scalar oracle: plain i64 dot product (no packing, no wrapping).
pub fn dotp_oracle(xs: &[u32], ws: &[i32]) -> i64 {
    xs.iter().zip(ws).map(|(&x, &w)| x as i64 * w as i64).sum()
}

/// Number of SIMD MAC instructions to reduce a `k`-element channel.
pub fn instructions_for(k: usize, px: u32, pw: u32) -> usize {
    k.div_ceil(lanes_mpic(px, pw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn lane_counts_match_mpic() {
        assert_eq!(lanes_mpic(8, 8), 4);
        assert_eq!(lanes_mpic(4, 4), 8);
        assert_eq!(lanes_mpic(2, 2), 16);
        assert_eq!(lanes_mpic(2, 8), 4);
        assert_eq!(lanes_mpic(4, 2), 8);
    }

    #[test]
    fn simd_matches_oracle_randomized() {
        // property test: accumulating a long vector through SIMD chunks
        // equals the scalar oracle, for every precision combo.
        let mut rng = Pcg32::seeded(99);
        for &px in &[2u32, 4, 8] {
            for &pw in &[2u32, 4, 8] {
                for _trial in 0..20 {
                    let k = 1 + rng.below(200) as usize;
                    let xs: Vec<u32> = (0..k).map(|_| rng.below(1 << px)).collect();
                    let ws: Vec<i32> = (0..k)
                        .map(|_| {
                            rng.below(1 << pw) as i32 - (1 << (pw - 1))
                        })
                        .collect();
                    let l = lanes_mpic(px, pw);
                    let mut acc = 0i32;
                    for c in 0..k.div_ceil(l) {
                        let lo = c * l;
                        let hi = (lo + l).min(k);
                        acc = simd_dotp(acc, &xs[lo..hi], &ws[lo..hi], px, pw);
                    }
                    assert_eq!(
                        acc as i64,
                        dotp_oracle(&xs, &ws),
                        "px={px} pw={pw} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn instruction_count() {
        assert_eq!(instructions_for(27, 8, 8), 7); // 27 / 4 lanes
        assert_eq!(instructions_for(27, 2, 2), 2); // 27 / 16 lanes
        assert_eq!(instructions_for(16, 2, 2), 1);
    }
}
