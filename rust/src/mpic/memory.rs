//! L2→L1 traffic model for the MPIC memory hierarchy.
//!
//! MPIC is a single-cluster MCU: weights live in non-volatile memory /
//! L2 SRAM and stream through the core once per layer; activations
//! round-trip L2 between layers (no multi-level cache).  The model:
//!
//! * weights: each layer's *packed* bytes loaded exactly once per
//!   inference (sub-byte packing — this is where the Fig. 3 memory wins
//!   turn into energy wins on bandwidth-bound layers);
//! * input activations: `in_h * in_w * cin` codes at `p_x` bits, loaded
//!   once per layer (ideal line-buffer reuse across the kernel window —
//!   the CMix-NN im2col buffers achieve ~1x reuse for 3x3 kernels);
//! * output activations: stored once at the *consumer's* precision; we
//!   charge 8 bits (the layer-wise activation format concatenated in
//!   adjacent memory, §III-C).

use crate::models::LayerSpec;

/// Bytes of activation traffic into a layer at `p_x` bits.
pub fn act_in_bytes(spec: &LayerSpec, px: u32) -> u64 {
    let codes = (spec.in_h * spec.in_w * spec.cin) as u64;
    (codes * px as u64).div_ceil(8)
}

/// Bytes of activation traffic out of a layer (stored byte-aligned at the
/// layer-wise 8-bit concatenation format of §III-C).
pub fn act_out_bytes(spec: &LayerSpec) -> u64 {
    (spec.out_h * spec.out_w * spec.cout) as u64
}

/// Total traffic for one quantized layer.
pub fn layer_traffic_bytes(spec: &LayerSpec, px: u32, packed_weight_bytes: usize) -> u64 {
    packed_weight_bytes as u64 + act_in_bytes(spec, px) + act_out_bytes(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> LayerSpec {
        LayerSpec {
            name: "c".into(),
            kind: "conv".into(),
            cin: 16,
            cout: 32,
            kx: 3,
            ky: 3,
            stride: 1,
            relu: true,
            bn: true,
            bias: false,
            in_h: 8,
            in_w: 8,
            out_h: 8,
            out_w: 8,
            qidx: 0,
            ops: 8 * 8 * 32 * 16 * 9,
            weights_per_channel: 144,
            save_as: None,
            add_from: None,
            input_from: None,
        }
    }

    #[test]
    fn sub_byte_activations_shrink_traffic() {
        let s = spec();
        assert_eq!(act_in_bytes(&s, 8), 1024);
        assert_eq!(act_in_bytes(&s, 4), 512);
        assert_eq!(act_in_bytes(&s, 2), 256);
    }

    #[test]
    fn totals_compose() {
        let s = spec();
        let total = layer_traffic_bytes(&s, 8, 100);
        assert_eq!(total, 100 + 1024 + 2048);
    }
}
