//! Packed-register encode/decode for the MPIC SIMD datapath.
//!
//! The MPIC dot-product unit consumes 32-bit registers holding
//! `32 / max(p_x, p_w)` lanes; the *precision decoder* sign/zero-extends
//! each lane to the common grid before the multiply.  This module models
//! that encode/decode exactly (the simulator's [`super::exec`] operates on
//! unpacked codes for speed — property tests assert both views agree, so
//! the fast path provably computes what the packed hardware would).
//!
//! Encoding: little-endian lanes, two's-complement for weights, plain
//! binary for unsigned activations — the same layout
//! [`crate::quant::pack_subbyte`] uses for flash storage, so a weight
//! word can be DMA'd straight from the packed model image.

use super::isa::lanes_mpic;

/// Pack up to `lanes` unsigned activation codes into one 32-bit register.
pub fn pack_acts(codes: &[u32], px: u32, pw: u32) -> u32 {
    let lane_bits = px.max(pw);
    debug_assert!(codes.len() <= lanes_mpic(px, pw));
    let mask = (1u64 << px) - 1;
    let mut reg = 0u32;
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!((c as u64) <= mask);
        reg |= (c as u32) << (i as u32 * lane_bits);
    }
    reg
}

/// Pack signed weight codes (two's complement in `pw` bits, placed in
/// `max(px,pw)`-bit lanes after sign extension to the lane width).
pub fn pack_weights(codes: &[i32], px: u32, pw: u32) -> u32 {
    let lane_bits = px.max(pw);
    debug_assert!(codes.len() <= lanes_mpic(px, pw));
    let lane_mask = if lane_bits == 32 { u32::MAX } else { (1u32 << lane_bits) - 1 };
    let mut reg = 0u32;
    for (i, &c) in codes.iter().enumerate() {
        let enc = (c as u32) & lane_mask; // sign-extended to lane width
        reg |= enc << (i as u32 * lane_bits);
    }
    reg
}

/// Decode one activation lane.
pub fn decode_act(reg: u32, lane: usize, px: u32, pw: u32) -> u32 {
    let lane_bits = px.max(pw);
    let raw = reg >> (lane as u32 * lane_bits);
    raw & ((1u32 << px) - 1)
}

/// Decode one weight lane (sign-extend from the lane width).
pub fn decode_weight(reg: u32, lane: usize, px: u32, pw: u32) -> i32 {
    let lane_bits = px.max(pw);
    let raw = (reg >> (lane as u32 * lane_bits)) & ((1u32 << lane_bits) - 1);
    let sign = 1u32 << (lane_bits - 1);
    if raw & sign != 0 {
        raw as i32 - (1i32 << lane_bits)
    } else {
        raw as i32
    }
}

/// One packed-register SDOTP: decode every lane and accumulate — the
/// bit-exact model of the hardware instruction.
pub fn sdotp_packed(acc: i32, xreg: u32, wreg: u32, n: usize, px: u32, pw: u32) -> i32 {
    let mut a = acc;
    for lane in 0..n {
        let x = decode_act(xreg, lane, px, pw) as i32;
        let w = decode_weight(wreg, lane, px, pw);
        a = a.wrapping_add(x.wrapping_mul(w));
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpic::isa::{dotp_oracle, lanes_mpic};
    use crate::util::Pcg32;

    #[test]
    fn pack_decode_roundtrip_all_combos() {
        let mut rng = Pcg32::seeded(21);
        for &px in &[2u32, 4, 8] {
            for &pw in &[2u32, 4, 8] {
                let n = lanes_mpic(px, pw);
                for _ in 0..50 {
                    let xs: Vec<u32> = (0..n).map(|_| rng.below(1 << px)).collect();
                    let ws: Vec<i32> = (0..n)
                        .map(|_| rng.below(1 << pw) as i32 - (1 << (pw - 1)))
                        .collect();
                    let xr = pack_acts(&xs, px, pw);
                    let wr = pack_weights(&ws, px, pw);
                    for lane in 0..n {
                        assert_eq!(decode_act(xr, lane, px, pw), xs[lane]);
                        assert_eq!(
                            decode_weight(wr, lane, px, pw),
                            ws[lane],
                            "px={px} pw={pw} lane={lane}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_sdotp_matches_oracle() {
        // the packed hardware path == the simulator's unpacked arithmetic
        let mut rng = Pcg32::seeded(22);
        for &px in &[2u32, 4, 8] {
            for &pw in &[2u32, 4, 8] {
                let l = lanes_mpic(px, pw);
                for _ in 0..20 {
                    let k = 1 + rng.below(100) as usize;
                    let xs: Vec<u32> = (0..k).map(|_| rng.below(1 << px)).collect();
                    let ws: Vec<i32> = (0..k)
                        .map(|_| rng.below(1 << pw) as i32 - (1 << (pw - 1)))
                        .collect();
                    let mut acc = 0i32;
                    for c in 0..k.div_ceil(l) {
                        let lo = c * l;
                        let hi = (lo + l).min(k);
                        let xr = pack_acts(&xs[lo..hi], px, pw);
                        let wr = pack_weights(&ws[lo..hi], px, pw);
                        acc = sdotp_packed(acc, xr, wr, hi - lo, px, pw);
                    }
                    assert_eq!(acc as i64, dotp_oracle(&xs, &ws));
                }
            }
        }
    }

    #[test]
    fn flash_layout_compatible() {
        // equal-precision lanes (px == pw): the register image must equal
        // the packed flash bytes (weights can be DMA'd without re-packing)
        let ws = [-2i32, 1, 0, -1];
        let reg = pack_weights(&ws, 2, 2);
        let flash = crate::quant::pack_subbyte(&ws, 2);
        let flash_word = u32::from_le_bytes([flash[0], 0, 0, 0]);
        assert_eq!(reg & 0xFF, flash_word & 0xFF);
    }
}
