//! Scalar-oracle integer executor over a [`DeployedModel`].
//!
//! Executes exactly the deployed arithmetic: PACT-quantized unsigned
//! activations (per-layer bits), two's-complement per-channel weights,
//! int32 accumulation per sub-convolution group, folded BN epilogue in
//! f32 (two flops/channel — what the MPIC C kernels do with fixed-point
//! requant multipliers), residual adds and pooling in f32.
//!
//! Numerically this equals the `infer` HLO graph: an integer conv of the
//! quantization *codes* scaled by `eps_x * s_w[c]` is the same number as
//! the float conv of the fake-quantized tensors (both products are exact
//! in f32 for <= 8-bit operands).
//!
//! [`run_sample`] is the **bit-exactness oracle**: simple per-sample
//! scalar loops with cost accounting interleaved, kept as the ground
//! truth every [`crate::engine`] backend must match bit for bit.  The
//! hot path is the compile-once engine — callers hold a
//! [`crate::engine::ExecPlan`] and call its `run_batch` (the seed-era
//! per-call re-planning wrapper that used to live here is gone).

use anyhow::{anyhow, bail, Result};

use crate::deploy::{DeployedLayer, DeployedModel};
use crate::energy::CostLut;
use crate::models::LayerSpec;
use crate::mpic::cost::{
    account_group, account_memory, account_structural, InferenceCost,
    LayerCost,
};
use crate::mpic::memory;

/// HWC activation buffer.
#[derive(Clone, Debug)]
pub struct Act {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Act {
    fn new(h: usize, w: usize, c: usize) -> Act {
        Act { h, w, c, data: vec![0.0; h * w * c] }
    }

    fn from_vec(c: usize, data: Vec<f32>) -> Act {
        Act { h: 1, w: 1, c, data }
    }

    #[inline]
    fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }
}

/// PACT quantization of a whole buffer: codes in `[0, 2^bits)` + step.
fn quantize_act(a: &Act, alpha: f32, bits: u32) -> (Vec<u32>, f32) {
    crate::quant::quantize_acts_pact(&a.data, alpha, bits)
}

/// SAME-padding offsets (matches XLA's `padding="SAME"`).  Shared with
/// the engine's plan compiler — the bit-exactness contract requires a
/// single definition.
pub(crate) fn same_pad(in_len: usize, out_len: usize, k: usize, stride: usize) -> i64 {
    let total = ((out_len - 1) * stride + k).saturating_sub(in_len) as i64;
    total / 2
}

fn conv_layer(
    dl: &DeployedLayer,
    input: &Act,
    lut: &CostLut,
    cost: &mut LayerCost,
) -> Act {
    let s = &dl.spec;
    let (qx, eps) = quantize_act(input, dl.alpha, dl.act_bits);
    let mut out = Act::new(s.out_h, s.out_w, s.cout);
    let k = dl.k();
    let cin_g = if s.kind == "dwconv" { 1 } else { s.cin };
    let pad_y = same_pad(s.in_h, s.out_h, s.kx, s.stride);
    let pad_x = same_pad(s.in_w, s.out_w, s.ky, s.stride);

    if s.kind == "dwconv" {
        // depthwise: channel c reads only input channel c; the im2col
        // gather does not amortise, keep the direct form.
        for g in &dl.groups {
            for c in g.start..g.start + g.len {
                let wrow = &dl.qweights[c * k..(c + 1) * k];
                let a = dl.a_fold[c] * eps;
                let b = dl.b_fold[c];
                for oy in 0..s.out_h {
                    for ox in 0..s.out_w {
                        let mut acc: i32 = 0;
                        for ki in 0..s.kx {
                            let iy = oy as i64 * s.stride as i64 + ki as i64 - pad_y;
                            if iy < 0 || iy >= s.in_h as i64 {
                                continue;
                            }
                            for kj in 0..s.ky {
                                let ix = ox as i64 * s.stride as i64 + kj as i64
                                    - pad_x;
                                if ix < 0 || ix >= s.in_w as i64 {
                                    continue;
                                }
                                let base = (iy as usize * s.in_w + ix as usize)
                                    * s.cin;
                                acc += qx[base + c] as i32
                                    * wrow[ki * s.ky + kj];
                            }
                        }
                        let mut y = acc as f32 * a + b;
                        if s.relu && s.add_from.is_none() {
                            y = y.max(0.0);
                        }
                        out.data[(oy * s.out_w + ox) * s.cout + c] = y;
                    }
                }
            }
            let macs = (s.out_h * s.out_w * g.len * k) as u64;
            account_group(cost, lut, dl.act_bits, g.bits, macs);
        }
    } else {
        // §Perf L3 optimisation: im2col per output pixel, gathered ONCE
        // and reused by all C_out channels (previously the window/padding
        // arithmetic re-ran per channel — the profile's top hot spot).
        // Zero-padding adds exact zeros to the integer accumulation.
        let mut col = vec![0i32; k];
        for oy in 0..s.out_h {
            for ox in 0..s.out_w {
                // gather the receptive field (zeros outside the image)
                for ki in 0..s.kx {
                    let iy = oy as i64 * s.stride as i64 + ki as i64 - pad_y;
                    for kj in 0..s.ky {
                        let ix = ox as i64 * s.stride as i64 + kj as i64 - pad_x;
                        let dst = (ki * s.ky + kj) * cin_g;
                        if iy < 0 || iy >= s.in_h as i64 || ix < 0
                            || ix >= s.in_w as i64
                        {
                            col[dst..dst + cin_g].fill(0);
                        } else {
                            let base = (iy as usize * s.in_w + ix as usize) * s.cin;
                            for ci in 0..cin_g {
                                col[dst + ci] = qx[base + ci] as i32;
                            }
                        }
                    }
                }
                let orow = (oy * s.out_w + ox) * s.cout;
                for c in 0..s.cout {
                    let wrow = &dl.qweights[c * k..(c + 1) * k];
                    let mut acc: i32 = 0;
                    for (x, w) in col.iter().zip(wrow) {
                        acc += x * w;
                    }
                    let mut y = acc as f32 * (dl.a_fold[c] * eps) + dl.b_fold[c];
                    if s.relu && s.add_from.is_none() {
                        y = y.max(0.0);
                    }
                    out.data[orow + c] = y;
                }
            }
        }
        for g in &dl.groups {
            let macs = (s.out_h * s.out_w * g.len * k) as u64;
            account_group(cost, lut, dl.act_bits, g.bits, macs);
        }
    }
    account_memory(cost, memory::layer_traffic_bytes(s, dl.act_bits, dl.packed_bytes()));
    out
}

fn fc_layer(
    dl: &DeployedLayer,
    input: &Act,
    lut: &CostLut,
    cost: &mut LayerCost,
) -> Act {
    let s = &dl.spec;
    let (qx, eps) = quantize_act(input, dl.alpha, dl.act_bits);
    let k = dl.k();
    debug_assert_eq!(qx.len(), k, "fc input width mismatch");
    let mut out = vec![0.0f32; s.cout];
    for g in &dl.groups {
        for c in g.start..g.start + g.len {
            let wrow = &dl.qweights[c * k..(c + 1) * k];
            let mut acc: i64 = 0;
            for (j, &x) in qx.iter().enumerate() {
                acc += x as i64 * wrow[j] as i64;
            }
            let mut y = acc as f32 * (dl.a_fold[c] * eps) + dl.b_fold[c];
            if s.relu && s.add_from.is_none() {
                y = y.max(0.0);
            }
            out[c] = y;
        }
        account_group(cost, lut, dl.act_bits, g.bits, (g.len * k) as u64);
    }
    account_memory(cost, memory::layer_traffic_bytes(s, dl.act_bits, dl.packed_bytes()));
    Act::from_vec(s.cout, out)
}

fn structural(
    spec: &LayerSpec,
    cur: Act,
    saved: &mut std::collections::HashMap<String, Act>,
    cost: &mut LayerCost,
) -> Result<Act> {
    let out = match spec.kind.as_str() {
        "tap" => cur,
        "avgpool" => {
            let mut v = vec![0.0f32; cur.c];
            for y in 0..cur.h {
                for x in 0..cur.w {
                    for ch in 0..cur.c {
                        v[ch] += cur.at(y, x, ch);
                    }
                }
            }
            let n = (cur.h * cur.w) as f32;
            for ch in v.iter_mut() {
                *ch /= n;
            }
            account_structural(cost, cur.h * cur.w * cur.c);
            Act::from_vec(spec.cout, v)
        }
        "flatten" => Act::from_vec(cur.h * cur.w * cur.c, cur.data),
        "add" => {
            let tag = spec.add_from.as_ref().ok_or_else(|| anyhow!("add w/o tag"))?;
            let other = saved
                .get(tag)
                .ok_or_else(|| anyhow!("missing saved tag {tag}"))?;
            if other.data.len() != cur.data.len() {
                bail!("add size mismatch");
            }
            let mut data = cur.data;
            for (d, &o) in data.iter_mut().zip(&other.data) {
                *d += o;
                if spec.relu {
                    *d = d.max(0.0);
                }
            }
            account_structural(cost, data.len());
            Act { h: cur.h, w: cur.w, c: cur.c, data }
        }
        other => bail!("unexpected structural kind {other}"),
    };
    Ok(out)
}

/// Run one sample through the deployed network.
///
/// `input` is the flattened HWC (or flat vector) sample; returns the
/// output activations (logits / reconstruction) and the cost breakdown.
pub fn run_sample(
    model: &DeployedModel,
    input: &[f32],
    lut: &CostLut,
) -> Result<(Vec<f32>, InferenceCost)> {
    let mut cur = match model.input_shape.len() {
        3 => {
            let (h, w, c) = (model.input_shape[0], model.input_shape[1], model.input_shape[2]);
            if input.len() != h * w * c {
                bail!("input length {} != {h}x{w}x{c}", input.len());
            }
            Act { h, w, c, data: input.to_vec() }
        }
        1 => Act::from_vec(model.input_shape[0], input.to_vec()),
        _ => bail!("unsupported input rank"),
    };
    let mut saved: std::collections::HashMap<String, Act> = std::collections::HashMap::new();
    let mut cost = InferenceCost::default();

    for node in &model.nodes {
        let spec = &node.spec;
        let mut lc = LayerCost { name: spec.name.clone(), ..Default::default() };
        // input_from: switch to a saved tensor before applying
        if let Some(tag) = &spec.input_from {
            cur = saved
                .get(tag)
                .ok_or_else(|| anyhow!("missing input tag {tag}"))?
                .clone();
        }
        cur = match &node.layer {
            Some(dl) => {
                let mut out = if spec.kind == "fc" {
                    fc_layer(dl, &cur, lut, &mut lc)
                } else {
                    conv_layer(dl, &cur, lut, &mut lc)
                };
                // residual epilogue for quant layers carrying add_from
                if let Some(tag) = &spec.add_from {
                    let other = saved
                        .get(tag)
                        .ok_or_else(|| anyhow!("missing saved tag {tag}"))?;
                    if other.data.len() != out.data.len() {
                        bail!("residual size mismatch at {}", spec.name);
                    }
                    for (d, &o) in out.data.iter_mut().zip(&other.data) {
                        *d += o;
                        if spec.relu {
                            *d = d.max(0.0);
                        }
                    }
                    account_structural(&mut lc, out.data.len());
                }
                out
            }
            None => structural(spec, cur, &mut saved, &mut lc)?,
        };
        if let Some(tag) = &spec.save_as {
            saved.insert(tag.clone(), cur.clone());
        }
        if lc.total_cycles() > 0.0 || lc.mem_bytes > 0 {
            cost.layers.push(lc);
        }
    }
    // un-permute the output space (free relabeling on device, §III-C)
    if !model.output_perm.is_empty()
        && model.output_perm.iter().enumerate().any(|(i, &p)| i != p)
    {
        let mut natural = vec![0.0f32; cur.data.len()];
        for (new_c, &orig_c) in model.output_perm.iter().enumerate() {
            natural[orig_c] = cur.data[new_c];
        }
        return Ok((natural, cost));
    }
    Ok((cur.data, cost))
}

