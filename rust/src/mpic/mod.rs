//! MPIC mixed-precision RISC-V simulator (substrate — DESIGN.md §2).
//!
//! The paper deploys on MPIC (Ottavi et al., ISVLSI 2020): a RI5CY-based
//! core with SIMD MAC units whose operands are *independently* quantized
//! to 2/4/8 bit.  The silicon is not available here, so this module
//! provides the closest synthetic equivalent that exercises the same code
//! paths the paper's evaluation needs:
//!
//! * [`isa`] — the mixed-precision SIMD dot-product semantics (lane
//!   packing by the wider operand, int32 accumulation) plus a scalar
//!   oracle used by property tests;
//! * [`exec`] — the scalar-oracle executor that runs a
//!   [`crate::deploy::DeployedModel`] sample-by-sample: PACT activation
//!   quantization, per-sub-convolution integer conv/FC (uint activations
//!   x two's-complement weights), folded BN epilogue, residual adds,
//!   pooling.  `exec::run_sample` is the bit-exactness ground truth for
//!   every engine backend; batch execution lives in the compile-once
//!   [`crate::engine`] (hold an `ExecPlan`, call its `run_batch`);
//! * [`cost`] — cycle and energy accounting per layer/sub-conv using the
//!   [`crate::energy::CostLut`] MAC table plus load/store and
//!   sub-convolution scheduling overheads — the refinement of Eq. (8)
//!   that the paper measures on hardware — and the per-batch amortized
//!   report [`cost::BatchCost`] for weight-stationary batch-plane
//!   execution;
//! * [`memory`] — the L2→L1 traffic model behind the memory-energy bucket.
//!
//! Numerical contract: for any assignment, [`exec::run_sample`] computes
//! the same function as the AOT'd `infer` graph (integer conv == float
//! conv of fake-quantized values, BN folded exactly); the integration
//! test `tests/deploy_matches_hlo.rs` asserts argmax agreement and
//! elementwise closeness on real trained weights.

pub mod cost;
pub mod exec;
pub mod isa;
pub mod regfile;
pub mod memory;

pub use cost::{BatchCost, InferenceCost, LayerCost};
pub use exec::run_sample;
