//! Synthetic MLPerf-Tiny-shaped datasets (DESIGN.md §2 substitution).
//!
//! The paper evaluates on CIFAR-10, Speech Commands v2, MSCOCO-VWW and
//! DCASE2020 ToyCar — none of which are available offline.  The DNAS only
//! consumes a dataset through (batches, task loss, accuracy/AUC), so each
//! generator below produces a seeded, class-conditional synthetic task
//! with the same tensor geometry and a calibrated difficulty: accuracy
//! saturates below 100% and degrades monotonically as precision drops,
//! which is exactly the property the Fig. 3 Pareto fronts measure.
//!
//! All inputs are generated non-negative (roughly `[0, 2.5]`) because the
//! first layer's PACT quantizer is unsigned — mirroring the standard
//! uint8-image / normalized-MFCC deployments the paper targets.

pub mod gen;

pub use gen::{make_dataset, Dataset, Split};

use crate::util::Pcg32;

/// A batch ready for the runtime: flattened inputs + labels.
pub struct Batch {
    /// `batch * prod(feat_shape)` f32 row-major.
    pub x: Vec<f32>,
    /// Classification labels (empty for AD, where y == x).
    pub y: Vec<i32>,
}

/// Iterates a split in shuffled fixed-size batches (drops the remainder,
/// like the reference MLPerf Tiny training loops).
pub struct BatchIter<'a> {
    ds: &'a Dataset,
    idx: Vec<usize>,
    pos: usize,
    batch: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, rng: &mut Pcg32) -> Self {
        let mut idx: Vec<usize> = (0..ds.n).collect();
        rng.shuffle(&mut idx);
        BatchIter { ds, idx, pos: 0, batch }
    }

    /// Sequential (unshuffled) iteration — evaluation order.
    pub fn sequential(ds: &'a Dataset, batch: usize) -> Self {
        let idx: Vec<usize> = (0..ds.n).collect();
        BatchIter { ds, idx, pos: 0, batch }
    }

    pub fn n_batches(&self) -> usize {
        self.ds.n / self.batch
    }

    /// Restrict to the first `frac` of the (already shuffled) epoch — the
    /// Alg. 1 20%/80% theta/W sample split.
    pub fn take_front(mut self, frac: f32) -> Self {
        let keep = ((self.idx.len() as f32 * frac) as usize).max(self.batch);
        self.idx.truncate(keep.min(self.idx.len()));
        self
    }

    /// Drop the first `frac` of the epoch (complement of `take_front`).
    pub fn drop_front(mut self, frac: f32) -> Self {
        let skip = (self.idx.len() as f32 * frac) as usize;
        self.idx.drain(..skip.min(self.idx.len()));
        self
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        if self.pos + self.batch > self.idx.len() {
            return None;
        }
        let feat = self.ds.feat_len();
        let mut x = Vec::with_capacity(self.batch * feat);
        let mut y = Vec::with_capacity(self.batch);
        for &i in &self.idx[self.pos..self.pos + self.batch] {
            x.extend_from_slice(&self.ds.x[i * feat..(i + 1) * feat]);
            y.push(self.ds.y[i]);
        }
        self.pos += self.batch;
        Some(Batch { x, y })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_epoch() {
        let ds = make_dataset("ic", Split::Train, 256, 0);
        let mut rng = Pcg32::seeded(1);
        let it = BatchIter::new(&ds, 32, &mut rng);
        assert_eq!(it.n_batches(), 8);
        let n: usize = it.map(|b| b.y.len()).sum();
        assert_eq!(n, 256);
    }

    #[test]
    fn split_20_80_partitions() {
        let ds = make_dataset("kws", Split::Train, 320, 0);
        let mut rng = Pcg32::seeded(2);
        let front = BatchIter::new(&ds, 32, &mut rng).take_front(0.2);
        let n_front: usize = front.map(|b| b.y.len()).sum();
        let mut rng = Pcg32::seeded(2);
        let back = BatchIter::new(&ds, 32, &mut rng).drop_front(0.2);
        let n_back: usize = back.map(|b| b.y.len()).sum();
        assert_eq!(n_front, 64);
        assert_eq!(n_back, 256);
    }

    #[test]
    fn batch_shapes() {
        let ds = make_dataset("ad", Split::Train, 64, 0);
        let mut rng = Pcg32::seeded(3);
        let b = BatchIter::new(&ds, 32, &mut rng).next().unwrap();
        assert_eq!(b.x.len(), 32 * 256);
    }
}
