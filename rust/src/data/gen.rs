//! The four seeded synthetic dataset generators.
//!
//! Shared construction: every class owns a smooth deterministic *template*
//! in the benchmark's native tensor geometry; samples are
//! `template[class] + noise` with per-benchmark structured variation.
//! Smoothness comes from summing a few random low-frequency sinusoids, so
//! the class signal survives 8-bit quantization but starts eroding at 2
//! bits — giving the precision/accuracy trade-off the NAS explores.
//!
//! | bench | geometry   | classes | variation                         |
//! |-------|------------|---------|-----------------------------------|
//! | ic    | 32x32x3    | 10      | additive noise + global gain      |
//! | kws   | 49x10x1    | 12      | time jitter of spectral ridges    |
//! | vww   | 48x48x3    | 2       | object blob present / absent      |
//! | ad    | 256 (flat) | normal  | low-rank manifold; anomalies off-manifold |
//!
//! Train/val/test use disjoint RNG streams of one seed, so every
//! experiment in EXPERIMENTS.md is exactly reproducible.

use crate::util::Pcg32;

/// Which split to generate (disjoint RNG streams; same templates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 101,
            Split::Val => 202,
            Split::Test => 303,
        }
    }
}

/// An in-memory dataset: `n` samples of `feat` geometry.
pub struct Dataset {
    pub name: String,
    pub feat: Vec<usize>,
    pub n: usize,
    /// row-major `n * prod(feat)`
    pub x: Vec<f32>,
    /// class labels; for AD: 0 = normal, 1 = anomaly (train is all 0)
    pub y: Vec<i32>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn feat_len(&self) -> usize {
        self.feat.iter().product()
    }
}

/// Build `n` samples of the given benchmark/split.
///
/// Templates depend only on `seed`, never on the split, so train and test
/// measure generalisation over the noise/variation process.
pub fn make_dataset(bench: &str, split: Split, n: usize, seed: u64) -> Dataset {
    match bench {
        "ic" => gen_ic(split, n, seed),
        "kws" => gen_kws(split, n, seed),
        "vww" => gen_vww(split, n, seed),
        "ad" => gen_ad(split, n, seed),
        other => panic!("unknown benchmark {other}"),
    }
}

/// Smooth 2D field: sum of `k` random sinusoids, normalised to [0, amp].
fn smooth_field(h: usize, w: usize, k: usize, amp: f32, rng: &mut Pcg32) -> Vec<f32> {
    let mut field = vec![0.0f32; h * w];
    for _ in 0..k {
        let fx = rng.uniform_in(0.5, 3.0);
        let fy = rng.uniform_in(0.5, 3.0);
        let px = rng.uniform_in(0.0, std::f32::consts::TAU);
        let py = rng.uniform_in(0.0, std::f32::consts::TAU);
        let a = rng.uniform_in(0.5, 1.0);
        for i in 0..h {
            for j in 0..w {
                let u = i as f32 / h as f32 * std::f32::consts::TAU;
                let v = j as f32 / w as f32 * std::f32::consts::TAU;
                field[i * w + j] += a * ((fx * u + px).sin() * (fy * v + py).cos());
            }
        }
    }
    // normalise to [0, amp]
    let lo = field.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = field.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let range = (hi - lo).max(1e-6);
    for v in &mut field {
        *v = (*v - lo) / range * amp;
    }
    field
}

// ---------------------------------------------------------------------------
// IC — CIFAR-10-shaped: 32x32x3, 10 classes.
// ---------------------------------------------------------------------------

fn gen_ic(split: Split, n: usize, seed: u64) -> Dataset {
    let (h, w, c, ncls) = (32usize, 32usize, 3usize, 10usize);
    let mut trng = Pcg32::new(seed, 7); // template stream (split-independent)
    // Difficulty model: a strong *shared* base image carries most of the
    // dynamic range; classes differ only by small smooth deltas.  Coarse
    // quantization preserves the common mode but erases the deltas, so
    // accuracy genuinely degrades with precision (the Fig. 3 axis).
    let base: Vec<Vec<f32>> = (0..c).map(|_| smooth_field(h, w, 4, 2.0, &mut trng)).collect();
    let mut templates = Vec::with_capacity(ncls);
    for _ in 0..ncls {
        let mut hwc = vec![0.0f32; h * w * c];
        for ch in 0..c {
            let delta = smooth_field(h, w, 5, 0.55, &mut trng);
            for p in 0..h * w {
                hwc[p * c + ch] = base[ch][p] + delta[p];
            }
        }
        templates.push(hwc);
    }
    let mut rng = Pcg32::new(seed, split.stream());
    let feat = h * w * c;
    let mut x = Vec::with_capacity(n * feat);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let cls = rng.below(ncls as u32) as usize;
        let gain = rng.uniform_in(0.9, 1.1);
        // smooth per-sample nuisance (illumination-like), shared across
        // channels: a structured confuser that does not average out
        let nuisance = smooth_field(h, w, 3, rng.uniform_in(0.2, 0.9), &mut rng);
        for (i, &t) in templates[cls].iter().enumerate() {
            let v = t * gain + nuisance[i / c] + rng.normal_ms(0.0, 0.45);
            x.push(v.max(0.0));
        }
        y.push(cls as i32);
    }
    Dataset { name: "ic".into(), feat: vec![h, w, c], n, x, y, n_classes: ncls }
}

// ---------------------------------------------------------------------------
// KWS — Speech-Commands-shaped MFCC grid: 49x10x1, 12 classes.
// ---------------------------------------------------------------------------

fn gen_kws(split: Split, n: usize, seed: u64) -> Dataset {
    let (t_len, f_len, ncls) = (49usize, 10usize, 12usize);
    let mut trng = Pcg32::new(seed, 7);
    // each class: 2-3 spectral ridges with characteristic (freq, slope)
    struct Ridge {
        f0: f32,
        slope: f32,
        amp: f32,
        width: f32,
    }
    // Shared loud "speech-like" background ridges (common mode) + small
    // class-specific ridges: coarse quantization keeps the background but
    // blurs the class signal (same difficulty model as IC).
    let mut shared = Vec::new();
    for _ in 0..3 {
        shared.push(Ridge {
            f0: trng.uniform_in(0.5, f_len as f32 - 1.5),
            slope: trng.uniform_in(-0.04, 0.04),
            amp: trng.uniform_in(1.4, 2.0),
            width: trng.uniform_in(1.0, 2.0),
        });
    }
    let mut class_ridges = Vec::with_capacity(ncls);
    for _ in 0..ncls {
        let k = 2 + trng.below(2) as usize;
        let mut ridges = Vec::with_capacity(k);
        for _ in 0..k {
            ridges.push(Ridge {
                f0: trng.uniform_in(0.5, f_len as f32 - 1.5),
                slope: trng.uniform_in(-0.06, 0.06),
                amp: trng.uniform_in(0.35, 0.7),
                width: trng.uniform_in(0.6, 1.4),
            });
        }
        class_ridges.push(ridges);
    }
    let mut rng = Pcg32::new(seed, split.stream());
    let feat = t_len * f_len;
    let mut x = Vec::with_capacity(n * feat);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let cls = rng.below(ncls as u32) as usize;
        let jitter = rng.uniform_in(-5.0, 5.0); // time shift
        let gain = rng.uniform_in(0.85, 1.15);
        for ti in 0..t_len {
            for fi in 0..f_len {
                let mut v = 0.0f32;
                for r in shared.iter().chain(&class_ridges[cls]) {
                    let center = r.f0 + r.slope * (ti as f32 + jitter);
                    let d = fi as f32 - center;
                    v += r.amp * (-d * d / (2.0 * r.width * r.width)).exp();
                }
                v = v * gain + rng.normal_ms(0.0, 0.5);
                x.push(v.max(0.0));
            }
        }
        y.push(cls as i32);
    }
    Dataset { name: "kws".into(), feat: vec![t_len, f_len, 1], n, x, y, n_classes: ncls }
}

// ---------------------------------------------------------------------------
// VWW — person-presence-shaped: 48x48x3, binary.
// ---------------------------------------------------------------------------

fn gen_vww(split: Split, n: usize, seed: u64) -> Dataset {
    let (h, w, c) = (48usize, 48usize, 3usize);
    let mut trng = Pcg32::new(seed, 7);
    // a fixed "object" appearance shared by all positives (coloured blob
    // with internal structure), composited onto varied backgrounds.
    let obj_size = 16usize;
    let mut obj = Vec::with_capacity(obj_size * obj_size * c);
    for _ in 0..c {
        obj.extend(smooth_field(obj_size, obj_size, 3, 2.2, &mut trng));
    }
    let mut rng = Pcg32::new(seed, split.stream());
    let feat = h * w * c;
    let mut x = Vec::with_capacity(n * feat);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let cls = rng.below(2) as usize;
        // varied smooth background
        let mut img = vec![0.0f32; feat];
        let bg_level = rng.uniform_in(0.3, 1.0);
        for v in img.iter_mut() {
            *v = bg_level + rng.normal_ms(0.0, 0.35);
        }
        if cls == 1 {
            // composite object at random position with random gain
            let oy = rng.below((h - obj_size) as u32) as usize;
            let ox = rng.below((w - obj_size) as u32) as usize;
            let g = rng.uniform_in(0.8, 1.3);
            for i in 0..obj_size {
                for j in 0..obj_size {
                    for ch in 0..c {
                        let dst = ((oy + i) * w + (ox + j)) * c + ch;
                        let src = ch * obj_size * obj_size + i * obj_size + j;
                        img[dst] += g * obj[src];
                    }
                }
            }
        }
        for v in img {
            x.push(v.max(0.0));
        }
        y.push(cls as i32);
    }
    Dataset { name: "vww".into(), feat: vec![h, w, c], n, x, y, n_classes: 2 }
}

// ---------------------------------------------------------------------------
// AD — ToyCar-shaped: 256-dim frames, low-rank normal manifold.
// ---------------------------------------------------------------------------

fn gen_ad(split: Split, n: usize, seed: u64) -> Dataset {
    let (d, latent) = (256usize, 8usize);
    let mut trng = Pcg32::new(seed, 7);
    // fixed decoder map latent -> observation (the "machine sound" manifold)
    let mut map = Vec::with_capacity(d * latent);
    for _ in 0..d * latent {
        map.push(trng.normal_ms(0.0, 1.0 / (latent as f32).sqrt()));
    }
    let mut bias = Vec::with_capacity(d);
    for _ in 0..d {
        bias.push(trng.uniform_in(0.8, 1.6));
    }
    let mut rng = Pcg32::new(seed, split.stream());
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    // test mixes anomalies in; train/val are normal-only (DCASE protocol)
    let anomaly_rate = if split == Split::Test { 0.5 } else { 0.0 };
    for _ in 0..n {
        let is_anom = rng.uniform() < anomaly_rate;
        let mut z = [0.0f32; 16];
        for zi in z.iter_mut().take(latent) {
            *zi = rng.normal();
        }
        for i in 0..d {
            let mut v = bias[i];
            for (j, zj) in z.iter().enumerate().take(latent) {
                v += map[i * latent + j] * zj;
            }
            v += rng.normal_ms(0.0, 0.08);
            if is_anom {
                // off-manifold excursions: sparse spectral spikes
                if rng.uniform() < 0.12 {
                    v += rng.normal_ms(0.0, 0.9).abs();
                }
            }
            x.push(v.max(0.0));
        }
        y.push(is_anom as i32);
    }
    Dataset { name: "ad".into(), feat: vec![d], n, x, y, n_classes: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let a = make_dataset("ic", Split::Train, 16, 5);
        let b = make_dataset("ic", Split::Train, 16, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn splits_differ_but_templates_shared() {
        let tr = make_dataset("kws", Split::Train, 32, 5);
        let te = make_dataset("kws", Split::Test, 32, 5);
        assert_ne!(tr.x, te.x);
    }

    #[test]
    fn geometry_matches_models() {
        assert_eq!(make_dataset("ic", Split::Train, 4, 0).feat, vec![32, 32, 3]);
        assert_eq!(make_dataset("kws", Split::Train, 4, 0).feat, vec![49, 10, 1]);
        assert_eq!(make_dataset("vww", Split::Train, 4, 0).feat, vec![48, 48, 3]);
        assert_eq!(make_dataset("ad", Split::Train, 4, 0).feat, vec![256]);
    }

    #[test]
    fn inputs_nonnegative() {
        for bench in ["ic", "kws", "vww", "ad"] {
            let ds = make_dataset(bench, Split::Train, 8, 1);
            assert!(ds.x.iter().all(|&v| v >= 0.0), "{bench} has negatives");
        }
    }

    #[test]
    fn labels_in_range() {
        let ds = make_dataset("ic", Split::Train, 128, 2);
        assert!(ds.y.iter().all(|&y| (0..10).contains(&y)));
        let all_classes: std::collections::HashSet<i32> = ds.y.iter().cloned().collect();
        assert!(all_classes.len() >= 8, "class coverage too thin");
    }

    #[test]
    fn ad_train_has_no_anomalies_test_does() {
        let tr = make_dataset("ad", Split::Train, 64, 3);
        assert!(tr.y.iter().all(|&y| y == 0));
        let te = make_dataset("ad", Split::Test, 200, 3);
        let n_anom: i32 = te.y.iter().sum();
        assert!(n_anom > 50 && n_anom < 150, "anomaly rate off: {n_anom}/200");
    }

    #[test]
    fn class_signal_present() {
        // nearest-template classification on clean means should beat chance
        let ds = make_dataset("ic", Split::Train, 400, 9);
        let feat = ds.feat_len();
        // compute per-class means
        let mut means = vec![vec![0.0f32; feat]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.n {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for j in 0..feat {
                means[c][j] += ds.x[i * feat + j];
            }
        }
        for c in 0..10 {
            for v in &mut means[c] {
                *v /= counts[c].max(1) as f32;
            }
        }
        let test = make_dataset("ic", Split::Test, 200, 9);
        let mut correct = 0;
        for i in 0..test.n {
            let xi = &test.x[i * feat..(i + 1) * feat];
            let mut best = (f32::INFINITY, 0);
            for (c, m) in means.iter().enumerate() {
                let d: f32 = xi.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 as i32 == test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / test.n as f32;
        assert!(acc > 0.5, "nearest-mean acc {acc} too low — task unlearnable");
    }
}
