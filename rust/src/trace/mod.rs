//! End-to-end request tracing: lock-free per-thread span buffers with a
//! single-branch disabled path.
//!
//! The serving layer's `/metrics` aggregates answer *how much* and *how
//! fast on average*; they cannot attribute one slow request to queueing
//! vs. quantization vs. a specific conv node.  This module records
//! **spans** — named `(start, duration)` intervals stamped with the
//! request id — across the whole lifecycle:
//!
//! ```text
//!   request ──┬ admission      submit(): validate + breaker + enqueue
//!             ├ queue_wait     enqueued → dequeued by the worker
//!             └ batch_ride     dequeued → reply sent
//!                 └ engine_pass    one executed batch-plane pass
//!                     └ node       one plan node (arg = node index)
//! ```
//!
//! **Disabled path.** Tracing is off by default.  Every span site is a
//! single relaxed [`enabled`] load (the disarmed-failpoint pattern from
//! `serve::faults`: one branch, no allocation, no clock read), so the
//! traced-but-disabled binary stays inside the `bench_serve` /
//! `bench_engine` perf gates.
//!
//! **Record path.** When enabled, a span is written into one of
//! [`SHARDS`] fixed-capacity rings of [`RING_SPANS`] cells.  Each
//! thread is pinned to a shard once (round-robin); a write claims a
//! slot with one relaxed `fetch_add` on the shard cursor and publishes
//! the span fields through a seqlock (`seq` odd while writing, even
//! when stable, `Release` on publish).  No lock is ever taken on the
//! record path, and the scrape side ([`export_last`]) detects and skips
//! torn cells by re-reading `seq`.  The rings overwrite oldest-first,
//! so memory is bounded at `SHARDS * RING_SPANS` spans regardless of
//! how long tracing stays on.
//!
//! **Export.** [`export_last`] renders the newest `n` stable spans as
//! chrome://tracing JSON (`traceEvents` with `ph:"X"` complete events;
//! `args.req` carries the request id, `args.arg` the span's extra
//! value, e.g. the plan-node index).  Served by `GET /v1/trace?last=N`
//! and written to a file by `cwmix serve --trace-out`.
//!
//! Request ids themselves ([`next_request_id`]) are allocated whether
//! or not tracing is on — the structured per-request log lines and the
//! `request_id` reply field need them even when nobody records spans.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::minijson::Json;

/// Per-thread span rings (threads are pinned round-robin).
pub const SHARDS: usize = 8;

/// Spans per ring; the global buffer holds `SHARDS * RING_SPANS`
/// spans and overwrites oldest-first.
pub const RING_SPANS: usize = 4096;

/// The fixed span-name catalog — record sites never intern strings,
/// they store an index into this table.
pub const SPAN_NAMES: &[&str] = &[
    "request",
    "admission",
    "queue_wait",
    "batch_ride",
    "engine_pass",
    "node",
];

/// A span site's name (index into [`SPAN_NAMES`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanName {
    /// Whole HTTP request: admission through reply serialization.
    Request = 0,
    /// `Batcher::submit`: validation, breaker admission, enqueue.
    Admission = 1,
    /// Enqueued → dequeued by the batcher worker.
    QueueWait = 2,
    /// Dequeued → reply sent (includes the engine pass).
    BatchRide = 3,
    /// One executed engine batch-plane pass (arg = batch size).
    EnginePass = 4,
    /// One plan node inside a pass (arg = node index).
    Node = 5,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Whether span sites record (one relaxed load — THE disabled-path
/// branch).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (`cwmix serve --trace`, tests).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Allocate the next request id (process-wide, starts at 1).  Always
/// live — ids stamp log lines and replies even when tracing is off.
pub fn next_request_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Microseconds since the process trace epoch (first use).
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// One published span cell.  A single seqlock (`seq` odd = writing)
/// protects the payload; each field is its own relaxed atomic so a
/// torn read can never be UB, only detected garbage.
struct Cell {
    seq: AtomicU64,
    name: AtomicU32,
    tid: AtomicU32,
    id: AtomicU64,
    arg: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

impl Cell {
    fn new() -> Cell {
        Cell {
            seq: AtomicU64::new(0),
            name: AtomicU32::new(0),
            tid: AtomicU32::new(0),
            id: AtomicU64::new(0),
            arg: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
        }
    }
}

struct Shard {
    /// Slots claimed so far (slot = pos % RING_SPANS).
    pos: AtomicU64,
    cells: Vec<Cell>,
}

struct Tracer {
    shards: Vec<Shard>,
}

fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(|| Tracer {
        shards: (0..SHARDS)
            .map(|_| Shard {
                pos: AtomicU64::new(0),
                cells: (0..RING_SPANS).map(|_| Cell::new()).collect(),
            })
            .collect(),
    })
}

/// This thread's (shard, display tid) — assigned once, round-robin.
fn thread_slot() -> (usize, u32) {
    static NEXT_TID: AtomicU32 = AtomicU32::new(0);
    thread_local! {
        static SLOT: (usize, u32) = {
            let n = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            (n as usize % SHARDS, n)
        };
    }
    SLOT.with(|s| *s)
}

/// Record a finished span (absolute times in [`now_us`] microseconds).
/// One relaxed `fetch_add` claims a ring slot; the seqlock publish
/// never blocks.
pub fn record_span(name: SpanName, id: u64, arg: u64, start_us: u64, end_us: u64) {
    if !enabled() {
        return;
    }
    let (shard_ix, tid) = thread_slot();
    let shard = &tracer().shards[shard_ix];
    let slot = shard.pos.fetch_add(1, Ordering::Relaxed) as usize % RING_SPANS;
    let c = &shard.cells[slot];
    c.seq.fetch_add(1, Ordering::Relaxed); // odd: writing
    c.name.store(name as u32, Ordering::Relaxed);
    c.tid.store(tid, Ordering::Relaxed);
    c.id.store(id, Ordering::Relaxed);
    c.arg.store(arg, Ordering::Relaxed);
    c.start_us.store(start_us, Ordering::Relaxed);
    c.dur_us.store(end_us.saturating_sub(start_us), Ordering::Relaxed);
    c.seq.fetch_add(1, Ordering::Release); // even: stable
}

/// Record a span that started at `start` and ends now.
pub fn record_since(name: SpanName, id: u64, arg: u64, start: Instant) {
    if !enabled() {
        return;
    }
    let end = now_us();
    let dur = start.elapsed().as_micros() as u64;
    record_span(name, id, arg, end.saturating_sub(dur), end);
}

/// A live span: records on drop.  [`span`] returns `None` when tracing
/// is disabled, so a disabled site is one branch and no clock read.
pub struct SpanGuard {
    name: SpanName,
    id: u64,
    arg: u64,
    start_us: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        record_span(self.name, self.id, self.arg, self.start_us, now_us());
    }
}

/// Open a span for request `id` (None when tracing is disabled).
#[inline]
pub fn span(name: SpanName, id: u64) -> Option<SpanGuard> {
    span_arg(name, id, 0)
}

/// [`span`] with an extra argument (batch size, node index, ...).
#[inline]
pub fn span_arg(name: SpanName, id: u64, arg: u64) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    Some(SpanGuard { name, id, arg, start_us: now_us() })
}

/// Total spans recorded so far (including overwritten ones).
pub fn recorded() -> u64 {
    tracer().shards.iter().map(|s| s.pos.load(Ordering::Relaxed)).sum()
}

/// A stable, decoded span.
#[derive(Clone, Debug)]
pub struct Span {
    pub name: u32,
    pub tid: u32,
    pub id: u64,
    pub arg: u64,
    pub start_us: u64,
    pub dur_us: u64,
}

impl Span {
    pub fn name_str(&self) -> &'static str {
        SPAN_NAMES.get(self.name as usize).copied().unwrap_or("span")
    }
}

/// Seqlock read: `None` for never-written, in-flight, or torn cells.
fn read_cell(c: &Cell) -> Option<Span> {
    let s1 = c.seq.load(Ordering::Acquire);
    if s1 == 0 || s1 % 2 == 1 {
        return None;
    }
    let span = Span {
        name: c.name.load(Ordering::Relaxed),
        tid: c.tid.load(Ordering::Relaxed),
        id: c.id.load(Ordering::Relaxed),
        arg: c.arg.load(Ordering::Relaxed),
        start_us: c.start_us.load(Ordering::Relaxed),
        dur_us: c.dur_us.load(Ordering::Relaxed),
    };
    std::sync::atomic::fence(Ordering::Acquire);
    if c.seq.load(Ordering::Relaxed) != s1 {
        return None;
    }
    Some(span)
}

/// Snapshot the newest `n` stable spans, oldest first.
pub fn snapshot_last(n: usize) -> Vec<Span> {
    let mut spans: Vec<Span> = tracer()
        .shards
        .iter()
        .flat_map(|s| s.cells.iter().filter_map(read_cell))
        .collect();
    spans.sort_by_key(|s| (s.start_us.saturating_add(s.dur_us), s.start_us));
    if spans.len() > n {
        spans.drain(..spans.len() - n);
    }
    spans
}

/// The newest `n` spans as a chrome://tracing document: load the
/// `dumps()` of this in `chrome://tracing` / Perfetto directly.
pub fn export_last(n: usize) -> Json {
    let events: Vec<Json> = snapshot_last(n)
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::str(s.name_str())),
                ("cat", Json::str("cwmix")),
                ("ph", Json::str("X")),
                ("ts", Json::num(s.start_us as f64)),
                ("dur", Json::num(s.dur_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.tid as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("req", Json::num(s.id as f64)),
                        ("arg", Json::num(s.arg as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write the newest `n` spans to `path` as chrome://tracing JSON
/// (`cwmix serve --trace-out`).
pub fn write_chrome_trace(path: &std::path::Path, n: usize) -> std::io::Result<()> {
    std::fs::write(path, export_last(n).dumps())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Tracing state is process-global; serialize the tests that flip
    /// it so `cargo test`'s threads cannot race each other's setup.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static M: Mutex<()> = Mutex::new(());
        M.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn spans_for(id: u64) -> Vec<Span> {
        snapshot_last(SHARDS * RING_SPANS).into_iter().filter(|s| s.id == id).collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = lock();
        set_enabled(false);
        let before = recorded();
        assert!(span(SpanName::Request, 0xD15A_B1ED).is_none());
        record_since(SpanName::QueueWait, 0xD15A_B1ED, 0, Instant::now());
        record_span(SpanName::Node, 0xD15A_B1ED, 3, 1, 2);
        assert_eq!(recorded(), before, "disabled sites must not publish");
        assert!(spans_for(0xD15A_B1ED).is_empty());
    }

    #[test]
    fn disabled_site_is_near_free() {
        let _g = lock();
        set_enabled(false);
        let t0 = Instant::now();
        for i in 0..1_000_000u64 {
            // the branch the hot paths pay per span site
            if let Some(_s) = span(SpanName::Node, i) {
                unreachable!("tracing is disabled");
            }
        }
        let per_site = t0.elapsed().as_nanos() / 1_000_000;
        // generous CI bound: a relaxed load + branch is single-digit ns
        assert!(per_site < 500, "disabled span site took {per_site} ns");
    }

    #[test]
    fn enabled_records_and_exports_chrome_json() {
        let _g = lock();
        set_enabled(true);
        let id = 0xE0_0001;
        {
            let _s = span_arg(SpanName::Request, id, 7).expect("enabled");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        record_since(SpanName::QueueWait, id, 0, Instant::now());
        set_enabled(false);
        let got = spans_for(id);
        assert_eq!(got.len(), 2, "both spans published");
        let req = got.iter().find(|s| s.name_str() == "request").unwrap();
        assert!(req.dur_us >= 1_000, "slept 1ms inside the span");
        assert_eq!(req.arg, 7);

        let doc = export_last(16).dumps();
        let parsed = crate::minijson::parse_bytes(doc.as_bytes()).expect("valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        for ev in events {
            assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X");
            assert!(ev.get("ts").unwrap().as_f64().is_ok());
            assert!(ev.get("dur").unwrap().as_f64().is_ok());
            assert!(SPAN_NAMES.contains(&ev.get("name").unwrap().as_str().unwrap()));
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_bounds_memory() {
        let _g = lock();
        set_enabled(true);
        let before = recorded();
        for i in 0..(RING_SPANS as u64 + 64) {
            record_span(SpanName::Node, 0xF10_0D00 + i, 0, i, i + 1);
        }
        set_enabled(false);
        assert_eq!(recorded() - before, RING_SPANS as u64 + 64);
        // this thread's shard holds at most RING_SPANS of them
        let mine: Vec<Span> = snapshot_last(SHARDS * RING_SPANS)
            .into_iter()
            .filter(|s| s.id >= 0xF10_0D00)
            .collect();
        assert!(mine.len() <= RING_SPANS);
        // the newest span always survives a wrap
        assert!(mine.iter().any(|s| s.id == 0xF10_0D00 + RING_SPANS as u64 + 63));
    }

    #[test]
    fn export_last_truncates_to_newest() {
        let _g = lock();
        set_enabled(true);
        for i in 0..32u64 {
            record_span(SpanName::Node, 0xCAFE, 0, 1_000_000 + i, 1_000_001 + i);
        }
        set_enabled(false);
        let doc = export_last(4);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
    }

    #[test]
    fn request_ids_are_unique_and_monotonic() {
        let a = next_request_id();
        let b = next_request_id();
        assert!(b > a);
    }
}
