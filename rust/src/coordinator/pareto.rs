//! Pareto-front extraction for the Fig. 3 trade-off plots.
//!
//! Points are `(cost, score)`; lower cost and higher score are better.
//! The paper plots *all* searched models and highlights the front; we
//! return the front indices so reports can do the same.

/// Indices of non-dominated points (sorted by increasing cost).
///
/// Point i dominates j iff `cost_i <= cost_j` and `score_i >= score_j`
/// with at least one strict inequality.
pub fn pareto_front(points: &[(f64, f32)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[b].1.partial_cmp(&points[a].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_score = f32::NEG_INFINITY;
    for &i in &idx {
        if points[i].1 > best_score {
            front.push(i);
            best_score = points[i].1;
        }
    }
    front
}

/// True iff point `a` dominates point `b`.
pub fn dominates(a: (f64, f32), b: (f64, f32)) -> bool {
    a.0 <= b.0 && a.1 >= b.1 && (a.0 < b.0 || a.1 > b.1)
}

/// Iso-accuracy cost saving of front `ours` vs front `base`: the largest
/// relative cost reduction at (approximately) equal-or-better score —
/// the paper's "up to X% at iso-accuracy" headline numbers.
///
/// For each point in `base`, find the cheapest point of `ours` whose
/// score is >= (base score - tol); report the max relative saving.
pub fn iso_score_saving(
    ours: &[(f64, f32)],
    base: &[(f64, f32)],
    tol: f32,
) -> Option<f64> {
    let mut best: Option<f64> = None;
    for &(bc, bs) in base {
        let candidate = ours
            .iter()
            .filter(|&&(_, s)| s >= bs - tol)
            .map(|&(c, _)| c)
            .fold(f64::INFINITY, f64::min);
        if candidate.is_finite() && candidate < bc {
            let saving = 1.0 - candidate / bc;
            best = Some(best.map_or(saving, |b: f64| b.max(saving)));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn simple_front() {
        let pts = vec![(1.0, 0.5), (2.0, 0.7), (3.0, 0.6), (4.0, 0.9)];
        assert_eq!(pareto_front(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn duplicate_costs_keep_best_score() {
        let pts = vec![(1.0, 0.5), (1.0, 0.8), (2.0, 0.6)];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![1]);
    }

    #[test]
    fn front_invariants_randomized() {
        // property: every non-front point is dominated by some front point;
        // no front point dominates another.
        let mut rng = Pcg32::seeded(17);
        for _ in 0..50 {
            let n = 2 + rng.below(40) as usize;
            let pts: Vec<(f64, f32)> = (0..n)
                .map(|_| (rng.uniform() as f64, rng.uniform()))
                .collect();
            let front = pareto_front(&pts);
            assert!(!front.is_empty());
            for (k, &i) in front.iter().enumerate() {
                for &j in front.iter().skip(k + 1) {
                    assert!(!dominates(pts[i], pts[j]), "{i} dominates {j}");
                    assert!(!dominates(pts[j], pts[i]), "{j} dominates {i}");
                }
            }
            for j in 0..n {
                if front.contains(&j) {
                    continue;
                }
                assert!(
                    front.iter().any(|&i| dominates(pts[i], pts[j])
                        || (pts[i].0 == pts[j].0 && pts[i].1 == pts[j].1)),
                    "point {j} neither on front nor dominated"
                );
            }
        }
    }

    #[test]
    fn iso_saving_basic() {
        let ours = vec![(1.0, 0.8), (0.5, 0.6)];
        let base = vec![(2.0, 0.8)];
        let s = iso_score_saving(&ours, &base, 0.0).unwrap();
        assert!((s - 0.5).abs() < 1e-9);
    }

    #[test]
    fn iso_saving_none_when_worse() {
        let ours = vec![(3.0, 0.7)];
        let base = vec![(2.0, 0.8)];
        assert!(iso_score_saving(&ours, &base, 0.0).is_none());
    }
}
