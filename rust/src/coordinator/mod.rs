//! Experiment coordination: λ sweeps, Pareto fronts, result stores, CLI.
//!
//! One process drives a whole Fig. 3 panel: shared warmup → λ-grid of
//! channel-wise searches → λ-grid of EdMIPS searches → fixed-precision
//! grid → Pareto extraction → JSON result store + report.
//!
//! Note on parallelism: the `xla` crate's `PjRtClient` is `Rc`-backed
//! (not `Send`), so one process = one runtime = sequential searches; the
//! Makefile-level `bench` targets run benchmarks as separate processes
//! for coarse parallelism.

pub mod cli;
pub mod pareto;
pub mod results;
pub mod sweep;

pub use pareto::pareto_front;
pub use sweep::{run_sweep, SweepOutput};
