//! Experiment coordination: λ sweeps, Pareto fronts, result stores, CLI.
//!
//! One process drives a whole Fig. 3 panel: shared warmup → λ-grid of
//! channel-wise searches → λ-grid of EdMIPS searches → fixed-precision
//! grid → Pareto extraction → JSON result store + report.
//!
//! Note on parallelism: the `xla` crate's `PjRtClient` is `Rc`-backed
//! (not `Send`), so sweep parallelism is organised as one runtime per
//! worker thread (see `sweep::run_sweep`); the Makefile-level `bench`
//! targets additionally run benchmarks as separate processes.

pub mod cli;
pub mod pareto;
pub mod results;
pub mod sweep;

pub use pareto::pareto_front;
#[cfg(feature = "xla")]
pub use sweep::run_sweep;
pub use sweep::SweepOutput;
