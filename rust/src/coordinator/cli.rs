//! Hand-rolled CLI (clap is not in the offline crate set).
//!
//! ```text
//! cwmix sweep    --bench ic --target energy [--quick] [--strengths 0.1,1] [--out results]
//! cwmix search   --bench ic --mode cw --target size --strength 1.0 [--quick]
//! cwmix baseline --bench ic --wbits 4 --xbits 8 [--quick]
//! cwmix deploy   --bench ic [--quick]           # train, deploy, verify, simulate
//! cwmix simulate --bench ic --wbits 8 --xbits 8 # MPIC cost model, no training
//! cwmix compile  --out modelpacks [--benches ic,kws]  # emit .cwm artifacts
//! cwmix inspect  --pack modelpacks/ic.cwm       # header + size accounting
//! cwmix profile  [--bench ic] [--iters 30]      # measured vs predicted per layer
//! cwmix serve    --benches ic,kws [--addr 127.0.0.1:8080]
//!                [--modelpack-dir modelpacks]   # resident server, cold start
//! cwmix report   [--dir results]                # Fig.3 panels + Fig.4 dump
//! cwmix lut                                     # print the C(px,pw) tables
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

#[cfg(feature = "xla")]
use crate::baselines;
use crate::coordinator::results;
#[cfg(feature = "xla")]
use crate::coordinator::sweep::{run_sweep, DEFAULT_STRENGTHS};
use crate::data::{make_dataset, Split};
use crate::deploy;
use crate::energy::CostLut;
use crate::engine;
use crate::models::{zoo, Manifest};
use crate::nas::{Mode, Target};
#[cfg(feature = "xla")]
use crate::nas::{SearchConfig, Trainer};
use crate::quant::Assignment;
use crate::report;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;

/// Parse `--key value` and bare flags into a map.
pub fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            bail!("unexpected argument {a:?}");
        }
    }
    Ok(out)
}

fn target_of(s: &str) -> Result<Target> {
    match s {
        "size" => Ok(Target::Size),
        "energy" => Ok(Target::Energy),
        other => bail!("unknown target {other} (size|energy)"),
    }
}

// only the xla-gated `search` command consumes modes at runtime, but the
// parser stays available (and unit-tested) on every feature set
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn mode_of(s: &str) -> Result<Mode> {
    match s {
        "cw" | "ours" => Ok(Mode::ChannelWise),
        "lw" | "edmips" => Ok(Mode::LayerWise),
        other => bail!("unknown mode {other} (cw|lw)"),
    }
}

fn artifacts_dir(flags: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(
        flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".to_string()),
    )
}

const HELP: &str = "\
cwmix — channel-wise mixed-precision DNAS (Risso et al., IGSC 2022)

USAGE: cwmix <command> [--flags]

COMMANDS
  sweep    --bench <ic|kws|vww|ad> --target <size|energy>
           [--quick] [--strengths 0.1,1,3] [--out results] [--artifacts artifacts]
           Regenerate one Fig.3 panel (ours vs EdMIPS vs fixed).
  search   --bench B --mode <cw|lw> --target T --strength S [--quick]
           One Alg.1 run; prints the result + Fig.4-style dump.
  baseline --bench B --wbits N --xbits M [--quick]
           One fixed-precision wNxM QAT run.
  deploy   --bench B [--quick]
           Short search, §III-C transform, HLO-vs-simulator verification,
           MPIC cost breakdown.
  simulate --bench B [--wbits N] [--xbits M]
           [--backend packed|reference|simd]
           §III-C transform + engine cost model on a fixed assignment.
           Pure Rust: uses the builtin model zoo when artifacts/ is
           absent; no training, no xla feature needed.
  compile  [--benches ic,kws,vww,ad] [--out modelpacks]
           [--backend packed|reference|simd] [--assignment stripy|wNxM]
           [--seed 0] [--artifacts artifacts]
           Compile each model and emit a .cwm modelpack artifact per
           bench — the durable form of ExecPlan::compile (packed
           sub-byte weights, gather tables, folded epilogues, cost) —
           then reload and verify it executes bit-identically.
  inspect  --pack <file.cwm>
           Validate a modelpack and print its header, per-layer
           channel bit-width histogram and the packed-vs-int8-vs-f32
           size table; exits non-zero when the packed totals disagree
           with the cost model's Eq. (7) accounting.
  profile  [--bench <all|ic|kws|vww|ad>] [--backend packed|reference|simd]
           [--assignment stripy|wNxM] [--seed 0] [--iters 30] [--batch 8]
           [--json [-|FILE]] [--artifacts artifacts]
           Per-layer engine profiler: run the compiled plan under the
           measurement hooks and print, per layer, measured wall time
           vs the share the analytical MPIC cost model predicts, plus
           modeled bytes moved and a Spearman rank-agreement summary
           (how well Eq. 4/5 cycles rank the real hotspots).  --json
           emits the same numbers machine-readable (- = stdout).
  serve    [--benches ic,kws,vww,ad] [--addr 127.0.0.1:8080]
           [--backend packed|reference|simd] [--assignment stripy|wNxM]
           [--max-batch 8] [--max-wait-us 2000] [--queue-cap 256]
           [--threads N] [--infer-budget-us 30000000]
           [--artifacts artifacts] [--modelpack-dir DIR]
           [--breaker-k 3] [--breaker-cooldown-ms 1000]
           [--faults SPEC] [--faults-seed 0]
           [--trace] [--trace-out trace.json]
           Resident multi-model inference server: one ExecPlan per
           bench at startup — cold-loaded from DIR/<bench>.cwm when
           --modelpack-dir is given (falling back to compile on a
           missing or unusable pack) — micro-batches concurrent POST
           /v1/infer/<bench> requests, exposes GET /v1/models,
           GET /healthz, GET /readyz and GET /metrics; POST
           /admin/shutdown drains and exits cleanly.  Workers are
           supervised: an engine panic respawns the worker (bounded
           backoff); --breaker-k consecutive panics open a per-model
           circuit breaker (503 + Retry-After).  Every request gets a
           max_wait + infer-budget deadline (expired -> 504).
           --backend simd dispatches kernels to the best SIMD tier the
           CPU reports (avx512 > avx2 > swar; override via CWMIX_SIMD=
           off|avx2|avx512|auto); the tier is printed at startup and
           exported per model in /metrics.
           --faults arms deterministic failpoints for chaos testing
           (kind:model:trigger[:ms], see serve/faults.rs; also via
           CWMIX_FAULTS / CWMIX_FAULTS_SEED).  --trace (or
           CWMIX_TRACE=1) turns span recording on: every request gets
           admission/queue/batch-ride/engine spans keyed by its id,
           scraped live via GET /v1/trace?last=N; --trace-out also
           writes the chrome://tracing JSON on shutdown.  Pure Rust,
           builtin zoo.  --addr with port 0 picks a free port (printed
           on stdout).
  report   [--dir results]
           Render every stored sweep as a Fig.3 panel + headline savings.
  lut      Print the MPIC C(p_x, p_w) energy/latency tables.

sweep/search/baseline/deploy drive the PJRT training path and need a
build with `--features xla` plus `make artifacts`.
";

/// Top-level dispatch.
pub fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{HELP}");
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "lut" => cmd_lut(),
        "sweep" => cmd_sweep(&flags),
        "search" => cmd_search(&flags),
        "baseline" => cmd_baseline(&flags),
        "deploy" => cmd_deploy(&flags),
        "simulate" => cmd_simulate(&flags),
        "compile" => cmd_compile(&flags),
        "inspect" => cmd_inspect(&flags),
        "profile" => cmd_profile(&flags),
        "serve" => cmd_serve(&flags),
        "report" => cmd_report(&flags),
        other => bail!("unknown command {other}; try `cwmix help`"),
    }
}

fn req<'a>(flags: &'a HashMap<String, String>, key: &str) -> Result<&'a str> {
    flags
        .get(key)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("missing --{key}"))
}

/// Stub for runtime-dependent commands in a default (no-`xla`) build.
#[cfg(not(feature = "xla"))]
fn cmd_needs_xla(cmd: &str) -> Result<()> {
    bail!(
        "`cwmix {cmd}` drives the PJRT training path; rebuild with \
         `cargo build --release --features xla` (and run `make artifacts`)"
    )
}

#[cfg(not(feature = "xla"))]
fn cmd_sweep(_flags: &HashMap<String, String>) -> Result<()> {
    cmd_needs_xla("sweep")
}

#[cfg(not(feature = "xla"))]
fn cmd_search(_flags: &HashMap<String, String>) -> Result<()> {
    cmd_needs_xla("search")
}

#[cfg(not(feature = "xla"))]
fn cmd_baseline(_flags: &HashMap<String, String>) -> Result<()> {
    cmd_needs_xla("baseline")
}

#[cfg(not(feature = "xla"))]
fn cmd_deploy(_flags: &HashMap<String, String>) -> Result<()> {
    cmd_needs_xla("deploy")
}

fn cmd_lut() -> Result<()> {
    let lut = CostLut::default();
    println!("MPIC C(p_x, p_w) — energy pJ/MAC (rows p_x, cols p_w in 2/4/8):");
    for &px in &[2u32, 4, 8] {
        let row: Vec<String> = [2u32, 4, 8]
            .iter()
            .map(|&pw| format!("{:6.3}", lut.energy_pj(px, pw)))
            .collect();
        println!("  px={px}: {}", row.join(" "));
    }
    println!("cycles/MAC:");
    for &px in &[2u32, 4, 8] {
        let row: Vec<String> = [2u32, 4, 8]
            .iter()
            .map(|&pw| format!("{:6.4}", lut.cycles(px, pw)))
            .collect();
        println!("  px={px}: {}", row.join(" "));
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_sweep(flags: &HashMap<String, String>) -> Result<()> {
    let bench = req(flags, "bench")?;
    let target = target_of(req(flags, "target")?)?;
    let quick = flags.contains_key("quick");
    let strengths: Vec<f32> = match flags.get("strengths") {
        Some(s) => s
            .split(',')
            .map(|v| v.parse::<f32>().map_err(|e| anyhow!("bad strength: {e}")))
            .collect::<Result<Vec<_>>>()?,
        None => DEFAULT_STRENGTHS.to_vec(),
    };
    let out_dir = PathBuf::from(flags.get("out").cloned().unwrap_or_else(|| "results".into()));
    let rt = Runtime::cpu(&artifacts_dir(flags))?;
    println!("platform: {}", rt.platform());
    let mut log = |s: &str| println!("{s}");
    let sw = run_sweep(&rt, bench, target, &strengths, quick, &mut log)?;
    let path = results::save_sweep(
        &out_dir, bench, target.name(), &sw.ours, &sw.edmips, &sw.fixed)?;
    println!("saved {}", path.display());
    // render immediately
    let (b, t, o, e, f) = results::load_sweep(&path)?;
    println!("{}", report::fig3_panel(&b, target_of(&t)?, &o, &e, &f));
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_search(flags: &HashMap<String, String>) -> Result<()> {
    let bench = req(flags, "bench")?;
    let mode = mode_of(flags.get("mode").map(|s| s.as_str()).unwrap_or("cw"))?;
    let target = target_of(req(flags, "target")?)?;
    let strength: f32 = req(flags, "strength")?.parse()?;
    let quick = flags.contains_key("quick");
    let rt = Runtime::cpu(&artifacts_dir(flags))?;
    let mk = if quick { SearchConfig::quick } else { SearchConfig::new };
    let mut cfg = mk(bench, mode, target, 0.0);
    let tr0 = Trainer::new(&rt, cfg.clone())?;
    let (rs0, re0) = tr0.initial_regs()?;
    drop(tr0);
    cfg.lambda = strength / match target {
        Target::Size => rs0,
        Target::Energy => re0,
    };
    println!("lambda = {:.3e}", cfg.lambda);
    let mut tr = Trainer::new(&rt, cfg)?;
    let r = tr.run()?;
    for h in &r.history {
        println!(
            "  [{}] epoch {:>2} loss {:.4} val_loss {:.4} val_score {:.4} tau {:.2}",
            h.phase, h.epoch, h.train_loss, h.val_loss, h.val_score, h.tau
        );
    }
    println!(
        "{}: score {:.4}  size {:.3} Mbit  energy {:.2} uJ",
        r.config_label,
        r.test_score,
        r.size_mb(),
        r.energy_uj()
    );
    println!("{}", report::fig4_dump(&r.config_label, &r.assignment));
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_baseline(flags: &HashMap<String, String>) -> Result<()> {
    let bench = req(flags, "bench")?;
    let wbits: u32 = req(flags, "wbits")?.parse()?;
    let xbits: u32 = req(flags, "xbits")?.parse()?;
    let quick = flags.contains_key("quick");
    let rt = Runtime::cpu(&artifacts_dir(flags))?;
    let mk = if quick { SearchConfig::quick } else { SearchConfig::new };
    let cfg = mk(bench, Mode::ChannelWise, Target::Size, 0.0);
    let warm = baselines::shared_warmup(&rt, &cfg)?;
    let r = baselines::run_fixed(&rt, &cfg, &warm, wbits, xbits)?;
    println!(
        "{}: score {:.4}  size {:.3} Mbit  energy {:.2} uJ",
        r.config_label,
        r.test_score,
        r.size_mb(),
        r.energy_uj()
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_deploy(flags: &HashMap<String, String>) -> Result<()> {
    let bench = req(flags, "bench")?;
    let rt = Runtime::cpu(&artifacts_dir(flags))?;
    let mut cfg = SearchConfig::quick(bench, Mode::ChannelWise, Target::Energy, 0.0);
    if !flags.contains_key("quick") {
        cfg.warmup_epochs = 4;
    }
    // short warmup + a mixed assignment from a brief search
    let tr0 = Trainer::new(&rt, cfg.clone())?;
    let (_, re0) = tr0.initial_regs()?;
    drop(tr0);
    cfg.lambda = 0.3 / re0;
    let mut tr = Trainer::new(&rt, cfg)?;
    let r = tr.run()?;
    println!("searched assignment:");
    println!("{}", report::fig4_dump(&r.config_label, &r.assignment));

    let ds = make_dataset(bench, Split::Test, 64, 0);
    let rep = deploy::verify::verify_against_hlo(&tr, &r.assignment, &ds, 1)?;
    println!(
        "verify vs HLO infer: n={} max|d|={:.3e} mean|d|={:.3e} argmax agreement {:.1}%",
        rep.n_samples,
        rep.max_abs_diff,
        rep.mean_abs_diff,
        rep.argmax_agreement * 100.0
    );

    let deployed = deploy::build(&tr.manifest, &tr.params_map(), &tr.bn_map(), &r.assignment)?;
    // hold the compiled plan directly — no per-call re-planning
    let plan = engine::ExecPlan::compile(&deployed, &tr.manifest.lut, &engine::PackedBackend)?;
    let feat = tr.manifest.feat_len();
    let (_, cost) = plan.run_batch(&ds.x[0..feat], feat)?;
    println!(
        "MPIC: {} sub-convs, {} packed weight bytes",
        deployed.n_subconvs(),
        deployed.packed_bytes()
    );
    println!(
        "MPIC per-inference: {:.0} cycles = {:.1} us @250MHz, {:.2} uJ total ({:.2} uJ MAC)",
        cost.total_cycles(),
        cost.latency_us(),
        cost.total_energy_uj(),
        cost.mac_energy_pj() * 1e-6
    );
    for lc in &cost.layers {
        println!(
            "   {:<10} cycles {:>10.0}  E {:>8.2} nJ  groups {:?}",
            lc.name,
            lc.total_cycles(),
            lc.total_energy_pj() * 1e-3,
            lc.macs_by_group.iter().map(|&(b, _)| b).collect::<Vec<_>>()
        );
    }
    Ok(())
}

/// Pure-Rust simulation: builtin zoo (or the artifacts manifest when
/// present), synthetic He-initialised weights, §III-C transform, engine
/// plan + cost model.  Runs on the default feature set.
fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let bench = req(flags, "bench")?;
    let wbits: u32 = flags.get("wbits").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let xbits: u32 = flags.get("xbits").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let backend = engine::backend_by_name(
        flags.get("backend").map(|s| s.as_str()).unwrap_or("packed"),
    )?;
    let art = artifacts_dir(flags);
    let manifest = if art.join(bench).join("manifest.json").exists() {
        Manifest::load(&art, bench)?
    } else {
        zoo::builtin_manifest(bench)?
    };
    let (params, bn) = zoo::synthetic_state(&manifest, 0);
    let a = Assignment::fixed(&manifest.qnames(), &manifest.qcouts(), wbits, xbits);
    let deployed = deploy::build(&manifest, &params, &bn, &a)?;
    let plan = engine::ExecPlan::compile(&deployed, &manifest.lut, backend)?;
    let ds = make_dataset(bench, Split::Test, 4, 0);
    let feat = manifest.feat_len();
    let (_, cost) = plan.run_batch(&ds.x[0..feat], feat)?;
    println!(
        "{bench} w{wbits}x{xbits} [{}]: {:.0} MACs, {:.1} us, {:.2} uJ, \
         {} bytes packed, {} sub-convs",
        plan.backend_name(),
        cost.total_macs() as f64,
        cost.latency_us(),
        cost.total_energy_uj(),
        deployed.packed_bytes(),
        deployed.n_subconvs(),
    );
    Ok(())
}

/// Compile models and emit durable `.cwm` modelpack artifacts — the
/// on-disk witness of the paper's packed-size claim (every server
/// start before this recompiled from raw f32 state).  Each artifact is
/// immediately reloaded and probed bit-identical before it is kept.
fn cmd_compile(flags: &HashMap<String, String>) -> Result<()> {
    // the SAME construction path the serve registry's fallback uses, so
    // a pack and the plan the server would compile cannot drift apart
    use crate::serve::registry::{build_model, verify_pack_roundtrip};

    let benches: Vec<String> = match flags.get("benches") {
        Some(b) => b.split(',').map(|s| s.trim().to_string()).collect(),
        None => zoo::BENCHES.iter().map(|b| b.to_string()).collect(),
    };
    let out_dir =
        PathBuf::from(flags.get("out").cloned().unwrap_or_else(|| "modelpacks".into()));
    let backend = engine::backend_by_name(
        flags.get("backend").map(|s| s.as_str()).unwrap_or("packed"),
    )?;
    let spec = flags.get("assignment").map(|s| s.as_str()).unwrap_or("stripy");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let art = artifacts_dir(flags);
    std::fs::create_dir_all(&out_dir)
        .map_err(|e| anyhow!("creating {}: {e}", out_dir.display()))?;
    for bench in &benches {
        let (_, deployed, plan) = build_model(bench, backend, spec, seed, &art)?;
        // provenance rides the pack so `serve --modelpack-dir` can
        // refuse an artifact built under different construction flags
        let prov = engine::Provenance { assignment: spec.to_string(), seed };
        let pack = plan.to_modelpack_with(Some(&prov));

        // an artifact is only kept if it executes bit-identically to
        // the plan it was serialized from
        verify_pack_roundtrip(&plan, &pack, bench)?;

        let path = out_dir.join(format!("{bench}.cwm"));
        std::fs::write(&path, &pack)
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        let f32_bytes: usize = deployed.qlayers().map(|l| l.qweights.len() * 4).sum();
        println!(
            "{bench:<4} -> {} [{}]: pack {} B, packed weights {} B \
             ({:.1}% of f32 {} B), load-verified bit-identical",
            path.display(),
            plan.backend_name(),
            pack.len(),
            deployed.packed_bytes(),
            deployed.packed_bytes() as f64 / f32_bytes.max(1) as f64 * 100.0,
            f32_bytes,
        );
    }
    Ok(())
}

/// Validate a `.cwm` and print the artifact-level memory comparison
/// (the paper's Fig. 3 memory axis, per layer and in total).
fn cmd_inspect(flags: &HashMap<String, String>) -> Result<()> {
    let path = PathBuf::from(req(flags, "pack")?);
    let bytes =
        std::fs::read(&path).map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let rep = engine::inspect(&bytes)?;
    let sections: Vec<String> = rep
        .sections
        .iter()
        .map(|&(kind, len)| format!("{kind}:{len}B"))
        .collect();
    println!(
        "{}: modelpack v{}.{}, {} B, sections [{}]",
        path.display(),
        rep.version.0,
        rep.version.1,
        rep.file_bytes,
        sections.join(", "),
    );
    println!(
        "bench {} / backend {} (kernel tier {} on this host) — {} plan \
         nodes, {} quantized layers, {} B resident kernel weights",
        rep.bench,
        rep.backend,
        rep.kernel_tier,
        rep.n_nodes,
        rep.layers.len(),
        rep.kernel_weight_bytes,
    );
    match &rep.provenance {
        Some(p) => println!("provenance: assignment {:?}, seed {}", p.assignment, p.seed),
        None => println!("provenance: (not recorded)"),
    }
    println!(
        "{:<10} {:<6} {:>5} {:>6} {:>3} {:>6} {:>6} {:>6} {:>10} {:>10} {:>10} {:<7}",
        "layer", "kind", "cout", "K", "px", "ch@2", "ch@4", "ch@8", "packed B",
        "int8 B", "f32 B", "fused"
    );
    for l in &rep.layers {
        // per-layer fusion coverage: `in` = input plane coded by an
        // earlier node, `out` = exit codes a consumer plane, `out!` =
        // same with the f32 slot write elided entirely
        let mut tags: Vec<&str> = Vec::new();
        if l.plane_reused {
            tags.push("in");
        }
        if l.fused_out {
            tags.push(if l.f32_elided { "out!" } else { "out" });
        }
        let fused = if tags.is_empty() { "-".to_string() } else { tags.join(",") };
        println!(
            "{:<10} {:<6} {:>5} {:>6} {:>3} {:>6} {:>6} {:>6} {:>10} {:>10} {:>10} {:<7}",
            l.name,
            l.kind,
            l.cout,
            l.k,
            l.act_bits,
            l.channels_at[0],
            l.channels_at[1],
            l.channels_at[2],
            l.packed_bytes,
            l.int8_bytes,
            l.f32_bytes,
            fused,
        );
    }
    let (packed, int8, f32b) = (rep.packed_total(), rep.int8_total(), rep.f32_total());
    println!(
        "TOTAL packed {packed} B | int8 {int8} B | f32 {f32b} B  \
         (packed = {:.1}% of f32, {:.1}% of int8)",
        packed as f64 / f32b.max(1) as f64 * 100.0,
        packed as f64 / int8.max(1) as f64 * 100.0,
    );
    let f = &rep.fusion;
    println!(
        "fused requantize: {}/{} edges ({:.0}% coverage), {} f32 slots elided, \
         {} residual plane reuse hits, {} plane slots, \
         activation bytes/sample {} -> {} on fused edges",
        f.fused_edges,
        f.total_edges,
        f.fused_ratio() * 100.0,
        f.elided_f32,
        f.reuse_hits,
        rep.plane_slots,
        f.act_bytes_unfused,
        f.act_bytes_fused,
    );
    println!(
        "cost-model packed bytes (Eq. 7): {} — {}",
        rep.cost_model_packed_bytes,
        if rep.matches_cost_model() { "match" } else { "MISMATCH" },
    );
    if !rep.matches_cost_model() {
        bail!(
            "packed totals ({packed} B) disagree with the mpic::cost accounting ({} B)",
            rep.cost_model_packed_bytes
        );
    }
    Ok(())
}

/// Per-layer engine profiler (DESIGN.md §9): runs the compiled plan
/// under the `run_batch_planes_profiled` hooks and reports measured
/// per-node wall time against the share the analytical MPIC cost model
/// predicts — the empirical check that Eq. 4/5 cycles rank the real
/// hotspots on this host.
fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    use crate::minijson::Json;
    use crate::serve::registry::build_model;
    use crate::util::stats::spearman;
    use std::time::Instant;

    let benches: Vec<String> = match flags.get("bench").map(|s| s.as_str()) {
        None | Some("all") => zoo::BENCHES.iter().map(|b| b.to_string()).collect(),
        Some(b) => vec![b.to_string()],
    };
    let backend = engine::backend_by_name(
        flags.get("backend").map(|s| s.as_str()).unwrap_or("packed"),
    )?;
    let spec = flags.get("assignment").map(|s| s.as_str()).unwrap_or("stripy");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let iters: usize =
        flags.get("iters").map(|s| s.parse()).transpose()?.unwrap_or(30).max(1);
    let batch: usize = flags
        .get("batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8)
        .clamp(1, engine::MAX_BATCH_CHUNK);
    let json_to = flags.get("json").map(|s| s.as_str());
    let art = artifacts_dir(flags);

    let mut bench_docs: Vec<Json> = Vec::new();
    for bench in &benches {
        let (_, _, plan) = build_model(bench, backend, spec, seed, &art)?;
        let cost = plan.cost();
        let feat = plan.feat();
        let ds = make_dataset(bench, Split::Test, batch, seed);
        let samples: Vec<&[f32]> = ds.x.chunks(feat).take(batch).collect();
        let mut arena = plan.batch_arena(batch);
        let mut prof = plan.profile();
        // one unprofiled warmup pass: page in weights, touch the arena
        plan.run_batch_planes(&mut arena, &samples)?;
        let t0 = Instant::now();
        for _ in 0..iters {
            plan.run_batch_planes_profiled(&mut arena, &samples, &mut prof)?;
        }
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        let pass_ms = prof.wall_ns as f64 / 1e6;
        let sum_node_ms = prof.node_wall_ns() as f64 / 1e6;

        // measured vs predicted shares over the accounted nodes; the
        // rank fit deliberately compares *shares*, so clock speed and
        // batch amortisation cancel out of the agreement score
        let node_total_ns = prof.node_wall_ns().max(1) as f64;
        let cycles_total = cost.total_cycles().max(1e-9);
        let mut measured: Vec<f64> = Vec::new();
        let mut predicted: Vec<f64> = Vec::new();
        let mut layer_docs: Vec<Json> = Vec::new();
        if json_to.is_none() {
            println!(
                "== {bench} [{}] batch={batch} iters={iters} ==",
                plan.backend_name()
            );
            println!(
                "{:<10} {:<7} {:>9} {:>8} {:>8} {:>7} {:>10}",
                "layer", "kind", "ms", "share", "pred", "ratio", "KB moved"
            );
        }
        for node in &prof.nodes {
            let Some(ix) = node.cost_ix else { continue };
            let ms = node.wall_ns() as f64 / 1e6;
            let share = node.wall_ns() as f64 / node_total_ns;
            let pred = cost.layers[ix].total_cycles() / cycles_total;
            let ratio = if pred > 0.0 { share / pred } else { 0.0 };
            measured.push(node.wall_ns() as f64);
            predicted.push(cost.layers[ix].total_cycles());
            if json_to.is_none() {
                println!(
                    "{:<10} {:<7} {:>9.3} {:>8.3} {:>8.3} {:>7.2} {:>10.1}",
                    node.name,
                    node.kind,
                    ms,
                    share,
                    pred,
                    ratio,
                    node.bytes_moved as f64 / 1e3,
                );
            }
            layer_docs.push(Json::obj(vec![
                ("name", Json::str(&node.name)),
                ("kind", Json::str(node.kind)),
                ("cost_ix", Json::num(ix as f64)),
                ("calls", Json::num(node.calls as f64)),
                ("ms", Json::num(ms)),
                ("share", Json::num(share)),
                ("predicted_share", Json::num(pred)),
                ("ratio", Json::num(ratio)),
                ("bytes_moved", Json::num(node.bytes_moved as f64)),
            ]));
        }
        let fit = spearman(&measured, &predicted);
        if json_to.is_none() {
            println!(
                "coverage: nodes {sum_node_ms:.3} ms / pass {pass_ms:.3} ms / \
                 e2e {total_ms:.3} ms ({:.1}% of e2e attributed)",
                sum_node_ms / total_ms.max(1e-9) * 100.0,
            );
            println!(
                "fit: spearman={fit:.3} over {} layers (predicted {:.1} us/inf)",
                measured.len(),
                cost.latency_us(),
            );
            println!();
        }
        bench_docs.push(Json::obj(vec![
            ("bench", Json::str(bench)),
            ("backend", Json::str(plan.backend_name())),
            ("batch", Json::num(batch as f64)),
            ("iters", Json::num(iters as f64)),
            ("batches", Json::num(prof.batches as f64)),
            ("samples", Json::num(prof.samples as f64)),
            ("total_ms", Json::num(total_ms)),
            ("pass_ms", Json::num(pass_ms)),
            ("sum_node_ms", Json::num(sum_node_ms)),
            ("spearman", Json::num(fit)),
            ("layers", Json::Arr(layer_docs)),
        ]));
    }
    if let Some(dst) = json_to {
        let doc = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("benches", Json::Arr(bench_docs)),
        ]);
        let text = doc.dumps();
        if dst == "-" || dst == "true" {
            println!("{text}");
        } else {
            std::fs::write(dst, &text).map_err(|e| anyhow!("writing {dst}: {e}"))?;
            println!("wrote {dst}");
        }
    }
    Ok(())
}

/// Resident multi-model inference server (pure Rust, builtin zoo).
/// Blocks until `POST /admin/shutdown`, then drains and exits cleanly.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    use crate::serve::{
        self, BatchPolicy, Faults, ModelRegistry, RegistryConfig, ServeConfig,
    };
    use std::sync::Arc;

    let mut policy = BatchPolicy::default();
    if let Some(v) = flags.get("max-batch") {
        policy.max_batch = v.parse().map_err(|e| anyhow!("bad --max-batch: {e}"))?;
    }
    if let Some(v) = flags.get("max-wait-us") {
        policy.max_wait_us = v.parse().map_err(|e| anyhow!("bad --max-wait-us: {e}"))?;
    }
    if let Some(v) = flags.get("queue-cap") {
        policy.queue_cap = v.parse().map_err(|e| anyhow!("bad --queue-cap: {e}"))?;
    }
    if let Some(v) = flags.get("threads") {
        policy.threads = v.parse().map_err(|e| anyhow!("bad --threads: {e}"))?;
    }
    if let Some(v) = flags.get("infer-budget-us") {
        policy.infer_budget_us =
            v.parse().map_err(|e| anyhow!("bad --infer-budget-us: {e}"))?;
    }
    // fault plan: the --faults flag wins over CWMIX_FAULTS
    let faults = match flags.get("faults") {
        Some(spec) => {
            let seed = match flags.get("faults-seed") {
                Some(s) => s.parse().map_err(|e| anyhow!("bad --faults-seed: {e}"))?,
                None => 0,
            };
            Arc::new(Faults::parse(spec, seed).map_err(|e| anyhow!("bad --faults: {e:#}"))?)
        }
        None => Faults::from_env()?,
    };
    if faults.armed() {
        println!("fault plan armed: {}", faults.describe());
    }
    // span recording: --trace / --trace-out win over CWMIX_TRACE=1
    let trace_out = flags.get("trace-out").map(PathBuf::from);
    if flags.contains_key("trace")
        || trace_out.is_some()
        || std::env::var("CWMIX_TRACE").map(|v| v == "1").unwrap_or(false)
    {
        crate::trace::set_enabled(true);
        println!("tracing enabled (GET /v1/trace?last=N)");
    }
    let mut reg_cfg = RegistryConfig {
        artifacts: artifacts_dir(flags),
        policy,
        faults: Arc::clone(&faults),
        ..RegistryConfig::default()
    };
    if let Some(v) = flags.get("breaker-k") {
        reg_cfg.supervisor.breaker_k =
            v.parse().map_err(|e| anyhow!("bad --breaker-k: {e}"))?;
    }
    if let Some(v) = flags.get("breaker-cooldown-ms") {
        reg_cfg.supervisor.cooldown_ms =
            v.parse().map_err(|e| anyhow!("bad --breaker-cooldown-ms: {e}"))?;
    }
    if let Some(b) = flags.get("benches") {
        reg_cfg.benches = b.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(b) = flags.get("backend") {
        reg_cfg.backend = b.clone();
    }
    if let Some(a) = flags.get("assignment") {
        reg_cfg.assignment = a.clone();
    }
    if let Some(d) = flags.get("modelpack-dir") {
        reg_cfg.modelpack_dir = Some(PathBuf::from(d));
    }
    let registry = Arc::new(ModelRegistry::build(&reg_cfg)?);
    for e in registry.entries() {
        let cost = e.plan().cost();
        let s = e.startup();
        println!(
            "model {:<4} backend {:<9} tier {:<6} feat {:>5} out {:>4} \
             est {:.1} us/inf ({} in {} us)",
            e.name(),
            e.plan().backend_name(),
            e.plan().kernel_tier(),
            e.plan().feat(),
            e.plan().out_len(),
            cost.latency_us(),
            s.source,
            s.micros,
        );
    }

    let mut cfg = ServeConfig { faults, ..ServeConfig::default() };
    if let Some(a) = flags.get("addr") {
        cfg.addr = a.clone();
    }
    let server = serve::serve(registry, cfg)?;
    // machine-parseable: the smoke harness greps this line for the port
    println!("listening on {}", server.addr());
    let joined = server.join();
    if let Some(path) = trace_out {
        crate::trace::write_chrome_trace(&path, usize::MAX)
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!(
            "wrote {} ({} spans recorded)",
            path.display(),
            crate::trace::recorded()
        );
    }
    joined
}

fn cmd_report(flags: &HashMap<String, String>) -> Result<()> {
    let dir = PathBuf::from(flags.get("dir").cloned().unwrap_or_else(|| "results".into()));
    let mut found = 0;
    let entries = std::fs::read_dir(&dir)
        .map_err(|e| anyhow!("reading {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    paths.sort();
    for p in paths {
        let (b, t, o, e, f) = results::load_sweep(&p)?;
        println!("{}", report::fig3_panel(&b, target_of(&t)?, &o, &e, &f));
        // Fig. 4 dump for the best 'ours' point
        if let Some(best) = o.iter().max_by(|a, b| {
            a.test_score.partial_cmp(&b.test_score).unwrap()
        }) {
            println!("{}", report::fig4_dump(&best.label, &best.assignment));
        }
        found += 1;
    }
    if found == 0 {
        println!("no sweep results in {} — run `cwmix sweep` first", dir.display());
    }
    Ok(())
}

/// Shared helper for examples/benches: artifacts dir fallback.
pub fn default_artifacts() -> &'static Path {
    Path::new("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parsing() {
        let args: Vec<String> = ["--bench", "ic", "--quick", "--target", "size"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = parse_flags(&args).unwrap();
        assert_eq!(f["bench"], "ic");
        assert_eq!(f["quick"], "true");
        assert_eq!(f["target"], "size");
    }

    #[test]
    fn rejects_positional() {
        let args = vec!["oops".to_string()];
        assert!(parse_flags(&args).is_err());
    }

    #[test]
    fn target_mode_parsing() {
        assert_eq!(target_of("size").unwrap(), Target::Size);
        assert_eq!(mode_of("edmips").unwrap(), Mode::LayerWise);
        assert!(target_of("latency").is_err());
    }
}
