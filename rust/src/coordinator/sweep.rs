//! λ-sweep driver: regenerates one Fig. 3 panel (one benchmark x one
//! regularizer target) end to end.

use anyhow::Result;

use crate::baselines;
use crate::nas::{Mode, SearchConfig, SearchResult, Target};
use crate::runtime::Runtime;

/// Relative λ grid: λ = strength / reg0 where reg0 is the 8-bit model's
/// regularizer value, so one grid works across benchmarks and targets
/// (the paper tunes λ per run; this is the reproducible equivalent).
pub const DEFAULT_STRENGTHS: [f32; 5] = [0.02, 0.08, 0.3, 1.0, 3.0];

/// Everything a Fig. 3 panel needs.
pub struct SweepOutput {
    pub bench: String,
    pub target: Target,
    pub ours: Vec<SearchResult>,
    pub edmips: Vec<SearchResult>,
    pub fixed: Vec<SearchResult>,
}

impl SweepOutput {
    /// (cost, score) series for Pareto analysis; cost = Mbit or µJ.
    pub fn points(results: &[SearchResult], target: Target) -> Vec<(f64, f32)> {
        results
            .iter()
            .map(|r| {
                let cost = match target {
                    Target::Size => r.size_mb(),
                    Target::Energy => r.energy_uj(),
                };
                (cost, r.test_score)
            })
            .collect()
    }
}

/// Run the full three-series sweep for one (bench, target) panel.
///
/// `strengths` are relative λ values (see [`DEFAULT_STRENGTHS`]);
/// `quick` shrinks every budget for smoke runs.
pub fn run_sweep(
    rt: &Runtime,
    bench: &str,
    target: Target,
    strengths: &[f32],
    quick: bool,
    log: &mut dyn FnMut(&str),
) -> Result<SweepOutput> {
    let mk = |mode: Mode, lambda: f32| {
        if quick {
            SearchConfig::quick(bench, mode, target, lambda)
        } else {
            SearchConfig::new(bench, mode, target, lambda)
        }
    };

    // shared warmup (Alg. 1: warmup once, reuse for every search)
    let base_cfg = mk(Mode::ChannelWise, 0.0);
    log(&format!("[{bench}/{}] warmup ({} epochs)", target.name(),
                 base_cfg.warmup_epochs));
    let warm = baselines::shared_warmup(rt, &base_cfg)?;

    // λ normalisation from the 8-bit regularizer magnitudes
    let tr = crate::nas::Trainer::new(rt, base_cfg.clone())?;
    let (reg_s0, reg_e0) = tr.initial_regs()?;
    let reg0 = match target {
        Target::Size => reg_s0,
        Target::Energy => reg_e0,
    };
    drop(tr);

    let mut ours = Vec::new();
    let mut edmips = Vec::new();
    for &s in strengths {
        let lambda = s / reg0;
        log(&format!("[{bench}/{}] ours: lambda = {s} / reg0 = {lambda:.3e}",
                     target.name()));
        ours.push(baselines::run_ours(rt, &mk(Mode::ChannelWise, lambda), &warm)?);
        log(&format!("[{bench}/{}] edmips: lambda = {lambda:.3e}", target.name()));
        edmips.push(baselines::run_edmips(rt, &mk(Mode::LayerWise, lambda), &warm)?);
    }

    let mut fixed = Vec::new();
    for (wb, xb) in baselines::fig3_fixed_combos(bench, target, quick) {
        log(&format!("[{bench}/{}] fixed w{wb}x{xb}", target.name()));
        fixed.push(baselines::run_fixed(rt, &base_cfg, &warm, wb, xb)?);
    }

    Ok(SweepOutput {
        bench: bench.to_string(),
        target,
        ours,
        edmips,
        fixed,
    })
}
