//! λ-sweep driver: regenerates one Fig. 3 panel (one benchmark x one
//! regularizer target) end to end.
//!
//! Independent λ points are embarrassingly parallel *after* the shared
//! warmup (Alg. 1 reuses one warmup for every search).  The PJRT client
//! is `Rc`-backed and not `Send`, so parallelism is organised as one
//! **runtime per worker thread**: each worker compiles its own graph set
//! and drains a round-robin share of the λ grid.  Set
//! `CWMIX_SWEEP_THREADS=1` to force the old sequential behaviour (or to
//! bound memory: each worker holds a full compiled graph set).

use crate::nas::{SearchResult, Target};

/// Relative λ grid: λ = strength / reg0 where reg0 is the 8-bit model's
/// regularizer value, so one grid works across benchmarks and targets
/// (the paper tunes λ per run; this is the reproducible equivalent).
pub const DEFAULT_STRENGTHS: [f32; 5] = [0.02, 0.08, 0.3, 1.0, 3.0];

/// Everything a Fig. 3 panel needs.
pub struct SweepOutput {
    pub bench: String,
    pub target: Target,
    pub ours: Vec<SearchResult>,
    pub edmips: Vec<SearchResult>,
    pub fixed: Vec<SearchResult>,
}

impl SweepOutput {
    /// (cost, score) series for Pareto analysis; cost = Mbit or µJ.
    pub fn points(results: &[SearchResult], target: Target) -> Vec<(f64, f32)> {
        results
            .iter()
            .map(|r| {
                let cost = match target {
                    Target::Size => r.size_mb(),
                    Target::Energy => r.energy_uj(),
                };
                (cost, r.test_score)
            })
            .collect()
    }
}

/// Worker count for a sweep over `n` independent jobs:
/// `CWMIX_SWEEP_THREADS` env override, else `min(n, cores)`.
pub fn sweep_threads(n: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    std::env::var("CWMIX_SWEEP_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(cores)
        .clamp(1, n.max(1))
}

#[cfg(feature = "xla")]
mod driver {
    use std::sync::Mutex;

    use anyhow::Result;

    use super::{sweep_threads, SweepOutput};
    use crate::baselines;
    use crate::nas::{Mode, SearchConfig, Target};
    use crate::runtime::Runtime;

    /// Progress sink shareable with worker threads.
    type Log<'l> = Mutex<&'l mut (dyn FnMut(&str) + Send)>;

    fn emit(log: &Log, msg: String) {
        (log.lock().unwrap())(&msg);
    }

    /// Run `jobs` across up to `threads` workers.  The PJRT client is
    /// not `Send`, so each *extra* worker owns its own runtime (and
    /// compiled-graph set); the sequential path reuses the caller's
    /// already-warm `rt`.  Results come back in the original job
    /// order; the first worker error aborts the sweep.
    fn par_runtime_map<J, R, F>(
        rt: &Runtime,
        jobs: Vec<J>,
        threads: usize,
        f: F,
    ) -> Result<Vec<R>>
    where
        J: Send,
        R: Send,
        F: Fn(&Runtime, J) -> Result<R> + Sync,
    {
        let n = jobs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if threads <= 1 || n == 1 {
            return jobs.into_iter().map(|j| f(rt, j)).collect();
        }
        let artifacts = rt.artifacts_dir();
        let threads = threads.min(n);
        let mut buckets: Vec<Vec<(usize, J)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, j) in jobs.into_iter().enumerate() {
            buckets[i % threads].push((i, j));
        }
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let collected: Vec<Result<Vec<(usize, R)>>> =
            std::thread::scope(|scope| {
                let f = &f;
                let handles: Vec<_> = buckets
                    .into_iter()
                    .map(|bucket| {
                        scope.spawn(move || -> Result<Vec<(usize, R)>> {
                            let rt = Runtime::cpu(artifacts)?;
                            bucket
                                .into_iter()
                                .map(|(i, j)| Ok((i, f(&rt, j)?)))
                                .collect()
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("sweep worker panicked"))
                    .collect()
            });
        for chunk in collected {
            for (i, r) in chunk? {
                out[i] = Some(r);
            }
        }
        Ok(out.into_iter().map(|o| o.expect("job lost")).collect())
    }

    /// Run the full three-series sweep for one (bench, target) panel.
    ///
    /// `strengths` are relative λ values (see
    /// [`super::DEFAULT_STRENGTHS`]); `quick` shrinks every budget for
    /// smoke runs.  Progress lines are emitted as each λ point /
    /// baseline starts and finishes, including from worker threads.
    pub fn run_sweep(
        rt: &Runtime,
        bench: &str,
        target: Target,
        strengths: &[f32],
        quick: bool,
        log: &mut (dyn FnMut(&str) + Send),
    ) -> Result<SweepOutput> {
        let mk = |mode: Mode, lambda: f32| {
            if quick {
                SearchConfig::quick(bench, mode, target, lambda)
            } else {
                SearchConfig::new(bench, mode, target, lambda)
            }
        };

        // shared warmup (Alg. 1: warmup once, reuse for every search)
        let base_cfg = mk(Mode::ChannelWise, 0.0);
        log(&format!(
            "[{bench}/{}] warmup ({} epochs)",
            target.name(),
            base_cfg.warmup_epochs
        ));
        let warm = baselines::shared_warmup(rt, &base_cfg)?;

        // λ normalisation from the 8-bit regularizer magnitudes
        let tr = crate::nas::Trainer::new(rt, base_cfg.clone())?;
        let (reg_s0, reg_e0) = tr.initial_regs()?;
        let reg0 = match target {
            Target::Size => reg_s0,
            Target::Energy => reg_e0,
        };
        drop(tr);

        let warm = &warm;
        let tname = target.name();

        // λ points: (ours, edmips) per strength, workers own runtimes
        let lam_jobs: Vec<(f32, f32)> = strengths.iter().map(|&s| (s, s / reg0)).collect();
        let threads = sweep_threads(lam_jobs.len());
        log(&format!(
            "[{bench}/{tname}] {} lambda points across {threads} worker(s)",
            lam_jobs.len(),
        ));
        let log_mx: Log = Mutex::new(log);
        let pairs = par_runtime_map(rt, lam_jobs, threads, |rt, (s, lambda)| {
            emit(
                &log_mx,
                format!("[{bench}/{tname}] lambda = {s} / reg0 = {lambda:.3e}"),
            );
            let ours = baselines::run_ours(rt, &mk(Mode::ChannelWise, lambda), warm)?;
            let ed = baselines::run_edmips(rt, &mk(Mode::LayerWise, lambda), warm)?;
            emit(
                &log_mx,
                format!(
                    "[{bench}/{tname}] lambda = {s} done: ours {:.4}, edmips {:.4}",
                    ours.test_score, ed.test_score
                ),
            );
            Ok((ours, ed))
        })?;
        let mut ours = Vec::with_capacity(pairs.len());
        let mut edmips = Vec::with_capacity(pairs.len());
        for (o, e) in pairs {
            ours.push(o);
            edmips.push(e);
        }

        // fixed-precision grid, same worker scheme
        let combos = baselines::fig3_fixed_combos(bench, target, quick);
        let threads = sweep_threads(combos.len());
        let base_cfg = &base_cfg;
        let fixed = par_runtime_map(rt, combos, threads, |rt, (wb, xb)| {
            emit(&log_mx, format!("[{bench}/{tname}] fixed w{wb}x{xb}"));
            let r = baselines::run_fixed(rt, base_cfg, warm, wb, xb)?;
            emit(
                &log_mx,
                format!(
                    "[{bench}/{tname}] fixed w{wb}x{xb} done: {:.4}",
                    r.test_score
                ),
            );
            Ok(r)
        })?;

        Ok(SweepOutput {
            bench: bench.to_string(),
            target,
            ours,
            edmips,
            fixed,
        })
    }
}

#[cfg(feature = "xla")]
pub use driver::run_sweep;
