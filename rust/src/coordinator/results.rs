//! JSON result store: every sweep writes one self-describing file that
//! `cwmix report` and the bench harnesses re-read, and EXPERIMENTS.md
//! references.  Format is stable and versioned.

use std::path::Path;

use anyhow::{Context, Result};

use crate::minijson::{parse_file, Json};
use crate::nas::SearchResult;
use crate::quant::{Assignment, LayerAssignment};

pub const STORE_VERSION: f64 = 1.0;

fn assignment_json(a: &Assignment) -> Json {
    Json::Arr(
        a.layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("name", Json::str(&l.name)),
                    ("act_bits", Json::num(l.act_bits as f64)),
                    (
                        "weight_bits",
                        Json::Arr(
                            l.weight_bits
                                .iter()
                                .map(|&b| Json::num(b as f64))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

fn assignment_from_json(j: &Json) -> Result<Assignment> {
    let layers = j
        .as_arr()?
        .iter()
        .map(|l| {
            Ok(LayerAssignment {
                name: l.get("name")?.as_str()?.to_string(),
                act_bits: l.get("act_bits")?.as_usize()? as u32,
                weight_bits: l
                    .get("weight_bits")?
                    .as_arr()?
                    .iter()
                    .map(|b| b.as_usize().map(|u| u as u32))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Assignment { layers })
}

/// One search result as JSON.
pub fn result_json(r: &SearchResult) -> Json {
    Json::obj(vec![
        ("label", Json::str(&r.config_label)),
        ("test_score", Json::num(r.test_score as f64)),
        ("test_loss", Json::num(r.test_loss as f64)),
        ("size_bits", Json::num(r.size_bits)),
        ("energy_pj", Json::num(r.energy_pj)),
        ("assignment", assignment_json(&r.assignment)),
        (
            "history",
            Json::Arr(
                r.history
                    .iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("phase", Json::str(h.phase)),
                            ("epoch", Json::num(h.epoch as f64)),
                            ("train_loss", Json::num(h.train_loss as f64)),
                            ("val_loss", Json::num(h.val_loss as f64)),
                            ("val_score", Json::num(h.val_score as f64)),
                            ("tau", Json::num(h.tau as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Parsed-back view of a stored result (enough for reports/benches).
#[derive(Clone, Debug)]
pub struct StoredResult {
    pub label: String,
    pub test_score: f32,
    pub size_bits: f64,
    pub energy_pj: f64,
    pub assignment: Assignment,
}

pub fn stored_from_json(j: &Json) -> Result<StoredResult> {
    Ok(StoredResult {
        label: j.get("label")?.as_str()?.to_string(),
        test_score: j.get("test_score")?.as_f64()? as f32,
        size_bits: j.get("size_bits")?.as_f64()?,
        energy_pj: j.get("energy_pj")?.as_f64()?,
        assignment: assignment_from_json(j.get("assignment")?)?,
    })
}

/// Write a sweep's three series to `<dir>/<bench>_<target>.json`.
pub fn save_sweep(
    dir: &Path,
    bench: &str,
    target: &str,
    ours: &[SearchResult],
    edmips: &[SearchResult],
    fixed: &[SearchResult],
) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{bench}_{target}.json"));
    let doc = Json::obj(vec![
        ("version", Json::num(STORE_VERSION)),
        ("bench", Json::str(bench)),
        ("target", Json::str(target)),
        ("ours", Json::Arr(ours.iter().map(result_json).collect())),
        ("edmips", Json::Arr(edmips.iter().map(result_json).collect())),
        ("fixed", Json::Arr(fixed.iter().map(result_json).collect())),
    ]);
    std::fs::write(&path, doc.pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// One sweep file's payload: `(bench, target, ours, edmips, fixed)`.
pub type SweepData = (String, String, Vec<StoredResult>, Vec<StoredResult>, Vec<StoredResult>);

/// Load a sweep file back.
pub fn load_sweep(path: &Path) -> Result<SweepData> {
    let j = parse_file(path)?;
    let series = |key: &str| -> Result<Vec<StoredResult>> {
        j.get(key)?.as_arr()?.iter().map(stored_from_json).collect()
    };
    Ok((
        j.get("bench")?.as_str()?.to_string(),
        j.get("target")?.as_str()?.to_string(),
        series("ours")?,
        series("edmips")?,
        series("fixed")?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::SearchResult;

    fn fake_result(label: &str, score: f32) -> SearchResult {
        SearchResult {
            config_label: label.into(),
            assignment: Assignment::fixed(
                &["a".to_string()], &[2], 4, 8),
            test_score: score,
            test_loss: 0.5,
            size_bits: 1000.0,
            energy_pj: 2000.0,
            history: vec![],
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("cwmix_test_results");
        let _ = std::fs::remove_dir_all(&dir);
        let ours = vec![fake_result("o1", 0.9)];
        let ed = vec![fake_result("e1", 0.85)];
        let fx = vec![fake_result("w8x8", 0.88)];
        let path = save_sweep(&dir, "ic", "size", &ours, &ed, &fx).unwrap();
        let (bench, target, o, e, f) = load_sweep(&path).unwrap();
        assert_eq!(bench, "ic");
        assert_eq!(target, "size");
        assert_eq!(o.len(), 1);
        assert_eq!(e[0].label, "e1");
        assert_eq!(f[0].assignment.layers[0].weight_bits, vec![4, 4]);
        assert!((o[0].test_score - 0.9).abs() < 1e-6);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
