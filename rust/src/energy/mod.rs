//! The MPIC cost model: `C(p_x, p_w)` LUT (Eq. (8)) and the Eq. (7)/(8)
//! evaluation of a *concrete* assignment — the numbers on the Fig. 3 axes.
//!
//! The differentiable versions of these live inside the AOT'd search
//! graphs (L2); this module is the reporting-side ground truth.  An
//! integration test cross-checks this LUT against the copy embedded in
//! every `manifest.json`, so the search and the reports can never drift.

pub mod lut;

pub use lut::{CostLut, CYCLES_PER_MAC, ENERGY_PJ_PER_MAC};

use crate::models::ModelGeom;
use crate::quant::Assignment;

/// Model size in **bits** under an assignment (Eq. (7) with one-hot
/// gammas): `sum_layers sum_channels K * bits(channel)`.
pub fn model_size_bits(geom: &ModelGeom, a: &Assignment) -> f64 {
    assert_eq!(geom.qlayers.len(), a.layers.len());
    let mut total = 0f64;
    for (l, la) in geom.qlayers.iter().zip(&a.layers) {
        assert_eq!(l.cout, la.weight_bits.len(), "layer {}", l.name);
        let k = l.weights_per_channel as f64;
        for &b in &la.weight_bits {
            total += k * b as f64;
        }
    }
    total
}

/// Model size in bits for the *packed* deployment layout (per-channel
/// rows padded to byte boundaries) — what actually lands in flash.
pub fn model_size_bits_packed(geom: &ModelGeom, a: &Assignment) -> f64 {
    let mut total = 0usize;
    for (l, la) in geom.qlayers.iter().zip(&a.layers) {
        total += crate::quant::packed_weight_bytes(
            l.cout, l.weights_per_channel, &la.weight_bits) * 8;
    }
    total as f64
}

/// Inference energy in **pJ** under an assignment (Eq. (8) with one-hot
/// NAS parameters): `sum_layers (ops/cout) * sum_i C(p_x, p_w_i)`.
pub fn model_energy_pj(geom: &ModelGeom, a: &Assignment, lut: &CostLut) -> f64 {
    let mut total = 0f64;
    for (l, la) in geom.qlayers.iter().zip(&a.layers) {
        let ops_per_ch = l.ops as f64 / l.cout as f64;
        for &wb in &la.weight_bits {
            total += ops_per_ch * lut.energy_pj(la.act_bits, wb) as f64;
        }
    }
    total
}

/// Inference latency in **cycles** under an assignment (same structure
/// with the cycles/MAC table; the MPIC simulator refines this with
/// per-layer overheads).
pub fn model_latency_cycles(geom: &ModelGeom, a: &Assignment, lut: &CostLut) -> f64 {
    let mut total = 0f64;
    for (l, la) in geom.qlayers.iter().zip(&a.layers) {
        let ops_per_ch = l.ops as f64 / l.cout as f64;
        for &wb in &la.weight_bits {
            total += ops_per_ch * lut.cycles(la.act_bits, wb) as f64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::QLayerGeom;

    fn tiny_geom() -> ModelGeom {
        ModelGeom {
            name: "t".into(),
            qlayers: vec![
                QLayerGeom {
                    name: "conv".into(),
                    kind: "conv".into(),
                    cin: 3,
                    cout: 4,
                    kx: 3,
                    ky: 3,
                    ops: 1000,
                    weights_per_channel: 27,
                },
                QLayerGeom {
                    name: "fc".into(),
                    kind: "fc".into(),
                    cin: 8,
                    cout: 2,
                    kx: 1,
                    ky: 1,
                    ops: 16,
                    weights_per_channel: 8,
                },
            ],
        }
    }

    #[test]
    fn size_matches_hand_count() {
        let g = tiny_geom();
        let names = vec!["conv".to_string(), "fc".to_string()];
        let a = Assignment::fixed(&names, &[4, 2], 8, 8);
        // conv: 4 ch * 27 * 8 + fc: 2 ch * 8 * 8
        assert_eq!(model_size_bits(&g, &a), (4 * 27 * 8 + 2 * 8 * 8) as f64);
    }

    #[test]
    fn mixed_size_smaller_than_w8() {
        let g = tiny_geom();
        let names = vec!["conv".to_string(), "fc".to_string()];
        let w8 = Assignment::fixed(&names, &[4, 2], 8, 8);
        let mut mixed = w8.clone();
        mixed.layers[0].weight_bits = vec![2, 2, 4, 8];
        assert!(model_size_bits(&g, &mixed) < model_size_bits(&g, &w8));
    }

    #[test]
    fn energy_uses_lut_nonlinearly() {
        let g = tiny_geom();
        let lut = CostLut::default();
        let names = vec!["conv".to_string(), "fc".to_string()];
        let e88 = model_energy_pj(&g, &Assignment::fixed(&names, &[4, 2], 8, 8), &lut);
        let e22 = model_energy_pj(&g, &Assignment::fixed(&names, &[4, 2], 2, 2), &lut);
        // cheaper, but NOT 16x cheaper (the paper's LUT motivation)
        assert!(e22 < e88);
        assert!(e22 > e88 / 16.0);
    }

    #[test]
    fn packed_size_at_least_logical() {
        let g = tiny_geom();
        let names = vec!["conv".to_string(), "fc".to_string()];
        let a = Assignment::fixed(&names, &[4, 2], 4, 8);
        assert!(model_size_bits_packed(&g, &a) >= model_size_bits(&g, &a));
    }
}
