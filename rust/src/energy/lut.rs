//! The MPIC energy/latency LUT — Rust mirror of
//! `python/compile/energy_lut.py` (single conceptual source; the
//! integration test `tests/manifest_consistency.rs` asserts the two match
//! via the copy embedded in every manifest).
//!
//! Derivation (DESIGN.md §7): the MPIC core's SIMD dot-product unit packs
//! `16 / max(p_x, p_w)` MAC lanes per cycle; energy/OP = P_core * T_cycle
//! / throughput * kappa, where kappa < 1 models the datapath gating of
//! narrower multipliers.  P_core = 1.75 mW @ 250 MHz => 7.0 pJ/cycle.

use crate::precision_index;

/// pJ per MAC, rows = p_x in {2,4,8}, cols = p_w in {2,4,8}.
pub const ENERGY_PJ_PER_MAC: [[f32; 3]; 3] = [
    // p_w:   2         4         8
    [7.0 / 16.0 * 0.85, 7.0 / 8.0 * 0.88, 7.0 / 4.0 * 0.92], // p_x = 2
    [7.0 / 8.0 * 0.88, 7.0 / 8.0 * 0.90, 7.0 / 4.0 * 0.95],  // p_x = 4
    [7.0 / 4.0 * 0.92, 7.0 / 4.0 * 0.95, 7.0 / 4.0 * 1.00],  // p_x = 8
];

/// Cycles per MAC (1 / SIMD throughput), same indexing.
pub const CYCLES_PER_MAC: [[f32; 3]; 3] = [
    [1.0 / 16.0, 1.0 / 8.0, 1.0 / 4.0],
    [1.0 / 8.0, 1.0 / 8.0, 1.0 / 4.0],
    [1.0 / 4.0, 1.0 / 4.0, 1.0 / 4.0],
];

/// MPIC core clock (the paper profiles its LUT at 250 MHz).
pub const F_CLK_HZ: f64 = 250e6;

/// Cost lookup with optional override (e.g. LUT loaded from a manifest).
#[derive(Clone, Debug)]
pub struct CostLut {
    pub energy_pj: [[f32; 3]; 3],
    pub cycles: [[f32; 3]; 3],
}

impl Default for CostLut {
    fn default() -> Self {
        CostLut { energy_pj: ENERGY_PJ_PER_MAC, cycles: CYCLES_PER_MAC }
    }
}

impl CostLut {
    /// Energy of one `p_x x p_w` MAC in pJ.
    pub fn energy_pj(&self, px: u32, pw: u32) -> f32 {
        self.energy_pj[precision_index(px)][precision_index(pw)]
    }

    /// Cycles of one `p_x x p_w` MAC (SIMD-amortised).
    pub fn cycles(&self, px: u32, pw: u32) -> f32 {
        self.cycles[precision_index(px)][precision_index(pw)]
    }

    /// Build from the 3x3 row-major table in a manifest.
    pub fn from_rows(energy: &[Vec<f32>], cycles: &[Vec<f32>]) -> Self {
        let mut e = [[0.0f32; 3]; 3];
        let mut c = [[0.0f32; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                e[i][j] = energy[i][j];
                c[i][j] = cycles[i][j];
            }
        }
        CostLut { energy_pj: e, cycles: c }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_both_operands() {
        let lut = CostLut::default();
        for &px in &[2u32, 4, 8] {
            assert!(lut.energy_pj(px, 2) <= lut.energy_pj(px, 4));
            assert!(lut.energy_pj(px, 4) <= lut.energy_pj(px, 8));
            assert!(lut.cycles(px, 2) <= lut.cycles(px, 4));
        }
        for &pw in &[2u32, 4, 8] {
            assert!(lut.energy_pj(2, pw) <= lut.energy_pj(4, pw));
            assert!(lut.energy_pj(4, pw) <= lut.energy_pj(8, pw));
        }
    }

    #[test]
    fn sub_byte_not_linear() {
        // The paper's reason for a LUT: 2x2 is NOT (8*8)/(2*2) = 16x cheaper.
        let lut = CostLut::default();
        let ratio = lut.energy_pj(8, 8) / lut.energy_pj(2, 2);
        assert!(ratio > 2.0 && ratio < 8.0, "ratio {ratio}");
    }

    #[test]
    fn symmetric_mixed_combos() {
        let lut = CostLut::default();
        assert_eq!(lut.energy_pj(2, 8), lut.energy_pj(8, 2));
        assert_eq!(lut.cycles(4, 8), lut.cycles(8, 4));
    }

    #[test]
    fn throughput_set_by_wider_operand() {
        let lut = CostLut::default();
        assert_eq!(lut.cycles(2, 8), lut.cycles(8, 8));
        assert_eq!(lut.cycles(4, 4), lut.cycles(2, 4));
    }
}
