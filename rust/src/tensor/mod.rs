//! Small host tensors used throughout the coordinator.
//!
//! These are deliberately simple row-major owned buffers: the heavy math
//! runs either in the XLA executables (training) or in the inference
//! engine (deployment), so the coordinator mostly moves data and
//! bookkeeps shapes.  Conversion to/from `xla::Literal` lives here so
//! `runtime/` stays thin; it is compiled only with the `xla` feature.

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction (0-d or single-element).
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on len {}", self.data.len());
        self.data[0]
    }

    /// 2D accessor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Reshape (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Row slice of a 2D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }
}

// ---- Literal conversion (xla feature) --------------------------------------

#[cfg(feature = "xla")]
mod literal {
    use super::{Tensor, TensorI32};
    use anyhow::{bail, Result};

    impl Tensor {
        /// To an `xla::Literal` with this tensor's shape.
        pub fn to_literal(&self) -> Result<xla::Literal> {
            let lit = xla::Literal::vec1(self.data());
            if self.shape().is_empty() {
                // 0-d scalar: reshape to rank-0
                Ok(lit.reshape(&[])?)
            } else {
                let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }

        /// From an `xla::Literal` (f32 or convertible).
        pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data: Vec<f32> = match shape.ty() {
                xla::ElementType::F32 => lit.to_vec::<f32>()?,
                xla::ElementType::S32 => lit
                    .to_vec::<i32>()?
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
                other => bail!("unsupported literal element type {other:?}"),
            };
            Ok(Tensor::new(dims, data))
        }
    }

    impl TensorI32 {
        pub fn to_literal(&self) -> Result<xla::Literal> {
            let lit = xla::Literal::vec1(self.data());
            let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }
}

/// Row-major i32 tensor (labels).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI32 { shape, data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_invariant() {
        let t = Tensor::zeros(vec![2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|v| v as f32).collect());
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![6], (0..6).map(|v| v as f32).collect());
        let t = t.reshape(vec![3, 2]);
        assert_eq!(t.at2(2, 1), 5.0);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(4.5).item(), 4.5);
    }
}
