//! `.cwm` — the compiled-model artifact container (modelpack).
//!
//! The paper's headline result is **memory**: channel-wise bit-width
//! assignment cuts model size by up to 63% vs layer-wise, yet until
//! this module the packed sub-byte weight layout only ever existed
//! *transiently* inside `ExecPlan::compile` — every server start
//! recompiled every plan from raw f32 state and nothing on disk
//! witnessed the size reduction.  A modelpack is the durable form of a
//! compiled plan: a versioned, checksummed binary container holding
//! everything `ExecPlan::compile` derives (channel-wise assignment
//! groups, packed sub-byte weight words, folded epilogues, im2col
//! gather tables, arena slot layout, the `InferenceCost`), laid out so
//! loading is a **validate-then-borrow** pass — zero-copy views into
//! one owned 8-aligned buffer, no re-packing, no f32 weight
//! materialization.
//!
//! This module owns the *container*: header, section table, checksum,
//! bounds-checked stream primitives and the shared-buffer view types.
//! The plan-specific record encoding lives next to the plan internals
//! in [`engine::pack`](crate::engine::pack).
//!
//! ## File layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//!      0     8  magic  b"CWMIXPAK"
//!      8     2  version_major (= 1; a loader rejects a different major)
//!     10     2  version_minor (informational; any value accepted)
//!     12     4  flags (v1 defines none; unknown bits are rejected —
//!                      a flag marks a change an old loader must NOT skip)
//!     16     8  file_len (total bytes incl. this header)
//!     24     8  checksum: FNV-1a 64 over bytes [0, 24) ++ [32, EOF)
//!     32     4  n_sections
//!     36     4  reserved (0)
//!     40   24n  section table: { kind u32, pad u32, offset u64, len u64 }
//!      …        section payloads, each 8-aligned
//! ```
//!
//! Unknown section *kinds* are skipped (forward compatibility: a newer
//! writer may add sections an old reader ignores); unknown *flags* and
//! a different *major* version are errors.  Every failure mode of a
//! hostile or truncated file — bad magic, checksum mismatch, offsets
//! past EOF, misaligned sections, short reads, lying element counts —
//! maps to a typed [`PackError`], never a panic and never UB.
//!
//! ## Zero-copy views
//!
//! [`Container::parse`] copies the file once into an [`AlignedBuf`]
//! (8-aligned backing store, the mmap stand-in) behind an `Arc`.
//! [`Bytes`] is a bounds-checked borrowed range of that buffer;
//! [`ByteArr`]/[`I32Arr`]/[`F32Arr`] are array handles that either
//! *view* such a range in place (packed weight rows, gather tables,
//! folded epilogues — on little-endian targets, after an alignment
//! check) or own a decoded copy (the big-endian / misaligned
//! fallback).  The deref target is a plain slice either way, so the
//! engine's hot paths are agnostic to where the data lives.

use std::fmt;
use std::ops::Deref;
use std::sync::Arc;

/// File magic, first 8 bytes of every `.cwm`.
pub const MAGIC: [u8; 8] = *b"CWMIXPAK";

/// Container major version this build reads and writes.
pub const VERSION_MAJOR: u16 = 1;

/// Container minor version this build writes.  Minor 1 adds the
/// fused-requantize plan state (`KIND_QUANT_FUSED` node records and the
/// META fusion extension); minor-0 packs remain fully readable, and
/// unfused plans still encode byte-identically to minor-0 bodies.
pub const VERSION_MINOR: u16 = 1;

/// Fixed header bytes before the section table.
pub const HEADER_LEN: usize = 40;

/// Bytes per section-table entry.
pub const SECTION_ENTRY_LEN: usize = 24;

/// Section kinds defined by v1.  Readers skip kinds they don't know.
pub const SECTION_META: u32 = 1;
pub const SECTION_PLAN: u32 = 2;
pub const SECTION_COST: u32 = 3;
pub const SECTION_DATA: u32 = 4;
/// Optional provenance (assignment spec + synthetic-state seed): not
/// needed to execute, checked by loaders that were asked for specific
/// construction parameters.
pub const SECTION_PROV: u32 = 5;

/// Length cap for any serialized string (layer/bench names).
pub const MAX_STR_LEN: usize = 4096;

// ---------------------------------------------------------------------------
// Errors.
// ---------------------------------------------------------------------------

/// Typed modelpack failure.  Every hostile-input path lands here; no
/// code in this module or in `engine::pack` panics on untrusted bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PackError {
    /// Fewer bytes than a field or payload requires.
    Truncated { need: usize, have: usize },
    /// First 8 bytes are not [`MAGIC`].
    BadMagic,
    /// Major version differs from [`VERSION_MAJOR`].
    VersionSkew { major: u16, minor: u16 },
    /// Header flags contain bits this reader does not understand.
    UnsupportedFlags(u32),
    /// Header `file_len` disagrees with the actual byte count.
    LengthMismatch { header: u64, actual: u64 },
    /// Stored checksum does not match the recomputed one.
    ChecksumMismatch { stored: u64, computed: u64 },
    /// A section or data reference reaches past the end of its buffer.
    OffsetOutOfRange { offset: u64, len: u64, limit: u64 },
    /// A section payload is not 8-aligned.
    Misaligned { offset: u64 },
    /// A known section kind appears twice.
    DuplicateSection(u32),
    /// A required section is absent.
    MissingSection(u32),
    /// Structurally valid container, semantically invalid content
    /// (bad tag bytes, lying counts, inconsistent plan geometry, …).
    Malformed(String),
}

impl fmt::Display for PackError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PackError::Truncated { need, have } => {
                write!(f, "truncated modelpack: need {need} bytes, have {have}")
            }
            PackError::BadMagic => write!(f, "not a modelpack (bad magic)"),
            PackError::VersionSkew { major, minor } => write!(
                f,
                "modelpack version {major}.{minor} incompatible with \
                 reader {VERSION_MAJOR}.{VERSION_MINOR}"
            ),
            PackError::UnsupportedFlags(bits) => {
                write!(f, "modelpack uses unsupported flags {bits:#x}")
            }
            PackError::LengthMismatch { header, actual } => write!(
                f,
                "header claims {header} bytes, file has {actual}"
            ),
            PackError::ChecksumMismatch { stored, computed } => write!(
                f,
                "checksum mismatch: stored {stored:#018x}, computed {computed:#018x}"
            ),
            PackError::OffsetOutOfRange { offset, len, limit } => write!(
                f,
                "range [{offset}, {offset}+{len}) past end {limit}"
            ),
            PackError::Misaligned { offset } => {
                write!(f, "section payload at {offset} is not 8-aligned")
            }
            PackError::DuplicateSection(kind) => {
                write!(f, "duplicate section kind {kind}")
            }
            PackError::MissingSection(kind) => {
                write!(f, "missing required section kind {kind}")
            }
            PackError::Malformed(msg) => write!(f, "malformed modelpack: {msg}"),
        }
    }
}

impl std::error::Error for PackError {}

/// Shorthand constructor for [`PackError::Malformed`].
pub fn malformed(msg: impl Into<String>) -> PackError {
    PackError::Malformed(msg.into())
}

/// Checked `u64 → usize` (32-bit hosts must not wrap hostile lengths).
pub fn as_usize(v: u64) -> Result<usize, PackError> {
    usize::try_from(v).map_err(|_| malformed(format!("length {v} exceeds address space")))
}

// ---------------------------------------------------------------------------
// Checksum.
// ---------------------------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over a concatenation of byte slices.
pub fn fnv1a64(parts: &[&[u8]]) -> u64 {
    let mut h = FNV_OFFSET;
    for part in parts {
        for &b in *part {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

fn checksum_of(bytes: &[u8]) -> u64 {
    // everything except the checksum field itself at [24, 32)
    fnv1a64(&[&bytes[..24], &bytes[32..]])
}

/// Recompute and store the checksum of an assembled (or test-mutated)
/// container in place.  No-op on buffers shorter than the header.
pub fn reseal(bytes: &mut [u8]) {
    if bytes.len() < HEADER_LEN {
        return;
    }
    let sum = checksum_of(bytes);
    bytes[24..32].copy_from_slice(&sum.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Aligned backing store + zero-copy views.
// ---------------------------------------------------------------------------

/// Owned byte buffer with guaranteed 8-byte base alignment — the
/// in-memory stand-in for an mmap'd `.cwm`.  `Vec<u8>` guarantees only
/// 1-byte alignment, which would make the in-file 8-aligned section
/// layout useless; backing the bytes with `Vec<u64>` makes every
/// 8-aligned file offset 8-aligned in memory too, so `i32`/`f32`
/// payloads can be viewed in place.
pub struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    /// Copy `bytes` into fresh 8-aligned storage (the one copy a load
    /// pays; everything downstream borrows).
    pub fn copy_from(bytes: &[u8]) -> AlignedBuf {
        let mut words = vec![0u64; bytes.len().div_ceil(8)];
        // SAFETY: `words` is fully initialised and its allocation covers
        // `bytes.len()` bytes; u8 has no validity requirements.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(words.as_mut_ptr() as *mut u8, bytes.len())
        };
        dst.copy_from_slice(bytes);
        AlignedBuf { words, len: bytes.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: the allocation holds at least `len` initialised bytes
        // and is never mutated after construction.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }
}

/// A bounds-checked borrowed byte range of a loaded modelpack; cloning
/// clones the `Arc`, not the bytes.
#[derive(Clone)]
pub struct Bytes {
    buf: Arc<AlignedBuf>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// Borrow `[off, off + len)` of `buf`; out-of-range is an error.
    pub fn new(buf: &Arc<AlignedBuf>, off: usize, len: usize) -> Result<Bytes, PackError> {
        let end = off.checked_add(len).ok_or(PackError::OffsetOutOfRange {
            offset: off as u64,
            len: len as u64,
            limit: buf.len() as u64,
        })?;
        if end > buf.len() {
            return Err(PackError::OffsetOutOfRange {
                offset: off as u64,
                len: len as u64,
                limit: buf.len() as u64,
            });
        }
        Ok(Bytes { buf: Arc::clone(buf), off, len })
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.buf.as_bytes()[self.off..self.off + self.len]
    }
}

/// Byte-array handle: an owned vector or a zero-copy [`Bytes`] view.
pub struct ByteArr(ByteRepr);

enum ByteRepr {
    Owned(Vec<u8>),
    View(Bytes),
}

impl ByteArr {
    pub fn view(bytes: Bytes) -> ByteArr {
        ByteArr(ByteRepr::View(bytes))
    }
}

impl From<Vec<u8>> for ByteArr {
    fn from(v: Vec<u8>) -> ByteArr {
        ByteArr(ByteRepr::Owned(v))
    }
}

impl Deref for ByteArr {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.0 {
            ByteRepr::Owned(v) => v,
            ByteRepr::View(b) => b,
        }
    }
}

/// `i32`-array handle over little-endian file bytes: a zero-copy view
/// when the target is little-endian and the range is 4-aligned (the
/// 8-aligned layout guarantees it for honestly written packs), an
/// owned decode otherwise.
pub struct I32Arr(I32Repr);

enum I32Repr {
    Owned(Vec<i32>),
    // invariant: len % 4 == 0, base pointer 4-aligned, LE target
    View(Bytes),
}

impl I32Arr {
    /// Interpret `bytes` as little-endian `i32`s.  `bytes.len()` must
    /// be a multiple of 4.
    pub fn from_le(bytes: Bytes) -> Result<I32Arr, PackError> {
        if bytes.len() % 4 != 0 {
            return Err(malformed(format!("i32 array of {} bytes", bytes.len())));
        }
        if cfg!(target_endian = "little") && (bytes.as_ptr() as usize) % 4 == 0 {
            Ok(I32Arr(I32Repr::View(bytes)))
        } else {
            Ok(I32Arr(I32Repr::Owned(
                bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )))
        }
    }
}

impl From<Vec<i32>> for I32Arr {
    fn from(v: Vec<i32>) -> I32Arr {
        I32Arr(I32Repr::Owned(v))
    }
}

impl Deref for I32Arr {
    type Target = [i32];

    fn deref(&self) -> &[i32] {
        match &self.0 {
            I32Repr::Owned(v) => v,
            // SAFETY: construction checked 4-alignment, length % 4 == 0
            // and a little-endian target; the Arc'd buffer is immutable
            // and outlives the view.
            I32Repr::View(b) => unsafe {
                std::slice::from_raw_parts(b.as_ptr() as *const i32, b.len() / 4)
            },
        }
    }
}

/// `f32`-array handle over little-endian file bytes (see [`I32Arr`]).
pub struct F32Arr(F32Repr);

enum F32Repr {
    Owned(Vec<f32>),
    // invariant: len % 4 == 0, base pointer 4-aligned, LE target
    View(Bytes),
}

impl F32Arr {
    /// Interpret `bytes` as little-endian `f32`s (bit patterns are
    /// preserved exactly — folded epilogues stay bit-identical).
    pub fn from_le(bytes: Bytes) -> Result<F32Arr, PackError> {
        if bytes.len() % 4 != 0 {
            return Err(malformed(format!("f32 array of {} bytes", bytes.len())));
        }
        if cfg!(target_endian = "little") && (bytes.as_ptr() as usize) % 4 == 0 {
            Ok(F32Arr(F32Repr::View(bytes)))
        } else {
            Ok(F32Arr(F32Repr::Owned(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )))
        }
    }
}

impl From<Vec<f32>> for F32Arr {
    fn from(v: Vec<f32>) -> F32Arr {
        F32Arr(F32Repr::Owned(v))
    }
}

impl Deref for F32Arr {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        match &self.0 {
            F32Repr::Owned(v) => v,
            // SAFETY: as for I32Arr::deref.
            F32Repr::View(b) => unsafe {
                std::slice::from_raw_parts(b.as_ptr() as *const f32, b.len() / 4)
            },
        }
    }
}

// ---------------------------------------------------------------------------
// Stream primitives.
// ---------------------------------------------------------------------------

/// Append-only writer for a structured section stream.
#[derive(Default)]
pub struct PackWriter {
    buf: Vec<u8>,
}

impl PackWriter {
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `u32` length + UTF-8 bytes.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked reader over a structured section stream.  Every read
/// returns `Err` past the end — hostile streams cannot index out of
/// bounds or panic.
pub struct PackReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> PackReader<'a> {
    pub fn new(b: &'a [u8]) -> PackReader<'a> {
        PackReader { b, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PackError> {
        let end = self.pos.checked_add(n).ok_or(PackError::Truncated {
            need: usize::MAX,
            have: self.b.len(),
        })?;
        if end > self.b.len() {
            return Err(PackError::Truncated { need: end, have: self.b.len() });
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, PackError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, PackError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(malformed(format!("bool byte {other}"))),
        }
    }

    pub fn u16(&mut self) -> Result<u16, PackError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, PackError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, PackError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// `u64` read into `usize` (bounds-safe on 32-bit hosts).
    pub fn len64(&mut self) -> Result<usize, PackError> {
        as_usize(self.u64()?)
    }

    pub fn f32(&mut self) -> Result<f32, PackError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f64(&mut self) -> Result<f64, PackError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn str(&mut self) -> Result<String, PackError> {
        let n = self.u32()? as usize;
        if n > MAX_STR_LEN {
            return Err(malformed(format!("string of {n} bytes")));
        }
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| malformed("non-UTF-8 string"))
    }

    /// Element count for a following repeated record.  Capped at `max`
    /// and at what the remaining bytes could possibly hold
    /// (`elem_min_bytes` each), so a lying count can neither
    /// over-allocate nor out-read.
    pub fn count(&mut self, elem_min_bytes: usize, max: usize) -> Result<usize, PackError> {
        let n = self.u32()? as usize;
        if n > max {
            return Err(malformed(format!("count {n} exceeds cap {max}")));
        }
        let need = n.saturating_mul(elem_min_bytes.max(1));
        if need > self.remaining() {
            return Err(PackError::Truncated {
                need: self.pos.saturating_add(need),
                have: self.b.len(),
            });
        }
        Ok(n)
    }

    /// Require the stream to be fully consumed (trailing garbage in a
    /// known section is a malformed pack, not padding).
    pub fn finish(&self) -> Result<(), PackError> {
        if self.remaining() != 0 {
            return Err(malformed(format!("{} trailing bytes in section", self.remaining())));
        }
        Ok(())
    }
}

/// Builder for the 8-aligned DATA section: every array is appended on
/// an 8-byte boundary and referenced by `(offset, len)` from the
/// structured sections.
#[derive(Default)]
pub struct DataWriter {
    buf: Vec<u8>,
}

impl DataWriter {
    fn align8(&mut self) {
        while self.buf.len() % 8 != 0 {
            self.buf.push(0);
        }
    }

    /// Append raw bytes; returns `(offset, len)` within the section.
    pub fn bytes(&mut self, b: &[u8]) -> (u64, u64) {
        self.align8();
        let off = self.buf.len() as u64;
        self.buf.extend_from_slice(b);
        (off, b.len() as u64)
    }

    /// Append `i32`s as little-endian bytes.
    pub fn i32s(&mut self, v: &[i32]) -> (u64, u64) {
        self.align8();
        let off = self.buf.len() as u64;
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        (off, (v.len() * 4) as u64)
    }

    /// Append `f32`s as little-endian bytes (exact bit patterns).
    pub fn f32s(&mut self, v: &[f32]) -> (u64, u64) {
        self.align8();
        let off = self.buf.len() as u64;
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        (off, (v.len() * 4) as u64)
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

// ---------------------------------------------------------------------------
// Container assembly + parsing.
// ---------------------------------------------------------------------------

/// Assemble a sealed `.cwm` file from `(kind, payload)` sections.
pub fn assemble(sections: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let table_end = HEADER_LEN + sections.len() * SECTION_ENTRY_LEN;
    let mut offsets = Vec::with_capacity(sections.len());
    let mut off = table_end;
    for (_, payload) in sections {
        off = (off + 7) & !7; // 8-align every payload
        offsets.push(off);
        off += payload.len();
    }
    let file_len = off;
    let mut out = vec![0u8; file_len];
    out[0..8].copy_from_slice(&MAGIC);
    out[8..10].copy_from_slice(&VERSION_MAJOR.to_le_bytes());
    out[10..12].copy_from_slice(&VERSION_MINOR.to_le_bytes());
    out[12..16].copy_from_slice(&0u32.to_le_bytes());
    out[16..24].copy_from_slice(&(file_len as u64).to_le_bytes());
    out[32..36].copy_from_slice(&(sections.len() as u32).to_le_bytes());
    for (i, ((kind, payload), &poff)) in sections.iter().zip(&offsets).enumerate() {
        let e = HEADER_LEN + i * SECTION_ENTRY_LEN;
        out[e..e + 4].copy_from_slice(&kind.to_le_bytes());
        out[e + 8..e + 16].copy_from_slice(&(poff as u64).to_le_bytes());
        out[e + 16..e + 24].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        out[poff..poff + payload.len()].copy_from_slice(payload);
    }
    reseal(&mut out);
    out
}

/// One validated section-table entry.
#[derive(Clone, Copy, Debug)]
pub struct SectionRef {
    pub kind: u32,
    pub off: usize,
    pub len: usize,
}

/// A parsed, checksum-verified container over an aligned owned buffer.
pub struct Container {
    pub buf: Arc<AlignedBuf>,
    pub version: (u16, u16),
    pub flags: u32,
    pub sections: Vec<SectionRef>,
}

impl Container {
    /// Validate the header, checksum and section table of `bytes` and
    /// take an aligned owned copy (the "mmap" the views borrow from).
    pub fn parse(bytes: &[u8]) -> Result<Container, PackError> {
        if bytes.len() < HEADER_LEN {
            return Err(PackError::Truncated { need: HEADER_LEN, have: bytes.len() });
        }
        if bytes[0..8] != MAGIC {
            return Err(PackError::BadMagic);
        }
        let major = u16::from_le_bytes([bytes[8], bytes[9]]);
        let minor = u16::from_le_bytes([bytes[10], bytes[11]]);
        if major != VERSION_MAJOR {
            return Err(PackError::VersionSkew { major, minor });
        }
        let flags = u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]);
        if flags != 0 {
            return Err(PackError::UnsupportedFlags(flags));
        }
        let file_len = u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes"));
        if file_len != bytes.len() as u64 {
            return Err(PackError::LengthMismatch {
                header: file_len,
                actual: bytes.len() as u64,
            });
        }
        let stored = u64::from_le_bytes(bytes[24..32].try_into().expect("8 bytes"));
        let computed = checksum_of(bytes);
        if stored != computed {
            return Err(PackError::ChecksumMismatch { stored, computed });
        }
        let n_sections =
            u32::from_le_bytes(bytes[32..36].try_into().expect("4 bytes")) as usize;
        let table_need = n_sections
            .checked_mul(SECTION_ENTRY_LEN)
            .and_then(|t| t.checked_add(HEADER_LEN))
            .ok_or_else(|| malformed("section count overflow"))?;
        if table_need > bytes.len() {
            return Err(PackError::Truncated { need: table_need, have: bytes.len() });
        }
        let mut sections = Vec::with_capacity(n_sections);
        let mut seen = [false; 6];
        for i in 0..n_sections {
            let e = HEADER_LEN + i * SECTION_ENTRY_LEN;
            let kind = u32::from_le_bytes(bytes[e..e + 4].try_into().expect("4 bytes"));
            let off = u64::from_le_bytes(bytes[e + 8..e + 16].try_into().expect("8 bytes"));
            let len = u64::from_le_bytes(bytes[e + 16..e + 24].try_into().expect("8 bytes"));
            if off % 8 != 0 {
                return Err(PackError::Misaligned { offset: off });
            }
            let end = off.checked_add(len).ok_or(PackError::OffsetOutOfRange {
                offset: off,
                len,
                limit: file_len,
            })?;
            if (off as usize) < table_need || end > file_len {
                return Err(PackError::OffsetOutOfRange { offset: off, len, limit: file_len });
            }
            let k = kind as usize;
            if k > 0 && k < seen.len() {
                if seen[k] {
                    return Err(PackError::DuplicateSection(kind));
                }
                seen[k] = true;
            }
            sections.push(SectionRef { kind, off: as_usize(off)?, len: as_usize(len)? });
        }
        Ok(Container {
            buf: Arc::new(AlignedBuf::copy_from(bytes)),
            version: (major, minor),
            flags,
            sections,
        })
    }

    /// Find a section by kind (unknown kinds are simply never asked for
    /// — that is the skip).
    pub fn find(&self, kind: u32) -> Option<SectionRef> {
        self.sections.iter().copied().find(|s| s.kind == kind)
    }

    /// A required section's payload bytes.
    pub fn section(&self, kind: u32) -> Result<&[u8], PackError> {
        let s = self.find(kind).ok_or(PackError::MissingSection(kind))?;
        Ok(&self.buf.as_bytes()[s.off..s.off + s.len])
    }

    /// A required section's absolute `(offset, len)` within the buffer
    /// (how DATA references become [`Bytes`] views).
    pub fn section_range(&self, kind: u32) -> Result<(usize, usize), PackError> {
        let s = self.find(kind).ok_or(PackError::MissingSection(kind))?;
        Ok((s.off, s.len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_sections() -> Vec<(u32, Vec<u8>)> {
        vec![
            (SECTION_META, b"meta-payload".to_vec()),
            (SECTION_DATA, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]),
        ]
    }

    #[test]
    fn assemble_parse_roundtrip() {
        let file = assemble(&sample_sections());
        let c = Container::parse(&file).unwrap();
        assert_eq!(c.version, (VERSION_MAJOR, VERSION_MINOR));
        assert_eq!(c.section(SECTION_META).unwrap(), b"meta-payload");
        assert_eq!(c.section(SECTION_DATA).unwrap(), &[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(matches!(
            c.section(SECTION_PLAN),
            Err(PackError::MissingSection(SECTION_PLAN))
        ));
        // every section payload is 8-aligned in the file AND in memory
        for s in &c.sections {
            assert_eq!(s.off % 8, 0);
            assert_eq!(c.buf.as_bytes()[s.off..].as_ptr() as usize % 8, 0);
        }
    }

    #[test]
    fn every_truncation_is_typed_error() {
        let file = assemble(&sample_sections());
        for cut in 0..file.len() {
            let err = Container::parse(&file[..cut]).unwrap_err();
            match err {
                PackError::Truncated { .. }
                | PackError::BadMagic
                | PackError::LengthMismatch { .. } => {}
                other => panic!("cut {cut}: unexpected error {other}"),
            }
        }
    }

    #[test]
    fn bad_magic_and_version_and_flags() {
        let file = assemble(&sample_sections());
        let mut bad = file.clone();
        bad[0] = b'X';
        reseal(&mut bad);
        assert_eq!(Container::parse(&bad).unwrap_err(), PackError::BadMagic);

        let mut skew = file.clone();
        skew[8] = 2; // major = 2
        reseal(&mut skew);
        assert!(matches!(
            Container::parse(&skew).unwrap_err(),
            PackError::VersionSkew { major: 2, .. }
        ));

        // minor skew is forward-compatible
        let mut minor = file.clone();
        minor[10] = 9;
        reseal(&mut minor);
        assert!(Container::parse(&minor).is_ok());

        let mut flagged = file.clone();
        flagged[12] = 1;
        reseal(&mut flagged);
        assert_eq!(
            Container::parse(&flagged).unwrap_err(),
            PackError::UnsupportedFlags(1)
        );
    }

    #[test]
    fn corrupted_byte_is_checksum_mismatch() {
        let file = assemble(&sample_sections());
        for &pos in &[HEADER_LEN + 2, file.len() - 1] {
            let mut bad = file.clone();
            bad[pos] ^= 0xff;
            assert!(matches!(
                Container::parse(&bad).unwrap_err(),
                PackError::ChecksumMismatch { .. }
            ));
        }
    }

    #[test]
    fn section_offset_past_eof_is_error() {
        let mut file = assemble(&sample_sections());
        // first table entry's offset field
        let e = HEADER_LEN + 8;
        file[e..e + 8].copy_from_slice(&(1u64 << 40).to_le_bytes());
        reseal(&mut file);
        assert!(matches!(
            Container::parse(&file).unwrap_err(),
            PackError::OffsetOutOfRange { .. }
        ));
    }

    #[test]
    fn misaligned_section_is_error() {
        let mut file = assemble(&sample_sections());
        let e = HEADER_LEN + 8;
        let off = u64::from_le_bytes(file[e..e + 8].try_into().unwrap());
        file[e..e + 8].copy_from_slice(&(off + 1).to_le_bytes());
        reseal(&mut file);
        assert!(matches!(
            Container::parse(&file).unwrap_err(),
            PackError::Misaligned { .. }
        ));
    }

    #[test]
    fn duplicate_known_section_is_error() {
        let file = assemble(&[
            (SECTION_META, vec![1]),
            (SECTION_META, vec![2]),
        ]);
        assert_eq!(
            Container::parse(&file).unwrap_err(),
            PackError::DuplicateSection(SECTION_META)
        );
    }

    #[test]
    fn unknown_sections_are_carried_and_skipped() {
        let mut sections = sample_sections();
        sections.push((99, b"from-the-future".to_vec()));
        let file = assemble(&sections);
        let c = Container::parse(&file).unwrap();
        assert_eq!(c.sections.len(), 3);
        assert_eq!(c.section(99).unwrap(), b"from-the-future");
        // known sections unaffected
        assert_eq!(c.section(SECTION_META).unwrap(), b"meta-payload");
    }

    #[test]
    fn writer_reader_primitives_roundtrip() {
        let mut w = PackWriter::default();
        w.u8(7);
        w.bool(true);
        w.u16(0xbeef);
        w.u32(0xdead_beef);
        w.u64(0x0123_4567_89ab_cdef);
        w.f32(-1.5);
        w.f64(std::f64::consts::PI);
        w.str("héllo");
        let bytes = w.into_bytes();
        let mut r = PackReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u16().unwrap(), 0xbeef);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89ab_cdef);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.str().unwrap(), "héllo");
        r.finish().unwrap();
        // reading past the end errors
        assert!(matches!(r.u8(), Err(PackError::Truncated { .. })));
    }

    #[test]
    fn reader_rejects_hostile_counts_and_strings() {
        // count claiming more elements than bytes remain
        let mut w = PackWriter::default();
        w.u32(1_000_000);
        let bytes = w.into_bytes();
        assert!(PackReader::new(&bytes).count(4, usize::MAX).is_err());
        // count over the semantic cap
        let mut w = PackWriter::default();
        w.u32(10);
        w.u64(0); // some payload so remaining() is ample
        let bytes = w.into_bytes();
        assert!(matches!(
            PackReader::new(&bytes).count(1, 5),
            Err(PackError::Malformed(_))
        ));
        // string length past the end
        let mut w = PackWriter::default();
        w.u32(50);
        let bytes = w.into_bytes();
        assert!(PackReader::new(&bytes).str().is_err());
        // non-UTF-8 string bytes
        let mut w = PackWriter::default();
        w.u32(2);
        let mut bytes = w.into_bytes();
        bytes.extend_from_slice(&[0xff, 0xfe]);
        assert!(matches!(
            PackReader::new(&bytes).str(),
            Err(PackError::Malformed(_))
        ));
        // bad bool byte
        assert!(PackReader::new(&[2]).bool().is_err());
    }

    #[test]
    fn data_writer_aligns_every_array() {
        let mut d = DataWriter::default();
        let (o1, l1) = d.bytes(&[1, 2, 3]);
        let (o2, l2) = d.i32s(&[-1, 2]);
        let (o3, l3) = d.f32s(&[0.5]);
        assert_eq!((o1, l1), (0, 3));
        assert_eq!((o2 % 8, l2), (0, 8));
        assert!(o2 >= 3);
        assert_eq!((o3 % 8, l3), (0, 4));
        let bytes = d.into_bytes();
        assert_eq!(&bytes[o2 as usize..o2 as usize + 4], &(-1i32).to_le_bytes());
    }

    #[test]
    fn views_deref_and_bounds_check() {
        let data: Vec<u8> = (0..32).collect();
        let buf = Arc::new(AlignedBuf::copy_from(&data));
        assert_eq!(buf.as_bytes(), &data[..]);
        assert_eq!(buf.as_bytes().as_ptr() as usize % 8, 0);

        let b = Bytes::new(&buf, 8, 8).unwrap();
        assert_eq!(&*b, &data[8..16]);
        assert!(matches!(
            Bytes::new(&buf, 30, 8),
            Err(PackError::OffsetOutOfRange { .. })
        ));
        assert!(Bytes::new(&buf, usize::MAX, 2).is_err());

        let ints = I32Arr::from_le(Bytes::new(&buf, 8, 8).unwrap()).unwrap();
        assert_eq!(
            &*ints,
            &[
                i32::from_le_bytes([8, 9, 10, 11]),
                i32::from_le_bytes([12, 13, 14, 15])
            ]
        );
        assert!(I32Arr::from_le(Bytes::new(&buf, 8, 7).unwrap()).is_err());

        let floats = F32Arr::from_le(Bytes::new(&buf, 0, 4).unwrap()).unwrap();
        assert_eq!(floats[0].to_le_bytes(), [0, 1, 2, 3]);

        let owned: I32Arr = vec![5, 6].into();
        assert_eq!(&*owned, &[5, 6]);
        let owned: F32Arr = vec![1.0f32].into();
        assert_eq!(&*owned, &[1.0]);
        let owned: ByteArr = vec![9u8].into();
        assert_eq!(&*owned, &[9]);
        let viewed = ByteArr::view(Bytes::new(&buf, 0, 2).unwrap());
        assert_eq!(&*viewed, &[0, 1]);
    }

    #[test]
    fn fnv_and_reseal() {
        assert_eq!(fnv1a64(&[b""]), 0xcbf2_9ce4_8422_2325);
        // split points don't change the digest
        assert_eq!(fnv1a64(&[b"ab", b"c"]), fnv1a64(&[b"abc"]));
        let mut file = assemble(&sample_sections());
        file[HEADER_LEN] ^= 1;
        assert!(Container::parse(&file).is_err());
        reseal(&mut file);
        assert!(Container::parse(&file).is_ok());
        // reseal on a too-short buffer is a no-op, not a panic
        reseal(&mut [0u8; 4]);
    }
}
