//! State threading + the Alg. 1 phases over the AOT'd graphs.
//!
//! The trainer owns every tensor of training state (weights, BN running
//! stats, NAS parameters, Adam moments) host-side and threads them
//! through the compiled XLA step functions.  Graph input/output orders
//! follow the manifest conventions (see `python/compile/train_graphs.py`
//! docstring); the orders are asserted once at construction.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::data::{make_dataset, Batch, BatchIter, Dataset, Split};
use crate::energy;
use crate::models::Manifest;
use crate::nas::{EpochLog, Mode, SearchConfig, SearchResult, Target};
use crate::quant::Assignment;
use crate::runtime::{Arg, Runtime};
use crate::tensor::{Tensor, TensorI32};
use crate::util::{auc_from_scores, mean, Pcg32};

/// Pinned 8-bit activation logits used when the size regularizer disables
/// the activation search (softmax(tau=5) of 40 is one-hot to 3 decimals).
const ACT_PIN_LOGIT: f32 = 40.0;

/// Snapshot of trainable state (for warmup reuse across a lambda sweep).
#[derive(Clone)]
pub struct StateSnapshot {
    params: Vec<Tensor>,
    bn: Vec<Tensor>,
}

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub manifest: Manifest,
    pub cfg: SearchConfig,
    train: Dataset,
    val: Dataset,
    test: Dataset,
    // trainable state
    params: Vec<Tensor>,
    bn: Vec<Tensor>,
    nas: Vec<Tensor>,
    mw: Vec<Tensor>,
    vw: Vec<Tensor>,
    tw: f32,
    mn: Vec<Tensor>,
    vn: Vec<Tensor>,
    tn: f32,
    tau: f32,
    pub history: Vec<EpochLog>,
}

// He/constant initialisation by tensor-name suffix (mirrors
// `models.common.init_params`; exact values need not match Python — the
// graphs are pure functions of the state we feed them).  Shared with the
// synthetic-state path so builtin-zoo runs see the same distributions.
use crate::models::zoo::init_slot_tensor as init_tensor;

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: SearchConfig) -> Result<Trainer<'rt>> {
        let manifest = Manifest::load(rt.artifacts_dir(), &cfg.bench)
            .context("loading manifest")?;
        manifest.validate()?;
        if cfg.batch != manifest.batch {
            bail!("config batch {} != manifest batch {}", cfg.batch, manifest.batch);
        }
        let train = make_dataset(&cfg.bench, Split::Train, cfg.train_n, cfg.seed);
        let val = make_dataset(&cfg.bench, Split::Val, cfg.val_n, cfg.seed);
        let test = make_dataset(&cfg.bench, Split::Test, cfg.test_n, cfg.seed);
        let mut rng = Pcg32::new(cfg.seed, 11);
        let params = manifest
            .params
            .iter()
            .map(|s| init_tensor(&s.name, &s.shape, &mut rng))
            .collect::<Vec<_>>();
        let bn = manifest
            .bn_state
            .iter()
            .map(|s| init_tensor(&s.name, &s.shape, &mut rng))
            .collect::<Vec<_>>();
        let nas_slots = match cfg.mode {
            Mode::ChannelWise => &manifest.nas_cw,
            Mode::LayerWise => &manifest.nas_lw,
        };
        let mut nas: Vec<Tensor> =
            nas_slots.iter().map(|s| Tensor::zeros(s.shape.clone())).collect();
        // size-target runs pin all activations to 8 bit (paper §III-A)
        if cfg.target == Target::Size {
            for (slot, t) in nas_slots.iter().zip(nas.iter_mut()) {
                if slot.name.ends_with(".delta") {
                    let d = t.data_mut();
                    d[d.len() - 1] = ACT_PIN_LOGIT;
                }
            }
        }
        let zeros_like =
            |v: &Vec<Tensor>| v.iter().map(|t| Tensor::zeros(t.shape().to_vec())).collect();
        let mw = zeros_like(&params);
        let vw = zeros_like(&params);
        let mn = zeros_like(&nas);
        let vn = zeros_like(&nas);
        let tau = cfg.tau0;
        Ok(Trainer {
            rt,
            manifest,
            cfg,
            train,
            val,
            test,
            params,
            bn,
            nas,
            mw,
            vw,
            tw: 0.0,
            mn,
            vn,
            tn: 0.0,
            tau,
            history: Vec::new(),
        })
    }

    // ---- state access -------------------------------------------------------

    pub fn snapshot(&self) -> StateSnapshot {
        StateSnapshot { params: self.params.clone(), bn: self.bn.clone() }
    }

    pub fn restore(&mut self, s: &StateSnapshot) {
        self.params = s.params.clone();
        self.bn = s.bn.clone();
        // fresh optimiser state after a restore
        self.mw = self.params.iter().map(|t| Tensor::zeros(t.shape().to_vec())).collect();
        self.vw = self.mw.clone();
        self.tw = 0.0;
    }

    pub fn params_map(&self) -> HashMap<String, Tensor> {
        self.manifest
            .params
            .iter()
            .zip(&self.params)
            .map(|(s, t)| (s.name.clone(), t.clone()))
            .collect()
    }

    pub fn bn_map(&self) -> HashMap<String, Tensor> {
        self.manifest
            .bn_state
            .iter()
            .zip(&self.bn)
            .map(|(s, t)| (s.name.clone(), t.clone()))
            .collect()
    }

    /// Current argmax assignment from the NAS parameters.
    pub fn assignment(&self) -> Assignment {
        let nas_slots = match self.cfg.mode {
            Mode::ChannelWise => &self.manifest.nas_cw,
            Mode::LayerWise => &self.manifest.nas_lw,
        };
        let mut names = Vec::new();
        let mut deltas = Vec::new();
        let mut gammas = Vec::new();
        for (slot, t) in nas_slots.iter().zip(&self.nas) {
            if slot.name.ends_with(".delta") {
                names.push(slot.name.trim_end_matches(".delta").to_string());
                deltas.push(t.data().to_vec());
            } else {
                gammas.push((t.shape()[0], t.data().to_vec()));
            }
        }
        let couts = self.manifest.qcouts();
        Assignment::from_nas_params(&names, &deltas, &gammas, &couts)
    }

    // ---- graph plumbing -----------------------------------------------------

    fn batch_tensors(&self, b: &Batch) -> (Tensor, Option<TensorI32>, Option<Tensor>) {
        let mut shape = vec![self.cfg.batch];
        shape.extend(&self.manifest.input_shape);
        let x = Tensor::new(shape.clone(), b.x.clone());
        if self.manifest.loss == "ce" {
            (x, Some(TensorI32::new(vec![self.cfg.batch], b.y.clone())), None)
        } else {
            let y = Tensor::new(shape, b.x.clone());
            (x, None, Some(y))
        }
    }

    fn hard_tensors(&self, a: &Assignment) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(2 * a.layers.len());
        for (d, g) in a.to_one_hot() {
            let cout = g.len() / 3;
            out.push(Tensor::new(vec![3], d));
            out.push(Tensor::new(vec![cout, 3], g));
        }
        out
    }

    /// One hard-assignment QAT step (warmup / finetune / baselines).
    fn step_w_hard(&mut self, b: &Batch, hard: &[Tensor], lr: f32) -> Result<(f32, f32)> {
        let g = self.rt.graph(&self.cfg.bench, "train_w_hard")?;
        let t = Tensor::scalar(self.tw);
        let lr_t = Tensor::scalar(lr);
        let (x, yi, yf) = self.batch_tensors(b);
        let mut args: Vec<Arg> = Vec::new();
        for t in &self.params { args.push(Arg::F32(t)); }
        for t in &self.bn { args.push(Arg::F32(t)); }
        for t in &self.mw { args.push(Arg::F32(t)); }
        for t in &self.vw { args.push(Arg::F32(t)); }
        args.push(Arg::F32(&t));
        for t in hard { args.push(Arg::F32(t)); }
        args.push(Arg::F32(&x));
        match (&yi, &yf) {
            (Some(y), _) => args.push(Arg::I32(y)),
            (_, Some(y)) => args.push(Arg::F32(y)),
            _ => unreachable!(),
        }
        args.push(Arg::F32(&lr_t));
        let out = g.run(&args)?;
        let np = self.params.len();
        let nb = self.bn.len();
        let expect = 3 * np + nb + 2;
        if out.len() != expect {
            bail!("train_w_hard returned {} outputs, expected {expect}", out.len());
        }
        let mut it = out.into_iter();
        self.params = (&mut it).take(np).collect();
        self.bn = (&mut it).take(nb).collect();
        self.mw = (&mut it).take(np).collect();
        self.vw = (&mut it).take(np).collect();
        let loss = it.next().unwrap().item();
        let metric = it.next().unwrap().item();
        self.tw += 1.0;
        Ok((loss, metric))
    }

    /// One theta step (Alg. 1 line 5).
    fn step_theta(&mut self, b: &Batch) -> Result<(f32, f32, f32)> {
        let graph = format!("search_theta_{}", self.cfg.mode.suffix());
        let g = self.rt.graph(&self.cfg.bench, &graph)?;
        let (lam_s, lam_e) = match self.cfg.target {
            Target::Size => (self.cfg.lambda, 0.0),
            Target::Energy => (0.0, self.cfg.lambda),
        };
        let act_freeze = if self.cfg.target == Target::Size { 1.0 } else { 0.0 };
        let scalars = [
            Tensor::scalar(self.tn),
            Tensor::scalar(self.tau),
            Tensor::scalar(lam_s),
            Tensor::scalar(lam_e),
            Tensor::scalar(self.cfg.lr_nas),
            Tensor::scalar(act_freeze),
        ];
        let (x, yi, yf) = self.batch_tensors(b);
        let mut args: Vec<Arg> = Vec::new();
        for t in &self.params { args.push(Arg::F32(t)); }
        for t in &self.bn { args.push(Arg::F32(t)); }
        for t in &self.nas { args.push(Arg::F32(t)); }
        for t in &self.mn { args.push(Arg::F32(t)); }
        for t in &self.vn { args.push(Arg::F32(t)); }
        args.push(Arg::F32(&scalars[0])); // t
        args.push(Arg::F32(&x));
        match (&yi, &yf) {
            (Some(y), _) => args.push(Arg::I32(y)),
            (_, Some(y)) => args.push(Arg::F32(y)),
            _ => unreachable!(),
        }
        args.push(Arg::F32(&scalars[1])); // tau
        args.push(Arg::F32(&scalars[2])); // lam_size
        args.push(Arg::F32(&scalars[3])); // lam_energy
        args.push(Arg::F32(&scalars[4])); // lr
        args.push(Arg::F32(&scalars[5])); // act_freeze
        let out = g.run(&args)?;
        let nn = self.nas.len();
        if out.len() != 3 * nn + 3 {
            bail!("search_theta returned {} outputs", out.len());
        }
        let mut it = out.into_iter();
        self.nas = (&mut it).take(nn).collect();
        self.mn = (&mut it).take(nn).collect();
        self.vn = (&mut it).take(nn).collect();
        let loss = it.next().unwrap().item();
        let reg_s = it.next().unwrap().item();
        let reg_e = it.next().unwrap().item();
        self.tn += 1.0;
        Ok((loss, reg_s, reg_e))
    }

    /// One W step under the soft assignment (Alg. 1 line 7).
    fn step_w_soft(&mut self, b: &Batch) -> Result<(f32, f32)> {
        let graph = format!("search_w_{}", self.cfg.mode.suffix());
        let g = self.rt.graph(&self.cfg.bench, &graph)?;
        let t = Tensor::scalar(self.tw);
        let tau = Tensor::scalar(self.tau);
        let lr = Tensor::scalar(self.cfg.lr_w);
        let (x, yi, yf) = self.batch_tensors(b);
        let mut args: Vec<Arg> = Vec::new();
        for t in &self.params { args.push(Arg::F32(t)); }
        for t in &self.bn { args.push(Arg::F32(t)); }
        for t in &self.nas { args.push(Arg::F32(t)); }
        for t in &self.mw { args.push(Arg::F32(t)); }
        for t in &self.vw { args.push(Arg::F32(t)); }
        args.push(Arg::F32(&t));
        args.push(Arg::F32(&x));
        match (&yi, &yf) {
            (Some(y), _) => args.push(Arg::I32(y)),
            (_, Some(y)) => args.push(Arg::F32(y)),
            _ => unreachable!(),
        }
        args.push(Arg::F32(&tau));
        args.push(Arg::F32(&lr));
        let out = g.run(&args)?;
        let np = self.params.len();
        let nb = self.bn.len();
        if out.len() != 3 * np + nb + 2 {
            bail!("search_w returned {} outputs", out.len());
        }
        let mut it = out.into_iter();
        self.params = (&mut it).take(np).collect();
        self.bn = (&mut it).take(nb).collect();
        self.mw = (&mut it).take(np).collect();
        self.vw = (&mut it).take(np).collect();
        let loss = it.next().unwrap().item();
        let metric = it.next().unwrap().item();
        self.tw += 1.0;
        Ok((loss, metric))
    }

    /// Evaluate a hard assignment on a split.  Returns `(loss, score)`:
    /// accuracy for classifiers; AUC when the split carries anomaly
    /// labels (AD test), else `-loss` (AD val early-stop criterion).
    pub fn evaluate(&self, split: Split, a: &Assignment) -> Result<(f32, f32)> {
        let ds = match split {
            Split::Train => &self.train,
            Split::Val => &self.val,
            Split::Test => &self.test,
        };
        let g = self.rt.graph(&self.cfg.bench, "eval")?;
        let hard = self.hard_tensors(a);
        let mut losses = Vec::new();
        let mut correct = 0.0f32;
        let mut seen = 0usize;
        let mut scores = Vec::new();
        let mut labels = Vec::new();
        for b in BatchIter::sequential(ds, self.cfg.batch) {
            let (x, yi, yf) = self.batch_tensors(&b);
            let mut args: Vec<Arg> = Vec::new();
            for t in &self.params { args.push(Arg::F32(t)); }
            for t in &self.bn { args.push(Arg::F32(t)); }
            for t in &hard { args.push(Arg::F32(t)); }
            args.push(Arg::F32(&x));
            match (&yi, &yf) {
                (Some(y), _) => args.push(Arg::I32(y)),
                (_, Some(y)) => args.push(Arg::F32(y)),
                _ => unreachable!(),
            }
            let out = g.run(&args)?;
            if out.len() != 5 {
                bail!("eval returned {} outputs", out.len());
            }
            losses.push(out[0].item());
            correct += out[1].item();
            seen += self.cfg.batch;
            scores.extend_from_slice(out[2].data());
            labels.extend(b.y.iter().map(|&v| v as u8));
        }
        let loss = mean(&losses);
        let score = if self.manifest.loss == "ce" {
            correct / seen.max(1) as f32
        } else if labels.iter().any(|&l| l == 1) {
            auc_from_scores(&scores, &labels)
        } else {
            -loss
        };
        Ok((loss, score))
    }

    /// Forward the `infer` graph on raw inputs (deployment cross-check).
    pub fn infer(&self, a: &Assignment, xs: &[f32], n: usize) -> Result<Vec<Vec<f32>>> {
        if n != self.cfg.batch {
            bail!("infer expects a full batch of {}", self.cfg.batch);
        }
        let g = self.rt.graph(&self.cfg.bench, "infer")?;
        let hard = self.hard_tensors(a);
        let mut shape = vec![self.cfg.batch];
        shape.extend(&self.manifest.input_shape);
        let x = Tensor::new(shape, xs.to_vec());
        let mut args: Vec<Arg> = Vec::new();
        for t in &self.params { args.push(Arg::F32(t)); }
        for t in &self.bn { args.push(Arg::F32(t)); }
        for t in &hard { args.push(Arg::F32(t)); }
        args.push(Arg::F32(&x));
        let out = g.run(&args)?;
        let o = &out[0];
        let cols = o.len() / n;
        Ok((0..n).map(|i| o.data()[i * cols..(i + 1) * cols].to_vec()).collect())
    }

    // ---- Alg. 1 phases ------------------------------------------------------

    /// Warmup: QAT at p_max = 8 (line 1-2).
    pub fn warmup(&mut self) -> Result<()> {
        let a8 = Assignment::fixed(
            &self.manifest.qnames(), &self.manifest.qcouts(), 8, 8);
        self.train_hard_phase("warmup", self.cfg.warmup_epochs, &a8, false)
    }

    /// QAT under any fixed hard assignment; used by warmup, finetune and
    /// the fixed-precision baselines.  With `track_best`, keeps the
    /// params/bn with the best val score seen.
    pub fn train_hard_phase(
        &mut self,
        phase: &'static str,
        epochs: usize,
        a: &Assignment,
        track_best: bool,
    ) -> Result<()> {
        let hard = self.hard_tensors(a);
        let mut best: Option<(f32, StateSnapshot)> = None;
        for e in 0..epochs {
            let mut rng = Pcg32::new(self.cfg.seed ^ 0xbeef, (e + 1) as u64);
            let mut losses = Vec::new();
            let batches: Vec<Batch> =
                BatchIter::new(&self.train, self.cfg.batch, &mut rng).collect();
            for b in &batches {
                let (l, _) = self.step_w_hard(b, &hard, self.cfg.lr_w)?;
                losses.push(l);
            }
            let (vl, vs) = self.evaluate(Split::Val, a)?;
            self.history.push(EpochLog {
                phase,
                epoch: e,
                train_loss: mean(&losses),
                val_loss: vl,
                val_score: vs,
                tau: self.tau,
                reg_size: 0.0,
                reg_energy: 0.0,
            });
            if track_best && best.as_ref().map(|(s, _)| vs > *s).unwrap_or(true) {
                best = Some((vs, self.snapshot()));
            }
        }
        if let Some((_, snap)) = best {
            self.params = snap.params;
            self.bn = snap.bn;
        }
        Ok(())
    }

    /// Search: alternated theta/W with temperature annealing (lines 3-8).
    pub fn search(&mut self) -> Result<()> {
        let mut best_score = f32::NEG_INFINITY;
        let mut stale = 0usize;
        for e in 0..self.cfg.search_epochs {
            let mut rng = Pcg32::new(self.cfg.seed ^ 0xcafe, (e + 1) as u64);
            // 20% of the epoch's samples train theta, the rest train W
            let frac = self.cfg.theta_frac;
            let theta_batches: Vec<Batch> =
                BatchIter::new(&self.train, self.cfg.batch, &mut rng)
                    .take_front(frac)
                    .collect();
            let mut rng2 = Pcg32::new(self.cfg.seed ^ 0xcafe, (e + 1) as u64);
            let w_batches: Vec<Batch> =
                BatchIter::new(&self.train, self.cfg.batch, &mut rng2)
                    .drop_front(frac)
                    .collect();
            let mut losses = Vec::new();
            let (mut reg_s, mut reg_e) = (0.0, 0.0);
            for b in &theta_batches {
                let (l, rs, re) = self.step_theta(b)?;
                losses.push(l);
                reg_s = rs;
                reg_e = re;
            }
            for b in &w_batches {
                let (l, _) = self.step_w_soft(b)?;
                losses.push(l);
            }
            self.tau *= self.cfg.tau_decay; // anneal (line 8)
            let a = self.assignment();
            let (vl, vs) = self.evaluate(Split::Val, &a)?;
            self.history.push(EpochLog {
                phase: "search",
                epoch: e,
                train_loss: mean(&losses),
                val_loss: vl,
                val_score: vs,
                tau: self.tau,
                reg_size: reg_s,
                reg_energy: reg_e,
            });
            if vs > best_score {
                best_score = vs;
                stale = 0;
            } else {
                stale += 1;
                if self.cfg.patience > 0 && stale >= self.cfg.patience {
                    break; // early stop (paper: "controlled with early-stop")
                }
            }
        }
        Ok(())
    }

    /// Fine-tune: freeze argmax(theta), train W (lines 9-11).
    pub fn finetune(&mut self) -> Result<Assignment> {
        let a = self.assignment();
        // fresh optimiser state for the frozen-architecture phase
        self.mw = self.params.iter().map(|t| Tensor::zeros(t.shape().to_vec())).collect();
        self.vw = self.mw.clone();
        self.tw = 0.0;
        self.train_hard_phase("finetune", self.cfg.finetune_epochs, &a, true)?;
        Ok(a)
    }

    /// Full Alg. 1, producing the Fig. 3 data point.
    pub fn run(&mut self) -> Result<SearchResult> {
        self.warmup()?;
        self.run_after_warmup()
    }

    /// Search + finetune only (warmup state already restored).
    pub fn run_after_warmup(&mut self) -> Result<SearchResult> {
        self.search()?;
        let a = self.finetune()?;
        self.result_for(&a)
    }

    /// Score + cost a hard assignment with the current weights.
    pub fn result_for(&self, a: &Assignment) -> Result<SearchResult> {
        let (tl, ts) = self.evaluate(Split::Test, a)?;
        let geom = self.manifest.geom();
        Ok(SearchResult {
            config_label: format!(
                "{}-{}-{}-lam{:.2e}",
                self.cfg.bench,
                self.cfg.mode.suffix(),
                self.cfg.target.name(),
                self.cfg.lambda
            ),
            assignment: a.clone(),
            test_score: ts,
            test_loss: tl,
            size_bits: energy::model_size_bits(&geom, a),
            energy_pj: energy::model_energy_pj(&geom, a, &self.manifest.lut),
            history: self.history.clone(),
        })
    }

    /// Initial regularizer magnitudes (for relative lambda grids).
    pub fn initial_regs(&self) -> Result<(f32, f32)> {
        let a8 = Assignment::fixed(
            &self.manifest.qnames(), &self.manifest.qcouts(), 8, 8);
        let geom = self.manifest.geom();
        Ok((
            energy::model_size_bits(&geom, &a8) as f32,
            energy::model_energy_pj(&geom, &a8, &self.manifest.lut) as f32,
        ))
    }
}
