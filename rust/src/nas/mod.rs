//! The Alg. 1 three-phase DNAS driver (the paper's training procedure).
//!
//! ```text
//! 1  warmup:   QAT at p_max = 8 bit, W only            (reused per bench)
//! 2  search:   per epoch — theta on a random 20% of samples,
//!              W on the remaining 80%; anneal tau; early-stop on val
//! 3  finetune: freeze argmax(theta), train W only
//! ```
//!
//! All numerics run in the AOT'd XLA graphs through [`crate::runtime`];
//! this module owns state threading, the 20/80 alternation, the
//! temperature schedule, early stopping, and assignment extraction.

#[cfg(feature = "xla")]
pub mod trainer;

#[cfg(feature = "xla")]
pub use trainer::Trainer;

use crate::quant::Assignment;

/// Channel-wise (ours) or layer-wise (EdMIPS baseline) search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    ChannelWise,
    LayerWise,
}

impl Mode {
    pub fn suffix(&self) -> &'static str {
        match self {
            Mode::ChannelWise => "cw",
            Mode::LayerWise => "lw",
        }
    }
}

/// Which regularizer drives the search (Eq. 7 vs Eq. 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    /// Eq. (7): model size; activations pinned to 8 bit (paper §III-A).
    Size,
    /// Eq. (8): energy; activations searched too.
    Energy,
}

impl Target {
    pub fn name(&self) -> &'static str {
        match self {
            Target::Size => "size",
            Target::Energy => "energy",
        }
    }
}

/// Hyper-parameters of one search run.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub bench: String,
    pub mode: Mode,
    pub target: Target,
    /// regularization strength lambda of Eq. (2)
    pub lambda: f32,
    pub warmup_epochs: usize,
    pub search_epochs: usize,
    pub finetune_epochs: usize,
    pub lr_w: f32,
    pub lr_nas: f32,
    /// initial softmax temperature (paper: 5.0)
    pub tau0: f32,
    /// per-epoch multiplicative decay (paper: e^-0.0045; our short
    /// schedules compress it so tau reaches the same endpoint)
    pub tau_decay: f32,
    pub train_n: usize,
    pub val_n: usize,
    pub test_n: usize,
    pub batch: usize,
    pub seed: u64,
    /// search early-stop patience in epochs (0 = disabled)
    pub patience: usize,
    /// fraction of each search epoch's samples used for theta updates
    /// (paper: 0.2 — the 20/80 split; exposed for the ablation driver)
    pub theta_frac: f32,
}

impl SearchConfig {
    /// Paper-faithful defaults scaled to the synthetic CPU budget.
    pub fn new(bench: &str, mode: Mode, target: Target, lambda: f32) -> Self {
        SearchConfig {
            bench: bench.to_string(),
            mode,
            target,
            lambda,
            warmup_epochs: 10,
            search_epochs: 12,
            finetune_epochs: 8,
            lr_w: 2e-3,
            lr_nas: 5e-3,
            tau0: 5.0,
            tau_decay: 0.82, // tau: 5 -> ~0.5 over 12 epochs
            train_n: 1024,
            val_n: 256,
            test_n: 512,
            batch: 32,
            seed: 0,
            patience: 5,
            theta_frac: 0.2,
        }
    }

    /// A smaller budget for smoke tests / quick benches.
    pub fn quick(bench: &str, mode: Mode, target: Target, lambda: f32) -> Self {
        let mut c = Self::new(bench, mode, target, lambda);
        c.warmup_epochs = 5;
        c.search_epochs = 5;
        c.finetune_epochs = 3;
        c.train_n = 512;
        c.val_n = 128;
        c.test_n = 256;
        c.tau_decay = 0.55;
        c
    }
}

/// Epoch-level training log entry (the EXPERIMENTS.md loss curves).
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub phase: &'static str,
    pub epoch: usize,
    pub train_loss: f32,
    pub val_loss: f32,
    pub val_score: f32,
    pub tau: f32,
    pub reg_size: f32,
    pub reg_energy: f32,
}

/// Result of a full Alg. 1 run.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub config_label: String,
    pub assignment: Assignment,
    /// accuracy (classification) or AUC (AD) on the test split
    pub test_score: f32,
    pub test_loss: f32,
    /// Eq. (7) under the hard assignment, in bits
    pub size_bits: f64,
    /// Eq. (8) under the hard assignment, in pJ per inference
    pub energy_pj: f64,
    pub history: Vec<EpochLog>,
}

impl SearchResult {
    pub fn size_mb(&self) -> f64 {
        self.size_bits / 1e6 // the paper's Fig. 3 axis is Mbit
    }

    pub fn energy_uj(&self) -> f64 {
        self.energy_pj * 1e-6
    }
}
