//! Dependency-free JSON parsing and serialization.
//!
//! serde is unavailable in the offline crate set, so the coordinator
//! carries a small but complete JSON implementation: it parses the
//! `manifest.json` files emitted by `python/compile/aot.py`, the sweep
//! configuration files, and writes the experiment result stores consumed
//! by `report/` and EXPERIMENTS.md.
//!
//! Supported: the full JSON grammar (objects, arrays, strings with
//! escapes incl. `\uXXXX`, numbers, booleans, null).  Numbers are stored
//! as `f64` (ample for manifest shapes and metric logs).
//!
//! Since the serving layer (`serve::http`) parses request bodies off the
//! network with this module, the parser is hardened against hostile
//! input: truncated documents, bad escapes and non-UTF-8 bytes
//! ([`parse_bytes`]) return `Err`, and nesting is capped at
//! [`MAX_DEPTH`] so a `[[[[…` bomb cannot overflow the recursive
//! descent's stack.  Malformed input must never panic — that contract
//! is unit-tested below.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors --------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object"),
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional object field (None when missing or null).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => match o.get(key) {
                Some(Json::Null) | None => None,
                Some(v) => Some(v),
            },
            _ => None,
        }
    }

    /// Shape-style array of usize.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors -----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- serialization ----------------------------------------------------

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches aot.py output).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser.
// ---------------------------------------------------------------------------

/// Maximum container nesting depth: deeper documents return `Err`
/// instead of exhausting the recursive-descent stack.  Generous for
/// every legitimate document in the repo (manifests nest < 10 deep).
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

/// Parse a JSON document from raw bytes (e.g. a network request body).
/// Non-UTF-8 input is an error, never a panic.
pub fn parse_bytes(bytes: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(bytes)
        .map_err(|e| anyhow!("body is not valid UTF-8: {e}"))?;
    parse(text)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// current container nesting, bounded by [`MAX_DEPTH`]
    depth: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, got {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.i);
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                c => {
                    bail!("expected , or }} at byte {}, got {:?}", self.i, c as char)
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.enter()?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(arr));
                }
                c => {
                    bail!("expected , or ] at byte {}, got {:?}", self.i, c as char)
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs (truncated input → Err,
                            // never an out-of-bounds panic)
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                let pair = self.b.get(self.i..self.i + 6);
                                match pair {
                                    Some([b'\\', b'u', hex2 @ ..]) => {
                                        let hex2 = std::str::from_utf8(hex2)?;
                                        let lo = u32::from_str_radix(hex2, 16)?;
                                        if !(0xDC00..0xE000).contains(&lo) {
                                            bail!("bad low surrogate");
                                        }
                                        self.i += 6;
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(c)
                                    }
                                    _ => None,
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // re-sync to char boundary for multi-byte UTF-8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8 sequence"))?;
                        s.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": "hi\n", "c": true, "d": null}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\n");
        assert!(v.get("c").unwrap().as_bool().unwrap());
        let re = parse(&v.dumps()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"[{"x": {"y": [[1],[2]]}}]"#).unwrap();
        let y = v.as_arr().unwrap()[0].get("x").unwrap().get("y").unwrap();
        assert_eq!(y.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo wörld ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn truncated_inputs_error_not_panic() {
        // every prefix of a valid document must parse to Err, not panic
        let full = r#"{"a": [1, -2.5e3, "x\u00e9\ud83d\ude00"], "b": null}"#;
        for cut in 0..full.len() {
            if let Some(prefix) = full.get(..cut) {
                assert!(parse(prefix).is_err(), "prefix {prefix:?} parsed");
            }
        }
    }

    #[test]
    fn malformed_escapes_error_not_panic() {
        for bad in [
            "\"\\",          // escape at EOF
            "\"\\u",         // \u at EOF
            "\"\\u12",       // truncated hex
            "\"\\uZZZZ\"",   // non-hex
            "\"\\ud834",     // high surrogate at EOF
            "\"\\ud834\\u",  // truncated low surrogate
            "\"\\ud834\\u0041\"", // low surrogate out of range
            "\"\\udc00\"",   // lone low surrogate
            "\"\\x41\"",     // unknown escape
        ] {
            assert!(parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn deep_nesting_errors_not_stack_overflow() {
        for doc in ["[".repeat(100_000), "{\"k\":".repeat(100_000)] {
            assert!(parse(&doc).is_err());
        }
        // a closed-but-too-deep document errors too
        let deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(parse(&deep).is_err());
        // ... while documents at the limit still parse
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok());
        // width is free: sibling containers don't accumulate depth
        let wide = format!("[{}]", vec!["[0]"; 300].join(","));
        assert!(parse(&wide).is_ok());
    }

    #[test]
    fn parse_bytes_rejects_non_utf8() {
        assert!(parse_bytes(b"\xff\xfe{\"a\": 1}").is_err());
        assert!(parse_bytes(b"{\"a\": \"\xc3\"}").is_err());
        assert_eq!(
            parse_bytes(br#"{"a": 1}"#).unwrap().get("a").unwrap().as_f64().unwrap(),
            1.0
        );
    }

    #[test]
    fn shape_accessor() {
        let v = parse("[3, 3, 16]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![3, 3, 16]);
    }

    #[test]
    fn pretty_reparses() {
        let v = parse(r#"{"m": [[1,2],[3,4]], "s": "x"}"#).unwrap();
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn escaped_output() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.dumps()).unwrap(), v);
    }
}
