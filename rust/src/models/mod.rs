//! Benchmark model geometry, parsed from `artifacts/<bench>/manifest.json`.
//!
//! The manifest is emitted by `python/compile/aot.py` from the very
//! `ModelDef` the graphs were traced with, so the Rust side — energy
//! model, MPIC simulator, deployment transform, runtime tensor plumbing —
//! always sees exactly the trained geometry.  When no artifacts are
//! available, [`zoo::builtin_manifest`] re-derives the same four
//! topologies natively.

pub mod zoo;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::energy::CostLut;
use crate::minijson::{parse_file, Json};

/// Quantized-layer geometry (the inputs to Eq. (7)/(8)).
#[derive(Clone, Debug)]
pub struct QLayerGeom {
    pub name: String,
    pub kind: String, // conv | dwconv | fc
    pub cin: usize,
    pub cout: usize,
    pub kx: usize,
    pub ky: usize,
    pub ops: usize,
    pub weights_per_channel: usize,
}

/// Just the quantized layers (what the cost model needs).
#[derive(Clone, Debug)]
pub struct ModelGeom {
    pub name: String,
    pub qlayers: Vec<QLayerGeom>,
}

/// Full layer record (structural layers included) for the simulator and
/// the deployment transform.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    pub kind: String,
    pub cin: usize,
    pub cout: usize,
    pub kx: usize,
    pub ky: usize,
    pub stride: usize,
    pub relu: bool,
    pub bn: bool,
    pub bias: bool,
    pub in_h: usize,
    pub in_w: usize,
    pub out_h: usize,
    pub out_w: usize,
    pub qidx: i64,
    pub ops: usize,
    pub weights_per_channel: usize,
    pub save_as: Option<String>,
    pub add_from: Option<String>,
    pub input_from: Option<String>,
}

impl LayerSpec {
    pub fn is_quant(&self) -> bool {
        matches!(self.kind.as_str(), "conv" | "dwconv" | "fc")
    }

    pub fn groups(&self) -> usize {
        if self.kind == "dwconv" {
            self.cin
        } else {
            1
        }
    }
}

/// Named tensor slot (parameter / state / NAS / hard-assignment input).
#[derive(Clone, Debug)]
pub struct TensorSlot {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSlot {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub benchmark: String,
    pub dir: PathBuf,
    pub batch: usize,
    pub seed: u64,
    pub precisions: Vec<u32>,
    pub loss: String,
    pub n_classes: usize,
    pub input_shape: Vec<usize>,
    pub layers: Vec<LayerSpec>,
    pub params: Vec<TensorSlot>,
    pub bn_state: Vec<TensorSlot>,
    pub nas_cw: Vec<TensorSlot>,
    pub nas_lw: Vec<TensorSlot>,
    pub hard_assign: Vec<TensorSlot>,
    pub lut: CostLut,
}

fn slot_list(v: &Json) -> Result<Vec<TensorSlot>> {
    v.as_arr()?
        .iter()
        .map(|e| {
            Ok(TensorSlot {
                name: e.get("name")?.as_str()?.to_string(),
                shape: e.get("shape")?.as_shape()?,
            })
        })
        .collect()
}

fn f32_rows(v: &Json) -> Result<Vec<Vec<f32>>> {
    v.as_arr()?
        .iter()
        .map(|row| {
            Ok(row
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect::<Result<Vec<f32>>>()?)
        })
        .collect()
}

impl Manifest {
    /// Load `artifacts/<bench>/manifest.json`.
    pub fn load(artifacts: &Path, bench: &str) -> Result<Manifest> {
        let dir = artifacts.join(bench);
        let path = dir.join("manifest.json");
        let j = parse_file(&path).context("loading manifest")?;
        let layers = j
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerSpec {
                    name: l.get("name")?.as_str()?.to_string(),
                    kind: l.get("kind")?.as_str()?.to_string(),
                    cin: l.get("cin")?.as_usize()?,
                    cout: l.get("cout")?.as_usize()?,
                    kx: l.get("kx")?.as_usize()?,
                    ky: l.get("ky")?.as_usize()?,
                    stride: l.get("stride")?.as_usize()?,
                    relu: l.get("relu")?.as_bool()?,
                    bn: l.get("bn")?.as_bool()?,
                    bias: l.get("bias")?.as_bool()?,
                    in_h: l.get("in_h")?.as_usize()?,
                    in_w: l.get("in_w")?.as_usize()?,
                    out_h: l.get("out_h")?.as_usize()?,
                    out_w: l.get("out_w")?.as_usize()?,
                    qidx: l.get("qidx")?.as_i64()?,
                    ops: l.get("ops")?.as_usize()?,
                    weights_per_channel: l.get("weights_per_channel")?.as_usize()?,
                    save_as: l.opt("save_as").map(|v| v.as_str().unwrap().to_string()),
                    add_from: l.opt("add_from").map(|v| v.as_str().unwrap().to_string()),
                    input_from: l.opt("input_from").map(|v| v.as_str().unwrap().to_string()),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let lut = CostLut::from_rows(
            &f32_rows(j.get("energy_lut_pj_per_mac")?)?,
            &f32_rows(j.get("cycles_per_mac")?)?,
        );
        Ok(Manifest {
            benchmark: j.get("benchmark")?.as_str()?.to_string(),
            dir,
            batch: j.get("batch")?.as_usize()?,
            seed: j.get("seed")?.as_usize()? as u64,
            precisions: j
                .get("precisions")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize().map(|u| u as u32))
                .collect::<Result<Vec<_>>>()?,
            loss: j.get("loss")?.as_str()?.to_string(),
            n_classes: j.get("n_classes")?.as_usize()?,
            input_shape: j.get("input_shape")?.as_shape()?,
            layers,
            params: slot_list(j.get("params")?)?,
            bn_state: slot_list(j.get("bn_state")?)?,
            nas_cw: slot_list(j.get("nas_cw")?)?,
            nas_lw: slot_list(j.get("nas_lw")?)?,
            hard_assign: slot_list(j.get("hard_assign")?)?,
            lut,
        })
    }

    /// Quantized layers in qidx order.
    pub fn qlayers(&self) -> Vec<&LayerSpec> {
        let mut q: Vec<&LayerSpec> = self.layers.iter().filter(|l| l.is_quant()).collect();
        q.sort_by_key(|l| l.qidx);
        q
    }

    /// Cost-model view.
    pub fn geom(&self) -> ModelGeom {
        ModelGeom {
            name: self.benchmark.clone(),
            qlayers: self
                .qlayers()
                .iter()
                .map(|l| QLayerGeom {
                    name: l.name.clone(),
                    kind: l.kind.clone(),
                    cin: l.cin,
                    cout: l.cout,
                    kx: l.kx,
                    ky: l.ky,
                    ops: l.ops,
                    weights_per_channel: l.weights_per_channel,
                })
                .collect(),
        }
    }

    /// Names/couts of quantized layers (assignment plumbing).
    pub fn qnames(&self) -> Vec<String> {
        self.qlayers().iter().map(|l| l.name.clone()).collect()
    }

    pub fn qcouts(&self) -> Vec<usize> {
        self.qlayers().iter().map(|l| l.cout).collect()
    }

    /// Path of a graph artifact.
    pub fn graph_path(&self, graph: &str) -> PathBuf {
        self.dir.join(format!("{graph}.hlo.txt"))
    }

    /// Per-sample input feature count.
    pub fn feat_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Sanity-check internal consistency (used by integration tests and
    /// at coordinator startup).
    pub fn validate(&self) -> Result<()> {
        let q = self.qlayers();
        if q.is_empty() {
            bail!("no quantized layers");
        }
        for (i, l) in q.iter().enumerate() {
            if l.qidx != i as i64 {
                bail!("qidx gap at {}", l.name);
            }
        }
        // hard_assign slots must alternate delta (3,) / gamma (cout, 3)
        if self.hard_assign.len() != 2 * q.len() {
            bail!("hard_assign count mismatch");
        }
        for (i, l) in q.iter().enumerate() {
            let d = &self.hard_assign[2 * i];
            let g = &self.hard_assign[2 * i + 1];
            if d.shape != vec![self.precisions.len()] {
                bail!("delta slot shape for {}", l.name);
            }
            if g.shape != vec![l.cout, self.precisions.len()] {
                bail!("gamma slot shape for {}: {:?}", l.name, g.shape);
            }
        }
        Ok(())
    }
}
