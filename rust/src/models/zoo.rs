//! Builtin Rust mirror of the four MLPerf-Tiny benchmark topologies.
//!
//! `python/compile/models/zoo.py` is the source of truth for the trained
//! artifacts; this module re-derives exactly the same geometry (SAME
//! ceil-division, dwconv channel inheritance, tags) natively, so the
//! deployment transform, the inference engine, the cost model, benches
//! and tests all run **without** `artifacts/` or the `xla` feature:
//!
//! * **IC**  — ResNet-8 (16/32/64, 3 stages), 32x32x3, 10 classes.
//! * **KWS** — DS-CNN small (64ch, 4 depthwise-separable blocks),
//!   49x10x1, 12 classes.
//! * **VWW** — MobileNetV1 width 0.25 at 48x48x3, 2 classes.
//! * **AD**  — dense autoencoder 256 → 128x2 → 8 → 128x2 → 256.
//!
//! [`builtin_manifest`] produces a [`Manifest`] indistinguishable from a
//! parsed `manifest.json` (it passes `Manifest::validate`);
//! [`synthetic_state`] produces He-initialised parameters with the same
//! per-suffix rules the trainer uses, for runs where trained weights are
//! unavailable (cost simulation, backend-equivalence tests, benches).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::energy::CostLut;
use crate::models::{LayerSpec, Manifest, TensorSlot};
use crate::tensor::Tensor;
use crate::util::Pcg32;
use crate::PRECISIONS;

/// The builtin benchmark names, in canonical order.
pub const BENCHES: [&str; 4] = ["ic", "kws", "vww", "ad"];

/// Layer definition before geometry resolution (mirrors python `LayerDef`).
struct L {
    name: String,
    kind: &'static str,
    cout: usize,
    kx: usize,
    ky: usize,
    stride: usize,
    relu: bool,
    bn: bool,
    bias: bool,
    save_as: Option<&'static str>,
    add_from: Option<&'static str>,
    input_from: Option<&'static str>,
}

impl L {
    fn new(name: &str, kind: &'static str) -> L {
        L {
            name: name.to_string(),
            kind,
            cout: 0,
            kx: 1,
            ky: 1,
            stride: 1,
            relu: true,
            bn: true,
            bias: false,
            save_as: None,
            add_from: None,
            input_from: None,
        }
    }

    fn conv(name: &str, cout: usize, kx: usize, ky: usize, stride: usize) -> L {
        L { cout, kx, ky, stride, ..L::new(name, "conv") }
    }

    fn dwconv(name: &str, k: usize, stride: usize) -> L {
        L { kx: k, ky: k, stride, ..L::new(name, "dwconv") }
    }

    fn fc(name: &str, cout: usize) -> L {
        L { cout, ..L::new(name, "fc") }
    }

    /// Head FC: logits/reconstruction — no relu/bn, biased.
    fn head(name: &str, cout: usize) -> L {
        L { relu: false, bn: false, bias: true, ..L::fc(name, cout) }
    }
}

fn ic_layers() -> Vec<L> {
    let mut v = vec![L::conv("c1", 16, 3, 3, 1)];
    // stage 1: identity skip
    v.push(L { save_as: Some("b1_in"), ..L::new("b1_tap", "tap") });
    v.push(L::conv("b1c1", 16, 3, 3, 1));
    v.push(L { add_from: Some("b1_in"), ..L::conv("b1c2", 16, 3, 3, 1) });
    // stage 2: downsample, 1x1 conv skip
    v.push(L { save_as: Some("b2_in"), ..L::new("b2_tap", "tap") });
    v.push(L::conv("b2c1", 32, 3, 3, 2));
    v.push(L {
        relu: false,
        save_as: Some("b2_main"),
        ..L::conv("b2c2", 32, 3, 3, 1)
    });
    v.push(L {
        input_from: Some("b2_in"),
        add_from: Some("b2_main"),
        ..L::conv("b2sc", 32, 1, 1, 2)
    });
    // stage 3: downsample, 1x1 conv skip
    v.push(L { save_as: Some("b3_in"), ..L::new("b3_tap", "tap") });
    v.push(L::conv("b3c1", 64, 3, 3, 2));
    v.push(L {
        relu: false,
        save_as: Some("b3_main"),
        ..L::conv("b3c2", 64, 3, 3, 1)
    });
    v.push(L {
        input_from: Some("b3_in"),
        add_from: Some("b3_main"),
        ..L::conv("b3sc", 64, 1, 1, 2)
    });
    v.push(L::new("pool", "avgpool"));
    v.push(L::head("fc", 10));
    v
}

fn kws_layers() -> Vec<L> {
    let mut v = vec![L::conv("c1", 64, 10, 4, 2)];
    for i in 1..5 {
        v.push(L::dwconv(&format!("dw{i}"), 3, 1));
        v.push(L::conv(&format!("pw{i}"), 64, 1, 1, 1));
    }
    v.push(L::new("pool", "avgpool"));
    v.push(L::head("fc", 12));
    v
}

fn vww_layers() -> Vec<L> {
    // MobileNetV1 x0.25 channel plan (full-size plan scaled by 1/4)
    let plan: [(usize, usize); 13] = [
        (16, 1),
        (32, 2),
        (32, 1),
        (64, 2),
        (64, 1),
        (128, 2),
        (128, 1),
        (128, 1),
        (128, 1),
        (128, 1),
        (128, 1),
        (256, 2),
        (256, 1),
    ];
    let mut v = vec![L::conv("c1", 8, 3, 3, 2)];
    for (i, &(cout, s)) in plan.iter().enumerate() {
        let i = i + 1;
        v.push(L::dwconv(&format!("dw{i}"), 3, s));
        v.push(L::conv(&format!("pw{i}"), cout, 1, 1, 1));
    }
    v.push(L::new("pool", "avgpool"));
    v.push(L::head("fc", 2));
    v
}

fn ad_layers() -> Vec<L> {
    let dims = [128usize, 128, 8, 128, 128];
    let mut v: Vec<L> = dims
        .iter()
        .enumerate()
        .map(|(i, &d)| L::fc(&format!("fc{}", i + 1), d))
        .collect();
    v.push(L::head("out", 256));
    v
}

/// Resolve geometry through the graph (mirrors python `build_model`:
/// SAME padding via ceil division, dwconv inherits channels, tags carry
/// shapes across skips).
fn resolve(
    bench: &str,
    layers: Vec<L>,
    input_shape: &[usize],
    n_classes: usize,
    loss: &str,
) -> Result<Manifest> {
    let (mut h, mut w, mut c) = match input_shape.len() {
        3 => (input_shape[0], input_shape[1], input_shape[2]),
        1 => (1, 1, input_shape[0]),
        _ => bail!("unsupported input rank"),
    };
    let mut tags: HashMap<&'static str, (usize, usize, usize)> = HashMap::new();
    let mut qidx = 0i64;
    let mut specs = Vec::with_capacity(layers.len());
    for mut l in layers {
        if let Some(tag) = l.input_from {
            let &(th, tw, tc) = tags
                .get(tag)
                .ok_or_else(|| anyhow::anyhow!("unknown tag {tag}"))?;
            (h, w, c) = (th, tw, tc);
        }
        let (in_h, in_w, cin) = (h, w, c);
        match l.kind {
            "conv" | "dwconv" => {
                if l.kind == "dwconv" {
                    l.cout = c;
                }
                h = h.div_ceil(l.stride); // SAME padding
                w = w.div_ceil(l.stride);
                c = l.cout;
            }
            "fc" => {
                c = l.cout;
                h = 1;
                w = 1;
            }
            "avgpool" => {
                h = 1;
                w = 1;
                l.cout = c;
            }
            "flatten" => {
                c = h * w * c;
                h = 1;
                w = 1;
                l.cout = c;
            }
            "add" | "tap" => {
                l.cout = c;
            }
            other => bail!("unknown layer kind {other}"),
        }
        let quant = matches!(l.kind, "conv" | "dwconv" | "fc");
        let cin_g = if l.kind == "dwconv" { 1 } else { cin };
        let wpc = if !quant {
            0
        } else if l.kind == "fc" {
            cin
        } else {
            cin_g * l.kx * l.ky
        };
        let ops = if !quant {
            0
        } else if l.kind == "fc" {
            l.cout * cin
        } else {
            h * w * l.cout * wpc
        };
        let this_qidx = if quant {
            qidx += 1;
            qidx - 1
        } else {
            -1
        };
        if let Some(tag) = l.save_as {
            tags.insert(tag, (h, w, c));
        }
        specs.push(LayerSpec {
            name: l.name.clone(),
            kind: l.kind.to_string(),
            cin,
            cout: l.cout,
            kx: l.kx,
            ky: l.ky,
            stride: l.stride,
            relu: l.relu,
            bn: l.bn,
            bias: l.bias,
            in_h,
            in_w,
            out_h: h,
            out_w: w,
            qidx: this_qidx,
            ops,
            weights_per_channel: wpc,
            save_as: l.save_as.map(|s| s.to_string()),
            add_from: l.add_from.map(|s| s.to_string()),
            input_from: l.input_from.map(|s| s.to_string()),
        });
    }

    // tensor slots, in the python naming/ordering convention
    let mut params = Vec::new();
    let mut bn_state = Vec::new();
    let mut nas_cw = Vec::new();
    let mut nas_lw = Vec::new();
    let mut hard_assign = Vec::new();
    let np = PRECISIONS.len();
    for s in specs.iter().filter(|s| s.is_quant()) {
        let wshape = if s.kind == "fc" {
            vec![s.cout, s.cin]
        } else {
            let cin_g = if s.kind == "dwconv" { 1 } else { s.cin };
            vec![s.cout, s.kx, s.ky, cin_g]
        };
        params.push(TensorSlot { name: format!("{}.w", s.name), shape: wshape });
        if s.bias {
            params.push(TensorSlot {
                name: format!("{}.b", s.name),
                shape: vec![s.cout],
            });
        }
        if s.bn {
            params.push(TensorSlot {
                name: format!("{}.bn_scale", s.name),
                shape: vec![s.cout],
            });
            params.push(TensorSlot {
                name: format!("{}.bn_bias", s.name),
                shape: vec![s.cout],
            });
            bn_state.push(TensorSlot {
                name: format!("{}.bn_mean", s.name),
                shape: vec![s.cout],
            });
            bn_state.push(TensorSlot {
                name: format!("{}.bn_var", s.name),
                shape: vec![s.cout],
            });
        }
        params.push(TensorSlot {
            name: format!("{}.alpha", s.name),
            shape: vec![],
        });
        nas_cw.push(TensorSlot {
            name: format!("{}.delta", s.name),
            shape: vec![np],
        });
        nas_cw.push(TensorSlot {
            name: format!("{}.gamma", s.name),
            shape: vec![s.cout, np],
        });
        nas_lw.push(TensorSlot {
            name: format!("{}.delta", s.name),
            shape: vec![np],
        });
        nas_lw.push(TensorSlot {
            name: format!("{}.gamma", s.name),
            shape: vec![1, np],
        });
        hard_assign.push(TensorSlot {
            name: format!("{}.delta_oh", s.name),
            shape: vec![np],
        });
        hard_assign.push(TensorSlot {
            name: format!("{}.gamma_oh", s.name),
            shape: vec![s.cout, np],
        });
    }

    Ok(Manifest {
        benchmark: bench.to_string(),
        dir: PathBuf::from(format!("builtin:{bench}")),
        batch: 32,
        seed: 0,
        precisions: PRECISIONS.to_vec(),
        loss: loss.to_string(),
        n_classes,
        input_shape: input_shape.to_vec(),
        layers: specs,
        params,
        bn_state,
        nas_cw,
        nas_lw,
        hard_assign,
        lut: CostLut::default(),
    })
}

/// Build the builtin manifest for one benchmark (`ic|kws|vww|ad`).
pub fn builtin_manifest(bench: &str) -> Result<Manifest> {
    let m = match bench {
        "ic" => resolve("ic", ic_layers(), &[32, 32, 3], 10, "ce")?,
        "kws" => resolve("kws", kws_layers(), &[49, 10, 1], 12, "ce")?,
        "vww" => resolve("vww", vww_layers(), &[48, 48, 3], 2, "ce")?,
        "ad" => resolve("ad", ad_layers(), &[256], 0, "mse")?,
        other => bail!("unknown benchmark {other} (ic|kws|vww|ad)"),
    };
    m.validate()?;
    Ok(m)
}

/// He/constant initialisation by tensor-name suffix — the single source
/// of truth shared with the trainer (`nas::trainer`), so synthetic and
/// trained state use identical initial distributions.
pub fn init_slot_tensor(name: &str, shape: &[usize], rng: &mut Pcg32) -> Tensor {
    let n: usize = shape.iter().product();
    if name.ends_with(".w") {
        let fan_in: usize = shape.iter().skip(1).product::<usize>().max(1);
        let std = (2.0f32 / fan_in as f32).sqrt();
        let data = (0..n).map(|_| rng.normal_ms(0.0, std)).collect();
        Tensor::new(shape.to_vec(), data)
    } else if name.ends_with(".bn_scale") || name.ends_with(".bn_var") {
        Tensor::full(shape.to_vec(), 1.0)
    } else if name.ends_with(".alpha") {
        Tensor::full(shape.to_vec(), 6.0)
    } else {
        Tensor::zeros(shape.to_vec())
    }
}

/// Deterministic "stripy" mixed assignment: cycles 2/4/8 across
/// channels with a per-layer phase — the adversarial case for the
/// deployment transform (reordering, residual space joins, fragmented
/// sub-conv groups).  Shared by the equivalence tests, the engine
/// bench and the HLO-verification tests.
pub fn stripy_assignment(manifest: &Manifest) -> crate::quant::Assignment {
    let bits = [2u32, 4, 8];
    let names = manifest.qnames();
    let couts = manifest.qcouts();
    crate::quant::Assignment {
        layers: names
            .iter()
            .zip(&couts)
            .enumerate()
            .map(|(li, (n, &c))| crate::quant::LayerAssignment {
                name: n.clone(),
                act_bits: bits[li % 3],
                weight_bits: (0..c).map(|i| bits[(i + li) % 3]).collect(),
            })
            .collect(),
    }
}

/// Synthetic parameter / BN-state maps for a manifest: what
/// `deploy::build` needs when no trained artifacts are available.
pub fn synthetic_state(
    manifest: &Manifest,
    seed: u64,
) -> (HashMap<String, Tensor>, HashMap<String, Tensor>) {
    let mut rng = Pcg32::new(seed, 11);
    let params = manifest
        .params
        .iter()
        .map(|s| (s.name.clone(), init_slot_tensor(&s.name, &s.shape, &mut rng)))
        .collect();
    let bn = manifest
        .bn_state
        .iter()
        .map(|s| (s.name.clone(), init_slot_tensor(&s.name, &s.shape, &mut rng)))
        .collect();
    (params, bn)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtin_manifests_validate() {
        for b in BENCHES {
            let m = builtin_manifest(b).unwrap();
            assert_eq!(m.benchmark, b);
            assert!(m.qlayers().len() >= 6, "{b}");
        }
    }

    #[test]
    fn ic_geometry_matches_resnet8() {
        let m = builtin_manifest("ic").unwrap();
        assert_eq!(m.feat_len(), 32 * 32 * 3);
        let q = m.qlayers();
        assert_eq!(q.len(), 10); // 9 convs + fc
        let b2sc = q.iter().find(|l| l.name == "b2sc").unwrap();
        assert_eq!((b2sc.in_h, b2sc.in_w, b2sc.cin), (32, 32, 16));
        assert_eq!((b2sc.out_h, b2sc.out_w, b2sc.cout), (16, 16, 32));
        let fc = q.iter().find(|l| l.name == "fc").unwrap();
        assert_eq!(fc.cin, 64);
        assert_eq!(fc.weights_per_channel, 64);
    }

    #[test]
    fn kws_geometry_matches_dscnn() {
        let m = builtin_manifest("kws").unwrap();
        let q = m.qlayers();
        assert_eq!(q.len(), 10); // c1 + 4x(dw+pw) + fc
        let c1 = &q[0];
        assert_eq!((c1.out_h, c1.out_w, c1.cout), (25, 5, 64));
        let dw1 = q.iter().find(|l| l.name == "dw1").unwrap();
        assert_eq!(dw1.cout, 64);
        assert_eq!(dw1.weights_per_channel, 9);
    }

    #[test]
    fn vww_has_28_quant_layers() {
        let m = builtin_manifest("vww").unwrap();
        assert_eq!(m.qlayers().len(), 28); // c1 + 13x(dw+pw) + fc
        // spatial chain: 48 →2 24 →2 12 →2 6 →2 3 →2 2 (SAME ceil-div)
        let last_pw = m.layers.iter().find(|l| l.name == "pw13").unwrap();
        assert_eq!((last_pw.out_h, last_pw.out_w, last_pw.cout), (2, 2, 256));
    }

    #[test]
    fn ad_is_fc_chain() {
        let m = builtin_manifest("ad").unwrap();
        let q = m.qlayers();
        assert_eq!(q.len(), 6);
        assert_eq!(q[2].cout, 8); // bottleneck
        assert_eq!(q[5].cout, 256);
        assert_eq!(m.feat_len(), 256);
    }

    #[test]
    fn synthetic_state_covers_all_slots() {
        let m = builtin_manifest("ic").unwrap();
        let (params, bn) = synthetic_state(&m, 0);
        for s in &m.params {
            let t = params.get(&s.name).unwrap();
            assert_eq!(t.shape(), &s.shape[..], "{}", s.name);
        }
        for s in &m.bn_state {
            assert!(bn.contains_key(&s.name), "{}", s.name);
        }
        // alpha is a scalar, var is ones
        assert_eq!(params["c1.alpha"].item(), 6.0);
        assert_eq!(bn["c1.bn_var"].data()[0], 1.0);
    }

    #[test]
    fn deterministic_state() {
        let m = builtin_manifest("kws").unwrap();
        let (p1, _) = synthetic_state(&m, 7);
        let (p2, _) = synthetic_state(&m, 7);
        assert_eq!(p1["c1.w"], p2["c1.w"]);
    }
}
