"""Manifest emission invariants (what the Rust side depends on)."""

import numpy as np
import pytest

from compile.aot import Lowerer
from compile.energy_lut import energy_lut
from compile.models import BENCHMARKS


@pytest.fixture(scope="module", params=list(BENCHMARKS))
def manifest(request):
    return Lowerer(request.param).manifest()


def test_manifest_has_all_sections(manifest):
    for key in ["benchmark", "batch", "precisions", "loss", "layers",
                "params", "bn_state", "nas_cw", "nas_lw", "hard_assign",
                "energy_lut_pj_per_mac", "cycles_per_mac", "graphs"]:
        assert key in manifest, key


def test_qidx_is_dense(manifest):
    q = [l for l in manifest["layers"] if l["qidx"] >= 0]
    assert sorted(l["qidx"] for l in q) == list(range(len(q)))


def test_hard_assign_alternates_delta_gamma(manifest):
    q = [l for l in manifest["layers"] if l["qidx"] >= 0]
    ha = manifest["hard_assign"]
    assert len(ha) == 2 * len(q)
    for i, l in enumerate(sorted(q, key=lambda l: l["qidx"])):
        assert ha[2 * i]["shape"] == [3]
        assert ha[2 * i + 1]["shape"] == [l["cout"], 3]


def test_params_order_matches_layer_order(manifest):
    # every quant layer contributes <name>.w and <name>.alpha
    q = [l["name"] for l in manifest["layers"] if l["qidx"] >= 0]
    pnames = [p["name"] for p in manifest["params"]]
    for name in q:
        assert f"{name}.w" in pnames
        assert f"{name}.alpha" in pnames


def test_nas_shapes(manifest):
    q = {l["name"]: l for l in manifest["layers"] if l["qidx"] >= 0}
    cw = {p["name"]: p["shape"] for p in manifest["nas_cw"]}
    lw = {p["name"]: p["shape"] for p in manifest["nas_lw"]}
    for name, l in q.items():
        assert cw[f"{name}.gamma"] == [l["cout"], 3]
        assert lw[f"{name}.gamma"] == [1, 3]
        assert cw[f"{name}.delta"] == [3]


def test_lut_roundtrip(manifest):
    np.testing.assert_allclose(
        np.asarray(manifest["energy_lut_pj_per_mac"], dtype=np.float32),
        energy_lut())


def test_ops_formula(manifest):
    for l in manifest["layers"]:
        if l["qidx"] < 0:
            continue
        if l["kind"] == "fc":
            assert l["ops"] == l["cout"] * l["cin"]
        else:
            cin_g = 1 if l["kind"] == "dwconv" else l["cin"]
            want = l["out_h"] * l["out_w"] * l["cout"] * cin_g * l["kx"] * l["ky"]
            assert l["ops"] == want, l["name"]
